"""The measure -> fit -> validate -> plan loop, end to end.

Three acts:

1. **Measure** a "running system" — the fork-join simulator driven by a
   flash-crowd `ArrivalProcess` (baseline qps with recurring burst
   windows, the fit-stability stress case: windows sweep a wide range of
   utilizations) with ground-truth Table-5 parameters the fit never sees.
2. **Fit + validate** — closed-form moment matching recovers the Eq-1
   decomposition, Gauss-Newton fits the Sec-3.4 imbalance blend, and the
   held-out report compares calibrated model vs measurements vs the
   calibrated simulator (the paper's Sec 5.3 discipline).
3. **Plan** — the calibrated parameters drop into `plan_capacity` and a
   `plan_over_grid` what-if sweep: the Section-6 manager answer computed
   from measurements alone.

`--engine` appends the real instrumented toy engine: document-partitioned
index shards timed under a query stream (`measure_engine_trace`), then
calibrated and planned the same way.

Run:  PYTHONPATH=src python examples/calibrate_and_plan.py [--engine]
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.calibrate import (calibrate_and_validate, measure_engine_trace,
                             plan_from_trace, simulate_trace)
from repro.core import capacity, planner, sweep
from repro.core.arrivals import ArrivalProcess

SLO = 0.300
TARGET_QPS = 120.0


def print_params(tag, p):
    print(f"  {tag}: S_broker={float(p.s_broker) * 1e3:.2f}ms "
          f"S_hit={float(p.s_hit) * 1e3:.2f}ms "
          f"S_miss={float(p.s_miss) * 1e3:.2f}ms "
          f"S_disk={float(p.s_disk) * 1e3:.2f}ms hit={float(p.hit):.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=60_000)
    ap.add_argument("--engine", action="store_true",
                    help="also calibrate the instrumented toy engine")
    args = ap.parse_args()

    print("== 1. measure: flash-crowd load on the 'production' cluster ==")
    true_params = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    crowd = ArrivalProcess.flash_crowd(
        10.0, burst_starts=[900.0], burst_seconds=450.0,
        burst_multiplier=2.2, period_seconds=1800.0, bin_seconds=60.0)
    print(f"  baseline 10 qps, bursts to {float(crowd.peak_rate):.0f} qps "
          f"(mean {float(crowd.mean_rate):.1f} qps)")
    trace = simulate_trace(jax.random.PRNGKey(0), crowd, args.queries,
                           true_params)
    print(f"  trace: {trace.n_queries} queries x {trace.p} servers, "
          f"span {float(trace.arrival[-1] - trace.arrival[0]):.0f}s")

    print("\n== 2. fit + validate (last 25% of the trace held out) ==")
    cal, report = calibrate_and_validate(trace, n_windows=24,
                                         holdout_fraction=0.25)
    print_params("true  ", true_params)
    print_params("fitted", cal.params)
    print(f"  imbalance blend alpha={float(cal.alpha):.3f} "
          f"(0 = Eq 7 lower bound, 1 = H_p upper bound)")
    print(report.summary())

    print("\n== 3. plan from the calibration ==")
    cal2, plan = plan_from_trace(trace, TARGET_QPS, SLO, n_windows=18)
    print(f"  {TARGET_QPS:.0f} qps @ {SLO * 1e3:.0f}ms SLO -> "
          f"{plan.n_replicas} replicas x {plan.servers_per_replica} "
          f"servers = {plan.total_servers} total "
          f"(R_upper {plan.response_upper_ms:.0f}ms, "
          f"util {plan.utilization:.2f})")

    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 16.0, 22.0]),
        p=jnp.asarray([4.0, 8.0, 16.0]),
        cpu=jnp.asarray([1.0, 2.0]),
        disk=jnp.asarray([1.0, 2.0]),
        base=cal.to_server_params(),
        hit=jnp.asarray([float(cal.params.hit)]),
        broker_from_p=False)
    _, frontier = planner.plan_over_grid(grid, SLO)
    print("  cheapest calibrated config per rate (analytic Eq-7 surface):")
    for i in range(grid.lam.shape[0]):
        print(f"    {frontier.describe(i)}")

    if args.engine:
        print("\n== 4. the same loop on the instrumented toy engine ==")
        import numpy as np

        from repro.engine import corpus as corpus_lib
        from repro.engine import partition, server
        from repro.workloadgen import loadgen, querygen

        ccfg = corpus_lib.CorpusConfig(n_docs=3000, vocab_size=2000,
                                       mean_doc_len=40, seed=0)
        corp = corpus_lib.generate_corpus(ccfg)
        parts = partition.partition_documents(corp, 2)
        shards = [server.IndexServer(ix, k_local=10) for ix in parts.shards]
        uni = querygen.build_universe(querygen.WorkloadConfig(
            "calib", n_unique_queries=1500, vocab_size=2000, seed=0))
        n_q = 2048
        _, qterms = querygen.sample_query_stream(uni, n_q, seed=3)
        arrivals = loadgen.poisson_arrivals(50.0, n_q / 50.0, seed=5)[:n_q]
        etrace = measure_engine_trace(
            shards, np.asarray(qterms), arrivals,
            cache_bytes=2_000_000, batch=64)
        ecal, eplan = plan_from_trace(etrace, 200.0, SLO, n_windows=8)
        print_params("engine", ecal.params)
        print(f"  alpha={float(ecal.alpha):.3f}; plan for 200 qps @ "
              f"{SLO * 1e3:.0f}ms: {eplan.n_replicas} x "
              f"{eplan.servers_per_replica} servers "
              f"(R_upper {eplan.response_upper_ms:.1f}ms)")


if __name__ == "__main__":
    main()
