"""Train a small qwen3-style LM end to end: data pipeline -> train step ->
checkpointing -> restart, with the capacity model watching step times.

Defaults are CPU-sized (a ~12M-param model, 300 steps, minutes on one
core); --preset full selects a ~110M model for real hardware.  The loss
must drop well below the unigram entropy floor — asserted at the end.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs.base import LMConfig
from repro.data.pipeline import LMBatchPipeline
from repro.models import transformer as T
from repro.train.optimizer import AdamW, cosine_schedule
from repro.train.trainer import TrainStep

PRESETS = {
    # ~12M params: CPU-demo scale
    "cpu": LMConfig(name="demo-12m", n_layers=4, d_model=256, n_heads=8,
                    n_kv_heads=4, d_ff=768, vocab_size=8192, d_head=32,
                    qk_norm=True, dtype="float32", vocab_pad_multiple=256),
    # ~110M params: single-accelerator scale
    "full": LMConfig(name="demo-110m", n_layers=12, d_model=768,
                     n_heads=12, n_kv_heads=4, d_ff=2304,
                     vocab_size=32768, d_head=64, qk_norm=True,
                     dtype="bfloat16", vocab_pad_multiple=256),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="cpu")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    print(f"== {cfg.name}: {cfg.n_params / 1e6:.1f}M params ==")
    pipe = LMBatchPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq,
                           global_batch=args.batch, coherence=0.7)

    def loss_fn(params, batch):
        return T.train_step_loss(params, cfg, batch["tokens"],
                                 batch["labels"])

    step_fn = TrainStep(loss_fn=loss_fn, optimizer=AdamW(
        lr=cosine_schedule(3e-3, warmup=20, total=args.steps)))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    state = step_fn.init_state(params)
    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every,
                            keep_last=2)

    start_step, restored = mgr.restore_latest(
        {"params": params, "state": state})
    if restored is not None:
        params, state = restored["params"], restored["state"]
        print(f"   restored from step {start_step}")
    start_step = start_step or 0

    jstep = jax.jit(step_fn)
    first_loss, last_loss = None, None
    t_log = time.time()
    for s in range(start_step + 1, args.steps + 1):
        tokens, labels = pipe.batch(s)
        params, state, loss = jstep(params, state, {
            "tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)})
        last_loss = float(loss)
        first_loss = first_loss or last_loss
        mgr.maybe_save(s, {"params": params, "state": state})
        if s % 25 == 0 or s == 1:
            dt = time.time() - t_log
            print(f"   step {s:4d} loss {last_loss:.3f} "
                  f"({dt / 25:.2f}s/step)")
            t_log = time.time()
    mgr.wait()

    floor = np.log(cfg.vocab_size)
    print(f"== done: loss {first_loss:.3f} -> {last_loss:.3f} "
          f"(ln V = {floor:.2f}) ==")
    assert last_loss < first_loss * 0.75, "training did not learn"
    print("   checkpoints in", args.ckpt_dir, "(re-run to test restart)")


if __name__ == "__main__":
    main()
