"""Web-scale capacity planning: the SNIPPETS.md global sizing exercise
on a MILLION-scenario sharded sweep.

The SCALE_LOAD_ESTIMATIONS document (SNIPPETS.md) plans a global search
deployment top-down: ~38.58M queries/s globally (100B queries/month),
split across 4 regions -> ~9.65M qps per region (~833B queries/day).
It then sizes workers by dividing rates by an ASSUMED per-worker
throughput.  This example replaces that assumption with the paper's
queueing model, evaluated over a 1,000,000-scenario what-if grid

    lam x p x cpu-speedup x disk-speedup x cache-hit x replicas

scenario-sharded over 8 XLA devices (`launch.mesh.make_sweep_mesh` +
`compat.shard_map`): the frontier picks the cheapest replicated cluster
cell that honors the SLO, and dividing the regional rate by the cell's
arrival rate gives the fleet size — capacity planning with response-time
guarantees instead of rule-of-thumb worker math.  A scenario-sharded run
of the fused replicated simulator then cross-checks the chosen cell's
analytic bound mechanistically.

Run:   XLA_FLAGS=--xla_force_host_platform_device_count=8 \
           PYTHONPATH=src python examples/global_sweep.py [--quick]
(the script forces 8 virtual devices itself if XLA_FLAGS doesn't; CI
runs the --quick variant as the sharded-sweep smoke job)
"""

import argparse
import math
import os
import time

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="small grid + short sim horizon (CI smoke)")
args = ap.parse_args()

# the device count is baked in when jax initializes — force it FIRST
if "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count"
                               "=8").strip()

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402

from repro.core import capacity, queueing, sweep              # noqa: E402
from repro.launch.mesh import make_sweep_mesh                 # noqa: E402

MS = 1e3
SLO = 0.650                 # s; must sit above the H_100 join-tax floor
REGIONS = 4
GLOBAL_QPS = 38.58e6        # the SNIPPETS exercise's ~38M qps target

print("== The workload (SNIPPETS SCALE_LOAD_ESTIMATIONS) ==")
region_qps = GLOBAL_QPS / REGIONS
print(f"  global: {GLOBAL_QPS / 1e6:.2f}M queries/s (~38M qps)")
print(f"  per region ({REGIONS} regions): {region_qps / 1e6:.2f}M qps, "
      f"{region_qps * 86_400 / 1e9:.0f}B queries/day, "
      f"{100e9 / REGIONS / 1e9:.0f}B queries/month of the stated "
      "100B global")

print("\n== Million-scenario planning surface ==")
mesh = make_sweep_mesh()
print(f"  devices: {len(jax.devices())}, mesh axes {mesh.axis_names}")
if args.quick:
    grid = sweep.SweepGrid.build(
        lam=jnp.linspace(10.0, 120.0, 10),
        p=jnp.asarray([50.0, 100.0]), cpu=jnp.asarray([1.0, 2.0]),
        disk=jnp.asarray([1.0, 2.0]), hit=jnp.linspace(0.05, 0.95, 5),
        r=jnp.asarray([1.0, 2.0, 4.0]), base=capacity.TABLE5_PARAMS,
        result_cache=(0.2, 2e-3))
else:
    grid = sweep.SweepGrid.build(
        lam=jnp.linspace(10.0, 120.0, 100),
        p=jnp.asarray([50.0, 100.0, 200.0, 400.0]),
        cpu=jnp.linspace(1.0, 3.0, 5), disk=jnp.linspace(1.0, 3.0, 5),
        hit=jnp.linspace(0.05, 0.95, 20),
        r=jnp.asarray([1.0, 2.0, 4.0, 8.0, 16.0]),
        base=capacity.TABLE5_PARAMS, result_cache=(0.2, 2e-3))
t0 = time.perf_counter()
result = sweep.sweep_analytical(grid, mesh=mesh)
jax.block_until_ready(result.response_upper)
dt = time.perf_counter() - t0
print(f"  {grid.n_scenarios:,} scenarios evaluated in {dt:.2f}s "
      f"({grid.n_scenarios / dt:,.0f} scenarios/s, sharded)")

frontier = sweep.extract_frontier(result, SLO)
i_best = int(jnp.argmax(jnp.where(
    frontier.feasible, grid.lam / frontier.cost, -jnp.inf)))
print(f"  best qps-per-cost cell under R <= {SLO * MS:.0f} ms:")
print("   ", frontier.describe(i_best))

print("\n== Sizing the global fleet from the chosen cell ==")
lam_cell = float(grid.lam[i_best])
p_c = int(round(float(frontier.p[i_best])))
r_c = int(round(float(frontier.r[i_best])))
cells_region = math.ceil(region_qps / lam_cell)
servers_global = REGIONS * cells_region * r_c * (p_c + 1)
print(f"  cell serves {lam_cell:.0f} qps -> "
      f"{cells_region:,} cells/region x {REGIONS} regions")
print(f"  fleet: {servers_global / 1e6:.1f}M index+broker servers "
      f"({r_c} replicas x {p_c} servers + broker per cell) vs the "
      "SNIPPETS worker-math answer of rate/throughput workers — same "
      "division, but the denominator now carries an SLO guarantee")

print("\n== Sharded simulated cross-check of the chosen cell ==")
n_q = 20_000 if args.quick else 200_000
sim_grid = sweep.SweepGrid.build(
    lam=jnp.linspace(0.6 * lam_cell, lam_cell, 8),
    p=jnp.asarray([float(p_c)]),
    cpu=jnp.asarray([float(frontier.cpu[i_best])]),
    disk=jnp.asarray([float(frontier.disk[i_best])]),
    hit=jnp.asarray([float(frontier.hit[i_best])]),
    r=jnp.asarray([float(r_c)]), base=capacity.TABLE5_PARAMS,
    result_cache=(0.2, 2e-3))
t0 = time.perf_counter()
sim = sweep.sweep_simulated(sim_grid, jax.random.PRNGKey(0),
                            n_queries=n_q, chunk_size=4096, mesh=mesh)
jax.block_until_ready(sim.mean)
dt = time.perf_counter() - t0
ana = sweep.sweep_analytical(sim_grid, mesh=mesh)
print(f"  {sim_grid.n_scenarios} scenarios x {n_q:,} queries "
      f"(fused replicated engine, sharded) in {dt:.2f}s")
ok = True
for k in range(sim_grid.lam.shape[0]):
    m = float(jnp.ravel(sim.mean)[k])
    hi = float(jnp.ravel(ana.response_upper)[k])
    tag = "ok" if m <= hi * 1.05 else "ABOVE BOUND"
    ok &= m <= hi * 1.05
    print(f"  lam={float(sim_grid.lam[k]):6.1f} qps  simulated mean "
          f"{m * MS:6.1f} ms  <=  Eq7/8 upper {hi * MS:6.1f} ms  [{tag}]")
assert ok, "simulated mean escaped the analytic planning surface"
print("\nall simulated means under the analytic planning surface — the "
      "38M-qps fleet above is sized on a bound the mechanism respects")
