"""End-to-end serving driver (the paper's kind of system is a serving
system, so this is the flagship example): a live vertical search engine
under open-loop Poisson load with batched request processing, an
application-level result cache, and capacity-model-driven admission.

The loop measures actual per-request latencies on this machine and
compares them against the queueing model parameterized from the same
measurements — the full Sec 5.3 validation, live.

Run:  PYTHONPATH=src python examples/serve_search.py [--duration 20]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import queueing
from repro.engine import cache as cache_lib
from repro.engine import corpus as corpus_lib
from repro.engine import index as index_lib
from repro.engine import server
from repro.launch.elastic import hedge_threshold
from repro.workloadgen import loadgen, querygen


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=15.0)
    ap.add_argument("--rate", type=float, default=None,
                    help="target qps (default: 60%% of capacity)")
    ap.add_argument("--batch-window-ms", type=float, default=20.0)
    args = ap.parse_args()

    print("== build engine ==")
    ccfg = corpus_lib.CorpusConfig(n_docs=4000, vocab_size=2500,
                                   mean_doc_len=40, seed=0)
    corp = corpus_lib.generate_corpus(ccfg)
    idx = index_lib.build_index(corp)
    srv = server.IndexServer(idx, k_local=10)
    uni = querygen.build_universe(querygen.WorkloadConfig(
        "serve", n_unique_queries=2000, vocab_size=2500, seed=0))

    # warm + measure service time per query at the serving batch size
    batch = 32
    qids, qterms = querygen.sample_query_stream(uni, 4096, seed=7)
    qt = jnp.asarray(qterms[:batch])
    srv.timed_process(qt)
    s_query = srv.timed_process(qt) / batch
    cap = 1.0 / s_query
    rate = args.rate or 0.6 * cap
    print(f"   measured S_query={s_query * 1e3:.3f} ms  capacity~{cap:.0f}"
          f" qps  offering {rate:.0f} qps")

    # the model's prediction for this operating point (p=1 local server)
    params = queueing.ServerParams(p=1, s_broker=1e-5, s_hit=s_query,
                                   s_miss=s_query, s_disk=0.0, hit=1.0)
    lo, hi = queueing.response_time_bounds(rate, params)
    hedge = hedge_threshold(s_query, 8)
    print(f"   model: {float(lo) * 1e3:.2f} <= R <= {float(hi) * 1e3:.2f}"
          f" ms;  hedged-duplicate threshold {hedge * 1e3:.1f} ms")

    print("== open-loop serving ==")
    n_req = int(rate * args.duration)
    arrivals = loadgen.poisson_arrivals(rate, args.duration, seed=3)
    qids, qterms = querygen.sample_query_stream(uni, len(arrivals), seed=9)
    result_cache = cache_lib.ResultCache(capacity_entries=500)

    t0 = time.perf_counter()
    latencies, cache_hits, served = [], 0, 0
    i = 0
    while i < len(arrivals):
        now = time.perf_counter() - t0
        if arrivals[i] > now:
            time.sleep(min(arrivals[i] - now, 0.01))
            continue
        # batching window: wait until `window` after the head arrival
        # (no wait when the loop is already running behind — batches then
        # fill from the backlog and it drains), then take every request
        # that has ACTUALLY arrived.  Admitting future arrivals would log
        # negative latencies and corrupt the measured-vs-model compare.
        wait_end = arrivals[i] + args.batch_window_ms / 1e3
        if now < wait_end:
            time.sleep(wait_end - now)
            now = time.perf_counter() - t0
        j = i
        while j < len(arrivals) and arrivals[j] <= now and j - i < batch:
            j += 1
        req_ids = qids[i:j]
        # result cache short-circuits repeats (Scenario 6)
        misses = [k for k, qid in enumerate(req_ids)
                  if not result_cache.lookup(int(qid))]
        cache_hits += len(req_ids) - len(misses)
        if misses:
            qt = np.full((batch, qterms.shape[1]), -1, np.int32)
            qt[: len(misses)] = qterms[i:j][misses]
            scores, docs = srv.process(jnp.asarray(qt))
            jax.block_until_ready(scores)
        done = time.perf_counter() - t0
        latencies.extend(done - arrivals[i:j])
        served += j - i
        i = j

    lat = np.asarray(latencies)
    print(f"   served {served} requests; result-cache hit "
          f"{cache_hits / max(served, 1):.2f}")
    print(f"   measured mean={lat.mean() * 1e3:.1f} ms "
          f"p50={np.quantile(lat, .5) * 1e3:.1f} "
          f"p95={np.quantile(lat, .95) * 1e3:.1f} "
          f"p99={np.quantile(lat, .99) * 1e3:.1f} ms")
    print(f"   model bound was [{float(lo) * 1e3:.1f}, "
          f"{float(hi) * 1e3:.1f}] ms + batching window "
          f"{args.batch_window_ms:.0f} ms")


if __name__ == "__main__":
    main()
