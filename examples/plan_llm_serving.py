"""The paper's capacity-planning methodology applied to the assigned
architectures: read the dry-run roofline records and produce Section-6
style serving plans per (arch x shape).

"How many 256-chip serving cells does qwen3-8b decode_32k need for 500
req/s under a 50 ms/token SLO?" — answered exactly the way the paper
sizes search clusters.

Run:  PYTHONPATH=src python examples/plan_llm_serving.py \
          [--dryrun-dir experiments/dryrun_v2]
"""

import argparse
import glob
import json
import os

from repro.core import planner
from repro.core.planner import RooflineTerms, ServingModel

SERVE_SHAPES = {"decode_32k": 600e-3, "serve_p99": 20e-3,
                "retrieval_cand": 100e-3, "long_500k": 400e-3}
TARGET_RATES = {"decode_32k": 500.0, "serve_p99": 50_000.0,
                "retrieval_cand": 2_000.0, "long_500k": 20.0}
BATCH = {"decode_32k": 128, "serve_p99": 512, "retrieval_cand": 1,
         "long_500k": 1}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun_v2")
    args = ap.parse_args()

    files = sorted(glob.glob(os.path.join(args.dryrun_dir,
                                          "*__single.json")))
    if not files:
        raise SystemExit(f"no dry-run records in {args.dryrun_dir}; run "
                         "python -m repro.launch.dryrun --all first")

    print(f"{'arch':24s} {'shape':14s} {'bound':>10s} {'step_ms':>8s} "
          f"{'cells':>6s} {'chips':>7s} {'R_ms':>7s} {'util':>5s}")
    for f in files:
        r = json.load(open(f))
        if r["shape"] not in SERVE_SHAPES:
            continue
        terms = RooflineTerms(compute_s=r["compute_s"],
                              memory_s=r["memory_s"],
                              collective_s=r["collective_s"])
        model = ServingModel(
            name=r["arch"], terms=terms, n_chips=r["n_chips"],
            batch_per_step=BATCH[r["shape"]])
        plan = planner.plan_serving(
            model, TARGET_RATES[r["shape"]], SERVE_SHAPES[r["shape"]])
        if plan.cells == 0:
            print(f"{r['arch']:24s} {r['shape']:14s} {plan.bound:>10s} "
                  f"{terms.step_time_serial_bound * 1e3:8.2f} "
                  f"{'SLO infeasible (step > SLO)':>28s}")
        else:
            print(f"{r['arch']:24s} {r['shape']:14s} {plan.bound:>10s} "
                  f"{terms.step_time_serial_bound * 1e3:8.2f} "
                  f"{plan.cells:6d} {plan.chips:7d} "
                  f"{plan.response_upper_ms:7.1f} {plan.utilization:5.2f}")

    print("\n(step_ms = serial roofline bound per step; cells sized so the"
          " Eq 7 upper bound meets the SLO at the target rate)")


if __name__ == "__main__":
    main()
