"""Does the fleet survive losing a replica at the worst moment?

The paper sizes a fleet for peak load (Section 6) assuming every replica
stays up; a real vertical deployment loses machines, and the capacity
question becomes N+k: does the p95 SLO hold while k replicas are down
and failover routing spills their share onto the survivors?  This
example stresses exactly that:

  1. a diurnal + flash-crowd week is replayed against a fixed r-replica
     fleet, fault-free, for the baseline p95;
  2. the same week is replayed with one replica DOWN for the hours
     around the flash crowd (a deterministic `FaultSpec` outage window)
     — the survivors' p95 answers "does N-1 hold the SLO at peak?";
  3. a `SweepGrid` fault axis compares graceful-degradation knobs at
     equal load: full fork-join vs k-of-p partial-quorum merging under
     a broker timeout, with and without the outage;
  4. an N+k plan from `plan_capacity(survive_faults=1)` shows what the
     planner would buy to make step 2 pass by construction.

The "week" is time-compressed (a few seconds per hourly bin) so the
whole shape fits in a tractable query budget.

Run:  PYTHONPATH=src python examples/failover_stress.py [--quick]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import capacity, simulator, sweep
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec
from repro.core.faults import FaultSpec
from repro.core.queueing import ServerParams
from repro.obs.report import render_timeline
from repro.obs.timeline import TelemetrySpec
from repro.workloadgen import loadgen

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="CI smoke mode: fewer queries, smaller grid")
args = ap.parse_args()

MS = 1e3
SLO = 0.75                     # p95 objective (s)
LAM = 24.0                     # time-averaged total qps
R = 3                          # the provisioned fleet
BIN_S = 2.0                    # seconds per "hour" of the compressed week
N_Q = 6_000 if args.quick else 48_000
CHUNK = 64                     # small: every ~2s profile bin gets sampled

PARAMS = ServerParams(p=4, s_broker=0.004, s_hit=0.0125, s_miss=0.05,
                      s_disk=0.04, hit=0.5)

# -- the load: a diurnal week with a flash crowd on Wednesday 15:00 -----
week = loadgen.diurnal_rates(1.0, peak_to_trough=3.0)      # (168,) hourly
crowd_hour = 2 * 24 + 15
week = week.at[crowd_hour].mul(2.5)
profile = week / jnp.mean(week)
arrival = ArrivalProcess.piecewise(LAM * profile, BIN_S)

# the outage covers the crowd and the hours around it — the worst window
down_t0, down_t1 = (crowd_hour - 2) * BIN_S, (crowd_hour + 4) * BIN_S
outage = FaultSpec(outages=((0, down_t0, down_t1),))

key = jax.random.PRNGKey(23)
tele = TelemetrySpec(n_bins=28)


def run(spec, k=key):
    return simulator.simulate_fork_join(
        k, arrival, N_Q, PARAMS, chunk_size=CHUNK, cluster=spec,
        telemetry=tele)


print(f"== failover stress: r={R}, lam={LAM:g} qps avg, flash crowd "
      f"x2.5, p95 SLO {SLO * MS:.0f} ms ==")

base = run(ClusterSpec(r=R, routing="round_robin"))
p95_base = float(base.quantile(0.95))
print(f"  fault-free     p95 {p95_base * MS:7.1f} ms  "
      f"mean {float(base.mean_response) * MS:6.1f} ms")

hit = run(ClusterSpec(r=R, routing="round_robin", fault=outage))
p95_hit = float(hit.quantile(0.95))
ok = p95_hit <= SLO
print(f"  1 replica down p95 {p95_hit * MS:7.1f} ms  "
      f"mean {float(hit.mean_response) * MS:6.1f} ms  "
      f"spill {float(hit.spill_fraction) * 100:.1f}%  "
      f"availability {float(hit.availability) * 100:.2f}%")
print(f"  -> survivors {'HOLD' if ok else 'VIOLATE'} the p95 SLO "
      f"during the outage ({p95_hit * MS:.0f} ms vs {SLO * MS:.0f} ms)")
print()
print(render_timeline(hit.timeline, "outage week (1 of 3 down at peak)"))

# -- graceful degradation: full fork-join vs k-of-p quorum --------------
# Under a broker timeout the merge returns with the k fastest servers'
# results; the query is DEGRADED (partial coverage) but fast.  Sweep the
# knob with and without the outage at equal load.
p = int(PARAMS.p)
deadline = 0.6 * SLO
scenarios = (
    None,
    FaultSpec(broker_timeout_seconds=deadline, quorum_k=p - 1),
    FaultSpec(outages=outage.outages),
    FaultSpec(outages=outage.outages,
              broker_timeout_seconds=deadline, quorum_k=p - 1),
)
labels = ("fault-free", f"quorum {p - 1}/{p}", "outage",
          f"outage + quorum {p - 1}/{p}")
grid = sweep.SweepGrid.build(
    lam=[LAM], p=[float(p)], hit=[PARAMS.hit], base=PARAMS,
    broker_from_p=False, r=[float(R)], fault=scenarios)
res = sweep.sweep_simulated(
    grid, jax.random.PRNGKey(5), n_queries=N_Q, chunk_size=CHUNK,
    profile=profile, profile_bin_seconds=BIN_S,
    cluster=ClusterSpec(routing="round_robin"))
p95s = jnp.reshape(res.quantile(0.95), (-1,))
degr = jnp.reshape(res.stats.degraded_fraction, (-1,))
print("\n== degraded operation vs full fork-join (same week, same fleet) ==")
for j, lab in enumerate(labels):
    d = float(degr[j])
    note = f"  degraded {d * 100:5.1f}%" if d > 0 else ""
    flag = "ok " if float(p95s[j]) <= SLO else "SLO"
    print(f"  {lab:<22} p95 {float(p95s[j]) * MS:7.1f} ms [{flag}]{note}")

# -- what would the planner buy to survive this? ------------------------
plan = capacity.plan_capacity(
    PARAMS, LAM * float(jnp.max(profile)), SLO, survive_faults=1,
    simulate=not args.quick, key=jax.random.PRNGKey(3),
    n_queries=max(4_000, N_Q // 4))
print(f"\n== N+1 plan for the peak rate ==")
print(f"  {plan.n_replicas} replicas x {plan.servers_per_replica} servers "
      f"(k={plan.survive_faults} spare) -> "
      f"{plan.total_servers} servers total")
if plan.response_faulted_p95_ms is not None:
    fok = plan.response_faulted_p95_ms <= SLO * MS
    print(f"  simulated p95 with {plan.survive_faults} replica down: "
          f"{plan.response_faulted_p95_ms:.1f} ms "
          f"[{'holds SLO' if fok else 'exceeds SLO'}]")
