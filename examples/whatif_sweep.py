"""Paper Section 6 as a dense what-if sweep (Figs 9-12 at grid scale).

Instead of evaluating the paper's six hand-picked scenarios one at a
time, sweep the full upgrade space — arrival rate x servers x CPU speedup
x disk speedup, for each Table 6 memory column — as one XLA program per
column, then extract the constraint frontier: the cheapest configuration
that keeps the Eq 7 upper bound under the 300 ms answer-time constraint.

A small simulation cross-check (batched Lindley recursions, all sample
paths in one program) validates the analytical surface on a sub-grid.

Run:  PYTHONPATH=src python examples/whatif_sweep.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capacity, planner, sweep

SLO = 0.300          # the paper's 300 ms answer-time constraint
MS = 1e3

print("== Upgrade sweep: lam x p x cpu x disk, per Table 6 memory column ==")
lam = jnp.asarray([16.0, 32.0, 56.0, 80.0])
for mem in (1, 2, 3, 4):
    grid = sweep.SweepGrid.build(
        lam=lam,
        p=jnp.asarray([50.0, 100.0, 150.0, 200.0]),
        cpu=jnp.linspace(1.0, 4.0, 7),
        disk=jnp.linspace(1.0, 4.0, 7),
        memory=mem,
    )
    result, frontier = planner.plan_over_grid(grid, SLO)
    feas = float(jnp.mean(jnp.isfinite(result.response_upper)
                          & (result.response_upper <= SLO)))
    print(f"\n  memory {mem}x — {grid.n_scenarios} scenarios, "
          f"{feas:5.1%} meet the SLO")
    for i in range(lam.shape[0]):
        print("   ", frontier.describe(i))

print("\n== The paper's Scenario 4 point, read off the same surface ==")
grid4 = sweep.SweepGrid.build(
    lam=jnp.asarray([56.0]), p=jnp.asarray([100.0]),
    cpu=jnp.asarray([4.0]), disk=jnp.asarray([4.0]), memory=4)
res4 = sweep.sweep_analytical(grid4)
print(f"  R_upper(56 qps | mem 4x, cpu 4x, disk 4x, p=100) = "
      f"{float(res4.response_upper.reshape(())) * MS:.0f} ms (paper: 286 ms)")

print("\n== Simulation cross-check on a sub-grid (batched Lindley) ==")
sub = sweep.SweepGrid.build(
    lam=jnp.asarray([10.0, 20.0]), p=jnp.asarray([8.0]),
    base=capacity.TABLE5_PARAMS, hit=jnp.asarray([0.17]),
    broker_from_p=False)
sim = sweep.sweep_simulated(sub, jax.random.PRNGKey(0), n_queries=60_000)
ana = sweep.sweep_analytical(sub)
p95 = sim.quantile(0.95)
for i, l in enumerate([10.0, 20.0]):
    lo = float(ana.response_lower[i].reshape(())) * MS
    hi = float(ana.response_upper[i].reshape(())) * MS
    m = float(sim.mean[i].reshape(())) * MS
    q = float(p95[i].reshape(())) * MS
    inside = "within bounds" if lo <= m <= hi * 1.02 else "OUT OF BOUNDS"
    print(f"  lam={l:4.0f}: simulated {m:6.1f} ms (p95 {q:6.1f} ms) vs "
          f"Eq 7 [{lo:.1f}, {hi:.1f}] ms — {inside}")

print("\n== Throughput: the whole grid is one jitted call ==")
big = sweep.SweepGrid.build(
    lam=jnp.linspace(1.0, 80.0, 20), p=jnp.linspace(20.0, 200.0, 10),
    cpu=jnp.linspace(1.0, 4.0, 7), disk=jnp.linspace(1.0, 4.0, 7),
    hit=jnp.linspace(0.02, 0.30, 8))
import time
out = sweep.sweep_analytical(big).response_upper
jax.block_until_ready(out)
t0 = time.perf_counter()
out = sweep.sweep_analytical(big).response_upper
jax.block_until_ready(out)
dt = time.perf_counter() - t0
print(f"  {big.n_scenarios} scenarios in {dt * MS:.1f} ms "
      f"({big.n_scenarios / dt / 1e6:.1f}M scenarios/s); "
      f"{float(jnp.mean(jnp.isfinite(out))):5.1%} below saturation")
