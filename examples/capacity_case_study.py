"""Paper Section 6, end to end: the 1-billion-page case study.

100 index servers x 10M pages each; evaluate Scenarios 1-6 and print the
replication answer.  All numbers check against the paper's published
values (286 ms @ 56 qps, 4x100 replicas; with result caching 282 ms @ 65
qps, 3x100).

Run:  PYTHONPATH=src python examples/capacity_case_study.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import capacity, queueing
from repro.core.cluster import ClusterSpec

SLO = 0.300
TARGET_QPS = 200.0

print("== Table 6 parameters (p=100, b=10M pages) ==")
for mem in (1, 2, 3, 4):
    s_hit, s_miss, s_disk, hit = capacity.MEMORY_TABLE[mem]
    print(f"  memory {mem}x: S_hit={s_hit * 1e3:.2f}ms "
          f"S_miss={s_miss * 1e3:.2f}ms S_disk={s_disk * 1e3:.2f}ms "
          f"hit={hit:.2f}")

print("\n== Scenario sweep (upper bound on R at selected rates) ==")
lam_grid = jnp.asarray([1.0, 4.0, 16.0, 32.0, 56.0])
for name in ("baseline", "memory+disks", "memory+cpus", "cpus+disks",
             "memory+cpus+disks"):
    params = capacity.scenario(name)
    hi = capacity.upper_bound_curve(lam_grid, params)
    vals = " ".join(
        f"{v * 1e3:7.0f}" if np.isfinite(v) else "    sat"
        for v in np.asarray(hi))
    print(f"  {name:20s} R(ms) @ {list(map(float, lam_grid))}: {vals}")

print("\n== Scenario 4: the paper's headline numbers ==")
p4 = capacity.scenario("memory+cpus+disks")
_, hi = queueing.response_time_bounds(56.0, p4)
print(f"  R_upper(56 qps) = {float(hi) * 1e3:.0f} ms   (paper: 286 ms)")
plan = capacity.plan_capacity(p4, TARGET_QPS, SLO)
print(f"  plan for {TARGET_QPS:.0f} qps @ {SLO * 1e3:.0f} ms: "
      f"{plan.n_replicas} replicas x {plan.servers_per_replica} = "
      f"{plan.total_servers} servers   (paper: 4 x 100 = 400)")

print("\n== Scenario 6: application-level result caching (Eq 8) ==")
r65 = queueing.response_time_with_result_cache(65.0, p4, 0.5, 0.069e-3)
print(f"  R(65 qps | hit_r=0.5) = {float(r65) * 1e3:.0f} ms "
      f"(paper: 282 ms)")
plan6 = capacity.plan_capacity(
    p4, 195.0, SLO, cluster=ClusterSpec(result_cache=(0.5, 0.069e-3)))
print(f"  plan for 195 qps: {plan6.n_replicas} x 100 "
      f"(paper: 3 x 100 at 65 qps each)")

print("\n== beyond-paper: q-percentile answer (paper future work) ==")
for q in (0.5, 0.95, 0.99):
    t = queueing.response_time_quantile_upper(56.0, p4, q)
    print(f"  p{int(q * 100):02d} upper estimate @56 qps: "
          f"{float(t) * 1e3:.0f} ms")
