"""Observing a replicated cluster through a flash crowd.

A capacity plan says what a cluster can sustain *on average*; the
observability layer shows what actually happens inside one run.  This
example replays the paper's Table-5 cluster (p=8 index servers) as
three JSQ-routed replicas through a flash crowd — a 4x arrival burst in
the middle of the horizon — and renders all three observability views:

  * streaming TIMELINES (`repro.obs.TelemetrySpec`): per-time-bin
    throughput, utilization, queue depth, SLO violations and routing
    imbalance, accumulated inside the simulator's scan carry at
    O(n_bins) memory — the burst is visible, the mean hides it;
  * operational-law self-checks: the binned telemetry satisfies
    U = X * S and L = lambda * W per bin as identities, so the
    dashboard can prove its own numbers are conserved;
  * a SPAN TRACE (`repro.obs.trace_export`): a bounded window of the
    same scenario as Chrome-trace JSON — open the file in
    chrome://tracing or https://ui.perfetto.dev to see each query fork
    across broker and servers;
  * kernel PROFILES (`repro.obs.profile`): compile time, flops, bytes
    and peak memory of the (max,+) kernel stack, placed on the machine
    roofline by `repro.roofline.report.kernel_roofline`.

Run:   PYTHONPATH=src python examples/observe_cluster.py \
           [--quick] [--trace-json /tmp/cluster_trace.json]
(CI runs the --quick variant as the obs-smoke job and schema-validates
the exported trace.)
"""

import argparse
import pathlib

import jax

from repro.core import capacity, simulator
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec
from repro.obs import TelemetrySpec
from repro.obs import profile as obs_profile
from repro.obs import report as obs_report
from repro.obs import trace_export
from repro.roofline.report import kernel_roofline

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="short horizon + tiny span window (CI smoke)")
ap.add_argument("--trace-json", default="/tmp/cluster_trace.json",
                help="where to write the Chrome-trace span export")
args = ap.parse_args()

R, ROUTING, LAM, SLO = 3, "jsq", 24.0, 0.7
N_QUERIES = 4_000 if args.quick else 40_000
N_SPAN = 300 if args.quick else 2_000
BINS = 32 if args.quick else 64

params = capacity.TABLE5_PARAMS
horizon = N_QUERIES / (LAM * 1.6)
flash = ArrivalProcess.flash_crowd(
    LAM, burst_starts=0.35 * horizon, burst_seconds=0.2 * horizon,
    burst_multiplier=4.0, period_seconds=horizon,
    bin_seconds=horizon / 64)

print(f"== scenario: flash crowd (lam {LAM:g} qps x4 burst), "
      f"r={R} {ROUTING}, p={int(params.p)}, SLO {SLO:g}s ==\n")

# 1. streaming timelines — one extra kwarg on the normal entry point
spec = TelemetrySpec(n_bins=BINS, slo_seconds=SLO)
res = simulator.simulate_fork_join(
    jax.random.PRNGKey(0), flash, N_QUERIES, params,
    cluster=ClusterSpec(r=R, routing=ROUTING), telemetry=spec)
print(obs_report.render_timeline(res.timeline, "flash crowd replay"))
print()

# 2. the telemetry proves itself: U = X*S and L = lam*W per bin
law_report, worst = obs_report.oplaw_check(res.timeline)
print(law_report)
if worst > 1e-3:
    raise SystemExit(f"operational-law self-check FAILED ({worst:.2e})")
print()

# 3. span trace of a bounded window of the same scenario
spans = trace_export.simulate_spans(
    jax.random.PRNGKey(0), flash, N_SPAN, params, r=R, routing=ROUTING)
path = trace_export.export_chrome_trace(spans, args.trace_json)
counts = trace_export.validate_chrome_trace(path)
print(f"== span trace ==\n  {path} — {counts['X']} service spans, "
      f"{counts['async_pairs']} query lifetimes, {counts['lanes']} FCFS "
      f"lanes; schema OK\n  (open in chrome://tracing or "
      f"ui.perfetto.dev)")
print()

# 4. kernel profiles on the machine roofline
records = obs_profile.profile_kernels(n_runs=0 if args.quick else 3)
print(obs_report.render_profiles(records))
print()
print(kernel_roofline(records))

assert pathlib.Path(path).stat().st_size > 0
print("\nobserve_cluster: OK")
