"""Calibration smoke check, shared by CI and the test suite.

This used to live as a heredoc inside the ``calibrate-smoke`` CI job,
which made it untestable and easy to drift from the library.  It is now
an importable function: CI runs the module, ``tests/test_calibrate.py``
imports and calls it, and both exercise exactly the same code.

The check: simulate two tiny measurement traces from a hidden
ground-truth cluster, run the measure -> fit -> validate pipeline with
a minimal iteration budget, and assert the fitted imbalance blend is
sane and the calibrated model tracks its own simulator.

Run:  PYTHONPATH=src python examples/calibrate_smoke.py
"""

from __future__ import annotations

import dataclasses

import jax


def run_smoke(*, n_queries: int = 3_000, n_iters: int = 2,
              simulator_queries: int = 5_000, verbose: bool = True):
    """Tiny end-to-end calibration; returns the (cal, report) pair.

    Raises AssertionError when the pipeline's accuracy contract breaks.
    Sizes are smoke-sized on purpose (~seconds on CPU): the thorough
    accuracy acceptance lives in tests/test_calibrate.py.
    """
    from repro.calibrate import calibrate_and_validate, simulate_trace
    from repro.core import capacity

    true = dataclasses.replace(capacity.TABLE5_PARAMS, p=2)
    traces = [simulate_trace(jax.random.PRNGKey(i), lam, n_queries, true)
              for i, lam in enumerate([10.0, 18.0])]
    cal, report = calibrate_and_validate(
        traces, n_windows=6, holdout_fraction=0.3, n_iters=n_iters,
        simulator_queries=simulator_queries)
    if verbose:
        print(report.summary())
    assert 0.0 < float(cal.alpha) < 1.0, float(cal.alpha)
    assert report.mean_rel_err_vs_sim < 0.5, report.mean_rel_err_vs_sim
    return cal, report


if __name__ == "__main__":
    run_smoke()
    print("calibrate smoke: OK")
