"""Quickstart: the paper's methodology in 60 seconds.

Build a small vertical search engine, characterize its workload, measure
one index server, parameterize the queueing model, and answer the
manager's three questions (paper Sec 5).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import capacity, queueing
from repro.engine import corpus as corpus_lib
from repro.engine import index as index_lib
from repro.engine import server
from repro.workloadgen import querygen

# 1. A synthetic collection with TodoBR-like statistics (Sec 4).
print("== building corpus + inverted index ==")
ccfg = corpus_lib.CorpusConfig(n_docs=5000, vocab_size=3000,
                               mean_doc_len=50, seed=0)
corp = corpus_lib.generate_corpus(ccfg)
idx = index_lib.build_index(corp)
print(f"   {corp.n_docs} docs, {idx.n_postings} postings, "
      f"{idx.index_bytes() / 2**20:.1f} MiB index")

# 2. A Zipf query workload (query alpha = 0.82, term alpha = 0.98).
uni = querygen.build_universe(querygen.WorkloadConfig(
    "demo", n_unique_queries=1000, vocab_size=3000, seed=0))
_, qterms = querygen.sample_query_stream(uni, 512)

# 3. Measure ONE index server (the paper's small-scale experiment).
print("== measuring one index server ==")
srv = server.IndexServer(idx, k_local=10)
params = server.measure_service_params(
    srv, np.tile(qterms, (2, 1)), cache_bytes=idx.index_bytes() // 5,
    p=8, s_broker=0.3e-3, batch=64)
s = float(queueing.service_time_server(params))
print(f"   hit={float(params.hit):.2f}  S_server={s * 1e3:.2f} ms")

# 4. Answer the manager's questions (Sec 5: questions i-iii).
lam = 0.5 / s
lo, hi = queueing.response_time_bounds(lam, params)
print(f"Q1  At {lam:.0f} qps on p=8 servers: "
      f"{float(lo) * 1e3:.1f} ms <= R <= {float(hi) * 1e3:.1f} ms")

fast = queueing.ServerParams(
    p=8, s_broker=params.s_broker, s_hit=params.s_hit / 2,
    s_miss=params.s_miss / 2, s_disk=params.s_disk, hit=params.hit)
_, hi2 = queueing.response_time_bounds(lam, fast)
print(f"Q2  2x faster CPUs would cut the bound to "
      f"{float(hi2) * 1e3:.1f} ms")

plan = capacity.plan_capacity(params, target_rate=20 * lam,
                              slo_seconds=float(hi) * 1.1)
print(f"Q3  To serve {20 * lam:.0f} qps under a "
      f"{float(hi) * 1.1 * 1e3:.0f} ms SLO: {plan.n_replicas} replicas "
      f"x {plan.servers_per_replica} servers "
      f"({plan.total_servers} total), each at "
      f"{plan.per_replica_rate_qps:.1f} qps")
