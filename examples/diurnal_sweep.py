"""Plan for the daily peak, not the daily average.

The paper's Section 4.2 shows query traffic is Poisson only *within* a
stable window — across a day the rate swings by ~4x.  The streaming
simulation core makes that load class first-class: an `ArrivalProcess`
profile modulates every scenario's arrival rate chunk by chunk, and the
streaming histogram gives p95/p99 surfaces next to the means.

This example answers the new planning question directly: for the Table 5
workload, what is the cheapest server count whose **p95 survives the
diurnal peak**, versus the cheaper answer you get by (mis)planning
against the **mean under stationary load** at the same average rate?

Run:  PYTHONPATH=src python examples/diurnal_sweep.py
"""

import jax
import jax.numpy as jnp

from repro.core import capacity, planner, sweep
from repro.workloadgen import loadgen

MS = 1e3
SLO = 0.8          # seconds
N_QUERIES = 40_000

lam = jnp.asarray([14.0, 20.0])            # time-AVERAGED rates (qps)
grid = sweep.SweepGrid.build(
    lam=lam,
    p=jnp.asarray([4.0, 8.0]),
    cpu=jnp.asarray([1.0, 2.0, 4.0]),
    base=capacity.TABLE5_PARAMS,
    hit=jnp.asarray([0.17]),
    broker_from_p=False,
)
cost = sweep.default_config_cost

key = jax.random.PRNGKey(0)

print("== Frontier 1: stationary load, mean response <= SLO ==")
_, fr_mean = planner.plan_over_grid(
    grid, SLO, simulate=True, key=key, n_queries=N_QUERIES, cost_fn=cost)
for i in range(lam.shape[0]):
    print("  ", fr_mean.describe(i))

print("\n== Frontier 2: diurnal load (4x peak/trough), p95 <= SLO ==")
profile = loadgen.diurnal_rates(1.0)       # weekly hourly curve, relative
# compress the week so the simulated horizon covers multiple full cycles
horizon_s = N_QUERIES / float(lam[0])
bin_s = horizon_s / profile.shape[0] / 4
res95, fr_p95 = planner.plan_over_grid(
    grid, SLO, simulate=True, key=key, n_queries=N_QUERIES, cost_fn=cost,
    quantile=0.95, profile=profile, profile_bin_seconds=bin_s)
for i in range(lam.shape[0]):
    print("  ", fr_p95.describe(i))

print("\n== The gap ==")
for i in range(lam.shape[0]):
    c_mean, c_p95 = float(fr_mean.cost[i]), float(fr_p95.cost[i])
    print(f"  lam={float(lam[i]):g} qps: mean-planning costs "
          f"{c_mean:g}; surviving the daily peak at p95 costs {c_p95:g}"
          + ("  <- UNDER-PROVISIONED by mean-planning"
             if c_p95 > c_mean else ""))

print("\np95 surface along cpu speedup (lam = {:.0f} qps, p=4, diurnal):"
      .format(float(lam[1])))
p95 = res95.quantile(0.95)
for j in range(grid.cpu.shape[0]):
    v = float(p95[1, 0, j, 0, 0, 0]) * MS   # trailing axis: r = 1 replica
    print(f"  cpu x{float(grid.cpu[j]):g}: p95 = {v:7.1f} ms "
          + ("(meets SLO)" if v <= SLO * MS else ""))
