"""Which autoscaler config is cheapest under the p95 SLO?

The paper sizes a FIXED fleet for the peak (Section 6); a real vertical
deployment scales replicas against load and pays for replica-seconds,
not peak replicas.  This example sweeps `AutoscalePolicy` configs —
(min_r, max_r, utilization trigger, stabilization window) — as a grid
axis over a diurnal + flash-crowd week and extracts the cheapest policy
whose p95 survives, then cross-checks it against the static-r plan the
paper would buy: the autoscaled fleet must meet the same SLO with fewer
replica-seconds.

The "week" is time-compressed (a few seconds per hourly bin) so the
whole diurnal + crowd shape fits in a tractable query budget; policy
decision intervals are scaled to match.

Run:  PYTHONPATH=src python examples/autoscale_sweep.py [--quick]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.core import planner, simulator, sweep
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec
from repro.core.queueing import ServerParams
from repro.launch.elastic import AutoscalePolicy
from repro.obs.timeline import TelemetrySpec
from repro.workloadgen import loadgen

ap = argparse.ArgumentParser()
ap.add_argument("--quick", action="store_true",
                help="CI smoke mode: fewer queries, fewer policies")
args = ap.parse_args()

MS = 1e3
SLO = 0.65                     # p95 objective (s)
LAM = [15.0, 30.0]             # time-averaged total qps
BIN_S = 2.0                    # seconds per "hour" of the compressed week
N_Q = 8_000 if args.quick else 80_000
CHUNK = 64                     # small: every ~2s profile bin gets sampled

# a small Table-5-flavored cluster (p=4) so one replica saturates inside
# the sweep's rates and the policy axis has real work to do
PARAMS = ServerParams(p=4, s_broker=0.004, s_hit=0.0125, s_miss=0.05,
                      s_disk=0.04, hit=0.5)

# -- the load: a diurnal week with a flash crowd on Wednesday 15:00 -----
week = loadgen.diurnal_rates(1.0, peak_to_trough=3.0)      # (168,) hourly
crowd_hour = 2 * 24 + 15
week = week.at[crowd_hour].mul(2.5)                        # the crowd
profile = week / jnp.mean(week)                            # mean-1 curve

# -- the policy grid: (min_r, max_r, trigger, stabilization window) ------
# decision interval ~= one compressed hour; stabilization counts intervals
policies = tuple(
    AutoscalePolicy(min_r=mn, max_r=mx, target_utilization=trig,
                    decision_interval_seconds=BIN_S,
                    stabilization_intervals=stab)
    for mn in (1,)
    for mx in ((4,) if args.quick else (2, 4))
    for trig in ((0.6, 0.8) if args.quick else (0.45, 0.6, 0.75))
    for stab in (2, 6)
)
print(f"== {len(policies)} autoscaler configs x {len(LAM)} rates over a "
      f"diurnal + flash-crowd week (p95 <= {SLO * MS:.0f} ms) ==")

grid = sweep.SweepGrid.build(lam=LAM, p=[4.0], hit=[PARAMS.hit],
                             base=PARAMS, broker_from_p=False,
                             autoscale=policies)
_, frontier = planner.plan_over_grid(
    grid, SLO, simulate=True, quantile=0.95, n_queries=N_Q,
    profile=profile, profile_bin_seconds=BIN_S, chunk_size=CHUNK,
    cluster=ClusterSpec(routing="jsq"), key=jax.random.PRNGKey(7))
for i in range(len(LAM)):
    print("  ", frontier.describe(i))

# -- cross-check: the static-r fleet the paper would buy ----------------
static = sweep.SweepGrid.build(lam=LAM, p=[4.0], hit=[PARAMS.hit],
                               base=PARAMS, broker_from_p=False,
                               r=[1.0, 2.0, 3.0, 4.0])
_, static_front = planner.plan_over_grid(
    static, SLO, simulate=True, quantile=0.95, n_queries=N_Q,
    profile=profile, profile_bin_seconds=BIN_S, chunk_size=CHUNK,
    cluster=ClusterSpec(routing="jsq"), key=jax.random.PRNGKey(7))

print("\n== Elastic vs static at equal SLO compliance ==")
for i, lam in enumerate(LAM):
    if not (bool(frontier.feasible[i]) and bool(static_front.feasible[i])):
        print(f"  lam={lam:g}: infeasible somewhere "
              f"(elastic {bool(frontier.feasible[i])}, "
              f"static {bool(static_front.feasible[i])})")
        continue
    eff = float(frontier.r[i])            # mean active replicas
    stat = float(static_front.r[i])       # peak-provisioned replicas
    saved = (1.0 - eff / stat) * 100.0
    verdict = "OK" if eff <= stat + 1e-6 else "WORSE (unexpected)"
    print(f"  lam={lam:g} qps: autoscaled {eff:.2f} replica-s/s vs "
          f"static r={stat:.0f} -> {saved:.0f}% replica-seconds saved "
          f"[{verdict}]")

# -- the winning policy's trajectory through the week -------------------
i = len(LAM) - 1
winner = frontier.autoscale[i]
arrival = ArrivalProcess.piecewise(float(LAM[i]) * profile, BIN_S)
res = simulator.simulate_fork_join(
    jax.random.PRNGKey(11), arrival, N_Q, PARAMS, chunk_size=CHUNK,
    cluster=ClusterSpec(routing="jsq", autoscale=winner),
    telemetry=TelemetrySpec(n_bins=28))
tl = res.timeline
act = jnp.where(tl.count > 0, tl.active_replicas, jnp.nan)
print(f"\n== Active-replica trajectory (lam={LAM[i]:g}, policy "
      f"{winner.min_r}..{winner.max_r}@{winner.target_utilization:.0%}, "
      f"stab={winner.stabilization_intervals}) ==")
blocks = " .:-=+*#"
lo, hi = 1.0, float(winner.max_r)
cells = []
for v in [float(x) for x in act]:
    if v != v:                            # NaN: bin saw no arrivals
        cells.append(" ")
        continue
    t = (v - lo) / max(hi - lo, 1e-9)
    cells.append(blocks[min(len(blocks) - 1,
                            max(0, int(t * (len(blocks) - 1) + 0.5)))])
print("  fleet  |" + "".join(cells) + f"|  ({lo:.0f}..{hi:.0f} replicas)")
print(f"  mean active {float(res.mean_active_replicas):.2f} of "
      f"{winner.max_r} provisioned; replica-seconds "
      f"{float(res.replica_seconds):.0f} over "
      f"{float(res.elapsed_seconds):.0f} s")
