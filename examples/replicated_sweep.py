"""Replicate, upgrade, or cache?  The Section-6 scale-out question as
one frontier extraction.

The paper sizes replicated clusters analytically (``replicas_needed``,
Eq 8 for the result cache).  The replicated simulation layer lets the
same question be answered three ways on one grid —

  * buy REPLICAS of the cheap memory-1x cluster,
  * buy the memory-4x UPGRADE and replicate less,
  * keep memory-1x but add a broker RESULT CACHE (Eq 8),

— and then cross-checks the winning plan mechanistically: the replicated
streaming simulator runs the chosen topology under join-shortest-queue
routing and a flash-crowd arrival profile, reporting the p95 the
analytical path cannot see.

Run:  PYTHONPATH=src python examples/replicated_sweep.py
"""

import jax
import jax.numpy as jnp

from repro.core import capacity, planner, simulator, sweep
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec

# The H_100 join tax puts the memory-1x cluster's latency FLOOR at
# ~520 ms (the paper's "baseline is infeasible even at very low rates"),
# so the constraint must sit above it for replication to compete at all.
SLO = 0.650
MS = 1e3
LAM = jnp.asarray([10.0, 20.0, 40.0])        # total qps to serve
REPLICAS = jnp.arange(1.0, 13.0)

print(f"== Cheapest way to serve under R <= {SLO * MS:.0f} ms ==")
strategies = {
    "replicate memory-1x":
        sweep.SweepGrid.build(lam=LAM, p=[100.0], memory=1, r=REPLICAS),
    "upgrade to memory-4x":
        sweep.SweepGrid.build(lam=LAM, p=[100.0], memory=4, r=REPLICAS),
    "memory-1x + result cache":
        sweep.SweepGrid.build(lam=LAM, p=[100.0], memory=1, r=REPLICAS,
                              result_cache=(0.3, 2e-3)),
}
frontiers = {}
for name, grid in strategies.items():
    _, frontier = planner.plan_over_grid(grid, SLO)
    frontiers[name] = frontier
    print(f"\n  {name}:")
    for i in range(LAM.shape[0]):
        print("   ", frontier.describe(i))

print("\n== Head to head (cost per total arrival rate) ==")
for i in range(LAM.shape[0]):
    costs = {n: float(f.cost[i]) if bool(f.feasible[i]) else float("inf")
             for n, f in frontiers.items()}
    best = min(costs, key=costs.get)
    row = "  ".join(f"{n}: {c:7.1f}" for n, c in costs.items())
    print(f"  lam={float(LAM[i]):5.0f} qps  {row}   -> {best}")

print("\n== Mechanistic cross-check of the analytical plan ==")
target, slo = 40.0, SLO
params = capacity.scenario_params(memory=4, p=100)
plan = capacity.plan_capacity(params, target, slo, simulate=True,
                              cluster=ClusterSpec(routing="jsq"),
                              key=jax.random.PRNGKey(0))
print(f"  replicas_needed -> {plan.n_replicas} replicas x "
      f"{plan.servers_per_replica} servers "
      f"(util {plan.utilization:.2f}); Eq 7 upper "
      f"{plan.response_upper_ms:.0f} ms")
print(f"  simulated (jsq dispatch, full {target:.0f} qps): mean "
      f"{plan.response_simulated_ms:.0f} ms, p95 "
      f"{plan.response_simulated_p95_ms:.0f} ms")

print("\n== The same topology under a 3x flash crowd ==")
# the stationary plan saturates during the burst (3x load on replicas
# sized for 1x); provisioning replicas for the PEAK restores the tail
crowd = ArrivalProcess.flash_crowd(
    target, burst_starts=[600.0], burst_seconds=300.0,
    burst_multiplier=3.0, period_seconds=1800.0, bin_seconds=60.0)
for r in (plan.n_replicas, 3 * plan.n_replicas):
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), crowd, 150_000, params,
        cluster=ClusterSpec(r=r, routing="jsq"), chunk_size=1024)
    tag = "planned" if r == plan.n_replicas else "peak-provisioned"
    print(f"  r={r} ({tag}): mean {float(res.mean_response) * MS:6.0f} ms,"
          f" p95 {float(res.quantile(0.95)) * MS:6.0f} ms "
          f"({'meets' if float(res.quantile(0.95)) <= slo else 'MISSES'} "
          f"the SLO at p95)")
