"""Simulate fork-join clusters at the scale the paper left as future work.

Sweeps cluster sizes p = 8 .. 1024 under the Table-5 workload and shows
where the measured (simulated) response sits between Eq 7's bounds for
the three service regimes: the model's iid-exponential assumption, the
mechanistic disk-cache mixture, and the prior-work "balanced" assumption.

Run:  PYTHONPATH=src python examples/simulate_cluster.py [--queries 40000]
"""

import argparse
import dataclasses
import time

import jax

from repro.core import capacity, queueing, simulator


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=40_000)
    ap.add_argument("--lam", type=float, default=15.0)
    args = ap.parse_args()

    print(f"{'p':>5s} {'lower':>8s} {'upper':>8s} | "
          f"{'exp':>8s} {'cache':>8s} {'balanced':>9s} {'wall_s':>7s}")
    for p in (8, 32, 128, 512, 1024):
        pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=p)
        lo, hi = queueing.response_time_bounds(args.lam, pr)
        t0 = time.time()
        sims = {}
        for mode in ("exponential", "cache", "balanced"):
            res = simulator.simulate_fork_join(
                jax.random.PRNGKey(p), args.lam, args.queries, pr,
                mode=mode)
            sims[mode] = float(res.mean_response)
        dt = time.time() - t0
        print(f"{p:5d} {float(lo):8.3f} {float(hi):8.3f} | "
              f"{sims['exponential']:8.3f} {sims['cache']:8.3f} "
              f"{sims['balanced']:9.3f} {dt:7.1f}")

    print("\nReading: 'balanced' (the Chowdhury & Pass assumption) hugs the"
          "\nlower bound at every scale — the paper's point that ignoring"
          "\nservice-time imbalance underestimates response time by up to"
          "\nthe H_p factor; the exponential regime approaches the upper"
          "\nbound as p grows.")


if __name__ == "__main__":
    main()
