"""End-to-end system test: the paper's full methodology on a small world.

Build corpus -> partition -> measure one server -> parameterize the model
-> validate against the DES -> produce a capacity plan.  This is the
entire paper pipeline in one test.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capacity, queueing, simulator
from repro.engine import corpus as corpus_lib
from repro.engine import index as index_lib
from repro.engine import server
from repro.workloadgen import querygen


def test_full_methodology_end_to_end():
    # 1. workload + collection (Sec 4)
    ccfg = corpus_lib.CorpusConfig(n_docs=3000, vocab_size=2000,
                                   mean_doc_len=40, seed=0)
    corp = corpus_lib.generate_corpus(ccfg)
    idx = index_lib.build_index(corp)
    wl = querygen.WorkloadConfig("t", n_unique_queries=800,
                                 vocab_size=2000, seed=0)
    uni = querygen.build_universe(wl)
    _, qterms = querygen.sample_query_stream(uni, 512)

    # 2. measure one index server (Sec 5.3 methodology)
    srv = server.IndexServer(idx, k_local=10)
    params = server.measure_service_params(
        srv, np.tile(qterms, (2, 1)), cache_bytes=idx.index_bytes() // 5,
        p=8, s_broker=0.2e-3, batch=64)

    # 3. model predicts; DES "measures" (replacing the paper's cluster)
    s = float(queueing.service_time_server(params))
    lam = 0.6 / s
    lo, hi = queueing.response_time_bounds(lam, params)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(0), lam, 60_000, params, mode="exponential")
    m = float(res.mean_response)
    assert float(lo) * 0.9 < m < float(hi) * 1.1

    # 4. capacity plan (Sec 6): target 10x the single-cluster rate; the
    # relaxed SLO (1.2x) lets each replica run slightly hotter than lam,
    # so 8-10 replicas are expected.
    plan = capacity.plan_capacity(params, target_rate=10 * lam,
                                  slo_seconds=float(hi) * 1.2)
    assert 5 <= plan.n_replicas <= 10
    assert plan.response_upper_ms <= float(hi) * 1.2 * 1e3 + 1e-3
