"""Tests for the observability layer (ISSUE 8).

Four contracts, each mapped to an acceptance criterion:

* zero-cost opt-out — ``telemetry=None`` leaves every base statistic
  BITWISE identical (telemetry draws no RNG and adds carry state only
  when a spec is present);
* conservation — per-bin tallies telescope exactly: counts sum to
  n_queries, trace-binned busy-seconds sum to the trace's totals,
  independent of n_bins and chunking;
* operational laws — U = X * S and L = lambda * W hold per bin as
  identities (float rounding only) and statistically against the
  analytic service time on a stationary M/M/c-style scenario;
* span traces — Chrome-trace JSON round-trips the schema validator,
  which in turn rejects tampered traces.
"""

from __future__ import annotations

import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, simulator, sweep
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec
from repro.core.queueing import ServerParams, service_time_server
from repro.obs import DEFAULT_TIMELINE_BINS, TelemetrySpec, Timeline
from repro.obs import profile as obs_profile
from repro.obs import report as obs_report
from repro.obs import trace_export
from repro.obs.timeline import timeline_from_trace

PARAMS = capacity.TABLE5_PARAMS
KEY = jax.random.PRNGKey(0)


def _base_stats(res):
    return {f: np.asarray(getattr(res, f))
            for f in ("count", "sum_response", "sumsq_response",
                      "sum_broker", "sum_cluster", "sum_server", "hist",
                      "tap_response")}


# --------------------------------------------------------------------------
# zero-cost opt-out
# --------------------------------------------------------------------------

def test_telemetry_none_returns_no_timeline():
    res = simulator.simulate_fork_join(KEY, 20.0, 2_000, PARAMS)
    assert res.timeline is None


@pytest.mark.parametrize("kwargs", [
    dict(),
    dict(r=3, routing="jsq", result_cache=(0.2, 2e-3)),
    dict(r=2, routing="round_robin", tap_size=8),
])
def test_telemetry_leaves_base_stats_bitwise_identical(kwargs):
    """The acceptance criterion: telemetry on/off draws the same RNG
    stream and produces bit-identical base statistics."""
    plain = simulator.simulate_fork_join(KEY, 24.0, 12_000, PARAMS,
                                         chunk_size=1024, **kwargs)
    teled = simulator.simulate_fork_join(
        KEY, 24.0, 12_000, PARAMS, chunk_size=1024,
        telemetry=TelemetrySpec(n_bins=16, slo_seconds=0.5), **kwargs)
    for f, a in _base_stats(plain).items():
        b = np.asarray(getattr(teled, f))
        np.testing.assert_array_equal(a, b, err_msg=f)
    assert teled.timeline is not None


# --------------------------------------------------------------------------
# conservation
# --------------------------------------------------------------------------

@pytest.mark.parametrize("chunk", [64, 1000, 4096])
def test_counts_conserved_across_chunkings(chunk):
    n_q = 9_000
    res = simulator.simulate_fork_join(
        KEY, 30.0, n_q, PARAMS, chunk_size=chunk,
        cluster=ClusterSpec(r=2), telemetry=TelemetrySpec(n_bins=12))
    tl = res.timeline
    assert float(jnp.sum(tl.count)) == float(n_q)
    assert float(jnp.sum(tl.replica_count)) == float(n_q)


def test_totals_independent_of_n_bins():
    """Same chunking, different bin counts: the per-chunk prefix sums
    telescope, so every total is conserved (f32 re-summation only)."""
    def totals(n_bins):
        tl = simulator.simulate_fork_join(
            KEY, 24.0, 10_000, PARAMS, chunk_size=1024,
            cluster=ClusterSpec(r=2, routing="jsq",
                                result_cache=(0.2, 2e-3)),
            telemetry=TelemetrySpec(n_bins=n_bins, slo_seconds=0.3),
        ).timeline
        return {f: float(jnp.sum(getattr(tl, f)))
                for f in ("count", "resp_sum", "busy_broker",
                          "busy_server", "replica_count", "hit_count",
                          "slo_count")}

    a, b = totals(4), totals(64)
    for f in a:
        np.testing.assert_allclose(a[f], b[f], rtol=1e-5, err_msg=f)


def test_trace_binned_busy_equals_trace_totals():
    """timeline_from_trace conservation: per-bin busy sums equal the
    TraceRecord's busy totals for any bin count."""
    from repro.calibrate.measure import simulate_trace

    true = dataclasses.replace(PARAMS, p=4)
    tr = simulate_trace(jax.random.PRNGKey(3), 15.0, 4_000, true)
    for n_bins in (1, 7, 64):
        tl = tr.to_timeline(TelemetrySpec(n_bins=n_bins))
        assert isinstance(tl, Timeline)
        np.testing.assert_allclose(
            float(jnp.sum(tl.busy_server)),
            float(jnp.sum(tr.server_busy)), rtol=1e-5)
        np.testing.assert_allclose(
            float(jnp.sum(tl.busy_broker)),
            float(jnp.sum(tr.broker_busy)), rtol=1e-5)
        np.testing.assert_allclose(
            float(jnp.sum(tl.resp_sum)),
            float(jnp.sum(tr.response)), rtol=1e-5)
        assert float(jnp.sum(tl.count)) == float(tr.n_queries)


def test_fused_and_masked_engines_agree_on_timelines():
    spec = TelemetrySpec(n_bins=8, slo_seconds=0.4)
    kw = dict(chunk_size=512, telemetry=spec)
    tf = simulator.simulate_fork_join(
        KEY, 20.0, 6_000, PARAMS,
        cluster=ClusterSpec(r=2, replica_impl="fused"), **kw).timeline
    tm = simulator.simulate_fork_join(
        KEY, 20.0, 6_000, PARAMS,
        cluster=ClusterSpec(r=2, replica_impl="masked"), **kw).timeline
    for f in ("count", "resp_sum", "busy_broker", "busy_server",
              "replica_count", "slo_count"):
        np.testing.assert_allclose(
            np.asarray(getattr(tf, f)), np.asarray(getattr(tm, f)),
            rtol=1e-4, atol=1e-4, err_msg=f)


def test_slo_zero_counts_everything():
    tl = simulator.simulate_fork_join(
        KEY, 20.0, 4_000, PARAMS,
        telemetry=TelemetrySpec(n_bins=8, slo_seconds=0.0)).timeline
    np.testing.assert_allclose(np.asarray(tl.slo_count),
                               np.asarray(tl.count))


# --------------------------------------------------------------------------
# operational laws
# --------------------------------------------------------------------------

def _stationary_timeline(lam, n_q=30_000, n_bins=16):
    return simulator.simulate_fork_join(
        KEY, lam, n_q, PARAMS, chunk_size=2048,
        telemetry=TelemetrySpec(n_bins=n_bins)).timeline


def test_oplaw_identities_per_bin():
    """U = X*S and L = lambda*W recomputed from the accumulators are
    identities — float rounding only (the dashboard's self-check)."""
    tl = _stationary_timeline(lam=24.0)
    report, worst = obs_report.oplaw_check(tl)
    assert worst < 1e-6, report


def test_utilization_law_statistical_mmc():
    """On a stationary scenario, mid-horizon per-server utilization must
    match the analytic U = lambda * S / 1 (each query visits every
    server) within sampling tolerance."""
    s_server = float(service_time_server(PARAMS))
    lam = 0.6 / s_server                      # target utilization 0.6
    tl = _stationary_timeline(lam=lam)
    util = np.asarray(tl.utilization)[..., 0, :]       # (B, p)
    mid = util[3:-3].mean()
    np.testing.assert_allclose(mid, 0.6, rtol=0.15)


def test_littles_law_statistical():
    """L = lambda * W with L and W measured independently per bin."""
    tl = _stationary_timeline(lam=24.0)
    depth = np.asarray(tl.queue_depth)[3:-3]
    lam_w = (np.asarray(tl.throughput)
             * np.asarray(tl.mean_response))[3:-3]
    np.testing.assert_allclose(depth, lam_w, rtol=1e-5)
    # and against the configured arrival rate * mean response
    w = np.asarray(tl.mean_response)[3:-3].mean()
    np.testing.assert_allclose(depth.mean(), 24.0 * w, rtol=0.2)


def test_sweep_simulated_threads_telemetry():
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([15.0, 25.0]), p=jnp.asarray([4.0]),
        base=dataclasses.replace(PARAMS, p=4), broker_from_p=False)
    res = sweep.sweep_simulated(grid, KEY, n_queries=2_000,
                                chunk_size=512,
                                telemetry=TelemetrySpec(n_bins=6))
    tl = res.stats.timeline
    assert tl is not None
    # leaves carry the full (L,P,C,D,H,R) grid shape in front
    assert tl.count.shape == (2, 1, 1, 1, 1, 1, 6)
    assert tl.busy_server.shape == (2, 1, 1, 1, 1, 1, 6, 1, 4)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(tl.count, axis=-1)).ravel(),
        [2_000.0, 2_000.0])
    # without a spec the sweep stays timeline-free
    plain = sweep.sweep_simulated(grid, KEY, n_queries=500,
                                  chunk_size=512)
    assert plain.stats.timeline is None


# --------------------------------------------------------------------------
# span traces
# --------------------------------------------------------------------------

def _flash_spans(n=400, r=3):
    proc = ArrivalProcess.flash_crowd(
        20.0, burst_starts=5.0, burst_seconds=4.0, burst_multiplier=4.0,
        period_seconds=20.0, bin_seconds=1.0)
    return trace_export.simulate_spans(KEY, proc, n, PARAMS, r=r,
                                       routing="jsq")


def test_chrome_trace_roundtrip_validates(tmp_path):
    spans = _flash_spans()
    path = trace_export.export_chrome_trace(spans, tmp_path / "t.json")
    counts = trace_export.validate_chrome_trace(path)
    # every query: 1 broker span + p server spans, one b/e pair
    p = int(PARAMS.p)
    assert counts["X"] == spans.n_queries * (p + 1)
    assert counts["b"] == counts["e"] == spans.n_queries
    assert counts["async_pairs"] == spans.n_queries
    assert counts["lanes"] <= 3 * (p + 1)
    obj = json.loads((tmp_path / "t.json").read_text())
    assert obj["displayTimeUnit"] == "ms"


def test_validator_rejects_tampered_traces():
    events = _flash_spans(n=50, r=1).to_events()
    # unbalanced async pair
    broken = [e for e in events if not (e["ph"] == "e"
                                        and e.get("id") == 0)]
    with pytest.raises(ValueError, match="unbalanced"):
        trace_export.validate_chrome_trace({"traceEvents": broken})
    # overlapping spans on one FCFS lane
    lanes = [e for e in events if e["ph"] == "X"]
    clone = dict(lanes[0])
    clone["ts"] = lanes[0]["ts"] - (lanes[0]["dur"] + 10_000.0)
    clone["dur"] = 10 * (lanes[0]["dur"] + 10_000.0)
    with pytest.raises(ValueError, match="overlap"):
        trace_export.validate_chrome_trace(
            {"traceEvents": events + [clone]})
    with pytest.raises(ValueError, match="traceEvents"):
        trace_export.validate_chrome_trace({"events": []})


def test_spans_from_trace_bridges_measured_records():
    from repro.calibrate.measure import simulate_trace

    true = dataclasses.replace(PARAMS, p=4)
    tr = simulate_trace(jax.random.PRNGKey(5), 12.0, 300, true)
    spans = trace_export.spans_from_trace(tr)
    assert spans.n_queries == tr.n_queries and spans.p == 4
    trace_export.validate_chrome_trace(
        {"traceEvents": spans.to_events()})


# --------------------------------------------------------------------------
# profiling hooks + roofline
# --------------------------------------------------------------------------

def test_profile_jit_records_cost_and_memory():
    rec = obs_profile.profile_jit(
        lambda a, b: a @ b, jnp.ones((64, 64)), jnp.ones((64, 64)),
        name="matmul", n_runs=2)
    assert rec.name == "matmul"
    assert rec.compile_s > 0.0 and rec.run_s > 0.0
    assert rec.flops > 0.0 and rec.peak_bytes > 0.0
    d = rec.to_json()
    rt = obs_profile.ProfileRecord.from_json(d)
    assert rt == rec and d["peak_bytes"] == rec.peak_bytes


def test_profile_jit_n_runs_zero_skips_execution():
    rec = obs_profile.profile_jit(lambda x: x * 2.0, jnp.ones((8,)),
                                  n_runs=0)
    assert rec.run_s == 0.0 and rec.compile_s > 0.0


def test_profile_kernels_and_roofline_table():
    from repro.roofline.report import kernel_roofline

    recs = obs_profile.profile_kernels(rows=8, cols=256, n_runs=0)
    names = {r.name for r in recs}
    assert names == {"maxplus_scan", "maxplus_segment_scan"}
    table = kernel_roofline(recs)
    for name in names:
        assert name in table
    assert "memory" in table or "compute" in table
    # dict form (as read back from BENCH_obs.json) renders identically
    assert kernel_roofline([r.to_json() for r in recs]) == table


# --------------------------------------------------------------------------
# dashboard helpers
# --------------------------------------------------------------------------

def test_report_renders_and_sparkline_handles_nan():
    assert obs_report.sparkline([0.0, float("nan"), 1.0]) == "▁ █"
    tl = simulator.simulate_fork_join(
        KEY, 20.0, 3_000, PARAMS,
        cluster=ClusterSpec(r=2, result_cache=(0.3, 1e-3)),
        telemetry=TelemetrySpec(n_bins=8, slo_seconds=0.2)).timeline
    panel = obs_report.render_timeline(tl, "unit")
    for needle in ("throughput", "imbalance", "cache hits",
                   "SLO viol frac"):
        assert needle in panel
    prof = obs_report.render_profiles(
        [obs_profile.ProfileRecord("k", 1.0, 0.1, 1e6, 1e6, 1.0, 2.0,
                                   3.0)])
    assert "k" in prof


def test_telemetry_spec_validation_and_defaults():
    assert TelemetrySpec().n_bins == DEFAULT_TIMELINE_BINS
    with pytest.raises(ValueError, match="at least one bin"):
        TelemetrySpec(n_bins=0)
    # hashable => usable as a jit static argument
    assert hash(TelemetrySpec()) == hash(TelemetrySpec())


def test_telemetry_horizon_override():
    spec = TelemetrySpec(n_bins=10, horizon_seconds=100.0)
    tl = simulator.simulate_fork_join(KEY, 20.0, 1_000, PARAMS,
                                      telemetry=spec).timeline
    np.testing.assert_allclose(float(tl.bin_seconds), 10.0)
    assert float(jnp.sum(tl.count)) == 1_000.0


# --------------------------------------------------------------------------
# hypothesis properties (guarded like tests/test_calibrate.py so the
# rest of the module runs without hypothesis)
# --------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @given(n_bins=st.integers(1, 97), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_binned_totals_conserved(n_bins, seed):
        """PROPERTY: binning is a partition — per-bin sums of any
        per-query quantity add back to the trace total, for ANY bin
        count."""
        rng = np.random.default_rng(seed)
        n, p = 257, 3
        arrival = np.cumsum(rng.random(n).astype(np.float32) * 0.1)
        response = rng.random(n).astype(np.float32)
        server_busy = rng.random((n, p)).astype(np.float32) * 0.05
        broker_busy = rng.random(n).astype(np.float32) * 0.01
        tl = timeline_from_trace(
            arrival - arrival[0], response, TelemetrySpec(n_bins=n_bins),
            broker_busy=broker_busy, server_busy=server_busy)
        assert float(jnp.sum(tl.count)) == float(n)
        np.testing.assert_allclose(float(jnp.sum(tl.resp_sum)),
                                   response.sum(), rtol=1e-4)
        np.testing.assert_allclose(float(jnp.sum(tl.busy_server)),
                                   server_busy.sum(), rtol=1e-4)
        np.testing.assert_allclose(float(jnp.sum(tl.busy_broker)),
                                   broker_busy.sum(), rtol=1e-4)

    @given(n_bins=st.integers(2, 64), seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_oplaws_hold_for_any_binning(n_bins, seed):
        """PROPERTY: U = X*S and L = lambda*W are identities of the
        binned accumulators regardless of bin count."""
        rng = np.random.default_rng(seed)
        n = 211
        arrival = np.cumsum(rng.random(n).astype(np.float32) * 0.2)
        response = rng.random(n).astype(np.float32)
        server_busy = rng.random((n, 2)).astype(np.float32) * 0.05
        tl = timeline_from_trace(
            arrival - arrival[0], response, TelemetrySpec(n_bins=n_bins),
            broker_busy=np.zeros(n, np.float32), server_busy=server_busy)
        _, worst = obs_report.oplaw_check(tl)
        assert worst < 1e-5
else:
    @pytest.mark.skip(reason="property tests need hypothesis (see "
                      "pyproject [project.optional-dependencies].test)")
    def test_property_binned_totals_conserved():
        pass

    @pytest.mark.skip(reason="property tests need hypothesis (see "
                      "pyproject [project.optional-dependencies].test)")
    def test_property_oplaws_hold_for_any_binning():
        pass
