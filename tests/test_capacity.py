"""Section-6 case-study reproduction: the paper's own numbers."""

import numpy as np
import pytest

from repro.core import capacity, queueing


def test_broker_fit_345ms_at_p100():
    """Paper: S_broker = 3.45 ms for p = 100."""
    assert np.isclose(float(capacity.broker_service_time(100)) * 1e3, 3.45,
                      atol=0.02)


def test_scenario4_286ms_at_56qps():
    """Paper Scenario 4: upper bound 286 ms at 56 queries/second."""
    p4 = capacity.scenario("memory+cpus+disks")
    _, hi = queueing.response_time_bounds(56.0, p4)
    assert abs(float(hi) * 1e3 - 286.0) < 3.0


def test_scenario4_replication_4x100_for_200qps():
    """Paper: 4 replicas x 100 servers serve 200 qps within 300 ms."""
    p4 = capacity.scenario("memory+cpus+disks")
    plan = capacity.plan_capacity(p4, 200.0, 0.300)
    assert plan.n_replicas == 4
    assert plan.total_servers == 400
    assert plan.response_upper_ms < 300.0


def test_scenario6_result_cache_282ms_at_65qps():
    """Paper Scenario 6: with result caching, 65 qps at ~282 ms."""
    p4 = capacity.scenario("memory+cpus+disks")
    r = queueing.response_time_with_result_cache(65.0, p4, 0.5, 0.069e-3)
    assert abs(float(r) * 1e3 - 282.0) < 5.0
    # and 3 replicas support the paper's 195 qps (3 x 65)
    n, per = capacity.replicas_needed(p4, 195.0, 0.300,
                                      result_cache=(0.5, 0.069e-3))
    assert int(n) == 3


def test_scenario_ordering_matches_paper():
    """Fig 12: memory+disks < memory+cpus < cpus+disks < all three
    (in max sustainable rate under the 300 ms SLO)."""
    names = ["baseline", "memory+disks", "memory+cpus", "cpus+disks",
             "memory+cpus+disks"]
    rates = [float(capacity.max_rate_under_slo(capacity.scenario(n), 0.300))
             for n in names]
    assert rates[0] < 1e-3                       # baseline infeasible
    assert rates[1] < rates[2] < rates[3] < rates[4]


def test_memory_scaling_table6():
    """Paper Scenario 1: 4x memory -> hit x9, disk demand / 2.53."""
    ref = capacity.MEMORY_TABLE[1]
    mem4 = capacity.MEMORY_TABLE[4]
    assert np.isclose(mem4[3] / ref[3], 9.0, rtol=0.01)
    assert np.isclose(ref[2] / (mem4[2] / 1.0), 66.03 / 26.14, rtol=0.01)


def test_upgrade_grid_shape_and_monotonicity():
    grid = capacity.upgrade_grid(4.0, memory=1)
    g = np.asarray(grid)
    assert g.shape == (7, 7)
    assert (np.diff(g, axis=0) <= 1e-9).all()  # faster cpu -> lower R
    assert (np.diff(g, axis=1) <= 1e-9).all()  # faster disk -> lower R


def test_fig13_crossover_memory_flips_bottleneck():
    """Fig 13: at 1x memory disk speed dominates; at 4x memory CPU does."""
    lam = 4.0
    g1 = np.asarray(capacity.upgrade_grid(lam, memory=1))
    g4 = np.asarray(capacity.upgrade_grid(lam, memory=4))
    disk_gain_1 = g1[0, 0] - g1[0, -1]   # vary disk at slow cpu
    cpu_gain_1 = g1[0, 0] - g1[-1, 0]
    disk_gain_4 = g4[0, 0] - g4[0, -1]
    cpu_gain_4 = g4[0, 0] - g4[-1, 0]
    assert disk_gain_1 > cpu_gain_1      # 1x memory: disk-bound
    assert cpu_gain_4 > disk_gain_4      # 4x memory: cpu-bound


def test_slo_solver_is_exact_boundary():
    p4 = capacity.scenario("memory+cpus+disks")
    lam = capacity.max_rate_under_slo(p4, 0.300)
    _, at = queueing.response_time_bounds(float(lam), p4)
    _, above = queueing.response_time_bounds(float(lam) * 1.02, p4)
    assert float(at) <= 0.300 + 1e-5
    assert float(above) > 0.300
