"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cin_fuse import ops as cin_ops, ref as cin_ref
from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.embedding_bag import ops as bag_ops, ref as bag_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.maxplus_scan import ops as mp_ops, ref as mp_ref


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-3, atol=2e-3)


# -------------------------------------------------------------------- maxplus
@pytest.mark.parametrize("shape,blk", [
    ((4, 1024), 256), ((1, 37), 512), ((2, 3, 500), 128), ((8, 4096), 512),
])
def test_maxplus_scan_sweep(shape, blk):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    arr = jnp.cumsum(jax.random.exponential(k1, shape), -1)
    svc = jax.random.exponential(k2, shape)
    oa, ob = mp_ops.maxplus_scan(arr + svc, svc, block_len=blk)
    ra, rb = mp_ref.maxplus_scan_ref(arr + svc, svc)
    np.testing.assert_allclose(np.asarray(oa), np.asarray(ra), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ob), np.asarray(rb), rtol=1e-5)


def test_maxplus_ref_equals_sequential():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    a = jax.random.normal(k1, (3, 257))
    b = jax.random.exponential(k2, (3, 257))
    ra, rb = mp_ref.maxplus_scan_ref(a, b)
    sa, sb = mp_ref.maxplus_scan_sequential(a, b)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(sa), rtol=1e-5)


# ----------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 256, 4, 2, 64), (1, 512, 8, 8, 128), (2, 128, 4, 1, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, kv, d, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = fa_ops.flash_attention(q, k, v, causal=True)
    qr = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kr = jnp.moveaxis(k, 2, 1).reshape(b * kv, s, d)
    vr = jnp.moveaxis(v, 2, 1).reshape(b * kv, s, d)
    expect = fa_ref.flash_attention_ref(qr, kr, vr, n_rep=h // kv)
    expect = jnp.moveaxis(expect.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **_tol(dtype))


# ---------------------------------------------------------------- decode attn
@pytest.mark.parametrize("b,s,h,kv,d,ln", [
    (2, 1024, 8, 2, 64, 700), (1, 512, 4, 4, 128, 511),
    (2, 512, 16, 8, 64, 3),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, s, h, kv, d, ln, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, 1, h, d), dtype)
    kc = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    vc = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    out = dec_ops.decode_attention(q, kc, vc, jnp.asarray(ln))
    g = h // kv
    qr = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kr = jnp.moveaxis(kc, 2, 1).reshape(b * kv, s, d)
    vr = jnp.moveaxis(vc, 2, 1).reshape(b * kv, s, d)
    expect = dec_ref.decode_attention_ref(
        qr, kr, vr, jnp.asarray(ln)).reshape(b, 1, h, d)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        **_tol(dtype))


# -------------------------------------------------------------- embedding bag
@pytest.mark.parametrize("r,d,b,f,m", [
    (1000, 16, 4, 6, 3), (512, 8, 8, 2, 1), (4096, 64, 2, 4, 5),
])
def test_embedding_bag_sweep(r, d, b, f, m):
    table = jax.random.normal(jax.random.PRNGKey(4), (r, d), jnp.float32)
    rng = np.random.default_rng(0)
    counts = rng.integers(1, m + 1, (b, f))
    ids = rng.integers(0, r, (b, f, m)).astype(np.int32)
    mask = np.arange(m)[None, None, :] < counts[:, :, None]
    out = bag_ops.embedding_bag(table, jnp.asarray(ids), jnp.asarray(mask))
    expect = bag_ref.embedding_bag_ref(
        table, jnp.asarray(np.where(mask, ids, 0).reshape(b * f, m)),
        jnp.asarray(counts.reshape(-1).astype(np.int32))).reshape(b, f, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_matches_model_op():
    """Kernel == the model's jnp embedding_bag (drop-in contract)."""
    from repro.models.recsys import embedding_bag as model_bag
    table = jax.random.normal(jax.random.PRNGKey(5), (256, 8), jnp.float32)
    rng = np.random.default_rng(1)
    counts = rng.integers(1, 4, (3, 5))
    ids = rng.integers(0, 256, (3, 5, 4)).astype(np.int32)
    mask = np.arange(4)[None, None, :] < counts[:, :, None]
    out_k = bag_ops.embedding_bag(table, jnp.asarray(ids),
                                  jnp.asarray(mask))
    out_m = model_bag(table, jnp.asarray(ids), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_m),
                               rtol=1e-5, atol=1e-5)


# -------------------------------------------------------------------- cin
@pytest.mark.parametrize("b,hk,m,d,o", [
    (512, 12, 6, 10, 16), (300, 8, 8, 4, 8), (64, 39, 39, 10, 200),
])
def test_cin_fuse_sweep(b, hk, m, d, o):
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    xk = jax.random.normal(ks[0], (b, hk, d), jnp.float32)
    x0 = jax.random.normal(ks[1], (b, m, d), jnp.float32)
    w = jax.random.normal(ks[2], (hk * m, o), jnp.float32) * 0.1
    out = cin_ops.cin_layer(xk, x0, w, block_b=64)
    expect = cin_ref.cin_layer_ref(xk, x0, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


# -------------------------------------------------- maxplus (segmented)
@pytest.mark.parametrize("shape,blk", [
    ((4, 1024), 256), ((1, 37), 512), ((2, 3, 500), 128), ((8, 2048), 512),
])
def test_maxplus_segment_scan_sweep(shape, blk):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    a = jnp.cumsum(jax.random.exponential(ks[0], shape), -1)
    b = jax.random.exponential(ks[1], shape)
    f = jax.random.uniform(ks[2], shape) < 0.05
    oa, ob = mp_ops.maxplus_segment_scan(a, b, f, block_len=blk)
    ra, rb = mp_ref.maxplus_segment_scan_ref(a, b, f)
    np.testing.assert_allclose(np.asarray(oa), np.asarray(ra), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ob), np.asarray(rb), rtol=1e-5)


def test_maxplus_segment_ref_equals_sequential():
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    a = jax.random.normal(ks[0], (3, 257))
    b = jax.random.exponential(ks[1], (3, 257))
    f = jax.random.uniform(ks[2], (3, 257)) < 0.1
    ra, rb = mp_ref.maxplus_segment_scan_ref(a, b, f)
    sa, sb = mp_ref.maxplus_segment_scan_sequential(a, b, f)
    np.testing.assert_allclose(np.asarray(ra), np.asarray(sa), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(rb), np.asarray(sb), rtol=1e-5,
                               atol=1e-5)


def test_maxplus_segment_no_flags_equals_plain():
    """With zero reset flags the segmented kernel IS the plain scan."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    a = jnp.cumsum(jax.random.exponential(k1, (4, 777)), -1)
    b = jax.random.exponential(k2, (4, 777))
    f = jnp.zeros_like(a, dtype=bool)
    sa, sb = mp_ops.maxplus_segment_scan(a, b, f, block_len=256)
    pa, pb = mp_ops.maxplus_scan(a, b, block_len=256)
    np.testing.assert_allclose(np.asarray(sa), np.asarray(pa), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(sb), np.asarray(pb), rtol=1e-6)


def test_maxplus_segment_every_flag_resets():
    """All-flags input degenerates to the identity: out == (a, b)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(10))
    a = jax.random.normal(k1, (2, 300))
    b = jax.random.exponential(k2, (2, 300))
    f = jnp.ones_like(a, dtype=bool)
    sa, sb = mp_ops.maxplus_segment_scan(a, b, f, block_len=128)
    np.testing.assert_array_equal(np.asarray(sa), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(sb), np.asarray(b))
