"""Elastic autoscaling + the ClusterSpec API redesign (ISSUE 9).

Five contracts:

* degenerate-policy equivalence — ``min_r == max_r == r`` pins the
  controller at r, so the elastic engine reproduces the static one
  (fused and masked, compaction and load-aware routing);
* impl-independence — under an ACTIVE policy the fused engine still
  matches the masked oracle in x64 (the replica-active mask commutes
  with route-compaction);
* chunking invariance — `autoscale_scan`'s carry threads through
  arbitrary block splits with identical per-query counts
  (hypothesis-property, mirroring tests/test_calibrate.py's guard);
* ClusterSpec-vs-legacy equivalence — the deprecation shim builds the
  same program as the loose keywords, warns once, and rejects
  ambiguous/invalid combinations;
* cost accounting — ``replica_seconds`` integrates the active count
  (bounded by min_r/max_r x elapsed) and telemetry exposes the
  active-replica trajectory.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, simulator, sweep
from repro.core import cluster as cluster_mod
from repro.core.cluster import ClusterSpec
from repro.launch import elastic
from repro.launch.elastic import AutoscalePolicy, autoscale_init, \
    autoscale_scan
from repro.obs import TelemetrySpec

T5 = capacity.TABLE5_PARAMS


@pytest.fixture
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


def _pinned(r, **kw):
    """A policy that can never move: min_r == max_r == r."""
    return AutoscalePolicy(min_r=r, max_r=r,
                           decision_interval_seconds=0.5, **kw)


# --------------------------------------------------------- degenerate policy

@pytest.mark.parametrize("routing,r", [
    ("round_robin", 3),   # chunk % r != 0: compaction path (the reshape
                          # fast path is gated OFF under elastic)
    ("jsq", 3),
])
@pytest.mark.parametrize("impl", ["fused", "masked"])
def test_pinned_policy_matches_static_engine(routing, r, impl):
    """ACCEPTANCE: min_r == max_r == r reproduces the static-r engine's
    statistics exactly — the controller runs but every decision is a
    no-op, and the active-mask multiplies by 1."""
    key = jax.random.PRNGKey(0)
    kw = dict(chunk_size=1024, tap_size=16)
    static = simulator.simulate_fork_join(
        key, 45.0, 8_000, T5,
        cluster=ClusterSpec(r=r, routing=routing, replica_impl=impl), **kw)
    pinned = simulator.simulate_fork_join(
        key, 45.0, 8_000, T5,
        cluster=ClusterSpec(routing=routing, replica_impl=impl,
                            autoscale=_pinned(r)), **kw)
    for name in ("count", "sum_response", "sumsq_response", "sum_broker",
                 "sum_cluster", "sum_server", "hist"):
        np.testing.assert_array_equal(
            np.asarray(getattr(static, name)),
            np.asarray(getattr(pinned, name)),
            err_msg=f"{routing} r={r} {impl}: {name}")
    # and the cost integral knows nothing ever scaled
    np.testing.assert_allclose(float(pinned.mean_active_replicas), r,
                               rtol=1e-6)


def test_active_policy_fused_matches_masked(x64):
    """Under a LIVE policy (scale-outs and drains actually happen) the
    fused route-compacted engine still agrees with the masked phantom
    oracle — x64 brings the float gap under 1e-9."""
    pol = AutoscalePolicy(min_r=1, max_r=3, target_utilization=0.5,
                          decision_interval_seconds=0.3,
                          stabilization_intervals=2)
    key = jax.random.PRNGKey(1)
    kw = dict(chunk_size=512, mode="cache", p=4)
    params = dataclasses.replace(capacity.scenario_params(memory=1, p=4),
                                 p=4)
    out = {}
    for impl in ("fused", "masked"):
        out[impl] = simulator.simulate_fork_join(
            key, 55.0, 6_000, params,
            cluster=ClusterSpec(routing="jsq", replica_impl=impl,
                                autoscale=pol), **kw)
    # the policy really moved (otherwise this test is the pinned one)
    assert 1.0 < float(out["fused"].mean_active_replicas) < 3.0
    for name in ("count", "sum_response", "sumsq_response", "sum_broker",
                 "sum_cluster", "sum_server", "replica_seconds",
                 "elapsed_seconds"):
        np.testing.assert_allclose(
            np.asarray(getattr(out["fused"], name)),
            np.asarray(getattr(out["masked"], name)), rtol=1e-9,
            err_msg=name)


# ------------------------------------------------------------ cost integral

def test_replica_seconds_bounds_and_trajectory():
    """replica_seconds integrates the active count over valid time, so
    min_r * elapsed <= replica_seconds <= max_r * elapsed; telemetry's
    active_replicas exposes the trajectory and reacts to load."""
    pol = AutoscalePolicy(min_r=1, max_r=4, target_utilization=0.6,
                          decision_interval_seconds=0.4,
                          stabilization_intervals=2)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(2), 60.0, 12_000, T5, chunk_size=1024,
        cluster=ClusterSpec(routing="jsq", autoscale=pol),
        telemetry=TelemetrySpec(n_bins=16))
    rs = float(res.replica_seconds)
    el = float(res.elapsed_seconds)
    assert 0.0 < el
    assert pol.min_r * el <= rs <= pol.max_r * el + 1e-6
    mean_act = float(res.mean_active_replicas)
    assert 1.0 <= mean_act <= 4.0

    act = np.asarray(res.timeline.active_replicas)
    cnt = np.asarray(res.timeline.count)
    live = cnt > 0
    assert live.any()
    assert np.all(act[live] >= pol.min_r - 1e-6)
    assert np.all(act[live] <= pol.max_r + 1e-6)
    # 60 qps on one Table-5 replica saturates: the policy must scale out
    assert act[live].max() > 1.5


def test_static_run_has_no_elastic_fields():
    res = simulator.simulate_fork_join(jax.random.PRNGKey(3), 20.0,
                                       2_000, T5)
    assert res.replica_seconds is None
    assert res.elapsed_seconds is None
    with pytest.raises(ValueError, match="no autoscaler ran"):
        _ = res.mean_active_replicas


# ------------------------------------------------- ClusterSpec vs legacy

def test_cluster_spec_equals_legacy_keywords():
    """The deprecation shim builds the same program: legacy keywords and
    the equivalent ClusterSpec produce bitwise-identical results, and
    the warning fires once per process."""
    key = jax.random.PRNGKey(4)
    cluster_mod._warned_legacy = False
    try:
        with pytest.warns(DeprecationWarning, match="cluster=ClusterSpec"):
            legacy = simulator.simulate_fork_join(  # staticcheck: disable=RPR006  (shim under test)
                key, 40.0, 4_000, T5, r=2, routing="jsq",
                result_cache=(0.3, 1e-3), chunk_size=512)
        # second legacy call: no second warning (warn-once flag)
        import warnings as _w
        with _w.catch_warnings():
            _w.simplefilter("error", DeprecationWarning)
            legacy2 = simulator.simulate_fork_join(  # staticcheck: disable=RPR006  (shim under test)
                key, 40.0, 4_000, T5, r=2, routing="jsq",
                result_cache=(0.3, 1e-3), chunk_size=512)
    finally:
        cluster_mod._warned_legacy = True
    spec = simulator.simulate_fork_join(
        key, 40.0, 4_000, T5, chunk_size=512,
        cluster=ClusterSpec(r=2, routing="jsq", result_cache=(0.3, 1e-3)))
    for name in ("count", "sum_response", "hist", "sum_broker"):
        np.testing.assert_array_equal(np.asarray(getattr(legacy, name)),
                                      np.asarray(getattr(spec, name)),
                                      err_msg=name)
        np.testing.assert_array_equal(np.asarray(getattr(legacy2, name)),
                                      np.asarray(getattr(spec, name)),
                                      err_msg=name)


def test_cluster_and_legacy_together_is_an_error():
    with pytest.raises(TypeError, match="both cluster= and deprecated"):
        simulator.simulate_fork_join(  # staticcheck: disable=RPR006  (error path under test)
            jax.random.PRNGKey(5), 20.0, 256, T5,
            cluster=ClusterSpec(r=2), routing="jsq")


def test_cluster_spec_validation():
    with pytest.raises(ValueError, match="unknown routing"):
        ClusterSpec(routing="nope")
    with pytest.raises(ValueError, match="unknown replica_impl"):
        ClusterSpec(replica_impl="nope")
    with pytest.raises(ValueError, match="leave r at its default"):
        ClusterSpec(r=2, autoscale=AutoscalePolicy(min_r=1, max_r=4))
    with pytest.raises(TypeError, match="AutoscalePolicy"):
        ClusterSpec(autoscale="1..4")
    assert ClusterSpec(autoscale=AutoscalePolicy(min_r=1,
                                                 max_r=4)).engine_r == 4
    assert ClusterSpec(r=3).engine_r == 3
    # hashable => valid jit static argument
    assert hash(ClusterSpec(result_cache=(0.3, 1e-3))) == \
        hash(ClusterSpec(result_cache=(0.3, 1e-3)))


def test_policy_validation():
    with pytest.raises(ValueError, match="min_r <= max_r"):
        AutoscalePolicy(min_r=3, max_r=2)
    with pytest.raises(ValueError, match="target_utilization"):
        AutoscalePolicy(min_r=1, max_r=2, target_utilization=1.5)
    with pytest.raises(ValueError, match="init_r"):
        AutoscalePolicy(min_r=2, max_r=4, init_r=1)
    assert AutoscalePolicy(min_r=2, max_r=4).start_r == 2
    assert AutoscalePolicy(min_r=2, max_r=4, init_r=3).start_r == 3


def test_for_slo_wires_straggler_tax():
    """for_slo budgets the Eq 6 synchronization tax H_p into the
    trigger: more servers per replica => hotter tax => lower target."""
    kw = dict(mean_service=0.05, slo_seconds=0.5)
    t4 = AutoscalePolicy.for_slo(1, 4, p=4, **kw).target_utilization
    t64 = AutoscalePolicy.for_slo(1, 4, p=64, **kw).target_utilization
    assert t64 < t4 < 1.0
    expect4 = 1.0 - elastic.expected_straggler_tax(4) * 0.05 / 0.5
    np.testing.assert_allclose(t4, expect4, rtol=1e-12)


# ----------------------------------------------------------- sweep plumbing

def test_policy_grid_axis_and_frontier():
    """The policy axis rides the sweep: shape swaps r for len(policies),
    the frontier prices by replica-seconds, and the analytic path
    refuses (policies are simulation-only)."""
    pols = (AutoscalePolicy(min_r=1, max_r=2,
                            decision_interval_seconds=0.5),
            AutoscalePolicy(min_r=1, max_r=3,
                            decision_interval_seconds=0.5))
    grid = sweep.SweepGrid.build(lam=[25.0, 50.0], p=[8.0], base=T5,
                                 hit=[0.17], broker_from_p=False,
                                 autoscale=pols)
    assert grid.shape == (2, 1, 1, 1, 1, 2)
    with pytest.raises(ValueError, match="sweep_analytical cannot"):
        sweep.sweep_analytical(grid)
    res = sweep.sweep_simulated(grid, jax.random.PRNGKey(6),
                                n_queries=4_000, chunk_size=512,
                                cluster=ClusterSpec(routing="jsq"))
    assert res.stats.replica_seconds.shape == grid.shape
    eff = np.asarray(res.stats.replica_seconds
                     / np.maximum(np.asarray(res.stats.elapsed_seconds),
                                  1e-30))
    assert np.all(eff >= 1.0 - 1e-6)
    assert np.all(eff[..., 0] <= 2.0 + 1e-6)
    assert np.all(eff[..., 1] <= 3.0 + 1e-6)

    fr = sweep.extract_frontier(res, 2.0)
    assert fr.autoscale is not None and len(fr.autoscale) == 2
    for i in range(2):
        if bool(fr.feasible[i]):
            assert fr.autoscale[i] in pols
            assert "autoscale" in fr.describe(i)


def test_policy_grid_keeps_r_axis_static_error():
    pols = (AutoscalePolicy(min_r=1, max_r=2),)
    with pytest.raises(ValueError, match="policy grid replaces"):
        sweep.SweepGrid.build(lam=[20.0], p=[8.0], base=T5,
                              r=[2.0], autoscale=pols)


def test_plan_capacity_autoscale_crosscheck():
    """plan_capacity keeps the static Sec-6 sizing as the headline but
    simulates the elastic fleet and reports its mean active count."""
    pol = AutoscalePolicy(min_r=1, max_r=6,
                          decision_interval_seconds=1.0)
    with pytest.raises(ValueError, match="simulate=True"):
        capacity.plan_capacity(T5, 60.0, 0.9,
                               cluster=ClusterSpec(autoscale=pol))
    plan = capacity.plan_capacity(T5, 60.0, 0.9, simulate=True,
                                  cluster=ClusterSpec(routing="jsq",
                                                      autoscale=pol),
                                  key=jax.random.PRNGKey(7))
    assert plan.autoscale is pol
    assert plan.mean_active_replicas is not None
    assert 1.0 <= plan.mean_active_replicas <= 6.0
    assert plan.response_simulated_ms is not None


# ------------------------------------------------ hypothesis: carry chaining
# Guarded like tests/test_calibrate.py so the rest of the module runs
# without hypothesis.

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _POL = AutoscalePolicy(min_r=1, max_r=5, target_utilization=0.55,
                           decision_interval_seconds=0.25,
                           stabilization_intervals=2,
                           queue_trigger_seconds=2.0)
    _N = 96
    _GAPS = jnp.asarray(
        np.random.default_rng(0).exponential(0.05, (2, _N)), jnp.float32)
    _DEMAND = jnp.asarray(
        np.random.default_rng(1).exponential(0.3, (2, _N)), jnp.float32)

    @given(st.lists(st.integers(min_value=1, max_value=_N - 1),
                    min_size=0, max_size=6, unique=True))
    @settings(max_examples=30, deadline=None)
    def test_autoscale_scan_chunking_invariant(cuts):
        """ACCEPTANCE: splitting the stream at ANY boundaries and
        chaining the carry reproduces the monolithic per-query active
        counts exactly — the controller is chunking-invariant, which is
        what lets the streaming engine run it per chunk."""
        carry0 = autoscale_init(_POL, 2, jnp.float32)
        _, whole = autoscale_scan(_POL, 8, carry0, _GAPS, _DEMAND)
        bounds = [0] + sorted(cuts) + [_N]
        carry = autoscale_init(_POL, 2, jnp.float32)
        parts = []
        for a, b in zip(bounds[:-1], bounds[1:]):
            carry, n = autoscale_scan(_POL, 8, carry,
                                      _GAPS[:, a:b], _DEMAND[:, a:b])
            parts.append(np.asarray(n))
        np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                      np.asarray(whole))
else:
    @pytest.mark.skip(reason="property tests need hypothesis (see "
                      "pyproject [project.optional-dependencies].test)")
    def test_autoscale_scan_chunking_invariant():
        pass
