"""Fault injection, failover routing and graceful degradation (ISSUE 10).

Five contracts:

* zero-cost identity — ``ClusterSpec(fault=None)`` and an all-up
  `FaultSpec` (no outages, slowdown factors of 1, never-firing broker
  timeout and hedge) are BIT-IDENTICAL to the pre-fault engine in every
  shared statistic, across routing policies;
* chunking invariance — `fault_scan`'s outage-mask recurrence threads
  its carry through arbitrary block splits with identical per-query
  masks (hypothesis property, mirroring tests/test_autoscale.py);
* failover semantics — a replica in an outage window receives no
  queries, its share spills to the survivors (``spill_fraction`` > 0,
  ``availability`` = 1 while any replica survives; with ALL replicas
  down arrivals are counted unavailable);
* degraded operation — a broker timeout with k-of-p quorum caps the
  join, degraded responses are counted, and hedged retries can only
  help (p95 never worse than the unhedged twin on the same draws);
* plan conservativeness — ``plan_capacity(survive_faults=k)`` never
  provisions fewer replicas than the fault-free plan and records the
  simulated p95 of the k-down scenario.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, simulator
from repro.core.cluster import ClusterSpec
from repro.core.faults import FaultSpec, fault_init, fault_scan
from repro.core.queueing import ServerParams

PARAMS = ServerParams(p=4, s_broker=0.004, s_hit=0.0125, s_miss=0.05,
                      s_disk=0.04, hit=0.5)
KEY = jax.random.PRNGKey(42)

# statistics the fault-free and all-up programs must share bitwise
SHARED = ("count", "sum_response", "sumsq_response", "sum_broker",
          "sum_cluster", "sum_server", "hist", "tap_response")

ALL_UP = FaultSpec(degraded=((0, 1.0), (2, 1.0)),
                   broker_timeout_seconds=1e9, quorum_k=1,
                   hedge_after_seconds=1e9, hedge_attempts=2)


def run(fault, *, routing="round_robin", r=3, n=4_000, rate=60.0,
        key=KEY, **kw):
    return simulator.simulate_fork_join(
        key, rate, n, PARAMS, chunk_size=512,
        cluster=ClusterSpec(r=r, routing=routing, fault=fault), **kw)


# ---------------------------------------------------------------- identity

@pytest.mark.parametrize("routing", ["round_robin", "random", "jsq"])
def test_fault_none_and_all_up_bit_identical(routing):
    """ACCEPTANCE: the fault machinery costs nothing when nothing can
    fail — fault=None and the all-up spec produce bit-identical shared
    statistics under every routing policy."""
    a = run(None, routing=routing, tap_size=16)
    b = run(ALL_UP, routing=routing, tap_size=16)
    for name in SHARED:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=f"{routing}: all-up FaultSpec perturbed {name}")
    # the all-up run still reports its (empty) fault channels
    assert a.spill_count is None and b.spill_count is not None
    assert float(b.availability) == 1.0
    assert float(b.spill_fraction) == 0.0


def test_fault_none_matches_missing_spec_exactly():
    a = simulator.simulate_fork_join(
        KEY, 60.0, 2_000, PARAMS, chunk_size=512, cluster=ClusterSpec(r=2))
    b = run(None, r=2, n=2_000)
    for name in SHARED[:-1]:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)))


# ---------------------------------------------------------------- failover

def test_outage_spills_to_survivors():
    horizon = 4_000 / 60.0
    down = FaultSpec(outages=((1, 0.0, horizon),))  # replica 1 out all run
    res = run(down)
    assert float(res.availability) == 1.0      # survivors always existed
    assert float(res.spill_fraction) > 0.2     # its share moved over
    assert float(res.unavail_count) == 0.0
    # round_robin sends ~1/3 of arrivals to the dead replica's slot
    assert abs(float(res.spill_fraction) - 1.0 / 3.0) < 0.1


def test_all_replicas_down_counts_unavailable():
    horizon = 4_000 / 60.0
    dead = FaultSpec(outages=tuple((j, 0.0, horizon) for j in range(3)))
    res = run(dead)
    assert float(res.availability) < 0.05
    assert float(res.unavail_count) > 0


def test_jsq_masks_down_replica():
    horizon = 4_000 / 60.0
    down = FaultSpec(outages=((0, 0.0, horizon),))
    res = run(down, routing="jsq")
    assert float(res.availability) == 1.0
    assert float(res.spill_fraction) > 0.2


def test_windowed_outage_only_affects_window():
    res_win = run(FaultSpec(outages=((0, 5.0, 10.0),)))
    res_always = run(FaultSpec(outages=((0, 0.0, 1e9),)))
    assert (0.0 < float(res_win.spill_fraction)
            < float(res_always.spill_fraction))


def test_mtbf_process_churns_and_repairs():
    res = run(FaultSpec(mtbf_seconds=5.0, mttr_seconds=1.0))
    # failures happened, but repairs kept availability high
    assert 0.0 < float(res.spill_fraction) < 0.5
    assert float(res.availability) > 0.9


# ------------------------------------------------------------- degradation

def test_quorum_timeout_caps_join_and_counts_degraded():
    slow = dataclasses.replace(PARAMS, hit=0.0)
    deadline = 0.08
    spec = ClusterSpec(r=1, fault=FaultSpec(
        broker_timeout_seconds=deadline, quorum_k=2))
    base = simulator.simulate_fork_join(KEY, 20.0, 3_000, slow,
                                        chunk_size=512,
                                        cluster=ClusterSpec(r=1))
    capped = simulator.simulate_fork_join(KEY, 20.0, 3_000, slow,
                                          chunk_size=512, cluster=spec)
    assert float(capped.degraded_fraction) > 0.1
    assert float(capped.mean_response) < float(base.mean_response)
    # quorum can cut short but never lengthen a response
    assert float(capped.quantile(0.99)) <= float(base.quantile(0.99)) + 1e-6


def test_degraded_server_slows_the_join():
    fast = run(None, n=3_000)
    slow = run(FaultSpec(degraded=((1, 4.0),)), n=3_000)
    assert float(slow.mean_response) > float(fast.mean_response)
    # slowdown factor 1 is a no-op (covered bitwise above); factor > 1
    # must not touch the fault counters
    assert float(slow.spill_fraction) == 0.0


def test_hedging_never_hurts():
    slow = dataclasses.replace(PARAMS, hit=0.0)

    def go(fault):
        return simulator.simulate_fork_join(
            KEY, 15.0, 3_000, slow, chunk_size=512,
            cluster=ClusterSpec(r=2, fault=fault))

    base = go(ALL_UP)  # same RNG plan as the hedged run, hedge never fires
    hedged = go(dataclasses.replace(ALL_UP, hedge_after_seconds=0.05))
    assert float(hedged.quantile(0.95)) <= float(base.quantile(0.95)) + 1e-6
    assert float(hedged.mean_response) <= float(base.mean_response) + 1e-6


# ------------------------------------------------------------ plan / sweep

def test_plan_survive_faults_is_conservative():
    """ACCEPTANCE: the N+k plan never provisions fewer replicas, and the
    simulated cross-check records the k-down p95."""
    kw = dict(simulate=True, key=KEY, n_queries=4_000)
    plan0 = capacity.plan_capacity(PARAMS, 120.0, 0.3, **kw)
    plan1 = capacity.plan_capacity(PARAMS, 120.0, 0.3, survive_faults=1,
                                   **kw)
    assert plan1.n_replicas >= plan0.n_replicas + 1
    assert plan1.survive_faults == 1
    assert plan1.response_faulted_p95_ms is not None
    assert plan0.survive_faults == 0
    assert plan0.response_faulted_p95_ms is None


def test_plan_rejects_double_injection():
    with pytest.raises(ValueError, match="fault"):
        capacity.plan_capacity(
            PARAMS, 50.0, 0.3, survive_faults=1,
            cluster=ClusterSpec(r=2, fault=FaultSpec(mtbf_seconds=9.0)))


def test_sweep_fault_axis_round_trips():
    from repro.core import sweep as sw
    faults = (None, FaultSpec(outages=((0, 0.0, 1e9),)))
    grid = sw.SweepGrid.build(lam=[40.0], p=[4.0], hit=[PARAMS.hit],
                              base=PARAMS, broker_from_p=False,
                              r=[3.0], fault=faults)
    assert grid.shape[-1] == 2
    res = sw.sweep_simulated(grid, KEY, n_queries=2_000, chunk_size=512)
    spill = np.ravel(np.asarray(res.stats.spill_fraction))
    assert spill[0] == 0.0 and spill[1] > 0.2
    with pytest.raises(ValueError, match="fault"):
        sw.sweep_analytical(grid)
    with pytest.raises(ValueError, match="6th axis|axis"):
        sw.SweepGrid.build(
            lam=[40.0], p=[4.0], hit=[0.5], base=PARAMS, r=[2.0],
            fault=faults,
            autoscale=(None,))


def test_faultspec_validation():
    with pytest.raises(ValueError):
        FaultSpec(outages=((0, 5.0, 5.0),))        # empty window
    with pytest.raises(ValueError):
        FaultSpec(outages=((-1, 0.0, 1.0),))       # bad index
    with pytest.raises(ValueError):
        FaultSpec(degraded=((0, 0.0),))            # factor must be > 0
    with pytest.raises(ValueError):
        FaultSpec(broker_timeout_seconds=0.0)
    with pytest.raises(ValueError):
        FaultSpec(quorum_k=0)
    with pytest.raises(ValueError):
        FaultSpec(hedge_backoff=0.5)
    with pytest.raises(TypeError):
        ClusterSpec(fault="down")                  # not a FaultSpec
    # quorum clips to the fork width
    assert FaultSpec(broker_timeout_seconds=1.0, quorum_k=9).quorum(4) == 4
    # hedge delays back off geometrically
    spec = FaultSpec(hedge_after_seconds=0.1, hedge_backoff=2.0,
                     hedge_attempts=3)
    np.testing.assert_allclose(spec.hedge_delays(), (0.1, 0.3, 0.7))


# ------------------------------------------------ hypothesis: carry chaining
# Guarded like tests/test_autoscale.py so the rest of the module runs
# without hypothesis.

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _N = 96
    _R = 4
    _SPEC = FaultSpec(outages=((0, 0.4, 1.1), (2, 2.0, 2.5)),
                      mtbf_seconds=1.5, mttr_seconds=0.4)
    _GAPS = jnp.asarray(
        np.random.default_rng(0).exponential(0.03, (2, _N)), jnp.float32)
    _T = jnp.cumsum(_GAPS, axis=1)
    _U = jnp.asarray(np.random.default_rng(1).random((2, _N, _R)),
                     jnp.float32)

    @given(st.lists(st.integers(min_value=1, max_value=_N - 1),
                    min_size=0, max_size=6, unique=True))
    @settings(max_examples=30, deadline=None)
    def test_fault_scan_chunking_invariant(cuts):
        """ACCEPTANCE: splitting the stream at ANY boundaries and
        chaining the carry reproduces the monolithic per-query replica
        masks exactly — the outage recurrence is chunking-invariant,
        which is what lets the streaming engine run it per chunk."""
        carry0 = fault_init(_SPEC, 2, _R)
        _, whole = fault_scan(_SPEC, _R, carry0, _T, _GAPS, _U)
        bounds = [0] + sorted(cuts) + [_N]
        carry = fault_init(_SPEC, 2, _R)
        parts = []
        for a, b in zip(bounds[:-1], bounds[1:]):
            carry, m = fault_scan(_SPEC, _R, carry, _T[:, a:b],
                                  _GAPS[:, a:b], _U[:, a:b])
            parts.append(np.asarray(m))
        np.testing.assert_array_equal(np.concatenate(parts, axis=1),
                                      np.asarray(whole))
else:
    @pytest.mark.skip(reason="property tests need hypothesis (see "
                      "pyproject [project.optional-dependencies].test)")
    def test_fault_scan_chunking_invariant():
        pass
