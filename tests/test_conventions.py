"""Repo-convention guards, enforced as tests so CI catches drift.

ROADMAP convention (PR 1): every JAX symbol that has been renamed or
gated across versions goes through ``src/repro/compat.py``.  Nothing else
under ``src/`` may touch the shimmed names directly — otherwise the next
JAX upgrade is a five-file hunt instead of a one-file edit.
"""

from __future__ import annotations

import pathlib
import re

SRC = pathlib.Path(__file__).resolve().parent.parent / "src"

# The symbols compat.py wraps; see its module docstring.
_SHIMMED = re.compile(
    r"TPUCompilerParams|jax\.sharding\.AxisType|jax\.shard_map")


def test_shimmed_jax_symbols_only_in_compat():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if path.name == "compat.py":
            continue
        for lineno, line in enumerate(
                path.read_text().splitlines(), start=1):
            if _SHIMMED.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{lineno}: "
                                 f"{line.strip()}")
    assert not offenders, (
        "shimmed JAX symbols used outside repro/compat.py — route them "
        "through the compat shims instead (ROADMAP convention):\n"
        + "\n".join(offenders))
