"""Repo-convention guards, enforced as tests so CI catches drift.

Historically this file held a 34-line grep for shimmed JAX symbols; the
grep body is gone — `repro.staticcheck` is the enforcement mechanism for
ALL standing conventions now (compat shims, ArrivalProcess, TraceRecord,
replica topology, plus the tracer-safety and Pallas families).  This test
drives the framework over the real tree so `pytest` alone still guards
the conventions even when the CI staticcheck job is skipped.

The eval_shape contract (RPR301) is exercised separately in
tests/test_staticcheck.py — here we keep the pure-AST pass, which needs
no jax import and runs in milliseconds.
"""

from __future__ import annotations

import pathlib

import repro.staticcheck as staticcheck

ROOT = pathlib.Path(__file__).resolve().parent.parent


def test_repo_is_staticcheck_clean():
    findings = staticcheck.run(["src", "tests"], ROOT)
    active = [f for f in findings if not f.suppressed]
    assert not active, (
        "staticcheck findings (fix them, or suppress a deliberate "
        "exception with `# staticcheck: disable=<RULE>` and a reason):\n"
        + "\n".join(f.render() for f in active))


def test_rule_registry_has_all_families():
    by_family: dict[str, int] = {}
    for r in staticcheck.RULES.values():
        by_family[r.family] = by_family.get(r.family, 0) + 1
    # ISSUE 6 acceptance: >= 10 distinct rules across the four families
    assert len(staticcheck.RULES) >= 10
    for family in ("convention", "tracer", "pallas", "contract"):
        assert by_family.get(family, 0) >= 1, f"no {family} rules"


def test_shimmed_jax_symbols_only_in_compat():
    """The original grep guard's contract, now enforced by RPR001."""
    rule = staticcheck.RULES["RPR001"]
    assert rule.applies_to("src/repro/core/simulator.py")
    assert not rule.applies_to("src/repro/compat.py")
    findings = staticcheck.check_source(
        "import jax.experimental.pallas.tpu as pltpu\n"
        "params = pltpu.TPUCompilerParams()\n",
        "src/repro/kernels/foo/kernel.py")
    assert any(f.rule_id == "RPR001" for f in findings)
