"""Beyond-paper extensions: M/M/c servers, two-phase model, hybrid
partitioning, distributed-search partition comparison."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, queueing, simulator
from repro.engine import corpus as corpus_lib
from repro.engine import partition


def test_erlang_c_limits():
    # c=1 reduces to M/M/1 waiting probability = rho
    assert np.isclose(float(queueing.erlang_c(0.5, 1.0, 1)), 0.5, atol=1e-5)
    r = queueing.mmc_residence_time(0.5, 1.0, 1)
    assert np.isclose(float(r), 2.0, rtol=1e-4)
    # many servers at low load -> no waiting
    r64 = queueing.mmc_residence_time(0.5, 1.0, 64)
    assert np.isclose(float(r64), 1.0, rtol=1e-3)


def test_mmc_analytical_matches_simulation():
    """Erlang-C mean response vs the Kiefer-Wolfowitz DES (future work)."""
    lam, s, c = 1.5, 1.0, 2
    analytic = float(queueing.mmc_residence_time(lam, s, c))
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(0),
                                            (80_000,)) / lam)
    svc = jax.random.exponential(jax.random.PRNGKey(1), (80_000,)) * s
    sim = float(jnp.mean(simulator.simulate_mmc(arr, svc, c=c)[8000:]))
    assert abs(sim - analytic) / analytic < 0.08


def test_multithreaded_servers_raise_capacity():
    """2 threads per index server push the feasible arrival rate up."""
    params = capacity.TABLE5_PARAMS
    lam = 35.0   # over single-thread saturation (sat ~30.1 qps)
    lo1, hi1 = queueing.response_time_bounds(lam, params)
    lo2, hi2 = queueing.response_time_bounds_mmc(lam, params, threads=2)
    assert np.isinf(float(hi1))
    assert np.isfinite(float(hi2))


def test_two_phase_model_additive():
    params = capacity.scenario("memory+cpus+disks")
    one = queueing.response_time_bounds(30.0, params)[1]
    two = queueing.two_phase_response_upper(
        30.0, params, s_docserver=2e-3, p_docservers=10)
    assert float(two) > float(one)
    # phase 2 roughly constant: doubling collection params doesn't touch it
    delta = float(two) - float(one)
    assert 0 < delta < 0.1


def test_hybrid_partition_balances_postings():
    cfg = corpus_lib.CorpusConfig(n_docs=1500, vocab_size=800,
                                  mean_doc_len=30, seed=2)
    corp = corpus_lib.generate_corpus(cfg)
    p = 4
    hybrid = partition.partition_hybrid(corp, p)
    term = partition.partition_terms(corp, p)

    def imbalance(part):
        sizes = np.array([s.n_postings for s in part.shards], float)
        return sizes.max() / max(sizes.mean(), 1.0)

    assert sum(s.n_postings for s in hybrid.shards) == corp.n_postings
    # hybrid storage balance should beat term partitioning (hot terms
    # concentrate whole lists on single owners)
    assert imbalance(hybrid) <= imbalance(term) + 0.05


def test_partition_schemes_same_global_df():
    cfg = corpus_lib.CorpusConfig(n_docs=800, vocab_size=400,
                                  mean_doc_len=25, seed=3)
    corp = corpus_lib.generate_corpus(cfg)
    doc = partition.partition_documents(corp, 3)
    hyb = partition.partition_hybrid(corp, 3)
    np.testing.assert_allclose(doc.shards[0].idf, hyb.shards[0].idf,
                               rtol=1e-6)
