"""The JAX compat shim works against whatever JAX this env has."""

import jax
import numpy as np

from repro import compat


def test_tpu_compiler_params_builds():
    cp = compat.tpu_compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert cp.dimension_semantics == ("parallel", "arbitrary")


def test_tpu_compiler_params_drops_unknown_kwargs():
    cp = compat.tpu_compiler_params(
        dimension_semantics=("parallel",),
        some_future_knob_that_does_not_exist=123)
    assert cp.dimension_semantics == ("parallel",)


def test_mesh_axis_types_shape_or_none():
    types = compat.mesh_axis_types(3)
    assert types is None or len(types) == 3


def test_make_mesh_single_device():
    mesh = compat.make_mesh((1,), ("data",))
    assert mesh.axis_names == ("data",)
    assert mesh.devices.shape == (1,)


def test_shard_map_identity_single_device():
    from jax.sharding import PartitionSpec as P

    mesh = compat.make_mesh((1,), ("x",))
    f = compat.shard_map(lambda a: a * 2.0, mesh=mesh, in_specs=(P(),),
                         out_specs=P(), check_vma=False)
    out = f(jax.numpy.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), 2.0)
