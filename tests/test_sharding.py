"""Sharding/distribution tests.

These need >1 XLA device, so they run a child Python with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the dry-run pattern;
the main test process keeps seeing 1 device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_child(body: str, devices: int = 8, timeout: int = 420):
    code = ("import os\n"
            f"os.environ['XLA_FLAGS'] = "
            f"'--xla_force_host_platform_device_count={devices}'\n"
            + textwrap.dedent(body))
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_distributed_search_8_shards():
    """Document-partitioned shard_map search == single-index search."""
    out = _run_in_child("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import compat
        from repro.engine import corpus as C, index as I, partition as P
        from repro.engine import server as S, distributed as D
        from repro.workloadgen import querygen as QG

        cfg = C.CorpusConfig(n_docs=4000, vocab_size=2000, mean_doc_len=40)
        corp = C.generate_corpus(cfg)
        idx = I.build_index(corp)
        uni = QG.build_universe(QG.WorkloadConfig(
            't', n_unique_queries=400, vocab_size=2000))
        _, qterms = QG.sample_query_stream(uni, 32)
        q = jnp.asarray(qterms)

        srv = S.IndexServer(idx, k_local=5)
        s_ref, _ = srv.process(q)

        part = P.partition_documents(corp, 8)
        stacked = D.stack_shards(part)
        mesh = compat.make_mesh((8,), ('servers',))
        search = D.make_search_fn(mesh, stacked, k=5)
        s_dist, d_dist = search(q)
        np.testing.assert_allclose(np.asarray(s_dist), np.asarray(s_ref),
                                   rtol=1e-4)
        print('OK distributed == single')
    """)
    assert "OK distributed == single" in out


def test_lm_train_step_shards_on_mesh():
    """Tiny LM train step lowers, compiles and RUNS on a (2,4) mesh with
    the production sharding rules; loss matches the single-device run."""
    out = _run_in_child("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.configs.base import LMConfig, MoESpec
        from repro.launch.sharding import sharding_rules
        from repro.models import transformer as T

        cfg = LMConfig(name='t', n_layers=2, d_model=32, n_heads=4,
                       n_kv_heads=2, d_ff=64, vocab_size=128, d_head=8,
                       dtype='float32', vocab_pad_multiple=64,
                       moe=MoESpec(n_experts=8, top_k=2, d_expert=32
                                   ).padded(4))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 128)
        labels = jnp.roll(tokens, -1, 1)
        ref = float(T.train_step_loss(params, cfg, tokens, labels))

        mesh = compat.make_mesh((2, 4), ('data', 'model'))
        rules = {'batch': ('data',), 'seq': None, 'seq_q': None,
                 'embed': None, 'heads': 'model', 'kv_heads': None,
                 'ffn': None, 'experts': 'model', 'vocab': 'model',
                 'kv_seq': None, 'kv_batch': ('data',), 'cand': None}
        with mesh, sharding_rules(rules):
            f = jax.jit(lambda p, t, l: T.train_step_loss(p, cfg, t, l))
            sharded = float(f(params, tokens, labels))
        np.testing.assert_allclose(sharded, ref, rtol=1e-4)
        print('OK sharded loss ==', sharded)
    """)
    assert "OK sharded loss" in out


def test_elastic_restore_across_mesh_shapes():
    """Checkpoint saved on a (4,2) mesh restores onto (2,2) — the node-
    failure path: fewer chips, identical values."""
    out = _run_in_child("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro import compat
        from repro.ckpt import checkpoint as CK

        mesh1 = compat.make_mesh((4, 2), ('data', 'model'))
        tree = {'w': jnp.arange(64.0).reshape(8, 8)}
        sh1 = {'w': NamedSharding(mesh1, P('data', 'model'))}
        placed = jax.tree.map(jax.device_put, tree, sh1)
        with tempfile.TemporaryDirectory() as d:
            CK.save(d, 5, placed)
            mesh2 = compat.make_mesh((2, 2), ('data', 'model'))
            sh2 = {'w': NamedSharding(mesh2, P('data', 'model'))}
            restored = CK.restore(d, 5, tree, shardings=sh2)
            np.testing.assert_allclose(np.asarray(restored['w']),
                                       np.asarray(tree['w']))
            assert restored['w'].sharding.mesh.shape['data'] == 2
        print('OK elastic restore')
    """)
    assert "OK elastic restore" in out


def test_dryrun_single_cell_small_devices():
    """The dry-run machinery itself (specs/rules/roofline parse) on a tiny
    8-device mesh with a reduced LM config."""
    out = _run_in_child("""
        import dataclasses, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro import compat
        from repro.configs.base import ArchSpec, LMConfig, ShapeSpec
        from repro.launch.sharding import sharding_rules
        from repro.launch import specs as SP
        from repro.roofline.analysis import roofline_from_compiled

        cfg = LMConfig(name='t', n_layers=2, d_model=64, n_heads=8,
                       n_kv_heads=2, d_ff=128, vocab_size=512, d_head=8,
                       vocab_pad_multiple=64)
        spec = ArchSpec(arch_id='t', family='lm', config=cfg,
                        smoke_config=cfg,
                        shapes=(ShapeSpec('train', 'train',
                                dict(seq_len=128, global_batch=8)),))
        mesh = compat.make_mesh((2, 4), ('data', 'model'))
        # patch data_axes/model divisibility: rules come from lm_rules
        build = SP.build_lm_cell(spec, spec.shapes[0], mesh, False)
        with mesh, sharding_rules(build.rules):
            compiled = jax.jit(build.fn, donate_argnums=build.donate
                               ).lower(*build.args).compile()
        cell = roofline_from_compiled(
            arch='t', shape='train', mesh_name='single', n_chips=8,
            compiled=compiled, model_flops=build.model_flops)
        assert cell.flops_global > 0
        assert cell.terms.compute_s > 0
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print('OK dryrun cell', cell.bound)
    """)
    assert "OK dryrun cell" in out


def test_scenario_sharded_sweep_8_devices():
    """Scenario-sharded sweeps on an 8-device ("scenario",) mesh.

    Analytical: sharded surface == unsharded surface EXACTLY (same math,
    split elementwise).  Simulated: the full grid runs under shard_map,
    and device 0's shard of one (p, r) slab reproduces a direct local
    batch run seeded with that device's split key — pinning the
    pad/split/key plumbing, not just shapes.
    """
    out = _run_in_child("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import simulator, sweep
        from repro.core.arrivals import ArrivalProcess
        from repro.core.cluster import ClusterSpec
        from repro.core.queueing import ServerParams
        from repro.launch.mesh import make_sweep_mesh
        import dataclasses

        mesh = make_sweep_mesh()
        assert mesh.devices.size == 8 and mesh.axis_names == ('scenario',)
        grid = sweep.SweepGrid.build(
            lam=jnp.linspace(40., 160., 5), p=[4.0], cpu=[1.0, 1.5],
            disk=[1.0], hit=[0.3, 0.7], r=[1.0, 2.0],
            result_cache=(0.2, 2e-3))

        ra = sweep.sweep_analytical(grid)
        rs = sweep.sweep_analytical(grid, mesh=mesh)
        for name in ('response_lower', 'response_upper', 'utilization'):
            a = np.asarray(getattr(ra, name))
            b = np.asarray(getattr(rs, name))
            m = np.isfinite(a)
            assert (m == np.isfinite(b)).all(), name
            np.testing.assert_array_equal(np.where(m, a, 0.),
                                          np.where(m, b, 0.), err_msg=name)

        key = jax.random.PRNGKey(0)
        res = sweep.sweep_simulated(grid, key, n_queries=3000,
                                    chunk_size=512, mesh=mesh)
        assert res.mean.shape == grid.shape
        assert bool(jnp.all(jnp.isfinite(res.mean)))

        # reconstruct device 0's shard of the (p=4, r=2) slab: dispatch
        # keys are split(key, n_p*n_r) flat over (i, j); slab scenarios
        # flatten (L,C,D,H) row-major, pad 20 -> 24, 3 per device
        lam_full, params_full = grid.broadcast_full()
        lam_slab = jnp.moveaxis(lam_full, (1, 5), (0, 1))[0, 1].reshape(-1)
        p_slab = ServerParams(**{
            f.name: jnp.moveaxis(getattr(params_full, f.name),
                                 (1, 5), (0, 1))[0, 1].reshape(-1)
            for f in dataclasses.fields(ServerParams)})
        keys = jax.random.split(key, 2)
        dev_keys = jax.random.split(keys[1], 8)
        direct = simulator.simulate_fork_join_batch(
            dev_keys[0], ArrivalProcess.stationary(lam_slab[:3]),
            jax.tree_util.tree_map(lambda x: x[:3], p_slab),
            3000, p=4, chunk_size=512,
            cluster=ClusterSpec(r=2, result_cache=(0.2, 2e-3)))
        flat_idx = [np.unravel_index(s, (5, 2, 1, 2)) for s in range(3)]
        got = np.asarray([res.stats.sum_response[l, 0, c, d, h, 1]
                          for (l, c, d, h) in flat_idx])
        np.testing.assert_allclose(got, np.asarray(direct.sum_response),
                                   rtol=1e-6)
        print('OK sharded sweep')
    """)
    assert "OK sharded sweep" in out
