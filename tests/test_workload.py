"""Workload characterization: distribution fits, Zipf, folding (Sec 4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import workload as W
from repro.workloadgen import loadgen, querygen


def _exp_samples(n=20000, mu=0.035, seed=0):
    return jax.random.exponential(jax.random.PRNGKey(seed), (n,)) * mu


def test_exponential_mle_recovers_mean():
    x = _exp_samples(mu=0.035)
    fit = W.fit_exponential(x)
    assert np.isclose(float(fit.params["mu"]), 0.035, rtol=0.05)


def test_gamma_mle_recovers_shape():
    key = jax.random.PRNGKey(1)
    x = jax.random.gamma(key, 3.0, (20000,)) * 2.0
    fit = W.fit_gamma(x)
    assert np.isclose(float(fit.params["k"]), 3.0, rtol=0.1)
    assert np.isclose(float(fit.params["theta"]), 2.0, rtol=0.1)


def test_weibull_mle_recovers_shape():
    key = jax.random.PRNGKey(2)
    u = jax.random.uniform(key, (20000,))
    x = 1.5 * (-jnp.log(u)) ** (1 / 2.0)          # Weibull(k=2, lam=1.5)
    fit = W.fit_weibull(x)
    assert np.isclose(float(fit.params["k"]), 2.0, rtol=0.1)
    assert np.isclose(float(fit.params["lam"]), 1.5, rtol=0.1)


def test_lognormal_and_pareto_fits():
    key = jax.random.PRNGKey(3)
    x = jnp.exp(jax.random.normal(key, (20000,)) * 0.5 - 2.0)
    fit = W.fit_lognormal(x)
    assert np.isclose(float(fit.params["mu"]), -2.0, atol=0.05)
    xp = 0.01 * (1 - jax.random.uniform(key, (20000,))) ** (-1 / 2.5)
    fitp = W.fit_pareto(xp)
    assert np.isclose(float(fitp.params["alpha"]), 2.5, rtol=0.1)


def test_ks_selects_exponential_for_poisson_gaps():
    """The paper's central claim (Fig 6): exponential fits interarrivals;
    lognormal and pareto fail."""
    x = _exp_samples()
    winner, stats = W.best_fit(x, criterion="ks")
    assert winner in ("exponential", "gamma", "weibull")  # paper: all close
    assert float(stats["exponential"]) < float(stats["lognormal"])
    assert float(stats["exponential"]) < float(stats["pareto"])


def test_ssq_criterion_agrees():
    x = _exp_samples(seed=9)
    _, stats = W.best_fit(x, criterion="ssq")
    assert float(stats["exponential"]) < float(stats["pareto"])


def test_zipf_alpha_recovery():
    """Fig 2: recover alpha from a sampled popularity distribution."""
    for alpha in (0.82, 0.98):
        ids = W.sample_zipf(jax.random.PRNGKey(4), 5000, alpha, (200_000,))
        freqs = W.rank_frequencies(ids, 5000)
        est = float(W.fit_zipf_alpha(freqs))
        assert abs(est - alpha) < 0.08, (alpha, est)


def test_folding_boost_factor():
    """Table 3: folding 243 days by a 1-week window boosts ~34x."""
    t = np.sort(np.random.default_rng(0).random(5000) * 243 * 86400)
    folded, boost = W.fold_timestamps(jnp.asarray(t), 7 * 86400.0)
    assert int(boost) == 35  # ceil(243/7)
    assert folded.shape == t.shape
    assert bool(jnp.all(jnp.diff(folded) >= 0))
    assert float(folded[-1]) <= 7 * 86400.0


def test_loadgen_diurnal_profile():
    t = loadgen.diurnal_arrivals(1.0, days=7, seed=0)
    hours = (t % 86400.0) // 3600
    counts = np.bincount(hours.astype(int), minlength=24)
    # peak-hour traffic well above trough (paper Fig 4)
    assert counts.max() > 2.0 * max(counts.min(), 1)


def test_querygen_matches_table2():
    cfg = querygen.WorkloadConfig("t", n_unique_queries=3000,
                                  vocab_size=2000, seed=0)
    uni = querygen.build_universe(cfg)
    qids, terms = querygen.sample_query_stream(uni, 30000)
    lens = (terms >= 0).sum(1)
    p1 = (lens == 1).mean()
    p2 = (lens == 2).mean()
    # stream proportions reflect the configured universe within tolerance
    # (popularity-weighted sampling skews slightly)
    assert abs(p1 - 0.32) < 0.1
    assert abs(p2 - 0.41) < 0.1
    assert np.median(lens) == 2  # paper: median query length 2
