"""Smoke tests for launch/elastic.py (revived by the staticcheck PR).

The module is the starting point for the ROADMAP autoscaling item; these
tests pin the arithmetic so it starts from working code.
"""

from __future__ import annotations

import math

import pytest

from repro.core import queueing
from repro.launch import elastic


def test_survivor_mesh_shrinks_data_axis():
    # 2 hosts x 4 chips lost out of a (8, 4) data x model mesh: the data
    # axis absorbs the loss, model stays intact.
    new = elastic.survivor_mesh_shape(
        (8, 4), failed_hosts=2, chips_per_host=4, axes=("data", "model"))
    assert new == (6, 4)


def test_survivor_mesh_raises_when_capacity_gone():
    with pytest.raises(ValueError):
        elastic.survivor_mesh_shape(
            (2, 4), failed_hosts=4, chips_per_host=4,
            axes=("data", "model"))


def test_plan_downsize_factors_are_reciprocal():
    plan = elastic.plan_downsize((8, 4), (6, 4))
    assert plan.throughput_fraction == pytest.approx(0.75)
    assert plan.step_time_factor == pytest.approx(4.0 / 3.0)
    assert plan.throughput_fraction * plan.step_time_factor == (
        pytest.approx(1.0))


def test_expected_straggler_tax_is_harmonic():
    # H_4 = 1 + 1/2 + 1/3 + 1/4
    assert elastic.expected_straggler_tax(4) == pytest.approx(
        25.0 / 12.0, rel=1e-5)
    # matches the queueing module it delegates to (Eq 6 factor)
    assert elastic.expected_straggler_tax(16) == pytest.approx(
        float(queueing.harmonic_number(16)), rel=1e-6)
    assert elastic.expected_straggler_tax(0) == pytest.approx(1.0)


def test_hedge_threshold_scales_with_log_p():
    r = 0.050
    assert elastic.hedge_threshold(r, 16) == pytest.approx(
        r * math.log(16))
    # duplicates twice as expensive -> wait twice as long
    assert elastic.hedge_threshold(
        r, 16, duplicate_cost_fraction=2.0) == pytest.approx(
        2 * r * math.log(16))
