"""Serving layer: continuous batcher + hedging, and the LM decode server."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as T
from repro.serving.engine import LMServer
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.workloadgen import loadgen


def test_batcher_serves_all_and_bounds_latency():
    step = lambda b: 0.01 + 0.001 * b
    sched = ContinuousBatcher(max_batch=8, step_time_fn=step, p_shards=8)
    arrivals = loadgen.poisson_arrivals(200.0, 1.0, seed=0)
    for i, t in enumerate(arrivals):
        sched.submit(Request(req_id=i, arrival=float(t)))
    sched.run_until(10.0)
    lats = sched.latencies()
    assert len(lats) == len(arrivals)
    assert min(lats) >= 0.005  # at least half a step (hedged floor)


def test_hedging_fires_under_overload_and_helps():
    step = lambda b: 0.05
    arrivals = loadgen.poisson_arrivals(300.0, 0.5, seed=1)

    def run(hedge):
        s = ContinuousBatcher(max_batch=4, step_time_fn=step, p_shards=64,
                              hedge=hedge)
        for i, t in enumerate(arrivals):
            s.submit(Request(req_id=i, arrival=float(t)))
        s.run_until(60.0)
        return s

    hedged = run(True)
    plain = run(False)
    assert hedged.hedges_fired > 0
    assert np.mean(hedged.latencies()) <= np.mean(plain.latencies())


def test_run_until_clamps_clock_and_ignores_future_arrivals():
    """Regression: idle-skipping to an arrival beyond t_end used to jump
    the clock past the horizon; the arrival must wait for the next call."""
    sched = ContinuousBatcher(max_batch=4, step_time_fn=lambda b: 0.01)
    sched.submit(Request(req_id=0, arrival=5.0))
    t = sched.run_until(2.0)
    assert t == 2.0                  # clamped to the horizon, not 5.0
    assert not sched.done            # nothing served before it arrived
    assert len(sched.queue) == 1
    t = sched.run_until(10.0, now=t)
    assert len(sched.done) == 1
    assert sched.done[0].start >= 5.0


def test_run_until_gates_batches_on_horizon():
    """A request arriving inside the window is served; one beyond t_end is
    not — even when both are queued together."""
    sched = ContinuousBatcher(max_batch=4, step_time_fn=lambda b: 0.01,
                              hedge=False)
    sched.submit(Request(req_id=0, arrival=1.0))
    sched.submit(Request(req_id=1, arrival=50.0))
    t = sched.run_until(10.0)
    assert t == 10.0                 # clamped, not jumped to 50.0
    assert [r.req_id for r in sched.done] == [0]
    assert len(sched.queue) == 1


def test_run_until_reports_batch_overrun():
    """A batch that starts before t_end but finishes after it must push
    the returned clock past the horizon, so chained calls cannot start a
    new batch while the server is still busy."""
    sched = ContinuousBatcher(max_batch=1, step_time_fn=lambda b: 5.0,
                              hedge=False)
    sched.submit(Request(req_id=0, arrival=0.0))
    t = sched.run_until(1.0)
    assert t == 5.0


def test_lm_server_generates():
    cfg = LMConfig(name="srv", n_layers=2, d_model=32, n_heads=4,
                   n_kv_heads=2, d_ff=64, vocab_size=128, d_head=8,
                   dtype="float32", vocab_pad_multiple=64)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    srv = LMServer(cfg, params, slots=2, max_seq=32)
    rng = np.random.default_rng(0)
    assert srv.admit(0, rng.integers(0, 128, 4).astype(np.int32), 5)
    assert srv.admit(1, rng.integers(0, 128, 4).astype(np.int32), 3)
    assert not srv.admit(2, rng.integers(0, 128, 4).astype(np.int32), 3)

    steps = 0
    while srv.step() and steps < 20:
        steps += 1
    done = {c["req_id"]: c for c in srv.completed}
    assert set(done) == {0, 1}
    assert len(done[0]["tokens"]) == 4 + 1 + 5
    assert len(done[1]["tokens"]) == 4 + 1 + 3
    assert all(0 <= t < cfg.vocab_padded
               for c in srv.completed for t in c["tokens"])
