"""Search-engine substrate: index invariants, scoring oracle, partitioning
equivalence, caches, broker merge."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import imbalance, queueing
from repro.engine import broker, cache as cache_lib
from repro.engine import corpus as corpus_lib
from repro.engine import index as index_lib
from repro.engine import partition, scoring, server
from repro.workloadgen import querygen


@pytest.fixture(scope="module")
def small_world():
    cfg = corpus_lib.CorpusConfig(n_docs=2000, vocab_size=1500,
                                  mean_doc_len=40, seed=0)
    corp = corpus_lib.generate_corpus(cfg)
    idx = index_lib.build_index(corp)
    wl = querygen.WorkloadConfig("t", n_unique_queries=500,
                                 vocab_size=1500, seed=0)
    uni = querygen.build_universe(wl)
    qids, qterms = querygen.sample_query_stream(uni, 128)
    return corp, idx, qterms


def test_index_invariants(small_world):
    corp, idx, _ = small_world
    assert idx.n_postings == corp.n_postings
    lens = idx.list_lengths()
    assert lens.sum() == idx.n_postings
    # postings doc-sorted within each term
    for t in np.random.default_rng(0).integers(0, 1500, 20):
        lo, hi = idx.term_offsets[t], idx.term_offsets[t + 1]
        docs = idx.doc_ids[lo:hi]
        assert (np.diff(docs) > 0).all()  # strictly increasing (unique)


def test_scoring_matches_bruteforce(small_world):
    corp, idx, qterms = small_world
    srv = server.IndexServer(idx, k_local=5)
    scores, docs = srv.process(jnp.asarray(qterms[:16]))
    scores, docs = np.asarray(scores), np.asarray(docs)

    # brute force: reconstruct doc-term matrix
    lens = np.diff(corp.doc_offsets)
    doc_of = np.repeat(np.arange(corp.n_docs), lens)
    for qi in range(4):
        terms = qterms[qi][qterms[qi] >= 0]
        match = None
        weights = np.zeros(corp.n_docs)
        for t in terms:
            sel = corp.doc_terms == t
            docs_t = doc_of[sel]
            w = corp.tf[sel] * idx.idf[t]
            hit = np.zeros(corp.n_docs, bool)
            hit[docs_t] = True
            weights[docs_t] += w
            match = hit if match is None else (match & hit)
        if match is None or not match.any():
            assert scores[qi, 0] == -np.inf or scores[qi, 0] <= 0 \
                or not np.isfinite(scores[qi, 0])
            continue
        cos = np.where(match, weights / idx.doc_norms, -np.inf)
        best = np.argmax(cos)
        assert np.isclose(scores[qi, 0], cos[best], rtol=1e-4)
        assert cos[docs[qi, 0]] >= cos[best] * (1 - 1e-5)


def test_document_partition_equals_single(small_world):
    """p-way document partitioning + broker merge == single index top-k —
    the correctness contract of Fig 1."""
    corp, idx, qterms = small_world
    q = jnp.asarray(qterms[:8])
    srv = server.IndexServer(idx, k_local=5)
    s_ref, d_ref = srv.process(q)

    part = partition.partition_documents(corp, 4)
    partial_s, partial_d = [], []
    for sh, shard in enumerate(part.shards):
        s = server.IndexServer(shard, k_local=5)
        ss, dd = s.process(q)
        g = np.asarray(part.local_to_global[sh])
        partial_s.append(np.asarray(ss))
        partial_d.append(g[np.asarray(dd)])
    ms, md = broker.merge_topk(jnp.asarray(np.stack(partial_s)),
                               jnp.asarray(np.stack(partial_d)), k=5)
    np.testing.assert_allclose(np.asarray(ms), np.asarray(s_ref), rtol=1e-4)


def test_term_partition_covers_all_postings(small_world):
    corp, idx, _ = small_world
    part = partition.partition_terms(corpus_lib.generate_corpus(
        corpus_lib.CorpusConfig(n_docs=500, vocab_size=300,
                                mean_doc_len=20, seed=1)), 3)
    total = sum(s.n_postings for s in part.shards)
    c2 = corpus_lib.generate_corpus(
        corpus_lib.CorpusConfig(n_docs=500, vocab_size=300,
                                mean_doc_len=20, seed=1))
    assert total == c2.n_postings


def test_lru_cache_hit_monotone_in_memory(small_world):
    corp, idx, qterms = small_world
    stream = np.tile(qterms, (4, 1))
    sizes = idx.list_bytes()
    hits = []
    for frac in (0.02, 0.1, 0.5):
        cap = int(sizes.sum() * frac)
        stats, _, _ = cache_lib.measure_cache_behavior(stream, sizes, cap)
        hits.append(stats.hit)
    assert hits[0] <= hits[1] <= hits[2]
    assert hits[2] > 0.3  # zipf reuse means big cache mostly hits


def test_result_cache_hit_ratio():
    rc = cache_lib.ResultCache(100)
    rng = np.random.default_rng(0)
    ids = rng.zipf(1.5, 5000) % 500
    for i in ids:
        rc.lookup(int(i))
    assert 0.3 < rc.hit_ratio < 0.99


def test_measured_params_drive_model(small_world):
    """The full paper methodology: measure one server, feed Eq 1-7."""
    corp, idx, qterms = small_world
    srv = server.IndexServer(idx, k_local=5)
    stream = np.tile(qterms, (3, 1))
    params = server.measure_service_params(
        srv, stream, cache_bytes=idx.index_bytes() // 10,
        p=8, s_broker=0.5e-3, batch=32)
    assert 0.0 <= float(params.hit) <= 1.0
    s = float(queueing.service_time_server(params))
    assert 0 < s < 1.0
    lam = 0.5 / s                           # 50% utilization
    lo, hi = queueing.response_time_bounds(lam, params)
    assert float(lo) < float(hi) < 10.0


def test_che_cache_model_properties():
    """Analytical disk-cache model: hit grows with memory AND with p
    (paper Sec 3.4: more servers -> smaller lists -> better caching)."""
    rng = np.random.default_rng(0)
    t = 2000
    rates = np.asarray(querygen._zipf_cdf(t, 1.0))
    rates = np.diff(np.concatenate([[0], rates])) * 10.0
    sizes = (rng.pareto(1.2, t) + 1) * 2e4

    def hit(p, mem):
        geom = imbalance.CacheGeometry(
            term_rates=jnp.asarray(rates, jnp.float32),
            list_bytes=jnp.asarray(sizes, jnp.float32),
            cache_bytes=mem, p=p)
        qt = jnp.asarray(rng.integers(0, t, (200, 2)).astype(np.int32))
        ln = jnp.full((200,), 2, jnp.int32)
        return float(jnp.mean(
            imbalance.query_full_hit_probability(geom, qt, ln)))

    assert hit(8, 1e6) < hit(8, 1e7) <= 1.0
    assert hit(2, 3e6) < hit(32, 3e6) <= 1.0


def test_imbalance_probability_peak():
    p = 8
    h = jnp.asarray([0.0, 0.5, 1.0])
    pi = imbalance.imbalance_probability(h, p)
    assert float(pi[0]) == 0.0 and float(pi[2]) == 0.0
    assert float(pi[1]) > 0.99  # half-hit rate nearly guarantees a split
