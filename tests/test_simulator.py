"""Max-plus DES validation: theory cross-checks + paper Fig 9-11 behavior."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, queueing, simulator
from repro.core.queueing import ServerParams

MM1 = ServerParams(p=1, s_broker=1e-9, s_hit=1.0, s_miss=1.0, s_disk=0.0,
                   hit=1.0)


def test_mm1_mean_response_matches_theory():
    for rho in (0.3, 0.6):
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(0), rho, 120_000, MM1, mode="exponential")
        expect = 1.0 / (1.0 - rho)
        assert abs(float(res.mean_response) - expect) / expect < 0.06, rho


def test_fcfs_recurrence_definition():
    """Completion times match the literal FCFS recurrence."""
    rng = np.random.default_rng(0)
    a = np.sort(rng.random(200) * 10)
    s = rng.random(200) * 0.5
    c = simulator.fcfs_completion_times(jnp.asarray(a), jnp.asarray(s))
    expect = np.zeros(200)
    prev = 0.0
    for i in range(200):
        prev = max(a[i], prev) + s[i]
        expect[i] = prev
    np.testing.assert_allclose(np.asarray(c), expect, rtol=1e-5)


def test_fork_join_within_paper_bounds():
    """Fig 10: measured response lies within Eq 7's bounds, near the upper
    bound at heavy load (paper: ~20% below at p=8, lam=28)."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), 28.0, 150_000, pr, mode="exponential")
    lo, hi = queueing.response_time_bounds(28.0, pr)
    m = float(res.mean_response)
    assert float(lo) < m < float(hi) * 1.02
    assert m > 0.6 * float(hi)  # closer to upper at heavy load


def test_balanced_mode_matches_lower_bound():
    """The Chowdhury & Pass assumption (no imbalance) sits at the lower
    bound — the paper's argument for why prior models underestimate."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(2), 20.0, 100_000, pr, mode="balanced")
    lo, hi = queueing.response_time_bounds(20.0, pr)
    assert abs(float(res.mean_response) - float(lo)) < 0.25 * (
        float(hi) - float(lo))


def test_cache_mode_between_bounds():
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(3), 20.0, 100_000, pr, mode="cache")
    lo, hi = queueing.response_time_bounds(20.0, pr)
    assert float(lo) * 0.95 < float(res.mean_response) < float(hi) * 1.05


def test_response_grows_with_p():
    """Fig 11: response time grows with the number of index servers."""
    means = []
    for p in (2, 4, 8, 16):
        pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=p)
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(4), 15.0, 60_000, pr, mode="exponential")
        means.append(float(res.mean_response))
    assert means == sorted(means)


def test_mmc_reduces_to_mm1():
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(5),
                                            (50_000,)) / 0.5)
    svc = jax.random.exponential(jax.random.PRNGKey(6), (50_000,))
    r1 = simulator.simulate_mmc(arr, svc, c=1)
    assert abs(float(jnp.mean(r1[5000:])) - 2.0) < 0.2


def test_mmc_multiserver_beats_single():
    """Future-work extension: 2 threads at same per-thread speed cut
    waiting drastically."""
    lam, mu = 1.5, 1.0  # rho = 0.75 on 2 servers; unstable on 1
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(7),
                                            (50_000,)) / lam)
    svc = jax.random.exponential(jax.random.PRNGKey(8), (50_000,)) / mu
    r2 = simulator.simulate_mmc(arr, svc, c=2)
    # Erlang-C M/M/2 at rho=0.75: W = ~1.93 (response = wait + service)
    mean = float(jnp.mean(r2[5000:]))
    assert 1.5 < mean < 2.4


def test_pallas_impl_matches_xla():
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    r1 = simulator.simulate_fork_join(jax.random.PRNGKey(9), 20.0, 20_000,
                                      pr, impl="xla")
    r2 = simulator.simulate_fork_join(jax.random.PRNGKey(9), 20.0, 20_000,
                                      pr, impl="pallas")
    np.testing.assert_allclose(float(r1.mean_response),
                               float(r2.mean_response), rtol=1e-4)


def test_thousand_server_scale():
    """The paper's stated future work: simulate thousands of servers."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=1024)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(10), 10.0, 20_000, pr, mode="exponential")
    lo, hi = queueing.response_time_bounds(10.0, pr)
    assert float(lo) < float(res.mean_response) < float(hi) * 1.05
