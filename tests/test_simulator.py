"""Max-plus DES validation: theory cross-checks + paper Fig 9-11 behavior,
plus the streaming engine's chunking/warmup/arrival-process contracts."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, queueing, simulator
from repro.core.arrivals import ArrivalProcess
from repro.core.queueing import ServerParams

MM1 = ServerParams(p=1, s_broker=1e-9, s_hit=1.0, s_miss=1.0, s_disk=0.0,
                   hit=1.0)


@pytest.fixture
def x64():
    """Temporarily enable float64 so association-order noise vanishes."""
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


def _monolithic_reference(key, lam, params, n_queries, p, mode, chunk,
                          warmup_fraction=0.1):
    """Rebuild the streaming engine's exact sample path, scanned whole.

    Uses the SAME chunk-invariant RNG plan (`chunk_random_draws`) to
    materialize every random draw, then runs the old-style monolithic
    whole-sequence scans and returns the post-warmup per-query responses.
    """
    vp = simulator._vec_params(params)
    n_chunks = -(-n_queries // chunk)
    ug, ub, sv = [], [], []
    for c in range(n_chunks):
        g, b, s = simulator.chunk_random_draws(key, c, 1, chunk, p, vp,
                                               mode)
        ug.append(g)
        ub.append(b)
        sv.append(s)
    ug = jnp.concatenate(ug, -1)[:, :n_queries]
    ub = jnp.concatenate(ub, -1)[:, :n_queries]
    sv = jnp.concatenate(sv, -1)[:, :, :n_queries]
    arrivals = jnp.cumsum(ug / lam, -1)
    broker_done = simulator.fcfs_completion_times(
        arrivals, ub * params.s_broker)
    completions = simulator.fcfs_completion_times(
        jnp.broadcast_to(broker_done[:, None, :], sv.shape), sv)
    response = (completions.max(axis=1) - arrivals)[0]
    return response[int(n_queries * warmup_fraction):]


def test_streaming_matches_monolithic_mean(x64):
    """Acceptance: same key, same RNG plan — streaming mean within 1e-5
    of the monolithic whole-sequence scan on the Table 5 cluster."""
    pr = capacity.TABLE5_PARAMS
    key = jax.random.PRNGKey(0)
    n, chunk = 50_000, 4096
    res = simulator.simulate_fork_join(key, 20.0, n, pr, chunk_size=chunk)
    ref = _monolithic_reference(key, 20.0, pr, n, 8, "exponential", chunk)
    np.testing.assert_allclose(float(res.mean_response),
                               float(jnp.mean(ref)), rtol=1e-5)


def test_streaming_p99_matches_unmasked_reference(x64):
    """Warmup is truly discarded: the streaming-histogram p99 tracks an
    unmasked reference run (the old mean-substitution masking injected
    n_warm copies of the mean, dragging every quantile toward it)."""
    pr = capacity.TABLE5_PARAMS
    key = jax.random.PRNGKey(1)
    n, chunk = 60_000, 4096
    res = simulator.simulate_fork_join(key, 24.0, n, pr, chunk_size=chunk,
                                       hist_bins=512)
    ref = _monolithic_reference(key, 24.0, pr, n, 8, "exponential", chunk)
    for q in (0.5, 0.95, 0.99):
        np.testing.assert_allclose(float(res.quantile(q)),
                                   float(jnp.quantile(ref, q)), rtol=0.05)
    # count reflects true discard, not masking
    assert float(res.count) == n - int(n * 0.1)


def test_chunk_count_does_not_move_the_estimate():
    """Carry-seeded chunking is exact: the same RNG plan scanned in 4096-
    query chunks equals the reference scanned monolithically (f32 noise
    only), for several chunk counts."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    key = jax.random.PRNGKey(2)
    chunk = 2048
    for n in (2048, 6144, 10_000):
        res = simulator.simulate_fork_join(key, 18.0, n, pr,
                                           chunk_size=chunk)
        ref = _monolithic_reference(key, 18.0, pr, n, 4, "exponential",
                                    chunk)
        np.testing.assert_allclose(float(res.mean_response),
                                   float(jnp.mean(ref)), rtol=2e-4)


def test_diurnal_process_raises_mean_over_stationary():
    """Time-varying load at the same average rate costs latency (response
    is convex in rho) — the scenario class the old engine could not
    express."""
    pr = capacity.TABLE5_PARAMS
    proc = ArrivalProcess.piecewise(jnp.asarray([10.0, 30.0]), 60.0)
    key = jax.random.PRNGKey(3)
    diurnal = simulator.simulate_fork_join(key, proc, 80_000, pr)
    flat = simulator.simulate_fork_join(key, 20.0, 80_000, pr)
    assert float(diurnal.mean_response) > 1.2 * float(flat.mean_response)


def test_trace_replay_matches_stationary_statistics():
    """Replaying a Poisson trace reproduces the drawn-gaps statistics."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    lam, n = 18.0, 60_000
    gaps = np.random.default_rng(0).exponential(1.0 / lam, n)
    trace = ArrivalProcess.from_trace(jnp.asarray(np.cumsum(gaps)))
    res = simulator.simulate_fork_join(jax.random.PRNGKey(4), trace, n, pr)
    lo, hi = queueing.response_time_bounds(lam, pr)
    assert float(lo) * 0.95 < float(res.mean_response) < float(hi) * 1.05


def test_mm1_mean_response_matches_theory():
    for rho in (0.3, 0.6):
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(0), rho, 120_000, MM1, mode="exponential")
        expect = 1.0 / (1.0 - rho)
        assert abs(float(res.mean_response) - expect) / expect < 0.06, rho


def test_fcfs_recurrence_definition():
    """Completion times match the literal FCFS recurrence."""
    rng = np.random.default_rng(0)
    a = np.sort(rng.random(200) * 10)
    s = rng.random(200) * 0.5
    c = simulator.fcfs_completion_times(jnp.asarray(a), jnp.asarray(s))
    expect = np.zeros(200)
    prev = 0.0
    for i in range(200):
        prev = max(a[i], prev) + s[i]
        expect[i] = prev
    np.testing.assert_allclose(np.asarray(c), expect, rtol=1e-5)


def test_fork_join_within_paper_bounds():
    """Fig 10: measured response lies within Eq 7's bounds, near the upper
    bound at heavy load (paper: ~20% below at p=8, lam=28)."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), 28.0, 150_000, pr, mode="exponential")
    lo, hi = queueing.response_time_bounds(28.0, pr)
    m = float(res.mean_response)
    assert float(lo) < m < float(hi) * 1.02
    assert m > 0.6 * float(hi)  # closer to upper at heavy load


def test_balanced_mode_matches_lower_bound():
    """The Chowdhury & Pass assumption (no imbalance) sits at the lower
    bound — the paper's argument for why prior models underestimate."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(2), 20.0, 100_000, pr, mode="balanced")
    lo, hi = queueing.response_time_bounds(20.0, pr)
    assert abs(float(res.mean_response) - float(lo)) < 0.25 * (
        float(hi) - float(lo))


def test_cache_mode_between_bounds():
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(3), 20.0, 100_000, pr, mode="cache")
    lo, hi = queueing.response_time_bounds(20.0, pr)
    assert float(lo) * 0.95 < float(res.mean_response) < float(hi) * 1.05


def test_response_grows_with_p():
    """Fig 11: response time grows with the number of index servers."""
    means = []
    for p in (2, 4, 8, 16):
        pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=p)
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(4), 15.0, 60_000, pr, mode="exponential")
        means.append(float(res.mean_response))
    assert means == sorted(means)


def test_mmc_reduces_to_mm1():
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(5),
                                            (50_000,)) / 0.5)
    svc = jax.random.exponential(jax.random.PRNGKey(6), (50_000,))
    r1 = simulator.simulate_mmc(arr, svc, c=1)
    assert abs(float(jnp.mean(r1[5000:])) - 2.0) < 0.2


def test_mmc_matches_erlang_c_mean():
    """Kiefer-Wolfowitz DES vs the closed-form Erlang-C M/M/c response."""
    lam, s, c = 2.1, 1.0, 3          # rho = 0.7 on 3 servers
    analytic = float(queueing.mmc_residence_time(lam, s, c))
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(11),
                                            (150_000,)) / lam)
    svc = jax.random.exponential(jax.random.PRNGKey(12), (150_000,)) * s
    sim = float(jnp.mean(simulator.simulate_mmc(arr, svc, c=c)[15_000:]))
    assert abs(sim - analytic) / analytic < 0.06, (sim, analytic)


def test_mmc_multiserver_beats_single():
    """Future-work extension: 2 threads at same per-thread speed cut
    waiting drastically."""
    lam, mu = 1.5, 1.0  # rho = 0.75 on 2 servers; unstable on 1
    arr = jnp.cumsum(jax.random.exponential(jax.random.PRNGKey(7),
                                            (50_000,)) / lam)
    svc = jax.random.exponential(jax.random.PRNGKey(8), (50_000,)) / mu
    r2 = simulator.simulate_mmc(arr, svc, c=2)
    # Erlang-C M/M/2 at rho=0.75: W = ~1.93 (response = wait + service)
    mean = float(jnp.mean(r2[5000:]))
    assert 1.5 < mean < 2.4


def test_pallas_impl_matches_xla():
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    r1 = simulator.simulate_fork_join(jax.random.PRNGKey(9), 20.0, 20_000,
                                      pr, impl="xla")
    r2 = simulator.simulate_fork_join(jax.random.PRNGKey(9), 20.0, 20_000,
                                      pr, impl="pallas")
    np.testing.assert_allclose(float(r1.mean_response),
                               float(r2.mean_response), rtol=1e-4)


def test_thousand_server_scale():
    """The paper's stated future work: simulate thousands of servers."""
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=1024)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(10), 10.0, 20_000, pr, mode="exponential")
    lo, hi = queueing.response_time_bounds(10.0, pr)
    assert float(lo) < float(res.mean_response) < float(hi) * 1.05
