"""Unit tests for the analytical queueing model (paper Eq 1-8)."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, queueing
from repro.core.queueing import ServerParams


def test_harmonic_number_integer_values():
    assert np.isclose(float(queueing.harmonic_number(1)), 1.0, atol=1e-5)
    assert np.isclose(float(queueing.harmonic_number(4)),
                      1 + 0.5 + 1 / 3 + 0.25, atol=1e-5)
    # H_100 drives the Section 6 case study
    assert np.isclose(float(queueing.harmonic_number(100)), 5.18738,
                      atol=1e-3)


def test_eq1_service_time_decomposition():
    p = ServerParams(p=8, s_broker=0.5e-3, s_hit=9.2e-3, s_miss=10.04e-3,
                     s_disk=28.08e-3, hit=0.17)
    s = float(queueing.service_time_server(p))
    expect = 0.17 * 9.2e-3 + 0.83 * (10.04e-3 + 28.08e-3)
    assert np.isclose(s, expect, rtol=1e-6)


def test_mm1_textbook():
    # rho = 0.5 -> R = S / (1 - rho) = 2S
    assert np.isclose(float(queueing.mm1_residence_time(0.5, 1.0)), 2.0,
                      rtol=1e-6)
    # at saturation -> inf
    assert np.isinf(float(queueing.mm1_residence_time(1.0, 1.0)))
    assert np.isinf(float(queueing.mm1_residence_time(2.0, 1.0)))


def test_bounds_ordering_and_logarithmic_gap():
    params = capacity.TABLE5_PARAMS
    lam = 20.0
    lo, hi = queueing.response_time_bounds(lam, params)
    assert float(lo) < float(hi)
    # gap is exactly H_p on the server component (paper Sec 5.2.2)
    r_b = queueing.broker_residence_time(lam, params)
    ratio = (float(hi) - float(r_b)) / (float(lo) - float(r_b))
    assert np.isclose(ratio, float(queueing.harmonic_number(8)), rtol=1e-5)


def test_interpolation_within_bounds():
    params = capacity.TABLE5_PARAMS
    for lam in [1.0, 10.0, 20.0, 28.0]:
        lo = queueing.fork_join_lower_bound(lam, params)
        hi = queueing.fork_join_upper_bound(lam, params)
        mid = queueing.fork_join_interpolation(lam, params)
        assert float(lo) <= float(mid) <= float(hi) * (1 + 1e-6), lam


def test_utilization_92_percent_at_28qps():
    """Paper Sec 5.3: U_server approaches 92% at 28 qps."""
    u = queueing.utilization(
        28.0, queueing.service_time_server(capacity.TABLE5_PARAMS))
    assert 0.90 < float(u) < 0.95


def test_result_cache_eq8_reduces_response():
    params = capacity.scenario("memory+cpus+disks")
    lam = 50.0
    _, hi = queueing.response_time_bounds(lam, params)
    hi_c = queueing.response_time_with_result_cache(
        lam, params, 0.5, 0.069e-3)
    assert float(hi_c) < float(hi)
    # hit -> 1 collapses to the broker-cache response
    hi_all = queueing.response_time_with_result_cache(
        lam, params, 1.0, 0.069e-3)
    assert float(hi_all) < 1e-3


def test_quantile_upper_exceeds_mean_bound():
    params = capacity.TABLE5_PARAMS
    q99 = queueing.response_time_quantile_upper(20.0, params, 0.99)
    _, hi = queueing.response_time_bounds(20.0, params)
    assert float(q99) > float(hi) * 0.9  # p99 of max >> mean bound region


def test_expected_max_exponential_is_hp():
    val = queueing.expected_max_exponential(8, 2.0)
    assert np.isclose(float(val), float(queueing.harmonic_number(8)) * 2.0,
                      rtol=1e-6)


def test_broadcasting_over_lambda_grid():
    grid = jnp.linspace(1.0, 25.0, 50)
    lo, hi = queueing.response_time_bounds(grid, capacity.TABLE5_PARAMS)
    assert lo.shape == (50,) and hi.shape == (50,)
    assert bool(jnp.all(jnp.diff(hi) > 0))  # monotone in lambda
