"""Replicated-cluster simulation layer: dispatcher routing, the broker
result cache, and their agreement with the paper's Sec-6 sizing math.

The engine simulates r replicas as masked max-plus scans over the FULL
arrival stream (zero-service phantoms for queries routed elsewhere), so
the first test pins that algebra sample-path-for-sample-path against a
literal per-replica subsequence reference.  The rest cross-check the
analytical path: Eq 7 at ``lam / r`` at low utilization, Eq 8 with the
result cache, and ``replicas_needed``'s SLO boundary (the ISSUE's
acceptance criterion).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capacity, queueing, simulator, sweep
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec
from repro.core.queueing import ServerParams

T5 = capacity.TABLE5_PARAMS


@pytest.fixture
def x64():
    old = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        yield
    finally:
        jax.config.update("jax_enable_x64", old)


def _materialized_draws(key, lam, params, n, p, chunk):
    """Canonical RNG plan materialized whole (same as the streaming run)."""
    vp = simulator._vec_params(params)
    n_chunks = -(-n // chunk)
    ug, ub, sv = [], [], []
    for c in range(n_chunks):
        g, b, s = simulator.chunk_random_draws(key, c, 1, chunk, p, vp,
                                               "exponential")
        ug.append(g)
        ub.append(b)
        sv.append(s)
    ug = jnp.concatenate(ug, -1)[:, :n]
    ub = jnp.concatenate(ub, -1)[:, :n]
    sv = jnp.concatenate(sv, -1)[:, :, :n]
    arrivals = jnp.cumsum(ug / lam, -1)
    return arrivals, ub * params.s_broker, sv


def test_round_robin_equals_subsequence_reference(x64):
    """The masked-phantom engine IS per-replica FCFS on the routed
    subsequences: round-robin r=2, same canonical draws, per-query sample
    paths rebuilt replica by replica — means agree to 1e-5."""
    lam, n, chunk, p, r = 40.0, 20_000, 4096, 8, 2
    key = jax.random.PRNGKey(0)
    arrivals, s_brk, sv = _materialized_draws(key, lam, T5, n, p, chunk)

    assign = np.arange(n) % r
    response = np.zeros(n)
    for k in range(r):
        idx = np.where(assign == k)[0]
        arr_k = arrivals[:, idx]
        brk = simulator.fcfs_completion_times(arr_k, s_brk[:, idx])
        comp = simulator.fcfs_completion_times(
            jnp.broadcast_to(brk[:, None, :], sv[:, :, idx].shape),
            sv[:, :, idx])
        response[idx] = np.asarray(comp.max(axis=1)[0] - arr_k[0])
    n_warm = int(n * 0.1)
    ref_mean = float(np.mean(response[n_warm:]))

    res = simulator.simulate_fork_join(key, lam, n, T5,
                                       cluster=ClusterSpec(r=r),
                                       chunk_size=chunk)
    np.testing.assert_allclose(float(res.mean_response), ref_mean,
                               rtol=1e-5)


def test_result_cache_hit0_bit_identical():
    """ACCEPTANCE: hit_r=0 compiles the cache path in but reproduces the
    pre-replication engine bit for bit (the cache RNG is salted)."""
    base = simulator.simulate_fork_join(jax.random.PRNGKey(1), 20.0,
                                        30_000, T5)
    zero = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), 20.0, 30_000, T5,
        cluster=ClusterSpec(result_cache=(0.0, 1e-3)))
    np.testing.assert_array_equal(np.asarray(base.sum_response),
                                  np.asarray(zero.sum_response))
    np.testing.assert_array_equal(np.asarray(base.hist),
                                  np.asarray(zero.hist))
    np.testing.assert_array_equal(np.asarray(base.sum_broker),
                                  np.asarray(zero.sum_broker))


def test_low_utilization_matches_analytic_prediction():
    """ACCEPTANCE: at low per-replica utilization the r-replica simulated
    mean converges to the Eq-7 prediction at lam / r (imbalance puts the
    exponential-mode mean at the H_p upper bound as rho -> 0)."""
    lam, r = 9.0, 3                       # per-replica util ~ 0.10
    _, hi = queueing.response_time_bounds(lam / r, T5)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(2), lam, 120_000, T5,
        cluster=ClusterSpec(r=r, routing="random"))
    rel = abs(float(res.mean_response) - float(hi)) / float(hi)
    assert rel <= 0.10, (float(res.mean_response), float(hi), rel)


def test_random_split_matches_single_replica():
    """Random routing thins Poisson(r * lam) into r independent
    Poisson(lam) streams, so r replicas at r x the load behave like one
    cluster at 1x — the linear-gain assumption of replicas_needed."""
    lam = 20.0
    one = simulator.simulate_fork_join(jax.random.PRNGKey(3), lam,
                                       150_000, T5)
    rep = simulator.simulate_fork_join(
        jax.random.PRNGKey(4), 3 * lam, 450_000, T5,
        cluster=ClusterSpec(r=3, routing="random"))
    m1, m3 = float(one.mean_response), float(rep.mean_response)
    assert abs(m3 - m1) / m1 <= 0.08, (m1, m3)


def test_routing_ordering_under_imbalanced_service():
    """JSQ <= round-robin <= random in mean response under highly
    variable (cache-mode, low-hit) service draws.

    Note the oblivious pair's ordering: round-robin BEATS random
    splitting — it feeds each replica Erlang-r interarrivals, which are
    smoother than random's Poisson thinning (E_r/G/1 waits less than
    M/G/1).  The load-aware JSQ dominates both.  The ISSUE sketch
    conjectured random <= round-robin; theory and measurement both give
    the order asserted here.
    """
    params = dataclasses.replace(capacity.scenario_params(memory=1, p=4),
                                 p=4)
    lam = 3 * 0.75 / float(queueing.service_time_server(params))
    means = {}
    for routing in simulator.ROUTING_POLICIES:
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(5), lam, 150_000, params, p=4,
            mode="cache", cluster=ClusterSpec(r=3, routing=routing))
        means[routing] = float(res.mean_response)
    assert means["jsq"] <= means["round_robin"] * 1.02, means
    assert means["round_robin"] <= means["random"] * 1.02, means
    # JSQ's advantage is real, not noise
    assert means["jsq"] <= means["random"] * 0.95, means


def test_slo_boundary_matches_replicas_needed():
    """ACCEPTANCE: the simulated SLO boundary of the replicated cluster
    sits within 10% of the analytical one replicas_needed plans against,
    at the paper's Table 5 operating point (p=8 validation cluster).

    The boundary is a RATE: max_rate_under_slo bisects the Eq 7 upper
    bound; here a rate sweep of the r=3 simulated topology locates where
    the simulated mean crosses the same SLO.
    """
    slo, r = 0.9, 3
    lam_star = float(capacity.max_rate_under_slo(T5, slo))
    factors = np.linspace(0.85, 1.15, 5)
    vec = ServerParams(**{
        f.name: jnp.asarray([getattr(T5, f.name)] * len(factors),
                            jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    lams = jnp.asarray(factors * lam_star * r, jnp.float32)
    res = simulator.simulate_fork_join_batch(
        jax.random.PRNGKey(6), lams, vec, 200_000, p=8,
        cluster=ClusterSpec(r=r, routing="random"))
    means = np.asarray(res.mean_response)
    assert means[0] < slo < means[-1], means
    cross = float(np.interp(slo, means, factors * lam_star))
    rel = abs(cross - lam_star) / lam_star
    assert rel <= 0.10, (cross, lam_star, rel)


def test_result_cache_below_eq8_bound_and_helps():
    """The mechanistic cache thins replica load, so the simulated mean
    sits at or below the conservative Eq 8 mixture — and strictly below
    the cache-less run."""
    lam, r, cache = 60.0, 3, (0.3, 2e-3)
    with_cache = simulator.simulate_fork_join(
        jax.random.PRNGKey(7), lam, 150_000, T5,
        cluster=ClusterSpec(r=r, routing="random", result_cache=cache))
    without = simulator.simulate_fork_join(
        jax.random.PRNGKey(7), lam, 150_000, T5,
        cluster=ClusterSpec(r=r, routing="random"))
    eq8 = float(queueing.response_time_with_result_cache(
        lam / r, T5, *cache))
    m = float(with_cache.mean_response)
    assert m <= eq8 * 1.05, (m, eq8)
    assert m < float(without.mean_response) * 0.85


def test_result_cache_is_per_replica():
    """The cache lives at each replica's broker (Eq 8's placement), so
    its load splits with r: at hit_r=0.9 and 450 qps total, a single
    dispatcher-level cache would saturate (405 qps x 5 ms = rho 2.0)
    while four per-replica caches run at rho ~0.5.  The simulated mean
    must land inside the mechanistic (load-thinned) per-replica
    envelope, not blow up."""
    lam, r, (hit_r, s_cache) = 450.0, 4, (0.9, 5e-3)
    assert lam * hit_r * s_cache > 1.0   # one shared cache WOULD saturate
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(11), lam, 200_000, T5,
        cluster=ClusterSpec(r=r, routing="random",
                            result_cache=(hit_r, s_cache)))
    m = float(res.mean_response)
    # thinned per-replica operating point: hits at lam*hit_r/r on the
    # cache queue, misses at lam*(1-hit_r)/r on the fork-join
    r_cache = float(queueing.mm1_residence_time(lam * hit_r / r, s_cache))
    lo, hi = queueing.response_time_bounds(lam * (1.0 - hit_r) / r, T5)
    lo_env = hit_r * r_cache + (1.0 - hit_r) * float(lo)
    hi_env = hit_r * r_cache + (1.0 - hit_r) * float(hi)
    assert np.isfinite(m)
    assert lo_env * 0.9 <= m <= hi_env * 1.1, (m, lo_env, hi_env)


def test_replicated_under_flash_crowd_profile():
    """Replicas + ArrivalProcess compose: a flash-crowd profile at the
    same average rate costs tail latency that extra replicas win back."""
    crowd = ArrivalProcess.flash_crowd(
        45.0, burst_starts=[200.0], burst_seconds=200.0,
        burst_multiplier=3.0, period_seconds=1000.0, bin_seconds=100.0)
    kw = dict(mode="exponential", chunk_size=1024)
    r2 = simulator.simulate_fork_join(jax.random.PRNGKey(8), crowd,
                                      120_000, T5,
                                      cluster=ClusterSpec(r=2), **kw)
    r4 = simulator.simulate_fork_join(jax.random.PRNGKey(8), crowd,
                                      120_000, T5,
                                      cluster=ClusterSpec(r=4), **kw)
    assert float(r4.quantile(0.95)) < float(r2.quantile(0.95))
    assert float(r4.mean_response) < float(r2.mean_response)


def test_sweep_replica_axis_and_frontier():
    """The r grid axis: analytic surface = Eq 7 at lam/r, the simulated
    surface tracks it, and the frontier buys replicas exactly when one
    cluster saturates (cost scales with r)."""
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([20.0, 70.0]), p=jnp.asarray([8.0]),
        base=T5, hit=jnp.asarray([0.17]), broker_from_p=False,
        r=jnp.asarray([1.0, 3.0]))
    assert grid.shape == (2, 1, 1, 1, 1, 2)
    ana = sweep.sweep_analytical(grid)
    # spot-check the per-replica evaluation
    _, hi = queueing.response_time_bounds(70.0 / 3.0, T5)
    np.testing.assert_allclose(
        float(ana.response_upper[1, 0, 0, 0, 0, 1]), float(hi), rtol=1e-5)
    # lam=70 saturates one cluster (util ~2.3) but not three
    assert not np.isfinite(float(ana.response_upper[1, ..., 0].max()))
    assert np.isfinite(float(ana.response_upper[1, ..., 1].max()))

    fr = sweep.extract_frontier(ana, 0.9)
    assert bool(fr.feasible[0]) and bool(fr.feasible[1])
    assert float(fr.r[0]) == 1.0      # light load: one replica suffices
    assert float(fr.r[1]) == 3.0      # heavy load: must replicate
    assert float(fr.cost[1]) == pytest.approx(3 * float(fr.cost[0]))
    assert "x3 replicas" in fr.describe(1)

    sim = sweep.sweep_simulated(grid, jax.random.PRNGKey(9),
                                n_queries=40_000,
                                cluster=ClusterSpec(routing="random"))
    assert sim.mean.shape == grid.shape
    lo = np.asarray(ana.response_lower)
    hi = np.asarray(ana.response_upper)
    m = np.asarray(sim.mean)
    ok = np.isfinite(hi)              # skip the saturated (r=1, 70qps) cell
    assert np.all(m[ok] > lo[ok] * 0.95)
    assert np.all(m[ok] < hi[ok] * 1.05)


def test_plan_capacity_simulated_crosscheck():
    """plan_capacity(simulate=True) replays the planned topology through
    the replicated engine: the simulated mean respects the SLO the plan
    promised and stays above the Eq 7 lower bound."""
    plan = capacity.plan_capacity(T5, 80.0, 0.9, simulate=True,
                                  cluster=ClusterSpec(routing="random"),
                                  key=jax.random.PRNGKey(10))
    assert plan.n_replicas >= 2
    assert plan.response_simulated_ms is not None
    assert plan.response_simulated_ms <= 0.9 * 1e3
    assert plan.response_simulated_ms >= plan.response_lower_ms * 0.9
    assert plan.response_simulated_p95_ms > plan.response_simulated_ms
    assert plan.routing == "random"


def test_validate_gains_replicated_column():
    """calibrate.validate(replicas=r) fills the simulated-replicated
    column; per-replica load equals the measured system's, so it tracks
    the single-cluster simulator column."""
    from repro.calibrate import calibrate, simulate_trace, validate
    true = dataclasses.replace(T5, p=2)
    traces = [simulate_trace(jax.random.PRNGKey(i), lam, 6_000, true)
              for i, lam in enumerate([10.0, 18.0])]
    cal = calibrate(traces, n_windows=8, n_iters=2)
    report = validate(traces, cal, n_windows=6, cluster=ClusterSpec(r=2),
                      simulator_queries=20_000)
    assert report.r_sim_replicated is not None
    assert report.replicas == 2
    rep = np.asarray(report.r_sim_replicated)
    sim = np.asarray(report.r_simulated)
    assert np.all(np.abs(rep - sim) / sim <= 0.25), (rep, sim)
    assert "sim(x2)" in report.summary()
    # default path is unchanged
    plain = validate(traces, cal, n_windows=6, simulator_queries=10_000)
    assert plain.r_sim_replicated is None
    assert "sim(x2)" not in plain.summary()


# ------------------------------------------------------------ fused engine

@pytest.mark.parametrize("routing,r", [
    ("round_robin", 2),   # chunk % r == 0: pure-reshape fast path
    ("round_robin", 3),   # chunk % r != 0: general compaction path
    ("random", 3),
    ("jsq", 3),
])
@pytest.mark.parametrize("cache", [None, (0.25, 2e-3)])
def test_fused_matches_masked_oracle(x64, routing, r, cache):
    """ACCEPTANCE: the fused route-compacted engine reproduces the masked
    phantom oracle sample path for sample path, for every routing policy,
    with and without the dispatcher result cache.  In exact arithmetic
    the two are EQUAL (the simulator docstring carries the phantom-carry
    proof); x64 brings the float gap under 1e-9 relative."""
    params = dataclasses.replace(capacity.scenario_params(memory=1, p=4),
                                 p=4)
    key = jax.random.PRNGKey(11)
    kw = dict(p=4, chunk_size=1024, mode="cache", tap_size=32)
    fused = simulator.simulate_fork_join(
        key, 50.0, 6000, params,
        cluster=ClusterSpec(r=r, routing=routing, result_cache=cache,
                            replica_impl="fused"), **kw)
    masked = simulator.simulate_fork_join(
        key, 50.0, 6000, params,
        cluster=ClusterSpec(r=r, routing=routing, result_cache=cache,
                            replica_impl="masked"), **kw)
    for name in ("count", "sum_response", "sumsq_response", "sum_broker",
                 "sum_cluster", "sum_server"):
        np.testing.assert_allclose(
            np.asarray(getattr(fused, name)),
            np.asarray(getattr(masked, name)), rtol=1e-9,
            err_msg=f"{routing} r={r} cache={cache}: {name}")
    np.testing.assert_array_equal(np.asarray(fused.hist),
                                  np.asarray(masked.hist))
    # the reservoir tap is priority-ordered, not arrival-ordered; the
    # fused engine permutes per-query priorities consistently, so the
    # SET of sampled responses matches (NaN pads sort to the end)
    np.testing.assert_allclose(np.sort(np.asarray(fused.tap_response)),
                               np.sort(np.asarray(masked.tap_response)),
                               rtol=1e-9)


def test_fused_r1_bit_identical_across_impls():
    """ACCEPTANCE: at r=1 the replica dispatch is compiled out, so
    "fused" and "masked" are the SAME program as the pre-fusion streaming
    engine — bit-identical statistics, cache path included."""
    key = jax.random.PRNGKey(12)
    cache = (0.2, 2e-3)
    a = simulator.simulate_fork_join(
        key, 30.0, 20_000, T5, chunk_size=2048,
        cluster=ClusterSpec(result_cache=cache, replica_impl="fused"))
    b = simulator.simulate_fork_join(
        key, 30.0, 20_000, T5, chunk_size=2048,
        cluster=ClusterSpec(result_cache=cache, replica_impl="masked"))
    for f in dataclasses.fields(simulator.SimResult):
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f.name)), np.asarray(getattr(b, f.name)),
            err_msg=f.name)


def test_sweep_replica_impl_passthrough(x64):
    """`sweep_simulated(replica_impl=...)` reaches the engine: fused and
    masked surfaces agree to float precision over a replicated grid."""
    grid = sweep.SweepGrid.build(lam=[30.0, 60.0], p=[4.0], cpu=[1.0],
                                 disk=[1.0], hit=[0.5], r=[2.0, 3.0],
                                 base=dataclasses.replace(T5, p=4),
                                 result_cache=(0.2, 2e-3))
    key = jax.random.PRNGKey(13)
    f = sweep.sweep_simulated(grid, key, n_queries=4000, chunk_size=512,
                              cluster=ClusterSpec(replica_impl="fused"))
    m = sweep.sweep_simulated(grid, key, n_queries=4000, chunk_size=512,
                              cluster=ClusterSpec(replica_impl="masked"))
    np.testing.assert_allclose(np.asarray(f.mean), np.asarray(m.mean),
                               rtol=1e-9)
