"""Tests for the repro.staticcheck analyzer itself.

One fixture triple per rule — a positive hit, the same hit suppressed,
and clean code the rule must NOT flag (the clean cases encode the false
positives found while tuning the rules on the real tree: static
`if r == 1:` branches under static_argnames, per-mode key dispatch where
every branch returns, `sweep_simulated`'s loop that DOES pass r=, bound
lambda defaults in GQA index maps, ...).

The eval_shape-contract tests at the bottom seed a deliberate shape
regression into a copy of the contract and assert the harness goes red.
"""

from __future__ import annotations

import json
import pathlib
import subprocess
import sys

import pytest

import repro.staticcheck as sc
from repro.staticcheck import contract

ROOT = pathlib.Path(__file__).resolve().parent.parent


def ids_of(src: str, rel: str) -> list[str]:
    return [f.rule_id for f in sc.check_source(src, rel)
            if not f.suppressed]


def assert_triple(rule: str, rel: str, bad: str, clean: str,
                  disable: str | None = None) -> None:
    """Positive hit, suppressed hit, clean code — the per-rule contract."""
    hits = sc.check_source(bad, rel)
    assert any(f.rule_id == rule and not f.suppressed for f in hits), (
        f"{rule} did not fire:\n{bad}")
    flagged_line = next(f.line for f in hits if f.rule_id == rule)
    lines = bad.splitlines()
    lines[flagged_line - 1] += (
        f"  # staticcheck: disable={disable or rule}")
    suppressed = sc.check_source("\n".join(lines) + "\n", rel)
    assert all(f.suppressed for f in suppressed
               if f.rule_id == rule and f.line == flagged_line), (
        f"{rule} suppression did not take")
    assert not any(f.rule_id == rule for f in sc.check_source(clean, rel)), (
        f"{rule} false-fired on clean code:\n{clean}")


# --------------------------------------------------------------------------
# framework: RPR000 + registry + CLI
# --------------------------------------------------------------------------

def test_rule_ids_are_stable_and_banded():
    for rid, rule in sc.RULES.items():
        assert rid == rule.id and rid.startswith("RPR")
        n = int(rid[3:])
        band = {"framework": (0, 0), "convention": (1, 99),
                "tracer": (101, 199), "pallas": (201, 299),
                "contract": (301, 399)}[rule.family]
        assert band[0] <= n <= band[1], f"{rid} outside {rule.family} band"


def test_bare_suppression_is_a_finding():
    src = "import jax\nx = 1  # staticcheck: disable\n"
    assert "RPR000" in ids_of(src, "src/repro/core/x.py")


def test_unknown_rule_id_suppression_is_a_finding():
    src = "x = 1  # staticcheck: disable=RPR999\n"
    assert "RPR000" in ids_of(src, "src/repro/core/x.py")


def test_docstring_mention_is_not_a_suppression():
    src = '"""Use # staticcheck: disable=RPR0xx on the line."""\nx = 1\n'
    assert ids_of(src, "src/repro/core/x.py") == []


def test_syntax_error_reports_not_raises():
    assert "RPR000" in ids_of("def f(:\n", "src/repro/core/x.py")


def test_cli_module_runs_and_gates(tmp_path):
    bad = tmp_path / "src" / "repro" / "core"
    bad.mkdir(parents=True)
    (bad / "x.py").write_text(
        "import jax\nparams = jax.sharding.AxisType\n")
    env_root = str(tmp_path)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", "src",
         "--root", env_root, "--no-contract", "--format", "json"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin",
             "HOME": str(tmp_path)})
    assert proc.returncode == 1, proc.stderr
    payload = json.loads(proc.stdout)
    assert payload["counts"]["active"] == 1
    assert payload["findings"][0]["rule"] == "RPR001"


def test_cli_list_rules():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.staticcheck", "--list-rules"],
        capture_output=True, text=True,
        env={"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"})
    assert proc.returncode == 0
    for rid in sc.RULES:
        assert rid in proc.stdout


# --------------------------------------------------------------------------
# convention rules
# --------------------------------------------------------------------------

def test_rpr001_compat_shims():
    assert_triple(
        "RPR001", "src/repro/core/x.py",
        bad=("from jax.experimental.pallas import tpu as pltpu\n"
             "cp = pltpu.TPUCompilerParams()\n"),
        clean=("from repro.compat import tpu_compiler_params\n"
               "cp = tpu_compiler_params(dimension_semantics=('parallel',))\n"))
    # compat.py itself is out of scope by design
    assert not sc.RULES["RPR001"].applies_to("src/repro/compat.py")


def test_rpr002_bespoke_arrivals():
    assert_triple(
        "RPR002", "src/repro/core/x.py",
        bad=("import jax, jax.numpy as jnp\n"
             "def arr(key, lam, n):\n"
             "    gaps = jax.random.exponential(key, (n,)) / lam\n"
             "    return jnp.cumsum(gaps)\n"),
        # the sanctioned construction: go through ArrivalProcess
        clean=("from repro.core.arrivals import ArrivalProcess\n"
               "def arr(lam):\n"
               "    return ArrivalProcess.stationary(lam)\n"))
    # the arrival modules themselves are allowed to do this
    assert not sc.RULES["RPR002"].applies_to("src/repro/core/arrivals.py")
    assert not sc.RULES["RPR002"].applies_to(
        "src/repro/calibrate/measure.py")
    # tests may synthesize arrivals freely (scope is src/ only)
    assert not sc.RULES["RPR002"].applies_to("tests/test_simulator.py")


def test_rpr003_raw_trace_arrays():
    assert_triple(
        "RPR003", "src/repro/calibrate/x.py",
        bad=("import jax.numpy as jnp\n"
             "from repro.calibrate.fit import fit_moments\n"
             "params = fit_moments(jnp.stack([a, b]))\n"),
        clean=("from repro.calibrate.fit import fit_moments\n"
               "from repro.calibrate.measure import TraceRecord\n"
               "def f(tr: TraceRecord):\n"
               "    return fit_moments(tr)\n"))


def test_rpr004_handwired_replicas():
    assert_triple(
        "RPR004", "src/repro/core/x.py",
        bad=("from repro.core.simulator import simulate_fork_join\n"
             "def f(key, lam, n, params, n_replicas):\n"
             "    outs = []\n"
             "    for i in range(n_replicas):\n"
             "        outs.append(simulate_fork_join(\n"
             "            key, lam / n_replicas, n, params))\n"
             "    return outs\n"),
        # sweep_simulated's real shape: loop over grid cells, but the
        # engine is told about replication via cluster=
        clean=("from repro.core.cluster import ClusterSpec\n"
               "from repro.core.simulator import simulate_fork_join_batch\n"
               "def f(keys, lam, n, params, n_rep):\n"
               "    outs = []\n"
               "    for j in range(2):\n"
               "        outs.append(simulate_fork_join_batch(\n"
               "            keys[j], lam, params, n, p=4,\n"
               "            cluster=ClusterSpec(r=n_rep)))\n"
               "    return outs\n"))


def test_rpr005_telemetry_literal():
    assert_triple(
        "RPR005", "src/repro/core/x.py",
        bad=("from repro.core.simulator import simulate_fork_join\n"
             "def f(key, params):\n"
             "    return simulate_fork_join(key, 50.0, 256, params,\n"
             "                              telemetry=64)\n"),
        # the sanctioned shapes: a TelemetrySpec, None, or a variable
        clean=("from repro.core.simulator import simulate_fork_join\n"
               "from repro.obs import TelemetrySpec\n"
               "def f(key, params, spec):\n"
               "    a = simulate_fork_join(key, 50.0, 256, params,\n"
               "                           telemetry=TelemetrySpec())\n"
               "    b = simulate_fork_join(key, 50.0, 256, params,\n"
               "                           telemetry=None)\n"
               "    c = simulate_fork_join(key, 50.0, 256, params,\n"
               "                           telemetry=spec)\n"
               "    return a, b, c\n"))


def test_rpr005_handbuilt_timeline():
    assert_triple(
        "RPR005", "src/repro/core/x.py",
        bad=("from repro.obs import Timeline\n"
             "def f(xs):\n"
             "    return Timeline(bin_seconds=xs, count=xs, resp_sum=xs,\n"
             "                    busy_broker=xs, busy_server=xs,\n"
             "                    replica_count=xs, hit_count=xs,\n"
             "                    slo_count=xs)\n"),
        clean=("def f(trace):\n"
               "    return trace.to_timeline()\n"))


def test_rpr006_loose_topology_keywords():
    assert_triple(
        "RPR006", "src/repro/core/x.py",
        bad=("from repro.core.simulator import simulate_fork_join\n"
             "def f(key, params):\n"
             "    return simulate_fork_join(key, 50.0, 256, params,\n"
             "                              r=3, routing='jsq')\n"),
        clean=("from repro.core.cluster import ClusterSpec\n"
               "from repro.core.simulator import simulate_fork_join\n"
               "def f(key, params):\n"
               "    return simulate_fork_join(\n"
               "        key, 50.0, 256, params,\n"
               "        cluster=ClusterSpec(r=3, routing='jsq'))\n"))


def test_rpr006_covers_validate_replicas():
    assert_triple(
        "RPR006", "tests/x.py",
        bad=("from repro.calibrate import validate\n"
             "def f(traces, cal):\n"
             "    return validate(traces, cal, replicas=2)\n"),
        clean=("from repro.calibrate import validate\n"
               "from repro.core.cluster import ClusterSpec\n"
               "def f(traces, cal):\n"
               "    return validate(traces, cal, cluster=ClusterSpec(r=2))\n"))


def test_rpr006_scope():
    # fnmatch `*` crosses `/`: files directly under tests/ and nested
    # under src/ are both in scope; the shim module itself is excluded
    assert sc.RULES["RPR006"].applies_to("tests/test_replication.py")
    assert sc.RULES["RPR006"].applies_to("src/repro/obs/report.py")
    assert sc.RULES["RPR006"].applies_to("examples/replicated_sweep.py")
    assert sc.RULES["RPR006"].applies_to("benchmarks/replicated_bench.py")
    assert not sc.RULES["RPR006"].applies_to("src/repro/core/cluster.py")


def test_rpr005_silent_in_obs_package():
    src = ("from repro.obs.timeline import Timeline\n"
           "def f(xs):\n"
           "    return Timeline(bin_seconds=xs, count=xs, resp_sum=xs,\n"
           "                    busy_broker=xs, busy_server=xs,\n"
           "                    replica_count=xs, hit_count=xs,\n"
           "                    slo_count=xs)\n")
    assert "RPR005" not in ids_of(src, "src/repro/obs/timeline.py")
    assert "RPR005" not in ids_of(src, "src/repro/core/simulator.py")


# --------------------------------------------------------------------------
# tracer rules
# --------------------------------------------------------------------------

def test_rpr101_branch_on_tracer():
    assert_triple(
        "RPR101", "src/repro/core/x.py",
        bad=("import jax, jax.numpy as jnp\n"
             "@jax.jit\n"
             "def f(x):\n"
             "    if jnp.any(x > 0):\n"
             "        return x\n"
             "    return -x\n"),
        # the streaming engine's legitimate static branches: static
        # argnames and `is None` structure probes stay STATIC
        clean=("import jax, functools\n"
               "import jax.numpy as jnp\n"
               "@functools.partial(jax.jit, static_argnames=('r', 'mode'))\n"
               "def f(x, mask, r, mode):\n"
               "    if r == 1:\n"
               "        x = x + 1\n"
               "    if mask is None:\n"
               "        x = x * 2\n"
               "    if x.shape[0] > 4:\n"
               "        x = x[:4]\n"
               "    return x\n"))


def test_rpr101_scan_body_params_are_traced():
    src = ("import jax\n"
           "import jax.numpy as jnp\n"
           "def outer(xs):\n"
           "    def body(carry, x):\n"
           "        if x > 0:\n"
           "            carry = carry + x\n"
           "        return carry, carry\n"
           "    return jax.lax.scan(body, jnp.float32(0), xs)\n")
    assert "RPR101" in ids_of(src, "src/repro/core/x.py")


def test_rpr102_key_reuse():
    assert_triple(
        "RPR102", "src/repro/core/x.py",
        bad=("import jax\n"
             "def draws(key, n):\n"
             "    a = jax.random.exponential(key, (n,))\n"
             "    b = jax.random.normal(key, (n,))\n"
             "    return a + b\n"),
        # per-mode dispatch where every branch returns: each path
        # consumes the key exactly once (sample_service_times_batch)
        clean=("import jax\n"
               "def draws(key, n, mode):\n"
               "    if mode == 'a':\n"
               "        return jax.random.exponential(key, (n,))\n"
               "    k1, k2 = jax.random.split(key)\n"
               "    return jax.random.normal(k1, (n,)) + "
               "jax.random.normal(k2, (n,))\n"))


def test_rpr102_loop_reuse():
    src = ("import jax\n"
           "def draws(key, n):\n"
           "    out = []\n"
           "    for i in range(n):\n"
           "        out.append(jax.random.normal(key, ()))\n"
           "    return out\n")
    assert "RPR102" in ids_of(src, "src/repro/core/x.py")
    # fold_in per iteration is the sanctioned pattern (chunk_random_draws)
    clean = ("import jax\n"
             "def draws(key, n):\n"
             "    out = []\n"
             "    for i in range(n):\n"
             "        ki = jax.random.fold_in(key, i)\n"
             "        out.append(jax.random.normal(ki, ()))\n"
             "    return out\n")
    assert "RPR102" not in ids_of(clean, "src/repro/core/x.py")


def test_rpr102_fold_in_is_not_consumption():
    # the simulator salts ONE key with three different salts — clean
    src = ("import jax\n"
           "def salted(key, c_idx):\n"
           "    k1 = jax.random.fold_in(jax.random.fold_in(key, c_idx), 1)\n"
           "    k2 = jax.random.fold_in(jax.random.fold_in(key, c_idx), 2)\n"
           "    return jax.random.uniform(k1), jax.random.uniform(k2)\n")
    assert "RPR102" not in ids_of(src, "src/repro/core/x.py")


def test_rpr103_numpy_on_tracers():
    assert_triple(
        "RPR103", "src/repro/core/x.py",
        bad=("import jax\n"
             "import numpy as np\n"
             "@jax.jit\n"
             "def f(x):\n"
             "    return np.sort(x)\n"),
        # numpy on host-side statics is fine (sweep_simulated's axis reads)
        clean=("import jax\n"
               "import jax.numpy as jnp\n"
               "import numpy as np\n"
               "@jax.jit\n"
               "def f(x, n: int):\n"
               "    scale = np.log(n)\n"
               "    return jnp.sort(x) * scale\n"))


def test_rpr104_f64_in_scan():
    assert_triple(
        "RPR104", "src/repro/core/x.py",
        bad=("import jax\n"
             "import jax.numpy as jnp\n"
             "@jax.jit\n"
             "def f(x):\n"
             "    return x.astype(jnp.float64)\n"),
        # host-side float64 differencing (ArrivalProcess.from_trace) is
        # not jit-reachable and must stay legal
        clean=("import numpy as np\n"
               "def from_trace(ts):\n"
               "    t = np.asarray(ts, dtype=np.float64)\n"
               "    return np.diff(t)\n"))


def test_rpr105_host_cast_on_tracer():
    assert_triple(
        "RPR105", "src/repro/calibrate/x.py",
        bad=("import jax\n"
             "@jax.jit\n"
             "def f(x):\n"
             "    return float(x) * 2\n"),
        # int() on a static argname is the simulator's `p = int(params.p)`
        clean=("import jax, functools\n"
               "@functools.partial(jax.jit, static_argnames=('p',))\n"
               "def f(x, p):\n"
               "    return x * int(p)\n"))


# --------------------------------------------------------------------------
# pallas rules
# --------------------------------------------------------------------------

_KREL = "src/repro/kernels/foo/kernel.py"


def test_rpr201_compiler_params_via_compat():
    assert_triple(
        "RPR201", _KREL,
        bad=("from jax.experimental import pallas as pl\n"
             "def f(a, k):\n"
             "    return pl.pallas_call(k, grid=(4,),\n"
             "        compiler_params=dict(dimension_semantics=('parallel',)),\n"
             "        interpret=False)(a)\n"),
        clean=("from jax.experimental import pallas as pl\n"
               "from repro.compat import tpu_compiler_params\n"
               "def f(a, k):\n"
               "    return pl.pallas_call(k, grid=(4,),\n"
               "        compiler_params=tpu_compiler_params(\n"
               "            dimension_semantics=('parallel',)),\n"
               "        interpret=False)(a)\n"))


def test_rpr202_index_map_arity():
    assert_triple(
        "RPR202", _KREL,
        bad=("from jax.experimental import pallas as pl\n"
             "def f(a, k, n):\n"
             "    assert n % 4 == 0\n"
             "    grid = (n // 4, 2)\n"
             "    spec = pl.BlockSpec((4, 4), lambda i: (i, 0))\n"
             "    return pl.pallas_call(k, grid=grid, in_specs=[spec],\n"
             "        out_specs=spec, interpret=False)(a)\n"),
        # bound defaults (GQA n_rep=n_rep) do NOT count toward arity
        clean=("from jax.experimental import pallas as pl\n"
               "def f(a, k, n, n_rep):\n"
               "    assert n % 4 == 0\n"
               "    grid = (n // 4, 2)\n"
               "    spec = pl.BlockSpec(\n"
               "        (4, 4), lambda i, j, n_rep=n_rep: (i // n_rep, j))\n"
               "    return pl.pallas_call(k, grid=grid, in_specs=[spec],\n"
               "        out_specs=spec, interpret=False)(a)\n"))


def test_rpr202_counts_scalar_prefetch():
    # PrefetchScalarGridSpec: arity = len(grid) + num_scalar_prefetch
    src = ("from jax.experimental import pallas as pl\n"
           "from jax.experimental.pallas import tpu as pltpu\n"
           "def f(a, k, ids):\n"
           "    grid = (4, 2)\n"
           "    return pl.pallas_call(k,\n"
           "        grid_spec=pltpu.PrefetchScalarGridSpec(\n"
           "            num_scalar_prefetch=1,\n"
           "            grid=grid,\n"
           "            in_specs=[pl.BlockSpec((1, 4),\n"
           "                lambda i, j, ids_ref: (i, 0))],\n"
           "            out_specs=pl.BlockSpec((1, 4),\n"
           "                lambda i, j: (i, 0))),\n"
           "        interpret=False)(ids, a)\n")
    findings = [f for f in sc.check_source(src, _KREL)
                if f.rule_id == "RPR202"]
    assert len(findings) == 1          # only the 2-arg out_specs lambda
    assert findings[0].line == 12


def test_rpr203_grid_divisibility():
    assert_triple(
        "RPR203", _KREL,
        bad=("from jax.experimental import pallas as pl\n"
             "def f(a, k, n):\n"
             "    grid = (n // 4,)\n"
             "    spec = pl.BlockSpec((4,), lambda i: (i,))\n"
             "    return pl.pallas_call(k, grid=grid, in_specs=[spec],\n"
             "        out_specs=spec, interpret=False)(a)\n"),
        clean=("from jax.experimental import pallas as pl\n"
               "def f(a, k, n):\n"
               "    assert n % 4 == 0, n\n"
               "    grid = (n // 4,)\n"
               "    spec = pl.BlockSpec((4,), lambda i: (i,))\n"
               "    return pl.pallas_call(k, grid=grid, in_specs=[spec],\n"
               "        out_specs=spec, interpret=False)(a)\n"))


def test_rpr204_interpret_plumbing():
    assert_triple(
        "RPR204", _KREL,
        bad=("from jax.experimental import pallas as pl\n"
             "def f(a, k):\n"
             "    return pl.pallas_call(k, grid=(4,))(a)\n"),
        clean=("from jax.experimental import pallas as pl\n"
               "def f(a, k, interpret=False):\n"
               "    return pl.pallas_call(k, grid=(4,),\n"
               "        interpret=interpret)(a)\n"))


def test_real_kernels_are_clean():
    for kernel in sorted(
            (ROOT / "src" / "repro" / "kernels").glob("*/kernel.py")):
        rel = kernel.relative_to(ROOT).as_posix()
        findings = [f for f in sc.check_source(kernel.read_text(), rel)
                    if not f.suppressed]
        assert not findings, (
            f"{rel}:\n" + "\n".join(f.render() for f in findings))


# --------------------------------------------------------------------------
# eval_shape contract (RPR301)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def live_snapshot():
    return contract.snapshot()


def test_contract_matches_committed(live_snapshot):
    findings = contract.check(live=live_snapshot)
    assert not findings, "\n".join(f.render() for f in findings)


def test_contract_catches_seeded_shape_regression(tmp_path, live_snapshot):
    doc = json.loads(contract.CONTRACT_PATH.read_text())
    # seed a regression: pretend the batch histogram gained an axis and
    # the response sum was promoted to f64
    probe = doc["probes"]["simulate_fork_join_batch"]
    probe[".hist"] = "float32[3,2,256]"
    probe[".sum_response"] = "float64[3]"
    seeded = tmp_path / "shape_contract.json"
    seeded.write_text(json.dumps(doc))
    findings = contract.check(seeded, live=live_snapshot)
    assert len(findings) == 2
    assert all(f.rule_id == "RPR301" for f in findings)
    messages = " ".join(f.message for f in findings)
    assert "float64[3]" in messages and "float32[3,2,256]" in messages


def test_contract_catches_removed_probe(tmp_path, live_snapshot):
    doc = json.loads(contract.CONTRACT_PATH.read_text())
    doc["probes"]["simulate_fork_join"][".p99"] = "float32[]"
    seeded = tmp_path / "shape_contract.json"
    seeded.write_text(json.dumps(doc))
    findings = contract.check(seeded, live=live_snapshot)
    assert any("disappeared" in f.message for f in findings)


def test_contract_missing_file_is_a_finding(tmp_path):
    findings = contract.check(tmp_path / "nope.json", live={})
    assert findings and findings[0].rule_id == "RPR301"


def test_rpr007_fault_spec_literal():
    assert_triple(
        "RPR007", "src/repro/core/x.py",
        bad=("from repro.core.cluster import ClusterSpec\n"
             "spec = ClusterSpec(r=3, fault=((0, 5.0, 10.0),))\n"),
        clean=("from repro.core.cluster import ClusterSpec\n"
               "from repro.core.faults import FaultSpec\n"
               "spec = ClusterSpec(r=3, fault=FaultSpec(\n"
               "    outages=((0, 5.0, 10.0),)))\n"))


def test_rpr007_hand_threaded_fault_scan():
    assert_triple(
        "RPR007", "examples/x.py",
        bad=("from repro.core.faults import FaultSpec, fault_init, "
             "fault_scan\n"
             "def masks(spec, t, gaps):\n"
             "    carry = fault_init(spec, 2, 4)\n"
             "    return fault_scan(spec, 4, carry, t, gaps)\n"),
        clean=("from repro.core.cluster import ClusterSpec\n"
               "from repro.core.faults import FaultSpec\n"
               "from repro.core.simulator import simulate_fork_join\n"
               "def f(key, params, spec):\n"
               "    return simulate_fork_join(\n"
               "        key, 50.0, 256, params,\n"
               "        cluster=ClusterSpec(r=3, fault=spec))\n"))


def test_rpr007_allows_none_and_names():
    ok = ("from repro.core.cluster import ClusterSpec\n"
          "from repro.core.faults import FaultSpec\n"
          "ft = FaultSpec(mtbf_seconds=30.0)\n"
          "a = ClusterSpec(r=2, fault=None)\n"
          "b = ClusterSpec(r=2, fault=ft)\n")
    assert "RPR007" not in ids_of(ok, "src/repro/core/x.py")


def test_rpr007_scope():
    # the engine and the spec module drive the recurrence legitimately,
    # and tests/test_faults.py property-tests it directly
    assert sc.RULES["RPR007"].applies_to("examples/failover_stress.py")
    assert sc.RULES["RPR007"].applies_to("benchmarks/faults_bench.py")
    assert sc.RULES["RPR007"].applies_to("tests/test_sweep.py")
    assert not sc.RULES["RPR007"].applies_to("src/repro/core/faults.py")
    assert not sc.RULES["RPR007"].applies_to("src/repro/core/simulator.py")
    assert not sc.RULES["RPR007"].applies_to("tests/test_faults.py")
