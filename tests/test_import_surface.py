"""Import-surface hygiene: every ``__all__`` export must exist.

A stale ``__all__`` entry (renamed function, deleted constant) only
bites on ``from module import *`` — which nothing in the repo does, so
the drift survives every other test.  This walks every module under
``repro`` that declares an ``__all__`` and resolves each exported name
with getattr, turning a stale export into an immediate failure with the
module and name spelled out.
"""

import importlib
import pathlib
import pkgutil

import pytest

import repro

_SKIP_PREFIXES = ("repro.kernels",)  # kernel modules may need a TPU


def _modules():
    root = pathlib.Path(repro.__file__).parent
    names = [m.name for m in pkgutil.walk_packages([str(root)], "repro.")
             if not m.name.startswith(_SKIP_PREFIXES)]
    return sorted(names)


@pytest.mark.parametrize("modname", _modules())
def test_all_exports_exist(modname):
    mod = importlib.import_module(modname)
    exported = getattr(mod, "__all__", None)
    if exported is None:
        pytest.skip(f"{modname} declares no __all__")
    assert len(set(exported)) == len(exported), (
        f"{modname}.__all__ has duplicates")
    missing = [name for name in exported if not hasattr(mod, name)]
    assert not missing, (
        f"{modname}.__all__ exports names that do not exist: {missing}")


def test_querygen_star_import_round_trip():
    # the original drift report: sanity-pin the workloadgen surface
    from repro.workloadgen import querygen
    ns = {}
    exec("from repro.workloadgen.querygen import *", ns)  # noqa: S102
    for name in querygen.__all__:
        assert name in ns, f"star-import dropped {name}"
    assert {"WorkloadConfig", "QueryUniverse", "build_universe",
            "sample_query_stream", "TODOBR", "RADIX"} == set(
                querygen.__all__)
