"""Calibration subsystem: round-trip recovery, held-out accuracy vs the
simulator (the ISSUE's acceptance criteria), chunking invariance, the
reservoir tap, and the flash-crowd arrival constructor."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calibrate import (CalibratedParams, calibrate,
                             calibrate_and_validate, fit_alpha, fit_moments,
                             simulate_trace, trace_from_tap, window_stats)
from repro.core import capacity, simulator
from repro.core.arrivals import ArrivalProcess
from repro.core.queueing import ServerParams

TRUE = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
_FIT_FIELDS = ("s_broker", "s_hit", "s_miss", "s_disk", "hit")


@pytest.fixture(scope="module")
def traces():
    """Four stationary measurement runs spanning light to heavy load."""
    return [simulate_trace(jax.random.PRNGKey(i), lam, 15_000, TRUE)
            for i, lam in enumerate([10.0, 22.0, 14.0, 18.0])]


def _rel_errs(fitted: ServerParams) -> dict[str, float]:
    return {f: abs(float(getattr(fitted, f)) - float(getattr(TRUE, f)))
            / float(getattr(TRUE, f)) for f in _FIT_FIELDS}


def test_roundtrip_parameter_recovery(traces):
    """ACCEPTANCE: Eq-1 service-time parameters back within 5%."""
    cal = calibrate(traces, n_windows=12)
    errs = _rel_errs(cal.to_server_params())
    assert max(errs.values()) <= 0.05, errs
    assert 0.0 < float(cal.alpha) < 1.0


def test_holdout_prediction_tracks_simulator(traces):
    """ACCEPTANCE: calibrated analytical mean response on held-out
    lambda-windows within 10% of the calibrated simulator's."""
    cal, report = calibrate_and_validate(
        traces, n_windows=20, holdout_fraction=0.25,
        key=jax.random.PRNGKey(42))
    assert report.max_rel_err_vs_sim <= 0.10, report.summary()
    # and the model tracks the actual measurements decently too
    assert report.mean_rel_err <= 0.15, report.summary()
    # R(lambda) prediction at the held-out rates is finite & ordered
    assert bool(jnp.all(jnp.isfinite(report.r_calibrated)))


def test_moment_fit_without_disk_split(traces):
    """No recorded CPU/disk split -> variance-based moment matching still
    recovers the decomposition (looser: it squares the noise)."""
    stripped = [dataclasses.replace(tr, server_disk=None) for tr in traces]
    fitted = fit_moments(stripped)
    errs = _rel_errs(fitted)
    assert max(errs.values()) <= 0.15, errs
    # convention: the larger miss component is labeled disk
    assert float(fitted.s_disk) > float(fitted.s_miss)


def test_fit_moments_invariant_to_chunking(traces):
    """Fitting accumulated sufficient statistics over ANY batching of the
    same measurements gives the same parameters."""
    whole = fit_moments(traces[0])
    for n_batches in (2, 5, 13):
        chunked = fit_moments(traces[0].split(n_batches))
        for f in _FIT_FIELDS:
            np.testing.assert_allclose(
                float(getattr(chunked, f)), float(getattr(whole, f)),
                rtol=1e-4, err_msg=f"{f} drifted at {n_batches} batches")


def test_maxplus_residual_path(traces):
    """The differentiable max-plus replay identifies the service scale:
    a trace whose busy times are inflated 10% over what its own moments
    report should fit s_scale ~= 1.1 ... here the self-consistent trace
    must fit s_scale ~= 1."""
    cal = calibrate(traces[:2], n_windows=8, residual="maxplus",
                    n_iters=4)
    assert abs(float(cal.s_scale) - 1.0) <= 0.03
    errs = _rel_errs(cal.to_server_params())
    assert max(errs.values()) <= 0.08, errs


def test_window_stats_estimate_observed_rates(traces):
    lam_w, r_w, cnt = window_stats(traces, 8)
    lam = np.asarray(lam_w)
    # two windows per batch, batches at 10/22/14/18 qps
    expect = np.repeat([10.0, 22.0, 14.0, 18.0], 2)
    np.testing.assert_allclose(lam, expect, rtol=0.08)
    assert bool(jnp.all(r_w > 0))


def test_tap_reservoir_matches_stream_statistics():
    """The scan-carry reservoir is a uniform post-warmup sample: its mean
    sits near the streaming mean, its range inside the quantile span."""
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(0), 18.0, 40_000, TRUE, tap_size=512)
    tap = np.asarray(res.tap_response)
    assert tap.shape == (512,) and not np.isnan(tap).any()
    m = float(res.mean_response)
    assert abs(tap.mean() - m) <= 0.15 * m
    assert tap.max() <= float(res.quantile(0.99999)) * 3.0
    assert tap.min() > 0.0


def test_tap_nan_pads_when_short():
    """Fewer post-warmup queries than tap slots -> NaN padding, and the
    valid entries are exactly the post-warmup count."""
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), 10.0, 200, TRUE, tap_size=256,
        chunk_size=64)
    tap = np.asarray(res.tap_response)
    assert np.isfinite(tap).sum() == int(res.count)


def test_tap_default_off_and_stats_unchanged():
    """tap_size=0 keeps the result bit-identical to the pre-tap engine
    (the tap draws from a salted key stream, not the canonical plan)."""
    r0 = simulator.simulate_fork_join(jax.random.PRNGKey(2), 15.0, 20_000,
                                      TRUE)
    r1 = simulator.simulate_fork_join(jax.random.PRNGKey(2), 15.0, 20_000,
                                      TRUE, tap_size=128)
    assert r0.tap_response.shape == (0,)
    np.testing.assert_array_equal(np.asarray(r0.sum_response),
                                  np.asarray(r1.sum_response))
    np.testing.assert_array_equal(np.asarray(r0.hist), np.asarray(r1.hist))


def test_fit_alpha_from_sweep_tap():
    """Response-only taps from a swept simulation calibrate the imbalance
    blend: the calibrated prediction tracks the simulated means."""
    from repro.core import sweep
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 16.0, 22.0]), p=jnp.asarray([4.0]),
        base=TRUE, hit=jnp.asarray([float(TRUE.hit)]), broker_from_p=False)
    res = sweep.sweep_simulated(grid, jax.random.PRNGKey(3),
                                n_queries=30_000, mode="cache",
                                tap_size=256)
    lam, r_obs = trace_from_tap(
        res.sample_response.reshape(3, -1), grid.lam)
    alpha = fit_alpha(TRUE, lam, r_obs)
    assert 0.0 < float(alpha) < 1.0
    cal = CalibratedParams(params=TRUE, alpha=alpha,
                           s_scale=jnp.asarray(1.0),
                           residual_rms=jnp.asarray(0.0))
    pred = cal.predict_mean_response(lam)
    sim_means = res.mean.reshape(-1)
    rel = np.abs(np.asarray(pred) - np.asarray(sim_means)) / np.asarray(
        sim_means)
    assert rel.max() <= 0.12, rel


def test_flash_crowd_process():
    proc = ArrivalProcess.flash_crowd(
        8.0, burst_starts=[120.0, 600.0], burst_seconds=60.0,
        burst_multiplier=3.0, period_seconds=1200.0, bin_seconds=60.0)
    assert proc.rates.shape == (20,)
    assert float(proc.peak_rate) == 24.0
    assert int(jnp.sum(proc.rates == 24.0)) == 2
    np.testing.assert_allclose(float(proc.rate_at(130.0)), 24.0)
    np.testing.assert_allclose(float(proc.rate_at(300.0)), 8.0)
    # scenario-dim base rates broadcast
    multi = ArrivalProcess.flash_crowd(
        jnp.asarray([5.0, 10.0]), burst_starts=60.0, burst_seconds=60.0,
        period_seconds=600.0, bin_seconds=60.0)
    assert multi.rates.shape == (2, 10)


def test_calibrate_smoke_example_runs():
    """CI's calibrate-smoke job and this test share one entry point
    (examples/calibrate_smoke.py) — the heredoc it replaced could drift
    from the library without any test noticing."""
    import importlib.util
    import pathlib
    path = (pathlib.Path(__file__).resolve().parent.parent / "examples"
            / "calibrate_smoke.py")
    spec = importlib.util.spec_from_file_location("calibrate_smoke", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    cal, report = mod.run_smoke(verbose=False)
    assert report.lam.shape[0] >= 1


def test_calibrated_params_flow_into_planner(traces):
    """Wiring: CalibratedParams -> ServerParams -> plan/sweep/planner."""
    from repro.calibrate import plan_from_trace
    from repro.core import planner, sweep
    cal, plan = plan_from_trace(traces, 100.0, 0.300, n_windows=12)
    assert plan.total_servers >= plan.servers_per_replica
    assert plan.response_upper_ms <= 300.0
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 18.0]), p=jnp.asarray([4.0, 8.0]),
        base=cal.to_server_params(),
        hit=jnp.asarray([float(cal.params.hit)]), broker_from_p=False)
    _, frontier = planner.plan_over_grid(grid, 0.400)
    assert bool(np.asarray(frontier.feasible).any())


# ----- hypothesis property: chunking invariance under ANY split sizes ----
# Guarded so the rest of this module still runs without hypothesis (the
# importorskip-at-module-top idiom of test_property.py would skip every
# test above too).

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    _PROPERTY_TRACE = simulate_trace(jax.random.PRNGKey(99), 15.0, 8_000,
                                     TRUE)

    @given(splits=st.lists(st.integers(1, 4000), min_size=1, max_size=8))
    @settings(max_examples=20, deadline=None)
    def test_fit_invariant_to_arbitrary_chunking(splits):
        """PROPERTY: moment fitting sees only accumulated sufficient
        statistics, so ANY contiguous re-batching of a trace fits the
        same parameters (float-accumulation noise only)."""
        trace = _PROPERTY_TRACE
        n = trace.n_queries
        edges = sorted({min(s, n - 1) for s in splits})
        bounds = [0] + edges + [n]
        batches = [jax.tree_util.tree_map(lambda x: x[lo:hi], trace)
                   for lo, hi in zip(bounds[:-1], bounds[1:]) if hi > lo]
        whole = fit_moments(trace)
        chunked = fit_moments(batches)
        for f in _FIT_FIELDS:
            np.testing.assert_allclose(
                float(getattr(chunked, f)), float(getattr(whole, f)),
                rtol=1e-3, err_msg=f)
else:
    @pytest.mark.skip(reason="property tests need hypothesis (see "
                      "pyproject [project.optional-dependencies].test)")
    def test_fit_invariant_to_arbitrary_chunking():
        pass
