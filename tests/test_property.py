"""Hypothesis property tests on system invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (see pyproject "
    "[project.optional-dependencies].test)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import queueing, simulator, workload
from repro.core.queueing import ServerParams
from repro.kernels.maxplus_scan import ref as mp_ref
from repro.models import transformer as T

_settings = settings(max_examples=25, deadline=None)


@given(
    vals=st.lists(st.floats(-50.0, 50.0), min_size=6, max_size=6),
)
@_settings
def test_maxplus_combine_is_associative(vals):
    """(x∘y)∘z == x∘(y∘z): the algebraic fact the whole streaming/chunked
    engine rests on (any chunking composes to the same map)."""
    a1, b1, a2, b2, a3, b3 = (jnp.float32(v) for v in vals)
    x, y, z = (a1, b1), (a2, b2), (a3, b3)
    left = simulator.maxplus_combine(simulator.maxplus_combine(x, y), z)
    right = simulator.maxplus_combine(x, simulator.maxplus_combine(y, z))
    np.testing.assert_allclose(np.asarray(left), np.asarray(right),
                               rtol=1e-6, atol=1e-5)


@given(
    n=st.integers(3, 300),
    chunk=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
@_settings
def test_chunked_streaming_matches_monolithic_scan(n, chunk, seed):
    """Carry-seeded chunked FCFS == one monolithic scan, for random chunk
    sizes (the determinism contract behind the streaming engine)."""
    rng = np.random.default_rng(seed)
    arr = np.cumsum(rng.random(n).astype(np.float32) * 0.5)
    svc = rng.random(n).astype(np.float32) * 0.3
    whole = np.asarray(simulator.fcfs_completion_times(
        jnp.asarray(arr), jnp.asarray(svc)))
    out, carry = [], None
    for lo in range(0, n, chunk):
        piece = simulator.fcfs_completion_times(
            jnp.asarray(arr[lo:lo + chunk]), jnp.asarray(svc[lo:lo + chunk]),
            carry=carry)
        out.append(np.asarray(piece))
        carry = piece[-1]
    np.testing.assert_allclose(np.concatenate(out), whole, rtol=2e-6,
                               atol=1e-5)


@given(
    p=st.integers(1, 2048),
    lam_frac=st.floats(0.01, 0.95),
    s_hit=st.floats(1e-4, 0.05),
    s_miss=st.floats(1e-4, 0.05),
    s_disk=st.floats(0.0, 0.2),
    hit=st.floats(0.0, 1.0),
)
@_settings
def test_queueing_invariants(p, lam_frac, s_hit, s_miss, s_disk, hit):
    """For any stable operating point: 0<=U<1, lower<=upper, H_p factor."""
    params = ServerParams(p=p, s_broker=1e-4, s_hit=s_hit, s_miss=s_miss,
                          s_disk=s_disk, hit=hit)
    lam = lam_frac * float(queueing.saturation_rate(params))
    u = float(queueing.utilization(
        lam, queueing.service_time_server(params)))
    assert 0.0 <= u < 1.0
    lo, hi = queueing.response_time_bounds(lam, params)
    assert 0.0 < float(lo) <= float(hi) + 1e-9
    hp = float(queueing.harmonic_number(p))
    assert hp >= 1.0
    rb = float(queueing.broker_residence_time(lam, params))
    assert np.isclose(float(hi) - rb, hp * (float(lo) - rb), rtol=1e-4)


@given(
    n=st.integers(2, 200),
    seed=st.integers(0, 2**31 - 1),
)
@_settings
def test_maxplus_scan_is_fcfs(n, seed):
    """Associative-scan completion times == sequential FCFS recurrence,
    and are nondecreasing with spacing >= service time."""
    rng = np.random.default_rng(seed)
    a = np.sort(rng.random(n).astype(np.float32) * 10)
    s = rng.random(n).astype(np.float32)
    ra, _ = mp_ref.maxplus_scan_ref(jnp.asarray(a + s), jnp.asarray(s))
    sa, _ = mp_ref.maxplus_scan_sequential(jnp.asarray(a + s),
                                           jnp.asarray(s))
    np.testing.assert_allclose(np.asarray(ra), np.asarray(sa), rtol=2e-5)
    c = np.asarray(ra)
    assert (c >= a + s - 1e-4).all()          # completion after arrival+svc
    assert (np.diff(c) >= s[1:] - 1e-4).all()  # single server serializes


@given(
    boost_windows=st.integers(2, 40),
    n=st.integers(100, 2000),
    seed=st.integers(0, 2**31 - 1),
)
@_settings
def test_folding_preserves_mass_and_boosts_rate(boost_windows, n, seed):
    rng = np.random.default_rng(seed)
    duration = boost_windows * 100.0
    t = np.sort(rng.random(n) * duration)
    folded, boost = workload.fold_timestamps(jnp.asarray(t, jnp.float32),
                                             100.0)
    assert folded.shape[0] == n                # mass preserved
    assert float(folded.max()) <= 100.0 + 1e-3
    assert abs(int(boost) - boost_windows) <= 1


@given(
    b=st.integers(1, 4),
    s=st.integers(2, 16),
    v=st.integers(8, 64),
    seed=st.integers(0, 1000),
)
@_settings
def test_sharded_cross_entropy_equals_naive(b, s, v, seed):
    """The vocab-sharded CE formulation == textbook log_softmax gather."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    logits = jax.random.normal(k1, (b, s, v))
    labels = jax.random.randint(k2, (b, s), 0, v)
    ours = T.cross_entropy_sharded(logits, labels)
    logp = jax.nn.log_softmax(logits, -1)
    naive = -jnp.mean(jnp.take_along_axis(logp, labels[..., None], -1))
    np.testing.assert_allclose(float(ours), float(naive), rtol=1e-4)


@given(alpha=st.floats(0.5, 1.5), seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_zipf_probs_normalized_and_ordered(alpha, seed):
    p = workload.zipf_probs(500, alpha)
    assert np.isclose(float(jnp.sum(p)), 1.0, atol=1e-5)
    assert bool(jnp.all(jnp.diff(p) <= 1e-12))  # nonincreasing in rank


@given(
    lam_scale=st.floats(0.1, 0.9),
    hit_r=st.floats(0.0, 1.0),
)
@_settings
def test_result_cache_never_hurts(lam_scale, hit_r):
    """Eq 8 with any hit ratio <= plain Eq 7 upper bound."""
    from repro.core import capacity
    params = capacity.scenario("memory+cpus+disks")
    lam = lam_scale * float(queueing.saturation_rate(params))
    _, hi = queueing.response_time_bounds(lam, params)
    r = queueing.response_time_with_result_cache(lam, params, hit_r,
                                                 0.069e-3)
    assert float(r) <= float(hi) + 1e-9


@given(
    n=st.integers(4, 120),
    chunk=st.integers(2, 48),
    r=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
@_settings
def test_routed_fcfs_chunk_invariance(n, chunk, r, seed):
    """`fcfs_completion_times_routed` carry-chained over arbitrary chunk
    splits == one whole call (the fused replicated engine's determinism
    contract: chunking only regroups the segmented associative scan)."""
    rng = np.random.default_rng(seed)
    arr = jnp.asarray(np.cumsum(rng.random(n) * 0.5, dtype=np.float32))
    svc = jnp.asarray(rng.random(n).astype(np.float32) * 0.3 + 1e-3)
    asg = jnp.asarray(rng.integers(0, r, n).astype(np.int32))
    whole, carry_w = simulator.fcfs_completion_times_routed(
        arr, svc, asg, r)
    out, carry = [], None
    for lo in range(0, n, chunk):
        piece, carry = simulator.fcfs_completion_times_routed(
            arr[lo:lo + chunk], svc[lo:lo + chunk], asg[lo:lo + chunk],
            r, carry=carry)
        out.append(np.asarray(piece))
    np.testing.assert_allclose(np.concatenate(out), np.asarray(whole),
                               rtol=1e-5, atol=1e-4)
    np.testing.assert_allclose(np.asarray(carry), np.asarray(carry_w),
                               rtol=1e-5, atol=1e-4)
