"""Training runtime: optimizer, microbatching, compression, checkpointing,
elastic scaling, straggler math."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as CK
from repro.core import planner
from repro.launch import elastic
from repro.train.compression import Compressor
from repro.train.optimizer import AdamW, SGD, clip_by_global_norm, cosine_schedule
from repro.train.trainer import TrainStep


def _toy():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(8, 1)).astype(np.float32)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    return {"x": jnp.asarray(x), "y": jnp.asarray(x @ w)}


def _loss(params, batch):
    pred = batch["x"] @ params["w"] + params["b"]
    return jnp.mean((pred - batch["y"]) ** 2)


def test_adamw_converges():
    params = {"w": jnp.zeros((8, 1)), "b": jnp.zeros((1,))}
    step = TrainStep(loss_fn=_loss, optimizer=AdamW(lr=3e-2))
    state = step.init_state(params)
    batch = _toy()
    jstep = jax.jit(step)
    first = None
    for _ in range(200):
        params, state, loss = jstep(params, state, batch)
        first = first or float(loss)
    assert float(loss) < first * 1e-3


def test_microbatch_equals_full_batch():
    """Gradient accumulation is exact for mean losses over equal splits."""
    params = {"w": jnp.ones((8, 1)), "b": jnp.zeros((1,))}
    batch = _toy()
    s1 = TrainStep(loss_fn=_loss, optimizer=SGD(lr=0.1, momentum=0.0,
                                                clip_norm=0.0))
    s4 = TrainStep(loss_fn=_loss, optimizer=SGD(lr=0.1, momentum=0.0,
                                                clip_norm=0.0),
                   microbatches=4)
    p1, _, l1 = s1(params, s1.init_state(params), batch)
    p4, _, l4 = s4(params, s4.init_state(params), batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped = clip_by_global_norm(g, 1.0)
    norm = float(jnp.sqrt(sum(jnp.sum(x * x)
                              for x in jax.tree.leaves(clipped))))
    assert np.isclose(norm, 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) < 1e-4
    assert np.isclose(float(lr(jnp.asarray(10))), 1e-3, rtol=1e-5)
    assert float(lr(jnp.asarray(100))) < 2e-4


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_compression_error_feedback(mode):
    """Residual stays bounded and compressed training still converges."""
    comp = Compressor(mode)
    params = {"w": jnp.zeros((8, 1)), "b": jnp.zeros((1,))}
    step = TrainStep(loss_fn=_loss, optimizer=AdamW(lr=3e-2),
                     compressor=comp)
    state = step.init_state(params)
    batch = _toy()
    jstep = jax.jit(step)
    for _ in range(150):
        params, state, loss = jstep(params, state, batch)
    assert float(loss) < 1e-3
    res_norm = max(float(jnp.max(jnp.abs(r)))
                   for r in jax.tree.leaves(state["residual"]))
    assert res_norm < 1.0  # error feedback keeps residual bounded


def test_compression_int8_quantization_error():
    comp = Compressor("int8")
    g = {"w": jnp.linspace(-1, 1, 100)}
    res = comp.init(g)
    q, res = comp.compress(g, res)
    err = float(jnp.max(jnp.abs(q["w"] - g["w"])))
    assert err <= 1.0 / 127.0 + 1e-6


def test_checkpoint_roundtrip_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 3))}}
        for s in (10, 20, 30, 40):
            CK.save(d, s, tree, keep_last=2)
        assert CK.latest_step(d) == 40
        kept = sorted(os.listdir(d))
        assert len([k for k in kept if k.startswith("step_")]) == 2
        restored = CK.restore(d, 40, tree)
        np.testing.assert_allclose(np.asarray(restored["a"]),
                                   np.arange(10.0))


def test_checkpoint_async_and_manager():
    with tempfile.TemporaryDirectory() as d:
        mgr = CK.CheckpointManager(d, every=5, keep_last=2)
        tree = {"w": jnp.ones((4,))}
        for s in range(1, 16):
            mgr.maybe_save(s, tree)
        mgr.wait()
        assert CK.latest_step(d) == 15
        step, restored = mgr.restore_latest(tree)
        assert step == 15


def test_checkpoint_atomicity_no_tmp_left():
    with tempfile.TemporaryDirectory() as d:
        CK.save(d, 1, {"w": jnp.ones((2,))})
        assert not any(f.endswith(".tmp") for f in os.listdir(d))


def test_elastic_survivor_mesh():
    shape = elastic.survivor_mesh_shape(
        (2, 16, 16), failed_hosts=8, chips_per_host=4,
        axes=("pod", "data", "model"))
    assert shape[2] == 16                    # model extent preserved
    assert np.prod(shape) >= 2 * 16 * 16 - 32
    plan = elastic.plan_downsize((2, 16, 16), shape)
    assert plan.throughput_fraction <= 1.0


def test_elastic_refuses_impossible():
    with pytest.raises(ValueError):
        elastic.survivor_mesh_shape((1, 1, 16), failed_hosts=100,
                                    chips_per_host=4,
                                    axes=("pod", "data", "model"))


def test_hedge_threshold_scales_with_p():
    t8 = elastic.hedge_threshold(0.03, 8)
    t512 = elastic.hedge_threshold(0.03, 512)
    assert t512 > t8 > 0


def test_planner_roofline_to_serving_plan():
    terms = planner.terms_from_analysis(
        hlo_flops=1e15, hlo_bytes=5e12, collective_bytes=2e12, n_chips=256)
    assert terms.bound in ("compute", "memory", "collective")
    model = planner.ServingModel(
        name="test", terms=terms, n_chips=256, batch_per_step=128)
    plan = planner.plan_serving(model, target_rate_per_s=2000.0,
                                slo_seconds=0.5)
    assert plan.cells >= 1
    assert plan.response_upper_ms <= 500.0 + 1e-6
    assert 0 <= plan.utilization < 1.0
