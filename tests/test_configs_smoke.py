"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates its REDUCED config and runs one forward/train step on CPU,
asserting output shapes and absence of NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_arch, list_archs
from repro.data import graph_sampler, recsys_data
from repro.models import dimenet as DN
from repro.models import recsys as RS
from repro.models import transformer as T

LM_ARCHS = ["qwen3-moe-30b-a3b", "granite-moe-3b-a800m",
            "command-r-plus-104b", "qwen3-1.7b", "qwen3-8b"]
CTR_ARCHS = ["deepfm", "xdeepfm", "autoint"]


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree_util.tree_leaves(tree))


def test_registry_has_all_ten():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_and_decode(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    labels = jnp.roll(tokens, -1, 1)
    loss = T.train_step_loss(params, cfg, tokens, labels)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: T.train_step_loss(p, cfg, tokens, labels))(
        params)
    assert _finite(grads)

    logits, cache = T.prefill(params, cfg, tokens, chunk=8)
    assert logits.shape == (2, 1, cfg.vocab_padded)
    assert _finite(logits)
    cache2 = T.init_kv_cache(cfg, 2, 32)
    cache2["k"] = cache2["k"].at[:, :, :16].set(cache["k"])
    cache2["v"] = cache2["v"].at[:, :, :16].set(cache["v"])
    cache2["len"] = jnp.asarray(16, jnp.int32)
    ld, cache3 = T.decode_step(params, cfg, tokens[:, -1:], cache2)
    assert ld.shape == (2, 1, cfg.vocab_padded)
    assert _finite(ld)
    assert int(cache3["len"]) == 17


def test_gnn_smoke_molecule_batch():
    spec = get_arch("dimenet")
    cfg = spec.smoke_config
    batch, y = graph_sampler.make_molecule_batch(
        n_molecules=4, n_atoms=8, n_bonds=16, d_feat=8, seed=0)
    batch = jax.tree.map(jnp.asarray, batch)
    params = DN.init_params(jax.random.PRNGKey(0), cfg, d_feat=8)
    out = DN.forward(params, cfg, batch)
    assert out.shape == (4, cfg.d_out)
    assert _finite(out)
    loss = DN.train_step_loss(params, cfg, batch, jnp.asarray(y))
    assert np.isfinite(float(loss))
    grads = jax.grad(
        lambda p: DN.train_step_loss(p, cfg, batch, jnp.asarray(y)))(params)
    assert _finite(grads)


def test_gnn_smoke_sampled_subgraph():
    spec = get_arch("dimenet")
    cfg = spec.smoke_config
    g = graph_sampler.make_power_law_graph(500, avg_degree=8, d_feat=8)
    nodes, es, ed = graph_sampler.neighbor_sample(
        g, np.arange(16), fanouts=(4, 3), seed=0)
    batch = graph_sampler.build_graph_batch(
        g, nodes, es, ed, pad_nodes=512, pad_edges=512, pad_triplets=2048)
    batch = jax.tree.map(jnp.asarray, batch)
    params = DN.init_params(jax.random.PRNGKey(0), cfg, d_feat=8)
    out = DN.forward(params, cfg, batch)
    assert out.shape == (1, cfg.d_out) and _finite(out)


@pytest.mark.parametrize("arch", CTR_ARCHS)
def test_ctr_smoke(arch):
    spec = get_arch(arch)
    cfg = spec.smoke_config
    ids, mask, labels = recsys_data.ctr_batch(cfg, 32)
    ids, mask, labels = map(jnp.asarray, (ids, mask, labels))
    init = {"deepfm": RS.init_deepfm, "xdeepfm": RS.init_xdeepfm,
            "autoint": RS.init_autoint}[arch]
    logits_fn = {"deepfm": RS.deepfm_logits, "xdeepfm": RS.xdeepfm_logits,
                 "autoint": RS.autoint_logits}[arch]
    params = init(jax.random.PRNGKey(0), cfg)
    logits = logits_fn(params, cfg, ids.astype(jnp.int32), mask)
    assert logits.shape == (32,) and _finite(logits)
    loss = RS.ctr_loss(logits, labels)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: RS.ctr_loss(
        logits_fn(p, cfg, ids.astype(jnp.int32), mask), labels))(params)
    assert _finite(grads)


def test_mind_smoke():
    spec = get_arch("mind")
    cfg = spec.smoke_config
    hist, mask, target = recsys_data.mind_batch(cfg, 16)
    hist, mask, target = map(jnp.asarray, (hist, mask, target))
    params = RS.init_mind(jax.random.PRNGKey(0), cfg)
    u = RS.mind_user_interests(params, cfg, hist, mask)
    assert u.shape == (16, cfg.n_interests, cfg.embed_dim) and _finite(u)
    logits = RS.mind_train_logits(params, cfg, hist, mask, target)
    loss = RS.sampled_softmax_loss(logits)
    assert np.isfinite(float(loss))
    scores, ids = RS.mind_retrieve(params, cfg, hist[:1], mask[:1],
                                   jnp.arange(cfg.item_vocab,
                                              dtype=jnp.int32), k=10)
    assert scores.shape == (1, 10) and _finite(scores)
    assert bool((np.diff(np.asarray(scores)[0]) <= 1e-6).all())


def test_fm_identity():
    """FM sum-of-squares identity == explicit pairwise sum."""
    v = jax.random.normal(jax.random.PRNGKey(2), (4, 6, 8))
    fast = RS.fm_interaction(v)
    slow = jnp.zeros(4)
    for i in range(6):
        for j in range(i + 1, 6):
            slow += jnp.sum(v[:, i] * v[:, j], -1)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=1e-4)


def test_triplet_builder_correctness():
    """Every triplet (k->j->i): tri_kj's dst == tri_ji's src, and k != i."""
    g = graph_sampler.make_power_law_graph(200, avg_degree=6, d_feat=4)
    nodes, es, ed = graph_sampler.neighbor_sample(
        g, np.arange(8), fanouts=(4,), seed=1)
    batch = graph_sampler.build_graph_batch(
        g, nodes, es, ed, pad_nodes=256, pad_edges=256, pad_triplets=1024)
    m = np.asarray(batch.tri_mask)
    kj = np.asarray(batch.tri_kj)[m]
    ji = np.asarray(batch.tri_ji)[m]
    src = np.asarray(batch.edge_src)
    dst = np.asarray(batch.edge_dst)
    assert (dst[kj] == src[ji]).all()
    assert (src[kj] != dst[ji]).all()
