"""What-if sweep engine: grid semantics, sim agreement, frontier."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capacity, planner, queueing, sweep
from repro.core.queueing import ServerParams


def _small_grid():
    return sweep.SweepGrid.build(
        lam=jnp.asarray([4.0, 16.0, 32.0]),
        p=jnp.asarray([50.0, 100.0]),
        cpu=jnp.asarray([1.0, 4.0]),
        disk=jnp.asarray([1.0, 4.0]),
        hit=jnp.asarray([0.02, 0.18]),
    )


def test_grid_matches_scalar_evaluation():
    """Every grid cell equals the one-scenario-at-a-time computation."""
    grid = _small_grid()
    res = sweep.sweep_analytical(grid)
    assert res.response_upper.shape == grid.shape
    rng = np.random.default_rng(0)
    for _ in range(10):
        il, ip, ic, id_, ih, ir = (int(rng.integers(0, d))
                                   for d in grid.shape)
        cpu, disk = float(grid.cpu[ic]), float(grid.disk[id_])
        p = float(grid.p[ip])
        params = ServerParams(
            p=p,
            s_broker=capacity.broker_service_time(p) / cpu,
            s_hit=grid.base.s_hit / cpu,
            s_miss=grid.base.s_miss / cpu,
            s_disk=grid.base.s_disk / disk,
            hit=float(grid.hit[ih]),
        )
        lam_rep = float(grid.lam[il]) / float(grid.r[ir])
        lo, hi = queueing.response_time_bounds(lam_rep, params)
        np.testing.assert_allclose(
            float(res.response_upper[il, ip, ic, id_, ih, ir]), float(hi),
            rtol=1e-5)
        np.testing.assert_allclose(
            float(res.response_lower[il, ip, ic, id_, ih, ir]), float(lo),
            rtol=1e-5)


def test_response_monotone_in_lambda():
    """Along the lam axis the upper bound is nondecreasing (inf-saturated)."""
    grid = sweep.SweepGrid.build(
        lam=jnp.linspace(1.0, 60.0, 12), p=jnp.asarray([50.0, 100.0]),
        cpu=jnp.asarray([1.0, 2.0]), disk=jnp.asarray([1.0, 2.0]),
        hit=jnp.asarray([0.02, 0.18]))
    hi = np.asarray(sweep.sweep_analytical(grid).response_upper)
    with np.errstate(invalid="ignore"):  # inf - inf in saturated cells
        diffs = np.diff(hi, axis=0)
    # inf - inf = nan where both saturated; treat as nondecreasing
    assert np.all((diffs >= -1e-6) | np.isnan(diffs))


def test_analytical_vs_simulation_agreement():
    """Simulated means land inside Eq 7 bounds across a small grid."""
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 20.0]), p=jnp.asarray([4.0, 8.0]),
        base=capacity.TABLE5_PARAMS, hit=jnp.asarray([0.17]),
        broker_from_p=False)
    sim_res = sweep.sweep_simulated(
        grid, jax.random.PRNGKey(0), n_queries=60_000)
    sim = np.asarray(sim_res.mean)
    res = sweep.sweep_analytical(grid)
    lo = np.asarray(res.response_lower)
    hi = np.asarray(res.response_upper)
    assert sim.shape == grid.shape
    assert np.all(sim > lo * 0.95), (sim, lo)
    assert np.all(sim < hi * 1.05), (sim, hi)
    # quantile surfaces ride along: p95 sits above the mean everywhere
    p95 = np.asarray(sim_res.quantile(0.95))
    assert p95.shape == grid.shape
    assert np.all(p95 > sim)


def test_batch_simulator_matches_single_scenario():
    """(S=1) batched streaming == the scalar simulate_fork_join estimate."""
    from repro.core import simulator
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=8)
    single = simulator.simulate_fork_join(
        jax.random.PRNGKey(1), 20.0, 60_000, pr, mode="exponential")
    vec = ServerParams(**{
        f.name: jnp.asarray([getattr(pr, f.name)], jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    batch = simulator.simulate_fork_join_batch(
        jax.random.PRNGKey(2), jnp.asarray([20.0]), vec, 60_000, p=8)
    assert abs(float(batch.mean_response[0]) - float(single.mean_response)
               ) < 0.1 * float(single.mean_response)


def test_batch_simulator_pallas_matches_xla():
    """The shared-Pallas-scan path computes the identical recurrence."""
    from repro.core import simulator
    pr = capacity.TABLE5_PARAMS
    vec = ServerParams(**{
        f.name: jnp.asarray([getattr(pr, f.name)] * 2, jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    lam = jnp.asarray([15.0, 25.0])
    r_xla = simulator.simulate_fork_join_batch(
        jax.random.PRNGKey(3), lam, vec, 8_000, p=4, impl="xla")
    r_pl = simulator.simulate_fork_join_batch(
        jax.random.PRNGKey(3), lam, vec, 8_000, p=4, impl="pallas")
    np.testing.assert_allclose(np.asarray(r_xla.mean_response),
                               np.asarray(r_pl.mean_response), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(r_xla.quantile(0.95)),
                               np.asarray(r_pl.quantile(0.95)), rtol=1e-3)


def test_streaming_sweep_beyond_old_memory_ceiling():
    """n_queries far past what the materializing path could hold.

    The old engine materialized ~6 arrays of S x p x n_queries floats; at
    S=8, p=8, n=200k that is ~1.2 GB of f32 intermediates inside one XLA
    program.  The streaming engine's footprint is S x p x chunk — this
    run holds ~1.5 MB of state regardless of n_queries.
    """
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([12.0, 22.0]), p=jnp.asarray([8.0]),
        cpu=jnp.asarray([1.0, 2.0]), disk=jnp.asarray([1.0, 2.0]),
        base=capacity.TABLE5_PARAMS, hit=jnp.asarray([0.17]),
        broker_from_p=False)
    res = sweep.sweep_simulated(grid, jax.random.PRNGKey(0),
                                n_queries=200_000, chunk_size=4096)
    ana = sweep.sweep_analytical(grid)
    assert np.all(np.asarray(res.mean) > np.asarray(ana.response_lower)
                  * 0.95)
    assert np.all(np.asarray(res.mean) < np.asarray(ana.response_upper)
                  * 1.05)


def test_diurnal_p95_frontier_differs_from_stationary_mean():
    """Time-varying load + tail targeting shifts the planning answer.

    The same grid, the same SLO: planning for the *mean under stationary
    load* picks cheaper configs than planning for *p95 under the diurnal
    peak* — the new knob the streaming core opens.
    """
    from repro.workloadgen import loadgen
    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([14.0, 20.0]),
        p=jnp.asarray([4.0, 8.0, 16.0]),
        base=capacity.TABLE5_PARAMS, hit=jnp.asarray([0.17]),
        broker_from_p=False)
    slo = 0.8
    key = jax.random.PRNGKey(7)
    mean_res, mean_fr = planner.plan_over_grid(
        grid, slo, simulate=True, key=key, n_queries=40_000)
    profile = loadgen.diurnal_rates(1.0)
    # compress the week so the 40k-query horizon covers full cycles
    horizon = 40_000 / 14.0
    p95_res, p95_fr = planner.plan_over_grid(
        grid, slo, simulate=True, key=key, n_queries=40_000,
        quantile=0.95, profile=profile,
        profile_bin_seconds=horizon / profile.shape[0] / 4)
    assert np.all(np.asarray(p95_fr.cost) >= np.asarray(mean_fr.cost))
    assert np.any(np.asarray(p95_fr.cost) > np.asarray(mean_fr.cost)) or \
        np.any(~np.asarray(p95_fr.feasible))


def test_frontier_picks_minimal_cost_feasible():
    """Vectorized frontier == numpy brute force over the same surface."""
    grid = _small_grid()
    slo = 0.300
    res, fr = planner.plan_over_grid(grid, slo)
    hi = np.asarray(res.response_upper)
    p = np.asarray(grid.p)
    cpu = np.asarray(grid.cpu)
    disk = np.asarray(grid.disk)
    hit = np.asarray(grid.hit)
    for il in range(grid.shape[0]):
        best_cost, best_cfg = np.inf, None
        for ip in range(len(p)):
            for ic in range(len(cpu)):
                for id_ in range(len(disk)):
                    for ih in range(len(hit)):
                        if hi[il, ip, ic, id_, ih] <= slo:
                            c = float(sweep.default_config_cost(
                                p[ip], cpu[ic], disk[id_], hit[ih]))
                            if c < best_cost:
                                best_cost = c
                                best_cfg = (p[ip], cpu[ic], disk[id_],
                                            hit[ih])
        if best_cfg is None:
            assert not bool(fr.feasible[il])
        else:
            assert bool(fr.feasible[il])
            np.testing.assert_allclose(float(fr.cost[il]), best_cost,
                                       rtol=1e-6)
            got = (float(fr.p[il]), float(fr.cpu[il]), float(fr.disk[il]),
                   float(fr.hit[il]))
            np.testing.assert_allclose(got, best_cfg, rtol=1e-6)
        # the chosen config's response must itself satisfy the SLO
        if bool(fr.feasible[il]):
            assert float(fr.response[il]) <= slo


def test_frontier_custom_cost_fn():
    """A server-count-only cost picks the smallest feasible p."""
    grid = _small_grid()
    res = sweep.sweep_analytical(grid)
    fr = sweep.extract_frontier(
        res, 0.300, cost_fn=lambda p, cpu, disk, hit: p + 0 * cpu * disk * hit)
    hi = np.asarray(res.response_upper)
    for il in range(grid.shape[0]):
        if bool(fr.feasible[il]):
            feasible_p = np.asarray(grid.p)[
                np.where((hi[il] <= 0.300).any(axis=(1, 2, 3)))[0]]
            assert float(fr.p[il]) == feasible_p.min()


def test_grid_build_from_memory_table():
    g = sweep.SweepGrid.build(lam=[10.0], memory=4)
    s_hit, s_miss, s_disk, hit = capacity.MEMORY_TABLE[4]
    assert float(g.base.s_hit) == s_hit
    assert float(g.hit[0]) == np.float32(hit)
    # trailing axis is the replica count, defaulting to a single replica
    assert g.shape == (1, 1, 1, 1, 1, 1)
    assert float(g.r[0]) == 1.0
    assert g.n_scenarios == 1
