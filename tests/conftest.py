# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see
# ONE device; only launch/dryrun.py (and subprocess tests) force 512/8
# host devices, each in its own process.
