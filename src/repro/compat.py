"""JAX version-compatibility shims.

The repo tracks two JAX API renames that landed at different versions:

  * ``pallas.tpu.TPUCompilerParams`` -> ``pallas.tpu.CompilerParams``
    (the TPU- prefix was dropped once params moved under the tpu module);
  * mesh axis types: ``jax.sharding.AxisType`` (new enum, accepted by
    ``jax.make_mesh(axis_types=...)``) vs older releases where
    ``make_mesh`` has no ``axis_types`` parameter at all;
  * ``jax.shard_map(..., check_vma=...)`` vs the older
    ``jax.experimental.shard_map.shard_map(..., check_rep=...)``.

Convention (recorded in ROADMAP.md): NO module outside this file touches a
JAX symbol that has been renamed or gated across the versions we support.
Kernels call :func:`tpu_compiler_params`, mesh builders call
:func:`make_mesh` / :func:`mesh_axis_types`, and a future JAX upgrade means
editing this one file instead of five kernels and every test body.
"""

from __future__ import annotations

import functools
import inspect
from typing import Any, Optional, Sequence

import jax

__all__ = ["tpu_compiler_params", "mesh_axis_types", "make_mesh",
           "shard_map"]


@functools.cache
def _compiler_params_cls():
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:  # pragma: no cover - unsupported JAX
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; JAX version unsupported")
    return cls


def tpu_compiler_params(
    *, dimension_semantics: Optional[Sequence[str]] = None, **kwargs: Any
):
    """Build Pallas TPU compiler params under either API name.

    Unknown keyword arguments are dropped (with the field filter below)
    rather than exploded, so kernels can request newer tuning knobs and
    still compile on older JAX.
    """
    cls = _compiler_params_cls()
    accepted = set(inspect.signature(cls).parameters)
    full = dict(kwargs, dimension_semantics=dimension_semantics)
    return cls(**{k: v for k, v in full.items()
                  if k in accepted and v is not None})


@functools.cache
def _axis_type_auto():
    """The 'Auto' mesh axis type, or None when this JAX has no such enum."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return axis_type.Auto
    return None


def mesh_axis_types(n_axes: int):
    """``axis_types`` tuple for an all-Auto mesh, or None if unsupported.

    Auto is the default partitioning mode everywhere we build meshes, so
    degrading to "no axis_types argument" on older JAX is behavior-neutral.
    """
    auto = _axis_type_auto()
    if auto is None:
        return None
    return (auto,) * n_axes


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, devices=None):
    """``jax.make_mesh`` with all-Auto axis types where supported."""
    kwargs: dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    sig = inspect.signature(jax.make_mesh)
    types = mesh_axis_types(len(axis_names))
    if types is not None and "axis_types" in sig.parameters:
        kwargs["axis_types"] = types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Per-shard mapping under either the top-level or experimental API.

    ``check_vma`` (varying-manual-axes checking) is the new name of the
    old ``check_rep`` replication check; both toggle the same validation.
    """
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    kw = ("check_vma" if "check_vma" in inspect.signature(fn).parameters
          else "check_rep")
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **{kw: check_vma})
