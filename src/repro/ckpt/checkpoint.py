"""Sharded checkpointing: atomic, async, resharding-on-restore.

Layout:  <dir>/step_<N>/
            manifest.json        — tree structure, shapes, dtypes
            arrays.npz           — flattened leaves keyed by tree path

Guarantees used for fault tolerance at scale:
  * atomicity — written to ``step_<N>.tmp`` then os.rename'd, so a crash
    mid-write never corrupts the latest checkpoint;
  * async — `save_async` snapshots to host memory synchronously (cheap)
    and writes on a background thread, overlapping I/O with compute;
  * elastic restore — `restore` takes target shardings (any mesh shape),
    so surviving hosts re-shard a checkpoint onto a smaller/larger mesh
    (launch.elastic drives this);
  * GC — keep_last bounds disk usage.

Data-pipeline state needs no saving: pipelines are pure functions of
(seed, step) (see repro.data.pipeline).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    named = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        named[key] = leaf
    return named, treedef


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3) -> str:
    named, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                 for k, v in arrays.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def save_async(ckpt_dir: str, step: int, tree, *, keep_last: int = 3
               ) -> threading.Thread:
    """Snapshot to host synchronously, write on a background thread."""
    named, _ = _flatten(tree)
    host = {k: np.asarray(v) for k, v in named.items()}  # device->host now

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step,
                       "keys": {k: {"shape": list(v.shape),
                                    "dtype": str(v.dtype)}
                                for k, v in host.items()}}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep_last)

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    return t


def _gc(ckpt_dir: str, keep_last: int):
    steps = sorted(
        d for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, tree_like, *, shardings=None):
    """Restore into the structure of ``tree_like``.

    shardings: optional pytree of jax.sharding.Sharding matching
    tree_like — arrays are placed (and thus RE-SHARDED) accordingly,
    which is the elastic-restart path: the mesh may differ from the one
    that saved the checkpoint.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    named, treedef = _flatten(tree_like)
    out = {}
    for k, like in named.items():
        arr = data[k]
        assert tuple(arr.shape) == tuple(like.shape), (k, arr.shape,
                                                       like.shape)
        out[k] = arr.astype(like.dtype)
    leaves = [out[k] for k in named]
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda x, s: jax.device_put(x, s), restored, shardings)
    else:
        restored = jax.tree.map(jnp.asarray, restored)
    return restored


class CheckpointManager:
    """Every-N-steps async checkpointing with restart discovery."""

    def __init__(self, ckpt_dir: str, *, every: int = 100,
                 keep_last: int = 3):
        self.dir = ckpt_dir
        self.every = every
        self.keep_last = keep_last
        self._pending: Optional[threading.Thread] = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree):
        if step % self.every != 0:
            return
        self.wait()
        self._pending = save_async(self.dir, step, tree,
                                   keep_last=self.keep_last)

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_latest(self, tree_like, *, shardings=None):
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return step, restore(self.dir, step, tree_like,
                             shardings=shardings)
