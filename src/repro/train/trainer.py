"""Train-step builder: loss -> grads -> (compressed) update, with
microbatch gradient accumulation under lax.scan.

Accumulation serves two purposes at scale: it fits large global batches in
HBM, and it lets XLA overlap each microbatch's gradient reduce-scatter
with the next microbatch's compute (the standard latency-hiding trick —
DESIGN.md §4).  The whole step is one jittable function, so the dry-run
lowers exactly what production would run.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.train.compression import Compressor
from repro.train.optimizer import AdamW


@dataclasses.dataclass(frozen=True)
class TrainStep:
    loss_fn: Callable            # (params, batch) -> scalar loss
    optimizer: object = None     # AdamW-like; default AdamW()
    microbatches: int = 1
    compressor: Optional[Compressor] = None

    def init_state(self, params):
        opt = self.optimizer or AdamW()
        state = {"opt": opt.init(params)}
        if self.compressor and self.compressor.mode != "none":
            state["residual"] = self.compressor.init(params)
        return state

    def __call__(self, params, state, batch):
        """One optimizer step; batch leading dim splits into microbatches."""
        opt = self.optimizer or AdamW()
        n = self.microbatches

        if n == 1:
            loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
        else:
            def micro(acc, mb):
                l, g = jax.value_and_grad(self.loss_fn)(params, mb)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None

            split = jax.tree.map(
                lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.zeros(()), zero_g), split)
            loss = loss / n
            grads = jax.tree.map(lambda g: g / n, grads)

        new_state = dict(state)
        if self.compressor and self.compressor.mode != "none":
            grads, new_state["residual"] = self.compressor.compress(
                grads, state["residual"])

        new_params, new_state["opt"] = opt.update(grads, state["opt"], params)
        return new_params, new_state, loss
