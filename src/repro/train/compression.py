"""Gradient compression for data-parallel all-reduce, with error feedback.

At 1000+ nodes the DP all-reduce of gradients is the dominant collective;
compressing it (bf16, or int8 with per-tensor scale) cuts its roofline
collective term 2-4x.  Biased compressors accumulate the quantization
residual locally (error feedback, Karimireddy et al. 2019) so SGD still
converges — tests assert the residual bound and end-to-end convergence.

Usage: wrap grads before the psum/optimizer:  g_c, state = compress(g, state)
(in pjit mode the all-reduce is implicit; compressing the tensor that
crosses the collective has the same byte effect and is what the roofline
measures).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Compressor:
    mode: str = "bf16"   # "none" | "bf16" | "int8"

    def init(self, grads):
        if self.mode == "none":
            return ()
        return jax.tree.map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def compress(self, grads, residual):
        """Returns (compressed-then-decompressed grads, new residual).

        The returned grads are what the collective would carry (already
        dequantized for the optimizer); the residual holds the error to be
        re-added next step.
        """
        if self.mode == "none":
            return grads, residual

        def one(g, r):
            x = g.astype(jnp.float32) + r
            if self.mode == "bf16":
                q = x.astype(jnp.bfloat16).astype(jnp.float32)
            elif self.mode == "int8":
                scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
                q = jnp.round(x / scale).clip(-127, 127) * scale
            else:
                raise ValueError(self.mode)
            return q.astype(g.dtype), x - q

        flat = jax.tree.map(one, grads, residual)
        comp = jax.tree.map(lambda t: t[0], flat,
                            is_leaf=lambda t: isinstance(t, tuple))
        res = jax.tree.map(lambda t: t[1], flat,
                           is_leaf=lambda t: isinstance(t, tuple))
        return comp, res

    def wire_bytes_per_element(self) -> float:
        return {"none": 4.0, "bf16": 2.0, "int8": 1.0}[self.mode]
