"""Optimizers (no external deps): AdamW, SGD-momentum, schedules, clipping.

States are pytrees mirroring the param tree, so they shard identically to
the params under pjit (fp32 master moments, bf16 params — the standard
mixed-precision layout; see launch.dryrun param shardings).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: object
    v: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[Array], Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(zeros, params),
            v=jax.tree.map(zeros, params),
        )

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state.m, grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2)
            * jnp.square(g.astype(jnp.float32)), state.v, grads)
        t = step.astype(jnp.float32)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        lr = self._lr(step)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step=step, m=m, v=v)


class SGDState(NamedTuple):
    step: Array
    momentum: object


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable[[Array], Array] | float = 1e-2
    momentum: float = 0.9
    clip_norm: float = 1.0

    def init(self, params) -> SGDState:
        return SGDState(
            step=jnp.zeros((), jnp.int32),
            momentum=jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def update(self, grads, state: SGDState, params):
        grads = clip_by_global_norm(grads, self.clip_norm)
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        mom = jax.tree.map(
            lambda m, g: self.momentum * m + g.astype(jnp.float32),
            state.momentum, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype),
            params, mom)
        return new_params, SGDState(step=step, momentum=mom)


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac)
                         * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr
