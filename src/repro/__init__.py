"""repro: capacity-planning framework for vertical search engines in JAX."""

__version__ = "1.0.0"
