"""Render the §Roofline table + §Dry-run summary from the JSON records.

Two consumers share this module:

* the dry-run experiment records (``experiments/*/*.json``) — the
  original §Roofline table over (arch, shape, mesh) cells;
* :func:`kernel_roofline` — the observability layer's
  `repro.obs.profile.ProfileRecord`s (the Pallas (max, +) kernel stack
  and the benchmark entry points) placed on a machine roofline:
  compute_s = flops / peak_flops, memory_s = bytes / HBM bandwidth,
  bound = the slower engine.  CI embeds the records in
  ``BENCH_obs.json``, so the table renders from a committed baseline
  without recompiling anything.
"""

from __future__ import annotations

import glob
import json
import os


def load_records(*dirs):
    recs = {}
    for d in dirs:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            r = json.load(open(f))
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs, mesh: str = "single") -> str:
    rows = [r for r in recs.values() if r["mesh"] == mesh]
    out = ["| arch | shape | compute_s | memory_s | collective_s | bound |"
           " MODEL/HLO | peak GB/dev | sentence |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = (r["memory_analysis"]["argument_bytes"]
                + r["memory_analysis"]["temp_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bound']} | {min(r['useful_flops_ratio'], 9.99):.2f} | "
            f"{peak:.1f} | {_advice(r)} |")
    return "\n".join(out)


def _advice(r) -> str:
    b = r["bound"]
    if b == "collective":
        return ("cut bytes on the join path (sharding/all-to-all) or "
                "overlap with compute")
    if b == "memory":
        return ("raise arithmetic intensity: fuse, cut remat re-reads, "
                "larger per-chip tiles")
    return "compute-bound: already near the MXU roofline; check MODEL/HLO"


def kernel_roofline(records, hw=None) -> str:
    """Place ProfileRecords on ``hw``'s roofline; return the table.

    ``records`` are `repro.obs.profile.ProfileRecord`s (or their
    ``to_json()`` dicts, e.g. read back from ``BENCH_obs.json``'s
    ``kernel_profiles``).  For each, the roofline terms come straight
    from XLA's cost analysis: compute_s = flops / peak_flops and
    memory_s = bytes_accessed / hbm_bandwidth; the larger term names the
    bound, and ``balance`` compares the record's arithmetic intensity to
    the machine's ridge point (flops/byte at which both engines tie).
    """
    from repro.core.planner import TPU_V5E, RooflineTerms
    from repro.obs.profile import ProfileRecord

    hw = TPU_V5E if hw is None else hw
    ridge = hw.peak_flops / hw.hbm_bandwidth
    out = [f"| kernel | compute_s | memory_s | bound | F/B "
           f"| ridge {ridge:.0f} | peak MiB |",
           "|---|---|---|---|---|---|---|"]
    for rec in records:
        r = (ProfileRecord.from_json(rec) if isinstance(rec, dict)
             else rec)
        terms = RooflineTerms(
            compute_s=r.flops / hw.peak_flops,
            memory_s=r.bytes_accessed / hw.hbm_bandwidth,
            collective_s=0.0)
        ai = r.arithmetic_intensity
        out.append(
            f"| {r.name} | {terms.compute_s:.3e} | {terms.memory_s:.3e} "
            f"| {terms.bound} | {ai:.2f} | {ai / ridge:.1%} of ridge "
            f"| {r.peak_bytes / 2**20:.1f} |")
    return "\n".join(out)


def dryrun_summary(recs) -> str:
    single = [r for r in recs.values() if r["mesh"] == "single"]
    multi = [r for r in recs.values() if r["mesh"] == "multi"]
    out = [f"single-pod cells compiled: {len(single)}/40",
           f"multi-pod cells compiled:  {len(multi)}/40"]
    worst = sorted(single, key=lambda r: -(
        r["memory_analysis"]["argument_bytes"]
        + r["memory_analysis"]["temp_bytes"]))[:5]
    out.append("largest per-device footprints (args+temp):")
    for r in worst:
        gb = (r["memory_analysis"]["argument_bytes"]
              + r["memory_analysis"]["temp_bytes"]) / 2**30
        out.append(f"  {r['arch']} x {r['shape']}: {gb:.1f} GB")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    if os.path.exists("BENCH_obs.json"):
        obs = json.load(open("BENCH_obs.json"))
        print(kernel_roofline(obs.get("kernel_profiles", [])))
        print()
    dirs = sys.argv[1:] or ["experiments/dryrun_v2", "experiments/perf"]
    recs = load_records(*dirs)
    if recs:
        print(dryrun_summary(recs))
        print()
        print(roofline_table(recs))
