"""Render the §Roofline table + §Dry-run summary from the JSON records."""

from __future__ import annotations

import glob
import json
import os


def load_records(*dirs):
    recs = {}
    for d in dirs:
        for f in sorted(glob.glob(os.path.join(d, "*.json"))):
            r = json.load(open(f))
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def roofline_table(recs, mesh: str = "single") -> str:
    rows = [r for r in recs.values() if r["mesh"] == mesh]
    out = ["| arch | shape | compute_s | memory_s | collective_s | bound |"
           " MODEL/HLO | peak GB/dev | sentence |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        peak = (r["memory_analysis"]["argument_bytes"]
                + r["memory_analysis"]["temp_bytes"]) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['bound']} | {min(r['useful_flops_ratio'], 9.99):.2f} | "
            f"{peak:.1f} | {_advice(r)} |")
    return "\n".join(out)


def _advice(r) -> str:
    b = r["bound"]
    if b == "collective":
        return ("cut bytes on the join path (sharding/all-to-all) or "
                "overlap with compute")
    if b == "memory":
        return ("raise arithmetic intensity: fuse, cut remat re-reads, "
                "larger per-chip tiles")
    return "compute-bound: already near the MXU roofline; check MODEL/HLO"


def dryrun_summary(recs) -> str:
    single = [r for r in recs.values() if r["mesh"] == "single"]
    multi = [r for r in recs.values() if r["mesh"] == "multi"]
    out = [f"single-pod cells compiled: {len(single)}/40",
           f"multi-pod cells compiled:  {len(multi)}/40"]
    worst = sorted(single, key=lambda r: -(
        r["memory_analysis"]["argument_bytes"]
        + r["memory_analysis"]["temp_bytes"]))[:5]
    out.append("largest per-device footprints (args+temp):")
    for r in worst:
        gb = (r["memory_analysis"]["argument_bytes"]
              + r["memory_analysis"]["temp_bytes"]) / 2**30
        out.append(f"  {r['arch']} x {r['shape']}: {gb:.1f} GB")
    return "\n".join(out)


if __name__ == "__main__":
    import sys
    dirs = sys.argv[1:] or ["experiments/dryrun_v2", "experiments/perf"]
    recs = load_records(*dirs)
    print(dryrun_summary(recs))
    print()
    print(roofline_table(recs))
