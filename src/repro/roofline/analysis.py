"""Roofline-term extraction from compiled dry-run artifacts (§Roofline).

  compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
  memory term     = HLO_bytes / (chips x HBM_bw)
  collective term = collective_bytes / (chips x link_bw)

`compiled.cost_analysis()` yields per-device FLOPs/bytes (the module is
the per-device SPMD program), so global = per_device x chips.  Collective
bytes are NOT in cost_analysis: we parse the post-optimization HLO and sum
the RESULT-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute (a standard, conservative proxy for bytes
crossing links per device).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

import numpy as np

from repro.core.planner import TPU_V5E, HardwareSpec, RooflineTerms

__all__ = ["CollectiveStats", "parse_collectives", "roofline_from_compiled",
           "CellRoofline"]

_COLLECTIVE_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\b"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, float]
    count_by_kind: Dict[str, int]

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes per collective kind over an HLO module.

    Skips the paired ``-done`` ops (async collectives appear as
    start/done; the start op carries the shape).
    """
    bytes_by, count_by = {}, {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        nbytes = n * _DTYPE_BYTES[dtype]
        bytes_by[kind] = bytes_by.get(kind, 0.0) + nbytes
        count_by[kind] = count_by.get(kind, 0) + 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_global: float
    bytes_global: float
    collective_bytes_global: float
    terms: RooflineTerms
    model_flops: float             # 6*N*D (or family analogue)
    memory_analysis: Dict[str, float]
    collectives: Dict[str, float]

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / max(self.flops_global, 1.0)

    @property
    def bound(self) -> str:
        return self.terms.bound

    @property
    def roofline_fraction(self) -> float:
        """dominant-term share of the serial step: how close the step is
        to the single-resource roofline (1.0 = perfectly bound by one
        engine, lower = time wasted on non-dominant engines)."""
        t = self.terms
        tot = t.compute_s + t.memory_s + t.collective_s
        return t.step_time_lower_bound / max(tot, 1e-30)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "flops_global": self.flops_global,
            "bytes_global": self.bytes_global,
            "collective_bytes_global": self.collective_bytes_global,
            "compute_s": self.terms.compute_s,
            "memory_s": self.terms.memory_s,
            "collective_s": self.terms.collective_s,
            "bound": self.bound,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "memory_analysis": self.memory_analysis,
            "collectives": self.collectives,
        }


def _costs(compiled) -> tuple[float, float, float]:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    coll = parse_collectives(compiled.as_text()).total_bytes
    return flops, nbytes, coll


def roofline_from_compiled(
    *, arch: str, shape: str, mesh_name: str, n_chips: int,
    compiled, model_flops: float,
    extrapolate=None,
    hw: HardwareSpec = TPU_V5E,
) -> CellRoofline:
    """extrapolate: optional (compiled_unroll2, n_layers).  XLA counts a
    while body once; the unroll=2 variant contains one extra body copy, so
    cost_true = cost1 + (cost2 - cost1) * (n_layers - 1)."""
    flops_dev, bytes_dev, coll_dev = _costs(compiled)
    if extrapolate is not None:
        compiled2, n_layers = extrapolate
        f2, b2, c2 = _costs(compiled2)
        flops_dev += max(f2 - flops_dev, 0.0) * (n_layers - 1)
        bytes_dev += max(b2 - bytes_dev, 0.0) * (n_layers - 1)
        coll_dev += max(c2 - coll_dev, 0.0) * (n_layers - 1)

    hlo = compiled.as_text()
    coll = parse_collectives(hlo)

    mem = compiled.memory_analysis()
    mem_summary = {
        "argument_bytes": float(getattr(mem, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(mem, "temp_size_in_bytes", 0)),
        "peak_bytes": float(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)),
    }

    flops_global = flops_dev * n_chips
    bytes_global = bytes_dev * n_chips
    coll_global = coll_dev * n_chips

    terms = RooflineTerms(
        compute_s=flops_global / (n_chips * hw.peak_flops),
        memory_s=bytes_global / (n_chips * hw.hbm_bandwidth),
        collective_s=coll_global / (n_chips * hw.ici_bandwidth),
    )
    return CellRoofline(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        flops_global=flops_global, bytes_global=bytes_global,
        collective_bytes_global=coll_global, terms=terms,
        model_flops=model_flops, memory_analysis=mem_summary,
        collectives={f"{k}_bytes": v for k, v in coll.bytes_by_kind.items()}
        | {f"{k}_count": float(v) for k, v in coll.count_by_kind.items()},
    )
