"""Deterministic synthetic data pipelines.

Every pipeline is a pure function of (seed, step) so any host can
regenerate any shard of any batch — this is what makes checkpoint/restart
and elastic re-sharding exact: no data-order state needs saving beyond the
step counter.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["LMBatchPipeline"]


@dataclasses.dataclass(frozen=True)
class LMBatchPipeline:
    """Token batches with a learnable bigram structure (so loss decreases).

    Tokens follow a Zipf unigram distribution mixed with a deterministic
    bigram successor function: p(next = succ(cur)) = coherence.  A ~100M
    model trained a few hundred steps shows a clear loss drop against the
    ln(V) floor — the end-to-end example's check.
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    zipf_alpha: float = 1.1
    coherence: float = 0.5
    seed: int = 0

    def _unigram_cdf(self) -> np.ndarray:
        w = np.arange(1, self.vocab_size + 1, dtype=np.float64) ** (
            -self.zipf_alpha)
        return np.cumsum(w / w.sum())

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1
              ) -> tuple[np.ndarray, np.ndarray]:
        """(tokens, labels) for this step's shard of the global batch."""
        assert self.global_batch % n_shards == 0
        b = self.global_batch // n_shards
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + shard)
        cdf = self._unigram_cdf()
        draws = np.searchsorted(
            cdf, rng.random((b, self.seq_len))).astype(np.int32)
        draws = np.minimum(draws, self.vocab_size - 1)
        # bigram successor: succ(t) = (t * 31 + 7) % V
        tokens = draws.copy()
        follow = rng.random((b, self.seq_len)) < self.coherence
        for s in range(1, self.seq_len):
            succ = (tokens[:, s - 1] * 31 + 7) % self.vocab_size
            tokens[:, s] = np.where(follow[:, s], succ, draws[:, s])
        labels = np.concatenate(
            [tokens[:, 1:], np.full((b, 1), -1, np.int32)], axis=1)
        return tokens, labels
