"""Graph generation, neighbor sampling, and triplet construction.

`minibatch_lg` requires a real neighbor sampler: layered fanout sampling
(GraphSAGE style) from a CSR adjacency, producing padded GraphBatch
buffers.  Triplets (k->j->i) for DimeNet's directional messages are built
per edge from the in-edges of its source, capped at a per-edge budget.

Geometry: molecular graphs carry true 3D positions; for non-geometric
assigned graphs (reddit/ogbn-products) positions are synthesized from a
random embedding so distances/angles are well-defined (DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.models.gnn_common import GraphBatch

__all__ = ["SyntheticGraph", "make_power_law_graph", "neighbor_sample",
           "build_graph_batch", "make_molecule_batch"]


@dataclasses.dataclass
class SyntheticGraph:
    n_nodes: int
    csr_offsets: np.ndarray   # (N+1,) in-neighbor CSR
    csr_indices: np.ndarray   # (E,)
    positions: np.ndarray     # (N, 3)
    features: np.ndarray      # (N, F)


def make_power_law_graph(n_nodes: int, avg_degree: int, d_feat: int,
                         *, seed: int = 0) -> SyntheticGraph:
    """Preferential-attachment-ish graph with power-law degree skew."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # power-law destination popularity (like term popularity in the paper)
    pop = (np.arange(1, n_nodes + 1) ** -0.8)
    pop /= pop.sum()
    dst = rng.choice(n_nodes, size=n_edges, p=pop)
    src = rng.integers(0, n_nodes, size=n_edges)
    keep = src != dst
    src, dst = src[keep], dst[keep]

    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    offsets = np.zeros(n_nodes + 1, np.int64)
    np.add.at(offsets, dst + 1, 1)
    offsets = np.cumsum(offsets)
    return SyntheticGraph(
        n_nodes=n_nodes,
        csr_offsets=offsets,
        csr_indices=src.astype(np.int32),
        positions=rng.normal(size=(n_nodes, 3)).astype(np.float32),
        features=rng.normal(size=(n_nodes, d_feat)).astype(np.float32) / 8,
    )


def neighbor_sample(graph: SyntheticGraph, seeds: np.ndarray,
                    fanouts: tuple[int, ...], *, seed: int = 0
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Layered fanout sampling; returns (nodes, edge_src, edge_dst).

    Node ids are *local* to the returned subgraph (seeds first); edges
    point child -> parent (message direction).
    """
    rng = np.random.default_rng(seed)
    local = {int(s): i for i, s in enumerate(seeds)}
    nodes = list(int(s) for s in seeds)
    e_src, e_dst = [], []
    frontier = list(int(s) for s in seeds)
    for fanout in fanouts:
        nxt = []
        for u in frontier:
            lo, hi = graph.csr_offsets[u], graph.csr_offsets[u + 1]
            if hi <= lo:
                continue
            neigh = graph.csr_indices[lo:hi]
            pick = rng.choice(neigh, size=min(fanout, len(neigh)),
                              replace=False)
            for v in pick:
                v = int(v)
                if v not in local:
                    local[v] = len(nodes)
                    nodes.append(v)
                    nxt.append(v)
                e_src.append(local[v])
                e_dst.append(local[u])
        frontier = nxt
    return (np.asarray(nodes, np.int64),
            np.asarray(e_src, np.int32), np.asarray(e_dst, np.int32))


def _build_triplets(e_src, e_dst, n_edges_pad, budget_per_edge, rng):
    """Triplets (k->j->i): for edge e=(j->i), partner edges e'=(k->j)."""
    by_dst: dict[int, list[int]] = {}
    for e, d in enumerate(e_dst):
        by_dst.setdefault(int(d), []).append(e)
    t_kj, t_ji = [], []
    for e in range(len(e_src)):
        partners = by_dst.get(int(e_src[e]), ())
        cnt = 0
        for e2 in partners:
            if e_src[e2] == e_dst[e]:
                continue  # exclude backtracking k == i
            t_kj.append(e2)
            t_ji.append(e)
            cnt += 1
            if cnt >= budget_per_edge:
                break
    return np.asarray(t_kj, np.int32), np.asarray(t_ji, np.int32)


def build_graph_batch(
    graph: SyntheticGraph,
    nodes: np.ndarray, e_src: np.ndarray, e_dst: np.ndarray,
    *,
    pad_nodes: int, pad_edges: int, pad_triplets: int,
    triplet_budget_per_edge: int = 4,
    n_graphs: int = 1,
    node_graph: np.ndarray = None,
    seed: int = 0,
) -> GraphBatch:
    """Pad a sampled subgraph into fixed GraphBatch buffers."""
    rng = np.random.default_rng(seed)
    pos = graph.positions[nodes]
    vec = pos[e_dst] - pos[e_src]
    dist = np.linalg.norm(vec, axis=1).astype(np.float32) + 1e-3

    t_kj, t_ji = _build_triplets(e_src, e_dst, pad_edges,
                                 triplet_budget_per_edge, rng)
    # angle between edge (k->j) and (j->i) at node j
    v1 = -vec[t_kj]
    v2 = vec[t_ji]
    cosang = np.sum(v1 * v2, axis=1) / np.maximum(
        np.linalg.norm(v1, axis=1) * np.linalg.norm(v2, axis=1), 1e-9)
    angle = np.arccos(np.clip(cosang, -1.0, 1.0)).astype(np.float32)

    nn, ne, nt = len(nodes), len(e_src), len(t_kj)
    assert nn <= pad_nodes and ne <= pad_edges and nt <= pad_triplets, (
        (nn, pad_nodes), (ne, pad_edges), (nt, pad_triplets))

    feat = np.zeros((pad_nodes, graph.features.shape[1]), np.float32)
    feat[:nn] = graph.features[nodes]
    if node_graph is None:
        node_graph = np.zeros(nn, np.int32)

    def pad1(x, n, fill=0):
        out = np.full((n,) + x.shape[1:], fill, x.dtype)
        out[: len(x)] = x
        return out

    return GraphBatch(
        node_feat=feat,
        edge_src=pad1(e_src, pad_edges),
        edge_dst=pad1(e_dst, pad_edges),
        edge_dist=pad1(dist, pad_edges, fill=1.0),
        edge_mask=pad1(np.ones(ne, bool), pad_edges, fill=False),
        tri_kj=pad1(t_kj, pad_triplets),
        tri_ji=pad1(t_ji, pad_triplets),
        tri_angle=pad1(angle, pad_triplets),
        tri_mask=pad1(np.ones(nt, bool), pad_triplets, fill=False),
        node_graph=pad1(node_graph.astype(np.int32), pad_nodes,
                        fill=n_graphs - 1),
        n_graphs=n_graphs,
    )


def make_molecule_batch(n_molecules: int, n_atoms: int, n_bonds: int,
                        d_feat: int, *, pad_triplet_factor: int = 6,
                        seed: int = 0) -> tuple[GraphBatch, np.ndarray]:
    """Batched small molecules (the `molecule` shape); returns (batch, y)."""
    rng = np.random.default_rng(seed)
    all_src, all_dst, node_graph = [], [], []
    positions, feats = [], []
    for m in range(n_molecules):
        base = m * n_atoms
        pos = rng.normal(size=(n_atoms, 3)).astype(np.float32) * 1.5
        positions.append(pos)
        feats.append(rng.normal(size=(n_atoms, d_feat)).astype(np.float32))
        # connect each atom to nearest neighbors until n_bonds edges
        d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        order = np.argsort(d, axis=1)
        cnt = 0
        for i in range(n_atoms):
            for j in order[i, :3]:
                all_src.append(base + int(j))
                all_dst.append(base + i)
                cnt += 1
                if cnt >= n_bonds:
                    break
            if cnt >= n_bonds:
                break
        node_graph.extend([m] * n_atoms)

    n_nodes = n_molecules * n_atoms
    g = SyntheticGraph(
        n_nodes=n_nodes,
        csr_offsets=np.zeros(n_nodes + 1, np.int64),
        csr_indices=np.zeros(0, np.int32),
        positions=np.concatenate(positions),
        features=np.concatenate(feats),
    )
    e_src = np.asarray(all_src, np.int32)
    e_dst = np.asarray(all_dst, np.int32)
    batch = build_graph_batch(
        g, np.arange(n_nodes), e_src, e_dst,
        pad_nodes=n_nodes, pad_edges=len(e_src),
        pad_triplets=len(e_src) * pad_triplet_factor,
        n_graphs=n_molecules,
        node_graph=np.asarray(node_graph), seed=seed)
    y = rng.normal(size=(n_molecules, 1)).astype(np.float32)
    return batch, y
