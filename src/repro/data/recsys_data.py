"""Synthetic Criteo-like CTR data and MIND behavior sequences.

Per-field categorical ids are Zipf-distributed (the same popularity skew
the paper measures for query terms — and the reason row-sharded embedding
shards develop hot spots).  Labels come from a fixed random logistic
teacher so models can actually learn in the examples.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import RecsysConfig
from repro.models.recsys import field_offsets

__all__ = ["ctr_batch", "mind_batch"]


def _zipf_ids(rng, vocab: int, size, alpha: float = 1.05) -> np.ndarray:
    w = np.arange(1, vocab + 1, dtype=np.float64) ** (-alpha)
    cdf = np.cumsum(w / w.sum())
    out = np.searchsorted(cdf, rng.random(size))
    return np.minimum(out, vocab - 1).astype(np.int32)


def ctr_batch(cfg: RecsysConfig, batch: int, *, step: int = 0,
              seed: int = 0) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(ids (B,F,M) globalized, mask (B,F,M), labels (B,)) for one step."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    offs = field_offsets(cfg)
    m = cfg.multi_hot
    ids = np.zeros((batch, cfg.n_sparse, m), np.int64)
    mask = np.zeros((batch, cfg.n_sparse, m), bool)
    for f, vocab in enumerate(cfg.field_vocabs):
        n_hot = 1 if vocab > 1000 else m   # big fields one-hot, small multi
        ids[:, f, :n_hot] = (_zipf_ids(rng, vocab, (batch, n_hot))
                             + offs[f])
        mask[:, f, :n_hot] = True
    # teacher: logistic over hashed id parities
    h = ((ids * 2654435761) % 97).sum(axis=(1, 2)) % 13
    prob = 1.0 / (1.0 + np.exp(-(h.astype(np.float64) - 6.0) / 2.0))
    labels = (rng.random(batch) < prob).astype(np.float32)
    return ids, mask, labels


def mind_batch(cfg: RecsysConfig, batch: int, *, step: int = 0,
               seed: int = 0) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(hist (B,H), hist_mask, target (B,)) behavior sequences."""
    rng = np.random.default_rng(seed * 7_000_003 + step)
    hist = _zipf_ids(rng, cfg.item_vocab, (batch, cfg.hist_len))
    lens = rng.integers(cfg.hist_len // 2, cfg.hist_len + 1, batch)
    mask = np.arange(cfg.hist_len)[None, :] < lens[:, None]
    # target correlated with the last visible history item
    last = hist[np.arange(batch), np.maximum(lens - 1, 0)]
    target = ((last * 31 + 7) % cfg.item_vocab).astype(np.int32)
    return hist, mask, target
