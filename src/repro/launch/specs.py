"""Per-cell dry-run builders: input_specs + param shardings + step fns.

For every (arch x shape) cell this module produces:
  * ``fn``            — the jittable step (train / prefill / decode / serve),
  * ``args``          — a pytree of jax.ShapeDtypeStruct stand-ins carrying
                        NamedShardings (weak-type-correct, NO allocation),
  * ``rules``         — logical-axis sharding rules active while tracing,
  * ``model_flops``   — MODEL_FLOPS for §Roofline's useful-compute ratio,
  * ``donate``        — donated arg indices (params/opt/caches), matching
                        how production would run the step.

Divisibility policy: tensor dims are padded (vocab, experts, candidate
count, graph buffers) or the corresponding logical axis is left unsharded
(e.g. granite's 24 heads on a 16-way model axis) — recorded in `notes`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, GNNConfig, LMConfig, RecsysConfig, ShapeSpec
from repro.launch.mesh import data_axes
from repro.models import dimenet as DN
from repro.models import recsys as RS
from repro.models import transformer as T
from repro.models.gnn_common import GraphBatch
from repro.train.optimizer import AdamW, AdamWState

Array = jax.Array

# candidate count padded so retrieval shards over the full 512-chip mesh
RETRIEVAL_CAND_PADDED = 1_000_448


@dataclasses.dataclass
class CellBuild:
    fn: Callable
    args: tuple
    rules: Dict[str, Any]
    model_flops: float
    donate: tuple = ()
    notes: str = ""


def _sharded_sds(tree, pspec_fn, mesh: Mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree via path rules."""

    def visit(path, leaf):
        key = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                       for p in path)
        spec = pspec_fn(key, leaf)
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(visit, tree)


def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, spec))


# -------------------------------------------------------------------------
# LM family
# -------------------------------------------------------------------------

def _lm_param_pspec(cfg: LMConfig, tp: int = 16, *, fsdp: bool = False,
                    dp_size: int = 16):
    """TP rules on the model axis; with fsdp=True, additionally shard the
    first remaining (non-layer-stack) divisible dim over ``data`` —
    ZeRO-3: at 104B params, replicating fp32 optimizer state across the
    data axis costs 54 GB/chip, far over HBM.  XLA inserts the per-layer
    all-gather inside the scan (classic FSDP schedule)."""
    heads_ok = cfg.n_heads % tp == 0
    ffn_ok = cfg.d_ff % tp == 0 if cfg.moe is None else False

    def base_rule(key: str, nd: int) -> list:
        if key == "embed":
            return [None, "model"]   # column-sharded: local gathers
        if key == "lm_head":
            return [None, "model"]
        if key.endswith("wq") and heads_ok:
            return [None, None, "model"]
        if key.endswith("wo") and heads_ok:
            return [None, "model", None]
        if (key.endswith("w_gate") or key.endswith("w_up")) and nd == 3 \
                and ffn_ok:
            return [None, None, "model"]           # dense mlp (L, d, ff)
        if key.endswith("w_down") and nd == 3 and ffn_ok:
            return [None, "model", None]
        if "moe" in key and nd == 4:                # (L, E, ., .)
            return [None, "model", None, None]
        return [None] * nd                          # norms, wk/wv, router

    def rule(key: str, leaf) -> P:
        nd = len(leaf.shape)
        spec = base_rule(key, nd)
        if fsdp:
            # skip dim 0 of layer-stacked tensors (scan slices that dim)
            start = 1 if nd >= 2 and key not in ("embed", "lm_head") else 0
            for i in range(start, nd):
                if spec[i] is None and leaf.shape[i] % dp_size == 0:
                    spec[i] = "data"
                    break
        return P(*spec)

    return rule


def lm_rules(cfg: LMConfig, shape: ShapeSpec, multi_pod: bool
             ) -> Dict[str, Any]:
    dp = data_axes(multi_pod)
    tp = 16
    heads = "model" if cfg.n_heads % tp == 0 else None
    ffn = "model" if (cfg.moe is None and cfg.d_ff % tp == 0) else None
    rules: Dict[str, Any] = {
        "batch": dp, "seq": "model", "seq_q": None, "embed": None,
        "embed_rows": None, "embed_cols": "model",
        "heads": heads, "kv_heads": None, "ffn": ffn, "experts": "model",
        "vocab": "model", "kv_seq": "model", "kv_batch": dp, "cand": None,
        "mlp": None, "fields": None, "rows": None,
    }
    if shape.kind == "decode":
        rules["seq"] = None
        if shape["global_batch"] == 1:             # long_500k
            rules["batch"] = None
            rules["kv_batch"] = None
            rules["kv_seq"] = (("pod", "data", "model") if multi_pod
                               else ("data", "model"))
    return rules


def _lm_params_sds(cfg: LMConfig, mesh: Mesh, *, fsdp: bool = False):
    shapes = jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.key(0))
    return _sharded_sds(shapes, _lm_param_pspec(cfg, fsdp=fsdp), mesh)


def _opt_sds(param_sds, mesh: Mesh):
    def f32_like(s):
        return jax.ShapeDtypeStruct(s.shape, jnp.float32,
                                    sharding=s.sharding)
    return AdamWState(
        step=_sds((), jnp.int32, mesh, P()),
        m=jax.tree.map(f32_like, param_sds),
        v=jax.tree.map(f32_like, param_sds),
    )


def build_lm_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh,
                  multi_pod: bool, *, scan_unroll: int = 1) -> CellBuild:
    # dry-run execution knobs: layers stay under lax.scan (compact HLO,
    # fast SPMD compiles); attention chunk loops are Python-unrolled so
    # per-layer cost analysis is exact.  XLA counts the scan body once
    # regardless of trip count, so the dry-run compiles each LM cell at
    # scan_unroll=1 and 2 and extrapolates per-layer costs to n_layers
    # (launch.dryrun).
    cfg: LMConfig = dataclasses.replace(
        spec.config, scan_layers=True, unroll_attn=True,
        scan_unroll=scan_unroll,
        attn_chunk=2048 if shape.kind == "train" else 0)
    dp = data_axes(multi_pod)
    rules = lm_rules(cfg, shape, multi_pod)
    # ZeRO-3 over data for training (optimizer state dominates at 104B);
    # serving keeps params TP-sharded + data-replicated (latency path).
    params_sds = _lm_params_sds(cfg, mesh, fsdp=shape.kind == "train")
    b = shape["global_batch"]
    s = shape["seq_len"]
    batch_spec = P(dp, None) if b > 1 else P(None, None)

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)

        def fn(params, opt_state, tokens, labels):
            loss, grads = jax.value_and_grad(T.train_step_loss)(
                params, cfg, tokens, labels)
            new_params, new_state = opt.update(grads, opt_state, params)
            return new_params, new_state, loss

        args = (params_sds, _opt_sds(params_sds, mesh),
                _sds((b, s), jnp.int32, mesh, batch_spec),
                _sds((b, s), jnp.int32, mesh, batch_spec))
        flops = 6.0 * cfg.n_active_params * b * s
        return CellBuild(fn, args, rules, flops, donate=(0, 1))

    if shape.kind == "prefill":
        def fn(params, tokens):
            return T.prefill(params, cfg, tokens, chunk=4096)

        args = (params_sds, _sds((b, s), jnp.int32, mesh, batch_spec))
        flops = 2.0 * cfg.n_active_params * b * s
        return CellBuild(fn, args, rules, flops)

    # decode (decode_32k / long_500k): one token against a KV cache
    kv_spec = P(None, rules["kv_batch"], rules["kv_seq"], None, None)
    cache_sds = {
        "k": _sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head),
                  jnp.dtype(cfg.dtype), mesh, kv_spec),
        "v": _sds((cfg.n_layers, b, s, cfg.n_kv_heads, cfg.d_head),
                  jnp.dtype(cfg.dtype), mesh, kv_spec),
        "len": _sds((), jnp.int32, mesh, P()),
    }

    def fn(params, tokens, cache):
        return T.decode_step(params, cfg, tokens, cache)

    args = (params_sds,
            _sds((b, 1), jnp.int32, mesh,
                 P(rules["batch"], None)),
            cache_sds)
    # decode step: 2*N_active per token + KV read "flops" are memory-side
    flops = 2.0 * cfg.n_active_params * b
    return CellBuild(fn, args, rules, flops, donate=(2,),
                     notes="serve_step (decode), not train_step")


# -------------------------------------------------------------------------
# GNN (DimeNet)
# -------------------------------------------------------------------------

def _pad_to(x: int, m: int) -> int:
    return x + (-x) % m


def gnn_cell_dims(shape: ShapeSpec) -> dict:
    """Padded (nodes, edges, triplets, feat, graphs) for a GNN cell."""
    pad = 512  # lcm of both mesh sizes
    if shape.name == "molecule":
        n = shape["batch"] * shape["n_nodes"]
        e = shape["batch"] * shape["n_edges"]
        return dict(nodes=_pad_to(n, pad), edges=_pad_to(e, pad),
                    triplets=_pad_to(4 * e, pad), feat=32,
                    graphs=shape["batch"])
    if shape.name == "minibatch_lg":
        return dict(nodes=_pad_to(shape["sub_nodes"], pad),
                    edges=_pad_to(shape["sub_edges"], pad),
                    triplets=_pad_to(4 * shape["sub_edges"], pad),
                    feat=shape["d_feat"], graphs=1)
    return dict(nodes=_pad_to(shape["n_nodes"], pad),
                edges=_pad_to(shape["n_edges"], pad),
                triplets=_pad_to(4 * shape["n_edges"], pad),
                feat=shape["d_feat"], graphs=1)


def gnn_model_flops(cfg: GNNConfig, dims: dict, train: bool = True) -> float:
    t, e, h, nb = dims["triplets"], dims["edges"], cfg.d_hidden, cfg.n_bilinear
    s = cfg.n_spherical * cfg.n_radial
    per_block = (2.0 * t * (s * nb + nb * h * h + h)    # sbf proj + bilinear
                 + 2.0 * e * h * h * 4)                 # edge MLPs
    fwd = cfg.n_blocks * per_block + 2.0 * e * h * (3 * h)
    return fwd * (3.0 if train else 1.0)


def build_gnn_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh,
                   multi_pod: bool) -> CellBuild:
    from repro.launch.sharding import gnn_rules
    cfg: GNNConfig = spec.config
    dims = gnn_cell_dims(shape)
    # replicated node states: ≤1 GB at ogb_products scale, and it keeps
    # every h[edge_src] gather local per edge shard (§Perf Cell D)
    rules = gnn_rules(multi_pod, replicate_nodes=True)
    every = rules["edges"]
    nodes_spec = rules["nodes"]

    params_shapes = jax.eval_shape(
        functools.partial(DN.init_params, cfg=cfg, d_feat=dims["feat"]),
        jax.random.key(0))
    params_sds = _sharded_sds(
        params_shapes, lambda k, l: P(*([None] * len(l.shape))), mesh)

    nspec, espec, tspec = P(nodes_spec), P(every), P(every)
    g_sds = GraphBatch(
        node_feat=_sds((dims["nodes"], dims["feat"]), jnp.dtype(cfg.dtype),
                       mesh, P(nodes_spec, None)),
        edge_src=_sds((dims["edges"],), jnp.int32, mesh, espec),
        edge_dst=_sds((dims["edges"],), jnp.int32, mesh, espec),
        edge_dist=_sds((dims["edges"],), jnp.float32, mesh, espec),
        edge_mask=_sds((dims["edges"],), jnp.bool_, mesh, espec),
        tri_kj=_sds((dims["triplets"],), jnp.int32, mesh, tspec),
        tri_ji=_sds((dims["triplets"],), jnp.int32, mesh, tspec),
        tri_angle=_sds((dims["triplets"],), jnp.float32, mesh, tspec),
        tri_mask=_sds((dims["triplets"],), jnp.bool_, mesh, tspec),
        node_graph=_sds((dims["nodes"],), jnp.int32, mesh, nspec),
        n_graphs=dims["graphs"],
    )
    targets = _sds((dims["graphs"], cfg.d_out), jnp.float32, mesh,
                   P(None, None))
    opt = AdamW(lr=1e-4)

    def fn(params, opt_state, g, y):
        loss, grads = jax.value_and_grad(DN.train_step_loss)(
            params, cfg, g, y)
        new_params, new_state = opt.update(grads, opt_state, params)
        return new_params, new_state, loss

    args = (params_sds, _opt_sds(params_sds, mesh), g_sds, targets)
    return CellBuild(fn, args, rules, gnn_model_flops(cfg, dims),
                     donate=(0, 1),
                     notes=f"padded dims {dims}")


# -------------------------------------------------------------------------
# RecSys
# -------------------------------------------------------------------------

def _recsys_param_pspec(key: str, leaf, *, shard_rows: bool = True) -> P:
    nd = len(leaf.shape)
    if key.endswith("table") or key.endswith("wide") \
            or key.endswith("item_table"):
        if shard_rows:
            return P("model", *([None] * (nd - 1)))  # row-sharded tables
        return P(*([None] * nd))  # serving: replicated read-only table
    return P(*([None] * nd))


def recsys_model_flops(cfg: RecsysConfig, batch: int, train: bool) -> float:
    d, f = cfg.embed_dim, cfg.n_sparse
    flops = 0.0
    sizes = (f * d,) + cfg.mlp + (1,)
    flops += 2.0 * sum(a * b for a, b in zip(sizes[:-1], sizes[1:]))
    if cfg.interaction == "fm":
        flops += 4.0 * f * d
    elif cfg.interaction == "cin":
        h_prev = f
        for h in cfg.cin_layers:
            flops += 2.0 * h_prev * f * d * (1 + h)
            h_prev = h
    elif cfg.interaction == "self-attn":
        da = cfg.n_heads * cfg.d_attn
        flops += cfg.n_attn_layers * (
            2.0 * f * cfg.embed_dim * da * 4 + 4.0 * f * f * da)
    elif cfg.interaction == "multi-interest":
        flops += cfg.capsule_iters * 4.0 * cfg.n_interests * cfg.hist_len * d
        flops += 4.0 * cfg.n_interests * d   # label-aware scoring per cand
        flops += 2.0 * d * d * 3             # out MLP per interest (coarse)
    return batch * flops * (3.0 if train else 1.0)


def build_recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh,
                      multi_pod: bool) -> CellBuild:
    from repro.launch.sharding import recsys_rules
    cfg: RecsysConfig = spec.config
    rules = recsys_rules(multi_pod)
    dp = data_axes(multi_pod)
    is_mind = cfg.interaction == "multi-interest"

    if is_mind:
        init = functools.partial(RS.init_mind, cfg=cfg)
    else:
        init = functools.partial(
            {"fm": RS.init_deepfm, "cin": RS.init_xdeepfm,
             "self-attn": RS.init_autoint}[cfg.interaction], cfg=cfg)
    params_shapes = jax.eval_shape(init, jax.random.key(0))
    # training shards table rows (optimizer state scales with rows);
    # serving replicates the read-only table (<1 GB) so every lookup is
    # local — a gather from a row-sharded table otherwise all-reduces the
    # full output across the mesh on every request.
    train_cell = shape.name == "train_batch"
    params_sds = _sharded_sds(
        params_shapes,
        functools.partial(_recsys_param_pspec, shard_rows=train_cell),
        mesh)
    rules = dict(rules, rows="model" if train_cell else None)

    def ctr_args(b, spec_b):
        m = cfg.multi_hot
        return (_sds((b, cfg.n_sparse, m), jnp.int32, mesh,
                     P(spec_b, None, None)),
                _sds((b, cfg.n_sparse, m), jnp.bool_, mesh,
                     P(spec_b, None, None)))

    logits_fn = (None if is_mind else
                 {"fm": RS.deepfm_logits, "cin": RS.xdeepfm_logits,
                  "self-attn": RS.autoint_logits}[cfg.interaction])

    if shape.name == "train_batch":
        b = shape["batch"]
        opt = AdamW(lr=1e-4)
        if is_mind:
            n_neg = 1024  # shared sampled negatives

            def fn(params, opt_state, hist, mask, target, negs):
                def loss_fn(p):
                    lg = RS.mind_train_logits(p, cfg, hist, mask, target,
                                              negs)
                    return RS.sampled_softmax_loss(lg, inbatch=False)
                loss, grads = jax.value_and_grad(loss_fn)(params)
                new_p, new_s = opt.update(grads, opt_state, params)
                return new_p, new_s, loss
            args = (params_sds, _opt_sds(params_sds, mesh),
                    _sds((b, cfg.hist_len), jnp.int32, mesh, P(dp, None)),
                    _sds((b, cfg.hist_len), jnp.bool_, mesh, P(dp, None)),
                    _sds((b,), jnp.int32, mesh, P(dp)),
                    _sds((n_neg,), jnp.int32, mesh, P(None)))
        else:
            def fn(params, opt_state, ids, mask, labels):
                def loss_fn(p):
                    return RS.ctr_loss(logits_fn(p, cfg, ids, mask), labels)
                loss, grads = jax.value_and_grad(loss_fn)(params)
                new_p, new_s = opt.update(grads, opt_state, params)
                return new_p, new_s, loss
            ids_sds, mask_sds = ctr_args(b, dp)
            args = (params_sds, _opt_sds(params_sds, mesh), ids_sds,
                    mask_sds, _sds((b,), jnp.float32, mesh, P(dp)))
        return CellBuild(fn, args, rules,
                         recsys_model_flops(cfg, b, True), donate=(0, 1))

    if shape.name in ("serve_p99", "serve_bulk"):
        b = shape["batch"]
        if is_mind:
            n_rerank = 1024

            def fn(params, hist, mask, cand):
                u = RS.mind_user_interests(params, cfg, hist, mask)
                c = jnp.take(params["item_table"], cand, axis=0)
                return jnp.max(jnp.einsum("bkd,cd->bkc", u, c),
                               axis=1).astype(jnp.float32)

            args = (params_sds,
                    _sds((b, cfg.hist_len), jnp.int32, mesh, P(dp, None)),
                    _sds((b, cfg.hist_len), jnp.bool_, mesh, P(dp, None)),
                    _sds((n_rerank,), jnp.int32, mesh, P(None)))
            notes = "MIND serve = interests + rerank 1024 candidates"
        else:
            def fn(params, ids, mask):
                return logits_fn(params, cfg, ids, mask)
            args = (params_sds,) + ctr_args(b, dp)
            notes = ""
        return CellBuild(fn, args, rules,
                         recsys_model_flops(cfg, b, False), notes=notes)

    # retrieval_cand: one query against ~1M candidates
    c = RETRIEVAL_CAND_PADDED
    every = ("pod", "data", "model") if multi_pod else ("data", "model")
    rules = dict(rules, cand=every, rows=None,
                 batch=None if is_mind else every)
    if is_mind:
        def fn(params, hist, mask, cand_ids):
            return RS.mind_retrieve(params, cfg, hist, mask, cand_ids,
                                    k=100)
        args = (params_sds,
                _sds((1, cfg.hist_len), jnp.int32, mesh, P(None, None)),
                _sds((1, cfg.hist_len), jnp.bool_, mesh, P(None, None)),
                _sds((c,), jnp.int32, mesh, P(every)))
        notes = "ANN-free exact max-interest dot over sharded candidates"
    else:
        # CTR retrieval: fixed user fields + per-candidate item fields
        m = cfg.multi_hot

        def fn(params, ids, mask):
            scores = logits_fn(params, cfg, ids, mask)
            return jax.lax.top_k(scores, 100)

        args = (params_sds,
                _sds((c, cfg.n_sparse, m), jnp.int32, mesh,
                     P(every, None, None)),
                _sds((c, cfg.n_sparse, m), jnp.bool_, mesh,
                     P(every, None, None)))
        notes = "bulk candidate scoring, batch axis = candidates"
    return CellBuild(fn, args, rules,
                     recsys_model_flops(cfg, c, False), notes=notes)


# -------------------------------------------------------------------------
# entry point
# -------------------------------------------------------------------------

def build_cell(spec: ArchSpec, shape: ShapeSpec, mesh: Mesh,
               multi_pod: bool, **kw) -> CellBuild:
    if spec.family == "lm":
        return build_lm_cell(spec, shape, mesh, multi_pod, **kw)
    if spec.family == "gnn":
        return build_gnn_cell(spec, shape, mesh, multi_pod)
    if spec.family == "recsys":
        return build_recsys_cell(spec, shape, mesh, multi_pod)
    raise ValueError(spec.family)


def input_specs(arch_id: str, shape_name: str, mesh: Mesh,
                multi_pod: bool) -> tuple:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    from repro.configs.registry import get_arch
    spec = get_arch(arch_id)
    shape = next(s for s in spec.shapes if s.name == shape_name)
    return build_cell(spec, shape, mesh, multi_pod).args
