import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell on the production meshes and record
memory/cost/collective analysis for §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import time
import traceback

import jax

from repro.configs.registry import all_cells, get_arch
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import sharding_rules
from repro.launch.specs import build_cell
from repro.roofline.analysis import roofline_from_compiled


def _lower_compile(spec, shape, mesh, multi_pod, **kw):
    build = build_cell(spec, shape, mesh, multi_pod, **kw)
    with mesh, sharding_rules(build.rules):
        jitted = jax.jit(build.fn, donate_argnums=build.donate)
        lowered = jitted.lower(*build.args)
        compiled = lowered.compile()
    return build, compiled


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str = None, verbose: bool = True) -> dict:
    spec = get_arch(arch_id)
    shape = next(s for s in spec.shapes if s.name == shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi" if multi_pod else "single"
    n_chips = 512 if multi_pod else 256

    t0 = time.time()
    build, compiled = _lower_compile(spec, shape, mesh, multi_pod)
    t_compile = time.time() - t0

    # XLA cost analysis counts a while (scan) body once regardless of trip
    # count.  For LM cells the layer stack is a scan over n_layers: compile
    # a second variant with scan_unroll=2 — the cost delta is exactly one
    # layer's worth — and extrapolate: total = cost1 + delta * (L - 1).
    extrapolate = None
    if spec.family == "lm":
        _, compiled2 = _lower_compile(spec, shape, mesh, multi_pod,
                                      scan_unroll=2)
        extrapolate = (compiled2, spec.config.n_layers)

    mem = compiled.memory_analysis()
    if verbose:
        print(f"[{arch_id} x {shape_name} x {mesh_name}] "
              f"compile {t_compile:.1f}s (+extrap {time.time()-t0-t_compile:.1f}s)")
        print("  memory_analysis:", mem)

    cell = roofline_from_compiled(
        arch=arch_id, shape=shape_name, mesh_name=mesh_name,
        n_chips=n_chips, compiled=compiled, model_flops=build.model_flops,
        extrapolate=extrapolate)
    rec = cell.to_json()
    t_lower, t_compile = 0.0, t_compile
    rec["lower_s"] = t_lower
    rec["compile_s"] = t_compile
    rec["notes"] = build.notes
    if verbose:
        print(f"  cost_analysis: flops/dev={cell.flops_global / n_chips:.3e}"
              f" bytes/dev={cell.bytes_global / n_chips:.3e}"
              f" coll_bytes/dev={cell.collective_bytes_global / n_chips:.3e}")
        print(f"  terms: compute={cell.terms.compute_s:.4e}s "
              f"memory={cell.terms.memory_s:.4e}s "
              f"collective={cell.terms.collective_s:.4e}s "
              f"bound={cell.bound} useful={cell.useful_flops_ratio:.3f}")

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(
            out_dir, f"{arch_id}__{shape_name}__{mesh_name}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true",
                    help="skip cells whose JSON already exists")
    args = ap.parse_args()

    if args.all:
        cells = all_cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch_id, shape_name in cells:
        for multi_pod in meshes:
            mesh_name = "multi" if multi_pod else "single"
            path = os.path.join(
                args.out, f"{arch_id}__{shape_name}__{mesh_name}.json")
            if args.skip_done and os.path.exists(path):
                print(f"skip {arch_id} x {shape_name} x {mesh_name}")
                continue
            try:
                run_cell(arch_id, shape_name, multi_pod, out_dir=args.out)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append((arch_id, shape_name, mesh_name, repr(e)))
                print(f"FAILED {arch_id} x {shape_name} x {mesh_name}: {e}")
                traceback.print_exc()

    print(f"\n{'=' * 60}\ndry-run complete;"
          f" {len(failures)} failures" + (":" if failures else ""))
    for f in failures:
        print("  ", *f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
