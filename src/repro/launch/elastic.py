"""Elastic scaling + straggler mitigation.

Node failure at scale is routine; the framework's contract is:
  1. training state is checkpointed every N steps (async, atomic);
  2. on failure, surviving hosts form a SMALLER mesh (same axis names,
     reduced ``data``/``pod`` extent), `restore` re-shards the checkpoint
     onto it, and the pure-function data pipeline replays from the saved
     step — bitwise-identical semantics, fewer chips;
  3. when capacity returns, the same path scales back up.

Straggler mitigation uses the paper's own mathematics: a synchronous
fork-join step waits for the slowest of p participants, and with iid
exponential tails the expected straggler tax is H_p (queueing.Eq 6).
`hedge_threshold` converts that into when to fire a hedged duplicate
(serving) or re-dispatch a microbatch (training): wait until the
conditional expected remaining time of the laggard exceeds the cost of a
duplicate, i.e. the (1 - 1/p)-quantile of the residence distribution.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import queueing

__all__ = ["survivor_mesh_shape", "expected_straggler_tax",
           "hedge_threshold", "ElasticPlan", "plan_downsize"]


def expected_straggler_tax(p: int) -> float:
    """E[slowest of p] / E[one], for iid exponential step times.

    This is the paper's Eq 6 synchronization factor H_p — the mean
    slowdown a synchronous fork-join step (training microbatch or
    serving fan-out) pays for waiting on p participants.  It is the
    quantity `hedge_threshold` trades against the cost of a duplicate.
    """
    return float(queueing.harmonic_number(max(int(p), 1)))


def survivor_mesh_shape(original: Sequence[int], failed_hosts: int,
                        chips_per_host: int, axes: Sequence[str]
                        ) -> tuple[int, ...]:
    """Shrink the data-most axis to exclude failed hosts' chips.

    Keeps the ``model`` extent intact (TP degree is a property of the
    model's sharding) and shrinks ``data`` (then ``pod``): DP width is the
    elastic dimension.
    """
    shape = list(original)
    lost = failed_hosts * chips_per_host
    order = [axes.index(a) for a in ("data", "pod") if a in axes]
    for ax in order:
        while lost > 0 and shape[ax] > 1:
            total_other = int(np.prod(shape)) // shape[ax]
            shape[ax] -= 1
            lost -= total_other
    if lost > 0:
        raise ValueError("not enough surviving capacity for model shards")
    return tuple(shape)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: tuple
    new_shape: tuple
    throughput_fraction: float
    step_time_factor: float


def plan_downsize(old_shape: Sequence[int], new_shape: Sequence[int]
                  ) -> ElasticPlan:
    old_n = int(np.prod(old_shape))
    new_n = int(np.prod(new_shape))
    return ElasticPlan(
        old_shape=tuple(old_shape), new_shape=tuple(new_shape),
        throughput_fraction=new_n / old_n,
        step_time_factor=old_n / new_n,
    )


def hedge_threshold(mean_service: float, p: int, *,
                    duplicate_cost_fraction: float = 1.0) -> float:
    """Wait time after which a hedged duplicate is worth sending.

    For exponential residence with mean R, the slowest of p has expected
    value H_p R; the marginal straggler (the gap between the (p-1)-th and
    p-th order statistic) costs R/1 on average.  Hedging pays when the
    observed wait exceeds the (1 - 1/p) quantile:
        t* = R * ln(p)        (quantile of Exp at 1 - 1/p)
    scaled by the relative cost of a duplicate.
    """
    return float(mean_service * np.log(max(p, 2))
                 * duplicate_cost_fraction)
