"""Elasticity: serving autoscaler policy + training-mesh resizing.

Two consumers share this module's mathematics:

* **Serving** (the paper's capacity story, grown time-varying): a search
  cluster sized by `repro.core.capacity` holds r replicas *forever*,
  but real diurnal load only needs the peak count for a few hours a day.
  :class:`AutoscalePolicy` is the HPA-shaped feedback controller —
  min/max replicas, a target utilization trigger, step-limited scale
  up/down, a stabilization window — and :func:`autoscale_scan` is its
  pure per-query recurrence, carried inside the streaming simulator's
  scan (``ClusterSpec(autoscale=...)``) so policies can be *simulated
  and swept* like any other capacity knob.  Scale-out replicas start
  cold (empty queues); scale-in stops routing new queries to a replica
  but lets its in-flight work drain.
* **Training** (`survivor_mesh_shape` / `ElasticPlan` / `plan_downsize`):
  on host failure the surviving chips form a smaller mesh (same axis
  names, reduced ``data``/``pod`` extent) and checkpointed state is
  re-sharded onto it; when capacity returns, the same path scales back
  up.  `plan_downsize` quantifies the throughput/step-time trade of a
  candidate shrink.

Straggler mitigation ties the two together with the paper's own Eq 6:
a synchronous fork-join step waits for the slowest of p participants,
and with iid exponential tails the expected straggler tax is H_p.
`hedge_threshold` converts that into when to fire a hedged duplicate;
:meth:`AutoscalePolicy.for_slo` converts it into the autoscaler's
utilization trigger (scale-out sizing must leave headroom for the H_p
synchronization tax, not just the mean service time).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import queueing

__all__ = ["AutoscalePolicy", "autoscale_init", "autoscale_scan",
           "survivor_mesh_shape", "expected_straggler_tax",
           "hedge_threshold", "ElasticPlan", "plan_downsize"]


def expected_straggler_tax(p: int) -> float:
    """E[slowest of p] / E[one], for iid exponential step times.

    This is the paper's Eq 6 synchronization factor H_p — the mean
    slowdown a synchronous fork-join step (training microbatch or
    serving fan-out) pays for waiting on p participants.  It is the
    quantity `hedge_threshold` trades against the cost of a duplicate
    and :meth:`AutoscalePolicy.for_slo` budgets against the SLO.
    """
    return float(queueing.harmonic_number(max(int(p), 1)))


@dataclasses.dataclass(frozen=True)
class AutoscalePolicy:
    """HPA-shaped feedback controller for the replica count.

    The controller observes the fleet once per ``decision_interval``
    of *simulated* time: utilization is the server-seconds of work that
    arrived during the interval divided by the server-seconds of
    capacity (``n_active * p * interval``), and the desired count is
    the usual horizontal-pod-autoscaler rule

        desired = ceil(n_active * utilization / target_utilization)

    clipped to ``[min_r, max_r]``.  Scale-up applies immediately, at
    most ``scale_up_step`` replicas per decision; scale-down waits for
    ``stabilization_intervals`` *consecutive* low decisions before
    removing at most ``scale_down_step`` (the HPA stabilization window,
    so a flash crowd's trailing edge cannot thrash the fleet).
    ``queue_trigger_seconds`` optionally adds a backlog override: if
    the fluid backlog would take longer than this to drain at current
    capacity, a scale-up step fires regardless of utilization.

    Replicas above the active count receive no new queries but keep
    draining in-flight work; a replica scaled back in before it fully
    drained resumes with its remaining backlog (nothing is dropped).
    Scale-out replicas start cold — empty queues, no carried work.

    The policy object is hashable and rides the simulator's jit cache
    as a static argument, exactly like ``TelemetrySpec``.
    """

    min_r: int
    max_r: int
    target_utilization: float = 0.7
    scale_up_step: int = 1
    scale_down_step: int = 1
    decision_interval_seconds: float = 15.0
    stabilization_intervals: int = 4
    queue_trigger_seconds: Optional[float] = None
    init_r: Optional[int] = None

    def __post_init__(self):
        if not 1 <= int(self.min_r) <= int(self.max_r):
            raise ValueError(
                f"need 1 <= min_r <= max_r; got ({self.min_r}, "
                f"{self.max_r})")
        if not 0.0 < float(self.target_utilization) < 1.0:
            raise ValueError("target_utilization must be in (0, 1); got "
                             f"{self.target_utilization}")
        if int(self.scale_up_step) < 1 or int(self.scale_down_step) < 1:
            raise ValueError("scale steps must be >= 1")
        if not float(self.decision_interval_seconds) > 0.0:
            raise ValueError("decision_interval_seconds must be > 0")
        if int(self.stabilization_intervals) < 1:
            raise ValueError("stabilization_intervals must be >= 1")
        if (self.queue_trigger_seconds is not None
                and not float(self.queue_trigger_seconds) > 0.0):
            raise ValueError("queue_trigger_seconds must be > 0 or None")
        if (self.init_r is not None
                and not self.min_r <= int(self.init_r) <= self.max_r):
            raise ValueError(
                f"init_r={self.init_r} outside [{self.min_r}, "
                f"{self.max_r}]")

    @property
    def start_r(self) -> int:
        """Replica count at t=0 (``init_r``, defaulting to ``min_r``)."""
        return int(self.min_r if self.init_r is None else self.init_r)

    @classmethod
    def for_slo(cls, min_r: int, max_r: int, *, p: int,
                mean_service: float, slo_seconds: float,
                **kwargs) -> "AutoscalePolicy":
        """Derive the utilization trigger from the SLO and Eq 6.

        A fork-join replica's response is roughly the synchronized
        service H_p * S inflated by queueing, R ~= H_p * S / (1 - rho)
        (the Eq 7 bounds collapse to this at the extremes), so keeping
        R <= SLO needs rho <= 1 - H_p * S / SLO.  Sizing scale-out
        against bare utilization ignores the straggler tax and runs the
        fleet too hot; this constructor wires
        :func:`expected_straggler_tax` into the trigger.
        """
        tax = expected_straggler_tax(p)
        target = 1.0 - tax * float(mean_service) / float(slo_seconds)
        target = min(max(target, 0.05), 0.95)
        return cls(min_r=min_r, max_r=max_r,
                   target_utilization=target, **kwargs)


def autoscale_init(policy: AutoscalePolicy, n_scen: int, dtype):
    """Initial controller carry: (n_active, t_epoch, w_epoch, stab, bklg).

    ``n_active`` (int32) is the live replica count, ``t_epoch`` /
    ``w_epoch`` accumulate seconds and server-seconds of demand since
    the last decision, ``stab`` (int32) counts consecutive scale-down
    votes, ``bklg`` is the fluid backlog (server-seconds of admitted
    but unfinished work) behind the queue trigger.
    """
    import jax.numpy as jnp
    return (jnp.full((n_scen,), policy.start_r, jnp.int32),
            jnp.zeros((n_scen,), dtype),
            jnp.zeros((n_scen,), dtype),
            jnp.zeros((n_scen,), jnp.int32),
            jnp.zeros((n_scen,), dtype))


def autoscale_scan(policy: AutoscalePolicy, p: int, carry,
                   gaps, demand, up_frac=None):
    """Run the controller over one block of queries; returns per-query n.

    gaps: (S, n) interarrival seconds; demand: (S, n) server-seconds of
    work each query brings (its summed per-server service times).  The
    recurrence is strictly per-query with the carry threaded through,
    so splitting a stream into blocks and chaining the carry gives the
    SAME per-query active counts as one monolithic call — the policy is
    chunking-invariant by construction (property-tested in
    tests/test_autoscale.py).  Zero-gap, zero-demand entries (the
    streaming engine's padded tail) advance nothing.

    up_frac (optional, (S, n)): fraction of provisioned replicas that
    are actually up (fault injection's capacity-loss coupling).  The
    controller sees an outage as lost capacity — effective demand is
    inflated by 1/up_frac and the fluid backlog drains at the surviving
    rate — so it scales OUT under failures exactly as a utilization
    autoscaler would in production.  ``None`` (the default) takes the
    original, bitwise-identical all-up path.

    Returns ``(new_carry, n_active (S, n) int32)`` where ``n_active[i]``
    is the count in force when query i is routed (decisions at interval
    boundaries apply from the query that crosses them).
    """
    import jax
    import jax.numpy as jnp

    interval = float(policy.decision_interval_seconds)
    target = float(policy.target_utilization)
    up = int(policy.scale_up_step)
    down = int(policy.scale_down_step)
    stab_n = int(policy.stabilization_intervals)
    lo, hi = int(policy.min_r), int(policy.max_r)
    trigger = policy.queue_trigger_seconds
    faulty = up_frac is not None

    def step(c, inp):
        if faulty:
            n, te, we, st, bk = c
            gap, dem, upf = inp                # (S,) each
            # floor: even fully-down fleets plan against >= one replica
            upf = jnp.maximum(upf, 1.0 / hi)
            nf = n.astype(gap.dtype)
            cap_rate = nf * p                  # server-seconds per second
            bk = jnp.maximum(bk - cap_rate * upf * gap, 0.0) + dem
            te = te + gap
            we = we + dem / upf
        else:
            n, te, we, st, bk = c
            gap, dem = inp                     # (S,), (S,)
            nf = n.astype(gap.dtype)
            cap_rate = nf * p                  # server-seconds per second
            bk = jnp.maximum(bk - cap_rate * gap, 0.0) + dem
            te = te + gap
            we = we + dem
        decide = te >= interval
        # HPA: desired = ceil(n * util / target) with
        # util = we / (n * p * te) — the n cancels into offered load
        desired = jnp.ceil(
            we / jnp.maximum(p * te * target, 1e-30)).astype(jnp.int32)
        if trigger is not None:
            hot = bk > cap_rate * float(trigger)
            desired = jnp.where(hot, jnp.maximum(desired, n + up), desired)
        desired = jnp.clip(desired, lo, hi)
        want_up = desired > n
        want_dn = desired < n
        n_up = jnp.minimum(n + up, desired)
        st_next = jnp.where(want_dn, st + 1, 0)
        fire_dn = want_dn & (st_next >= stab_n)
        n_next = jnp.where(want_up, n_up,
                           jnp.where(fire_dn, jnp.maximum(n - down, desired),
                                     n))
        st_next = jnp.where(fire_dn, 0, st_next)
        n = jnp.where(decide, n_next, n)
        st = jnp.where(decide, st_next, st)
        te = jnp.where(decide, 0.0, te)
        we = jnp.where(decide, 0.0, we)
        return (n, te, we, st, bk), n

    xs = ((gaps.T, demand.T, up_frac.T) if faulty
          else (gaps.T, demand.T))
    carry, n_seq = jax.lax.scan(step, carry, xs)   # n_seq: (n, S)
    return carry, n_seq.T


def survivor_mesh_shape(original: Sequence[int], failed_hosts: int,
                        chips_per_host: int, axes: Sequence[str]
                        ) -> tuple[int, ...]:
    """Shrink the data-most axis to exclude failed hosts' chips.

    Keeps the ``model`` extent intact (TP degree is a property of the
    model's sharding) and shrinks ``data`` (then ``pod``): DP width is the
    elastic dimension.
    """
    shape = list(original)
    lost = failed_hosts * chips_per_host
    order = [axes.index(a) for a in ("data", "pod") if a in axes]
    for ax in order:
        while lost > 0 and shape[ax] > 1:
            total_other = int(np.prod(shape)) // shape[ax]
            shape[ax] -= 1
            lost -= total_other
    if lost > 0:
        raise ValueError("not enough surviving capacity for model shards")
    return tuple(shape)


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Throughput/step-time consequences of resizing a training mesh.

    The training-side counterpart of :class:`AutoscalePolicy`: where the
    serving autoscaler varies the replica count against load, this plan
    quantifies what a *forced* resize (host failure, capacity return)
    costs — ``throughput_fraction`` of the old mesh's examples/s and the
    matching ``step_time_factor`` slowdown at fixed global batch.
    """

    old_shape: tuple
    new_shape: tuple
    throughput_fraction: float
    step_time_factor: float


def plan_downsize(old_shape: Sequence[int], new_shape: Sequence[int]
                  ) -> ElasticPlan:
    """Quantify a mesh shrink (chips removed -> linear throughput loss).

    Assumes compute-bound steps: a mesh with new_n of old_n chips runs
    at new_n / old_n the throughput and old_n / new_n the step time.
    Checkpointed state re-shards onto the survivor mesh (same axis
    names), so the trade is purely this ratio — the serving analogue is
    a scale-in decision by :class:`AutoscalePolicy`, which likewise
    removes capacity without losing in-flight work.
    """
    old_n = int(np.prod(old_shape))
    new_n = int(np.prod(new_shape))
    return ElasticPlan(
        old_shape=tuple(old_shape), new_shape=tuple(new_shape),
        throughput_fraction=new_n / old_n,
        step_time_factor=old_n / new_n,
    )


def hedge_threshold(mean_service: float, p: int, *,
                    duplicate_cost_fraction: float = 1.0) -> float:
    """Wait time after which a hedged duplicate is worth sending.

    For exponential residence with mean R, the slowest of p has expected
    value H_p R; the marginal straggler (the gap between the (p-1)-th and
    p-th order statistic) costs R/1 on average.  Hedging pays when the
    observed wait exceeds the (1 - 1/p) quantile:
        t* = R * ln(p)        (quantile of Exp at 1 - 1/p)
    scaled by the relative cost of a duplicate.
    """
    return float(mean_service * np.log(max(p, 2))
                 * duplicate_cost_fraction)
