"""Logical-axis sharding: model code names axes, meshes bind them.

Model definitions call ``constrain(x, "batch", "seq", "embed")`` with
*logical* axis names.  A `sharding_rules` context binds logical names to
mesh axis names (or None).  Outside any context (CPU unit tests) the call
is a no-op, so the same model code runs everywhere.

Standard rule sets for the production meshes live here too; the per-shape
overrides used by the §Perf hillclimb are plain dict updates.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

AxisBinding = Union[None, str, Tuple[str, ...]]

_state = threading.local()


def current_rules() -> Optional[Dict[str, AxisBinding]]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: Optional[Dict[str, AxisBinding]]):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def spec(*logical: Optional[str]) -> P:
    """PartitionSpec for logical axis names under the active rules."""
    rules = current_rules() or {}
    return P(*[rules.get(name) if name else None for name in logical])


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without rules."""
    if current_rules() is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec(*logical))


# ---------------------------------------------------------------------------
# Standard rule sets.  Mesh axes: ("pod",) "data", "model".
# ---------------------------------------------------------------------------

def lm_rules(multi_pod: bool, *, seq_sharded_decode: bool = True
             ) -> Dict[str, AxisBinding]:
    """Megatron TP + (pod, data) DP + sequence-parallel residual stream."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "seq": "model",        # sequence-parallel residual stream
        "seq_q": None,         # attention runs with heads sharded instead
        "embed": None,
        "heads": "model",      # TP: attention heads
        "kv_heads": "model",
        "qkv": None,
        "ffn": "model",        # TP: FFN hidden
        "experts": "model",    # expert parallelism
        "vocab": "model",      # row-sharded embedding/logits
        "kv_seq": "model" if seq_sharded_decode else None,  # decode KV cache
        "kv_batch": dp,
        "cand": "model",
    }


def gnn_rules(multi_pod: bool, *, replicate_nodes: bool = False
              ) -> Dict[str, AxisBinding]:
    """Edge/triplet partitioning over the whole mesh.

    replicate_nodes=True keeps node states replicated (≤1 GB even at
    2.45M nodes): gathers h[edge_src] become LOCAL on every edge shard,
    instead of GSPMD replicating gather outputs mesh-wide (§Perf Cell D).
    """
    everything = ("pod", "data", "model") if multi_pod else ("data", "model")
    return {
        "edges": everything,
        "triplets": everything,
        "nodes": None if replicate_nodes else everything,
        "graph_batch": everything,
        "feat": None,
        "hidden": None,
    }


def recsys_rules(multi_pod: bool) -> Dict[str, AxisBinding]:
    """Row-sharded embedding tables; batch DP; candidates model-sharded."""
    dp = ("pod", "data") if multi_pod else ("data",)
    return {
        "batch": dp,
        "rows": "model",       # embedding-table rows (the 'index servers')
        "embed": None,
        "fields": None,
        "mlp": None,           # MLP weights are replicated (tiny)
        "cand": "model",       # retrieval candidates
        "hist": None,
    }
