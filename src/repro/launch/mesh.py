"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS for 512 host devices BEFORE any jax
import; smoke tests and benches must keep seeing 1 device).
"""

from __future__ import annotations

from typing import Optional

from repro import compat

__all__ = ["make_production_mesh", "make_sweep_mesh", "mesh_axes",
           "data_axes"]


def make_sweep_mesh(n_devices: Optional[int] = None):
    """1-D ("scenario",) mesh for scenario-sharded what-if sweeps.

    The ONE mesh constructor shared by `core.sweep`, the benches and
    `examples/global_sweep.py` — call sites must not hand-build meshes.
    ``n_devices`` defaults to every local device (8 virtual CPU devices
    under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``; all
    chips of a TPU slice in production).
    """
    import jax

    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return compat.make_mesh((n,), ("scenario",))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(shape, axes)


def mesh_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data", "model") if multi_pod else ("data", "model")


def data_axes(multi_pod: bool) -> tuple[str, ...]:
    """The data-parallel axes (replica dimension for DP batch sharding)."""
    return ("pod", "data") if multi_pod else ("data",)
