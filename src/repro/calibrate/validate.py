"""Held-out validation of a calibrated model (the paper's Sec 5.3 figures).

The paper judges its model by predicted-vs-measured response times on
operating points the fit never saw.  :func:`validate` does exactly that
with three columns per held-out window:

  * **observed** — the trace's windowed mean response (the measurement);
  * **calibrated** — the analytical model at the window's observed rate,
    with the fitted Eq-1 parameters and imbalance blend;
  * **simulated** — the streaming max-plus simulator run at the same rate
    with the same calibrated parameters (the model's mechanistic twin).

Error metrics (mean/p95 relative error, per-lambda error curves) mirror
the validation figures; :func:`calibrate_and_validate` wires the
time-split train/held-out protocol end to end.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.calibrate import fit, measure
from repro.calibrate.fit import CalibratedParams
from repro.calibrate.measure import TraceRecord
from repro.core import simulator
from repro.core.cluster import ClusterSpec, resolve_cluster
from repro.core.faults import FaultSpec
from repro.core.queueing import ServerParams
from repro.launch.elastic import AutoscalePolicy

Array = jax.Array

__all__ = ["ValidationReport", "validate", "calibrate_and_validate"]


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    """Held-out predicted-vs-measured-vs-simulated comparison.

    All arrays are per held-out window, sorted by observed rate.
    """

    lam: Array            # observed window arrival rates (qps)
    r_observed: Array     # windowed mean response from the trace (s)
    r_calibrated: Array   # calibrated analytical prediction (s)
    r_simulated: Array    # calibrated-simulator mean response (s)
    calibrated: CalibratedParams
    # Replicated cross-check (``validate(..., cluster=ClusterSpec(r=...))``):
    # the calibrated cluster simulated as r dispatcher-routed copies at
    # r x the window rate — per-replica load is unchanged, so deviations
    # from ``r_simulated`` isolate routing/imbalance effects that the
    # analytical even-split assumption cannot see.  None when r == 1.
    # Under an autoscale policy ``replicas`` is the policy's max_r (the
    # provisioned fleet) and ``autoscale`` records the policy itself.
    # With a FaultSpec on the spec the column runs the same calibrated
    # fleet under injected faults (``fault`` records the spec, and
    # ``faulted_degraded_fraction`` its partial-quorum share) — the
    # "does the calibrated model survive an outage" column.
    r_sim_replicated: Optional[Array] = None
    replicas: int = 1
    autoscale: Optional[AutoscalePolicy] = None
    fault: Optional["FaultSpec"] = None
    faulted_degraded_fraction: Optional[Array] = None

    @property
    def rel_err_observed(self) -> Array:
        """|calibrated - observed| / observed, per window."""
        return jnp.abs(self.r_calibrated - self.r_observed) / self.r_observed

    @property
    def rel_err_simulated(self) -> Array:
        """|calibrated - simulated| / simulated, per window."""
        return jnp.abs(self.r_calibrated - self.r_simulated) / self.r_simulated

    @property
    def rel_err_replicated(self) -> Optional[Array]:
        """|calibrated - replicated sim| / replicated sim, per window."""
        if self.r_sim_replicated is None:
            return None
        return (jnp.abs(self.r_calibrated - self.r_sim_replicated)
                / self.r_sim_replicated)

    @property
    def mean_rel_err(self) -> float:
        return float(jnp.mean(self.rel_err_observed))

    @property
    def p95_rel_err(self) -> float:
        return float(jnp.quantile(self.rel_err_observed, 0.95))

    @property
    def mean_rel_err_vs_sim(self) -> float:
        return float(jnp.mean(self.rel_err_simulated))

    @property
    def max_rel_err_vs_sim(self) -> float:
        return float(jnp.max(self.rel_err_simulated))

    def error_curve(self) -> tuple[np.ndarray, np.ndarray]:
        """(lam, relative error vs observed) — the per-lambda error curve."""
        return np.asarray(self.lam), np.asarray(self.rel_err_observed)

    def summary(self) -> str:
        replicated = self.r_sim_replicated is not None
        head = (f"{'lam (qps)':>10s} {'observed':>10s} {'calibrated':>11s} "
                f"{'simulated':>10s} {'err(obs)':>9s} {'err(sim)':>9s}")
        if replicated:
            head += f" {f'sim(x{self.replicas})':>10s} {'err(rep)':>9s}"
        lines = [
            "== calibration validation "
            f"({self.lam.shape[0]} held-out windows) ==",
            head,
        ]
        eo = np.asarray(self.rel_err_observed)
        es = np.asarray(self.rel_err_simulated)
        er = (np.asarray(self.rel_err_replicated) if replicated else None)
        for i in range(self.lam.shape[0]):
            row = (
                f"{float(self.lam[i]):10.2f} "
                f"{float(self.r_observed[i]) * 1e3:8.1f}ms "
                f"{float(self.r_calibrated[i]) * 1e3:9.1f}ms "
                f"{float(self.r_simulated[i]) * 1e3:8.1f}ms "
                f"{eo[i] * 100:8.1f}% {es[i] * 100:8.1f}%")
            if replicated:
                row += (f" {float(self.r_sim_replicated[i]) * 1e3:8.1f}ms"
                        f" {er[i] * 100:8.1f}%")
            lines.append(row)
        lines.append(
            f"vs observed:  mean {self.mean_rel_err * 100:.1f}%  "
            f"p95 {self.p95_rel_err * 100:.1f}%")
        lines.append(
            f"vs simulator: mean {self.mean_rel_err_vs_sim * 100:.1f}%  "
            f"max {self.max_rel_err_vs_sim * 100:.1f}%")
        if replicated:
            lines.append(
                f"vs x{self.replicas}-replicated simulator: mean "
                f"{float(jnp.mean(self.rel_err_replicated)) * 100:.1f}%  "
                f"max {float(jnp.max(self.rel_err_replicated)) * 100:.1f}%")
        if self.fault is not None:
            note = f"replicated column fault-injected: {self.fault!r}"
            if self.faulted_degraded_fraction is not None:
                note += (
                    "  (degraded "
                    f"{float(jnp.mean(self.faulted_degraded_fraction)) * 100:.1f}%)")
            lines.append(note)
        return "\n".join(lines)


def _vec_params(params: ServerParams, n: int) -> ServerParams:
    return ServerParams(**{
        f.name: jnp.full((n,), jnp.asarray(getattr(params, f.name),
                                           jnp.float32))
        for f in dataclasses.fields(ServerParams)})


def validate(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
    calibrated: CalibratedParams,
    *,
    n_windows: int = 8,
    holdout_fraction: float = 1.0,
    key: Optional[Array] = None,
    simulator_queries: int = 40_000,
    impl: str = "xla",
    cluster: Optional[ClusterSpec] = None,
    replicas: Optional[int] = None,
    routing: Optional[str] = None,
    result_cache=None,
) -> ValidationReport:
    """Score a calibrated model on (held-out) trace windows.

    ``holdout_fraction`` keeps the LAST fraction of windows (a time
    split: validation data is strictly later than anything a preceding
    `fit.calibrate` call saw); 1.0 scores every window of ``traces`` —
    the mode :func:`calibrate_and_validate` uses after splitting the raw
    trace itself.  The simulator column re-runs the streaming engine at
    each held-out window's observed rate under the calibrated parameters
    (mode="cache", one batched dispatch for all windows).

    ``cluster=ClusterSpec(r > 1)`` adds the simulated-replicated column:
    the same calibrated cluster deployed as r dispatcher-routed copies
    (with the spec's routing/result cache/replica engine) at r x each
    window's observed rate.  Per-replica load matches the measured
    system, so this column scores the scale-out story the single-cluster
    trace cannot measure directly: does calibrated + replicated still
    behave like calibrated x 1 under the chosen routing?  With
    ``autoscale=`` on the spec the column runs the elastic fleet at
    ``max_r`` x the window rate (peak per-replica load matches when
    fully scaled out).  With ``fault=FaultSpec(...)`` on the spec the
    column runs the calibrated fleet UNDER those injected faults —
    outage windows, degraded disks, partial-quorum merging — scoring
    how far degraded operation drifts from the calibrated prediction
    (the report then carries the spec and the observed
    ``faulted_degraded_fraction``).  The loose ``replicas=`` /
    ``routing=`` / ``result_cache=`` keywords keep working through the
    `repro.core.cluster.resolve_cluster` deprecation shim.
    """
    spec = resolve_cluster(cluster, r=replicas, routing=routing,
                           result_cache=result_cache, caller="validate")
    lam_w, r_obs_w, _ = measure.window_stats(traces, n_windows)
    n_hold = max(1, int(round(lam_w.shape[0] * holdout_fraction)))
    lam_h, r_obs_h = lam_w[-n_hold:], r_obs_w[-n_hold:]

    r_cal = calibrated.predict_mean_response(lam_h)

    params = calibrated.to_server_params()
    key = jax.random.PRNGKey(0) if key is None else key
    sim = simulator.simulate_fork_join_batch(
        key, lam_h, _vec_params(params, n_hold), simulator_queries,
        p=int(params.p), mode="cache", impl=impl)
    r_sim = sim.mean_response

    r_rep = degr_frac = None
    rep_r = spec.engine_r
    if rep_r > 1 or spec.autoscale is not None or spec.fault is not None:
        rep = simulator.simulate_fork_join_batch(
            jax.random.fold_in(key, rep_r), lam_h * rep_r,
            _vec_params(params, n_hold), simulator_queries,
            p=int(params.p), mode="cache", impl=impl, cluster=spec)
        r_rep = rep.mean_response
        if (spec.fault is not None
                and spec.fault.broker_timeout_seconds is not None):
            degr_frac = rep.degraded_fraction

    order = jnp.argsort(lam_h)
    return ValidationReport(
        lam=lam_h[order], r_observed=r_obs_h[order],
        r_calibrated=r_cal[order], r_simulated=r_sim[order],
        calibrated=calibrated,
        r_sim_replicated=None if r_rep is None else r_rep[order],
        replicas=rep_r, autoscale=spec.autoscale, fault=spec.fault,
        faulted_degraded_fraction=(None if degr_frac is None
                                   else degr_frac[order]))


def calibrate_and_validate(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
    *,
    n_windows: int = 16,
    holdout_fraction: float = 0.25,
    key: Optional[Array] = None,
    simulator_queries: int = 40_000,
    **fit_kwargs,
) -> tuple[CalibratedParams, ValidationReport]:
    """Time-split protocol: fit on the head, validate on the tail.

    The last ``holdout_fraction`` of the measurements never enters the
    fit; the report's error metrics are honest held-out numbers.  The
    split walks trace batches from the end (batches are independent runs
    with their own clocks — see `measure.concat_traces`), cutting at most
    one batch in two, so held-out windows keep clean interarrival spans.
    """
    batches = measure.as_trace_list(traces)
    total = sum(tr.n_queries for tr in batches)
    n_hold = max(2, int(total * holdout_fraction))
    train: list[TraceRecord] = []
    held: list[TraceRecord] = []
    remaining = n_hold
    for tr in reversed(batches):
        if remaining <= 0:
            train.insert(0, tr)
        elif tr.n_queries <= remaining:
            held.insert(0, tr)
            remaining -= tr.n_queries
        else:
            cut = tr.n_queries - remaining
            train.insert(0, jax.tree_util.tree_map(
                lambda x: x[:cut], tr))
            held.insert(0, jax.tree_util.tree_map(
                lambda x: x[cut:], tr))
            remaining = 0
    if not train:
        raise ValueError("holdout_fraction leaves no training data")
    cal = fit.calibrate(
        train, n_windows=max(4, n_windows - int(n_windows
                                                * holdout_fraction)),
        **fit_kwargs)
    report = validate(
        held, cal, n_windows=max(2, int(n_windows * holdout_fraction)),
        holdout_fraction=1.0, key=key, simulator_queries=simulator_queries)
    return cal, report
