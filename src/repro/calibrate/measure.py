"""Measurement harness: every trace the calibration layer consumes.

The paper's model earns its keep by being *tuned from measurements*
(Secs. 4-6): Tables 5-6 come from instrumented runs, and accuracy is
judged predicted-vs-measured.  This module is the instrumented run.  All
trace ingestion goes through one record type:

:class:`TraceRecord` — per-query arrival time, response time, broker busy
time, per-(query, server) busy time and cache hit/miss split, all JAX
arrays, registered as a pytree so fitting can jit/vmap over whole traces.

Three trace sources feed it:

  * :func:`simulate_trace` — a materializing fork-join sample path with
    known ground-truth :class:`ServerParams` (the round-trip test bed and
    the "run the toy engine under workloadgen load" stand-in).  Unlike the
    streaming engine it records the full per-query record; calibration
    traces are bounded (tens of thousands of queries), so materializing is
    the right trade here.
  * :func:`measure_engine_trace` — the instrumented toy search engine:
    per-shard busy times from the timed compiled scorer
    (`engine.server.measure_busy_trace`) + LRU cache replay, broker busy
    time from the timed top-k merge (`engine.broker.timed_merge_topk`).
    Response times come from replaying the measured busy times against the
    arrival sequence through the max-plus FCFS recurrence — the paper's
    methodology of measuring service at the servers and deriving response
    from the queueing structure.
  * :func:`trace_from_tap` — the streaming simulator's bounded reservoir
    tap (`SimResult.tap_response` / `SimSweepResult.sample_response`):
    response-only samples from systems too large to materialize.  These
    carry no busy-time split, so they support alpha/validation fitting
    (`fit.fit_alpha`) but not the Eq-1 moment decomposition.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.queueing import ServerParams
from repro.core.simulator import fcfs_completion_times

Array = jax.Array

__all__ = [
    "TraceRecord",
    "simulate_trace",
    "measure_engine_trace",
    "trace_from_tap",
    "concat_traces",
    "window_plan",
    "window_stats",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TraceRecord:
    """One batch of per-query measurements (the calibration currency).

    arrival:     (n,) absolute arrival timestamps, nondecreasing.
    response:    (n,) end-to-end response times (join - arrival).
    broker_busy: (n,) broker service time actually spent per query.
    server_busy: (n, p) busy time at each index server.
    server_hit:  (n, p) 1.0 where the server answered fully from cache.
    server_disk: (n, p) disk component of the busy time (0 on hits), or
                 None when the instrumentation cannot split CPU from disk
                 (fitting then falls back to moment matching).
    """

    arrival: Array
    response: Array
    broker_busy: Array
    server_busy: Array
    server_hit: Array
    server_disk: Optional[Array] = None

    @property
    def n_queries(self) -> int:
        return self.arrival.shape[0]

    @property
    def p(self) -> int:
        return self.server_busy.shape[1]

    @property
    def observed_rate(self) -> Array:
        """Mean arrival rate over the record's span (qps)."""
        span = jnp.maximum(self.arrival[-1] - self.arrival[0], 1e-9)
        return (self.n_queries - 1) / span

    def to_timeline(self, spec=None):
        """Bin this trace into a `repro.obs.timeline.Timeline`.

        The TraceRecord <-> Timeline bridge: measured engines and
        streaming-simulated ones render on the same dashboard
        (``python -m repro.obs.report``) and obey the same per-bin
        conservation checks.  ``spec`` is a
        :class:`repro.obs.timeline.TelemetrySpec` (default: the default
        bin count over the record's own span).
        """
        from repro.obs.timeline import TelemetrySpec, timeline_from_trace
        if spec is None:
            spec = TelemetrySpec()
        return timeline_from_trace(
            self.arrival - self.arrival[0], self.response, spec,
            broker_busy=self.broker_busy, server_busy=self.server_busy,
            server_hit=self.server_hit)

    def split(self, n_batches: int) -> list["TraceRecord"]:
        """Split into ``n_batches`` contiguous batches (last takes the
        remainder) — fitting is invariant to this chunking."""
        n = self.n_queries
        size = max(1, n // n_batches)
        edges = [i * size for i in range(n_batches)] + [n]
        out = []
        for lo, hi in zip(edges[:-1], edges[1:]):
            if hi <= lo:
                continue
            out.append(jax.tree_util.tree_map(lambda x: x[lo:hi], self))
        return out


def concat_traces(traces: Sequence[TraceRecord]) -> TraceRecord:
    """Concatenate trace batches along the query axis.

    Only for batches that continue one clock (arrivals stay monotone) —
    e.g. the chunks of a single measurement run.  Independent runs (each
    restarting at t=0) must stay a *list*: every consumer here accepts
    one, and windowing never straddles list entries, so mixed-rate trace
    sets keep their per-run rate structure intact.
    """
    traces = list(traces)
    if len(traces) == 1:
        return traces[0]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *traces)


def as_trace_list(traces: Union[TraceRecord, Sequence[TraceRecord]]
                  ) -> list[TraceRecord]:
    return [traces] if isinstance(traces, TraceRecord) else list(traces)


def window_plan(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
    n_windows: int,
) -> list[tuple[int, int]]:
    """Per-batch (n_windows, window_size) so windows NEVER straddle
    batches — independent runs restart the clock, and a straddling
    window's interarrival span would be garbage.  Shared by
    :func:`window_stats` and the max-plus replay residual path so both
    see identical windows."""
    batches = as_trace_list(traces)
    per_batch = max(1, n_windows // len(batches))
    plan = []
    for tr in batches:
        w = max(2, tr.n_queries // per_batch)
        plan.append((tr.n_queries // w, w))
    return plan


def window_stats(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
    n_windows: int,
) -> tuple[Array, Array, Array]:
    """Per-window (observed rate, mean response, query count).

    Windows are equal-count contiguous slices *per trace batch* (see
    :func:`window_plan`).  The observed rate is the within-window
    interarrival-based estimate — the lambda the analytical model is
    asked to reproduce.
    """
    lams, means, counts = [], [], []
    batches = as_trace_list(traces)
    for tr, (k, w) in zip(batches, window_plan(batches, n_windows)):
        if k == 0:
            continue
        arr = tr.arrival[: k * w].reshape(k, w)
        resp = tr.response[: k * w].reshape(k, w)
        span = jnp.maximum(arr[:, -1] - arr[:, 0], 1e-9)
        lams.append((w - 1) / span)
        means.append(jnp.mean(resp, axis=1))
        counts.append(jnp.full((k,), float(w)))
    return (jnp.concatenate(lams), jnp.concatenate(means),
            jnp.concatenate(counts))


def _sample_arrivals(key: Array, proc: ArrivalProcess, n: int) -> Array:
    """Arrival timestamps from a (single-scenario) arrival process.

    Piecewise profiles draw each gap at the rate in force at the previous
    arrival — per-query granularity, finer than the streaming engine's
    rate-per-chunk read, which is what a calibration trace wants (flash
    crowds shorter than a chunk still show up)."""
    if proc.trace_gaps is not None:
        return jnp.cumsum(proc.trace_gaps[:n])
    if proc.rates.ndim != 1:
        raise ValueError("simulate_trace is single-scenario; rates must "
                         f"be 1-D, got {proc.rates.shape}")
    u = jax.random.exponential(key, (n,), jnp.result_type(float))
    if proc.n_bins == 1:
        return jnp.cumsum(u / jnp.maximum(proc.rates[0], 1e-30))

    def step(t, ui):
        t2 = t + ui / jnp.maximum(proc.rate_at(t), 1e-30)
        return t2, t2

    _, arr = jax.lax.scan(step, jnp.asarray(0.0, u.dtype), u)
    return arr


def simulate_trace(
    key: Array,
    arrival: Union[ArrivalProcess, float],
    n_queries: int,
    params: ServerParams,
    *,
    impl: str = "xla",
    warmup_fraction: float = 0.1,
) -> TraceRecord:
    """Ground-truth fork-join trace from known Eq-1 parameters.

    The service mechanism is the paper's Sec 3.4 "cache" regime — per
    (query, server) Bernoulli(hit) between Exp(s_hit) and
    Exp(s_miss)+Exp(s_disk) — because that is the only regime whose trace
    identifies the full Eq-1 decomposition.  The hit flag and the disk
    component are recorded, so moment fitting can recover every parameter
    (the round-trip test).  The first ``warmup_fraction`` of queries is
    dropped from the record (queue fill-up transient).

    ``impl="pallas"`` routes the two FCFS recurrences through the
    `maxplus_scan` kernel, same as the streaming engine.
    """
    proc = (arrival if isinstance(arrival, ArrivalProcess)
            else ArrivalProcess.stationary(float(arrival)))
    p = int(params.p)
    k_arr, k_brk, k_hit, k_h, k_m, k_d = jax.random.split(key, 6)
    dtype = jnp.result_type(float)

    arrivals = _sample_arrivals(k_arr, proc, n_queries).astype(dtype)
    broker_busy = (jax.random.exponential(k_brk, (n_queries,), dtype)
                   * jnp.asarray(params.s_broker, dtype))
    shape = (n_queries, p)
    is_hit = jax.random.bernoulli(
        k_hit, jnp.asarray(params.hit, dtype), shape)
    t_hit = (jax.random.exponential(k_h, shape, dtype)
             * jnp.asarray(params.s_hit, dtype))
    t_cpu_miss = (jax.random.exponential(k_m, shape, dtype)
                  * jnp.asarray(params.s_miss, dtype))
    t_disk = (jax.random.exponential(k_d, shape, dtype)
              * jnp.asarray(params.s_disk, dtype))
    server_disk = jnp.where(is_hit, 0.0, t_disk)
    server_busy = jnp.where(is_hit, t_hit, t_cpu_miss) + server_disk

    broker_done = fcfs_completion_times(arrivals, broker_busy, impl=impl)
    fork = jnp.broadcast_to(broker_done[None, :], (p, n_queries))
    completions = fcfs_completion_times(fork, server_busy.T, impl=impl)
    response = jnp.max(completions, axis=0) - arrivals

    rec = TraceRecord(
        arrival=arrivals, response=response, broker_busy=broker_busy,
        server_busy=server_busy, server_hit=is_hit.astype(dtype),
        server_disk=server_disk)
    n_warm = int(n_queries * warmup_fraction)
    return jax.tree_util.tree_map(lambda x: x[n_warm:], rec)


def measure_engine_trace(
    shards,
    query_terms: np.ndarray,
    arrivals: np.ndarray,
    *,
    cache_bytes: int,
    batch: int = 64,
    warmup_batches: int = 2,
    disk_bw: float = 50e6,
    disk_seek: float = 8e-3,
    k_merge: int = 10,
    impl: str = "xla",
) -> TraceRecord:
    """Instrumented run of the toy engine -> a calibration trace.

    shards:      list of `repro.engine.server.IndexServer` (one per index
                 partition; the fork-join's p servers).
    query_terms: (n, L) padded term ids (`workloadgen.querygen` stream).
    arrivals:    (n,) arrival timestamps (`workloadgen.loadgen`).

    Per shard, `engine.server.measure_busy_trace` times the compiled
    scorer batch-by-batch and replays the LRU disk cache for the
    hit/miss/disk split; `engine.broker.timed_merge_topk` times the join
    merge.  Response times are the max-plus replay of those measured busy
    times over the arrival sequence (measure service, derive response —
    Sec 4.3's low-load instrumentation discipline).
    """
    from repro.engine import broker as broker_lib
    from repro.engine import server as server_lib

    n = min(query_terms.shape[0], len(arrivals))
    n = (n // batch) * batch
    if n == 0:
        raise ValueError("need at least one full batch of queries")
    query_terms = np.asarray(query_terms[:n])
    arrivals = np.sort(np.asarray(arrivals[:n], dtype=np.float64))

    busy, hit, disk = [], [], []
    partial_s, partial_d = [], []
    for srv in shards:
        b, h, d, scores, docs = server_lib.measure_busy_trace(
            srv, query_terms, cache_bytes, batch=batch,
            warmup_batches=warmup_batches, disk_bw=disk_bw,
            disk_seek=disk_seek)
        busy.append(b)
        hit.append(h)
        disk.append(d)
        partial_s.append(scores)
        partial_d.append(docs)

    # broker: timed top-k merge over the same batches
    ps = np.stack(partial_s)          # (p, n, k_local)
    pd = np.stack(partial_d)
    broker_busy = np.zeros(n, dtype=np.float64)
    broker_lib.timed_merge_topk(                     # compile + warm
        jnp.asarray(ps[:, :batch]), jnp.asarray(pd[:, :batch]), k=k_merge)
    for i in range(0, n, batch):
        (_, _), dt = broker_lib.timed_merge_topk(
            jnp.asarray(ps[:, i:i + batch]), jnp.asarray(pd[:, i:i + batch]),
            k=k_merge)
        broker_busy[i:i + batch] = dt / batch

    dtype = jnp.result_type(float)
    arr = jnp.asarray(arrivals, dtype)
    brk = jnp.asarray(broker_busy, dtype)
    sb = jnp.asarray(np.stack(busy, axis=1), dtype)      # (n, p)
    broker_done = fcfs_completion_times(arr, brk, impl=impl)
    fork = jnp.broadcast_to(broker_done[None, :], (len(shards), n))
    completions = fcfs_completion_times(fork, sb.T, impl=impl)
    response = jnp.max(completions, axis=0) - arr

    return TraceRecord(
        arrival=arr, response=response, broker_busy=brk, server_busy=sb,
        server_hit=jnp.asarray(np.stack(hit, axis=1), dtype),
        server_disk=jnp.asarray(np.stack(disk, axis=1), dtype))


def trace_from_tap(
    tap_response: Array,
    lam: Union[Array, float],
) -> tuple[Array, Array]:
    """(lam, mean response) points from reservoir-tap samples.

    ``tap_response`` is `SimResult.tap_response` (one scenario, (k,)) or
    any leading-scenario-shaped stack of taps ((S, k), the sweep's
    ``sample_response`` reshaped); ``lam`` the matching scenario rates.
    NaN padding (scenarios with fewer post-warmup queries than the tap)
    is ignored.  The result feeds `fit.fit_alpha` — response-only traces
    cannot drive the Eq-1 moment decomposition.
    """
    tap = jnp.asarray(tap_response)
    lam = jnp.broadcast_to(jnp.asarray(lam, tap.dtype), tap.shape[:-1])
    mean = jnp.nanmean(tap, axis=-1)
    return lam, mean
