"""Fit Eq-1 parameters + the Sec-3.4 imbalance correction from traces.

Two stages, mirroring the ISSUE:

1. **Closed-form moment matching** (:func:`fit_moments`): the Eq-1
   decomposition falls straight out of the trace's sufficient statistics.
   ``hit`` is the hit-flag mean; ``s_hit`` the mean busy time over hit
   entries; ``s_broker`` the mean broker busy time.  When the trace
   records the disk split (ours do), ``s_disk``/``s_miss`` are exact
   conditional means; without it they come from the first two moments of
   the miss busy time — for Exp(a)+Exp(b), mean m and variance v give
   (a - b)^2 = 2v - m^2, closed form up to the {a, b} labeling, resolved
   by the larger-is-disk convention (true for paper Tables 5 and 6 except
   the 4x-memory column — record the split when you can).

2. **Gauss-Newton refinement** (:func:`refine`): a damped Gauss-Newton
   on windowed predicted-vs-observed mean-response residuals fitting the
   Sec-3.4 imbalance blend ``alpha`` between the Eq-7 bounds:

       R_pred(lam) = R_broker + (1 + alpha (H_p - 1)) R_server.

   ``alpha`` is what the paper's Sec 5.3 validation estimates by eye
   ("measured response sits ~20% under the upper bound"); here it is a
   fitted parameter.  The (candidate-params x trace-window) residual grid
   is evaluated as ONE vmapped XLA program to seed the iteration, and the
   `lax.scan` Gauss-Newton loop differentiates the residuals with
   ``jax.jacfwd``.  An optional joint service scale (``fit_scale=True``,
   ``theta = (log s_scale, logit alpha)``) is off by default: the moments
   already pin the scale, and `refine`'s docstring explains the
   identifiability trap a free scale opens.  The ``residual="maxplus"``
   path instead replays the trace's arrivals through the differentiable
   max-plus FCFS recurrence (`simulator.fcfs_completion_times`, the same
   kernel the streaming engine uses) with busy times rescaled by
   ``s_scale`` — gradients flow through the whole queueing sample path,
   where the scale IS identified.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import jax
import jax.numpy as jnp

from repro.calibrate import measure
from repro.calibrate.measure import TraceRecord
from repro.core import queueing
from repro.core.queueing import ServerParams
from repro.core.simulator import fcfs_completion_times

Array = jax.Array

__all__ = [
    "CalibratedParams",
    "fit_moments",
    "fit_alpha",
    "refine",
    "calibrate",
]

_RHO_CAP = 0.995          # soft saturation guard inside the optimizer
_GN_DAMPING = 1e-6


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CalibratedParams:
    """A calibrated model: Eq-1 parameters + the imbalance blend.

    ``params`` is a plain :class:`ServerParams` with the refinement scale
    already folded in, so it drops straight into `capacity.plan_capacity`,
    `sweep.SweepGrid.build(base=...)`, and `planner.plan_over_grid` — the
    measure -> fit -> plan wiring is just ``cal.to_server_params()``.
    """

    params: ServerParams
    alpha: Array            # Sec 3.4 imbalance blend in [0, 1]
    s_scale: Array          # refinement scale applied to the service times
    residual_rms: Array     # final weighted log-residual RMS of the fit

    def to_server_params(self) -> ServerParams:
        return self.params

    def predict_mean_response(self, lam) -> Array:
        """Calibrated mean response: R_lo + alpha (R_hi - R_lo) (Eq 7)."""
        lo, hi = queueing.response_time_bounds(lam, self.params)
        return lo + self.alpha * (hi - lo)

    def predict_bounds(self, lam) -> tuple[Array, Array]:
        return queueing.response_time_bounds(lam, self.params)


def _scale_service(params: ServerParams, s_scale) -> ServerParams:
    """Rescale the index-server service decomposition (broker untouched)."""
    s = jnp.asarray(s_scale)
    return dataclasses.replace(
        params,
        s_hit=jnp.asarray(params.s_hit) * s,
        s_miss=jnp.asarray(params.s_miss) * s,
        s_disk=jnp.asarray(params.s_disk) * s)


def fit_moments(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
) -> ServerParams:
    """Closed-form Eq-1 decomposition from trace sufficient statistics.

    Accepts a single record or any chunking of one into batches; the
    estimate only depends on accumulated sums, so it is invariant to the
    chunking (tested by hypothesis).
    """
    batches = measure.as_trace_list(traces)
    p = batches[0].p
    has_disk = all(tr.server_disk is not None for tr in batches)

    n_entries = n_hit = 0.0
    s_busy_hit = s_busy_miss = ss_busy_miss = 0.0
    s_disk_miss = s_broker = 0.0
    n_queries = 0.0
    for tr in batches:
        hit = tr.server_hit
        miss = 1.0 - hit
        n_entries += hit.size
        n_hit += jnp.sum(hit)
        s_busy_hit += jnp.sum(tr.server_busy * hit)
        s_busy_miss += jnp.sum(tr.server_busy * miss)
        ss_busy_miss += jnp.sum(tr.server_busy**2 * miss)
        if has_disk:
            s_disk_miss += jnp.sum(tr.server_disk * miss)
        s_broker += jnp.sum(tr.broker_busy)
        n_queries += tr.n_queries

    n_miss = jnp.maximum(n_entries - n_hit, 1.0)
    hit_ratio = n_hit / n_entries
    s_hit = s_busy_hit / jnp.maximum(n_hit, 1.0)
    m = s_busy_miss / n_miss                       # E[busy | miss]
    if has_disk:
        s_disk = s_disk_miss / n_miss
        s_miss = m - s_disk
    else:
        v = jnp.maximum(ss_busy_miss / n_miss - m * m, 0.0)
        d = jnp.sqrt(jnp.maximum(2.0 * v - m * m, 0.0))
        s_disk = 0.5 * (m + d)                     # larger-is-disk
        s_miss = 0.5 * (m - d)
    return ServerParams(
        p=p, s_broker=s_broker / n_queries, s_hit=s_hit,
        s_miss=s_miss, s_disk=s_disk, hit=hit_ratio)


def _soft_mean_response(lam, params: ServerParams, alpha) -> Array:
    """The fitted-mean predictor with a saturation-safe M/M/1 core.

    Identical to `CalibratedParams.predict_mean_response` below rho_cap;
    the clip keeps residuals finite while the optimizer passes through
    infeasible candidates (an Inf residual would NaN the Jacobian)."""
    lam = jnp.asarray(lam)
    s = queueing.service_time_server(params)

    def r_mm1(s_):
        rho = jnp.clip(lam * s_, 0.0, _RHO_CAP)
        return s_ / (1.0 - rho)

    hp = queueing.harmonic_number(params.p)
    return r_mm1(jnp.asarray(params.s_broker)) + (
        1.0 + alpha * (hp - 1.0)) * r_mm1(s)


def fit_alpha(params: ServerParams, lam, r_observed) -> Array:
    """Closed-form imbalance blend from (lam, mean response) points.

    alpha solves R_obs = R_lo + alpha (R_hi - R_lo) per point; points are
    averaged weighted by the bound gap (wide-gap points constrain alpha
    best).  This is the whole fit available to response-only traces —
    e.g. the streaming tap (`measure.trace_from_tap`)."""
    lo, hi = queueing.response_time_bounds(lam, params)
    gap = jnp.maximum(hi - lo, 1e-12)
    ok = jnp.isfinite(lo) & jnp.isfinite(hi) & jnp.isfinite(
        jnp.asarray(r_observed))
    a = jnp.clip((jnp.asarray(r_observed) - lo) / gap, 0.0, 1.0)
    a = jnp.where(ok, a, 0.0)   # NaN observations would survive a*0
    w = jnp.where(ok, gap, 0.0)
    return jnp.sum(a * w) / jnp.maximum(jnp.sum(w), 1e-30)


def _window_residuals_analytic(theta, params, lam_w, r_obs_w, sqrt_w):
    """theta = (logit alpha,) or (log s_scale, logit alpha)."""
    s_scale = jnp.exp(theta[0]) if theta.shape[0] == 2 else 1.0
    alpha = jax.nn.sigmoid(theta[-1])
    pred = _soft_mean_response(lam_w, _scale_service(params, s_scale),
                               alpha)
    return sqrt_w * (jnp.log(pred) - jnp.log(r_obs_w))


def _replay_window_means(trace: TraceRecord, s_scale, k: int, w: int
                         ) -> Array:
    """Mean response per window from a max-plus replay at scaled service.

    Replays the OBSERVED arrivals and busy times — rescaled by
    ``s_scale`` — through the same FCFS recurrence the simulator uses.
    Differentiable end-to-end (the XLA associative scan), so `refine` can
    Gauss-Newton through the queueing sample path itself.  (k, w) is the
    batch's `measure.window_plan` entry."""
    arr = trace.arrival
    broker_done = fcfs_completion_times(arr, trace.broker_busy)
    busy = trace.server_busy.T * s_scale          # (p, n)
    fork = jnp.broadcast_to(broker_done[None, :], busy.shape)
    response = jnp.max(fcfs_completion_times(fork, busy), axis=0) - arr
    return jnp.mean(response[: k * w].reshape(k, w), 1)


def refine(
    params: ServerParams,
    lam_w: Array,
    r_obs_w: Array,
    weights: Array,
    *,
    n_iters: int = 20,
    residual: str = "analytic",
    traces: Union[TraceRecord, Sequence[TraceRecord], None] = None,
    n_candidates: int = 9,
    fit_scale: bool = False,
    n_windows: int = None,
) -> tuple[Array, Array, Array]:
    """Damped Gauss-Newton refinement; returns (s_scale, alpha, rms).

    Seeds from the best point of a (candidate-params x window) residual
    grid — candidate (s_scale, alpha) points against every window, one
    vmapped XLA program — then runs ``n_iters`` Gauss-Newton steps via
    `lax.scan` with `jax.jacfwd` Jacobians.  Residuals are log-space
    (scale-free), weighted by sqrt(window count).

    The analytic path fits ``alpha`` ONLY unless ``fit_scale=True``: the
    moment-matched decomposition already pins the service scale from
    direct busy-time measurement, and a free scale lets constant-alpha
    misspecification (the true blend drifts with utilization) leak into
    the directly-measured parameters — the classic identifiability trap
    of fitting scale and shape to one response curve.

    ``residual="maxplus"`` fits ``s_scale`` against the differentiable
    max-plus replay of ``traces`` instead of the analytic curve.  There
    the scale IS well-identified — the replay pins the queueing mechanism
    exactly, so the only freedom left is the busy-time scale (e.g. timer
    overhead in an engine harness) — and alpha then comes from
    :func:`fit_alpha` against the replayed windows.
    ``lam_w``/``r_obs_w``/``weights`` must come from
    `measure.window_stats` on the same traces, and ``n_windows`` must be
    the SAME value that call used (the realized window count can differ
    from the request for uneven batches, so it cannot be recovered from
    ``lam_w`` alone), so the replayed windows line up one-to-one.
    """
    sqrt_w = jnp.sqrt(weights / jnp.maximum(jnp.sum(weights), 1e-30))
    if residual == "maxplus":
        if traces is None:
            raise ValueError("residual='maxplus' needs the traces")
        batches = measure.as_trace_list(traces)
        plan = measure.window_plan(
            batches, lam_w.shape[0] if n_windows is None else n_windows)
        realized = sum(k for k, _ in plan if k > 0)
        if realized != lam_w.shape[0]:
            raise ValueError(
                f"window plan yields {realized} windows but lam_w has "
                f"{lam_w.shape[0]}; pass refine(..., n_windows=) the same "
                "value the window_stats call used")
        # The replay starts each batch from an EMPTY queue, but the
        # observed responses carry backlog in from the (trimmed) warmup,
        # so each batch's first window systematically reads low in the
        # replay.  Mask it out of the residuals (and the later alpha fit)
        # rather than letting Gauss-Newton inflate s_scale to paper over
        # the transient.
        sqrt_w = sqrt_w * jnp.concatenate([
            (jnp.arange(k) > 0).astype(sqrt_w.dtype)
            for k, _ in plan if k > 0])

        def resid(theta):
            s = jnp.exp(theta[0])
            pred = jnp.concatenate([
                _replay_window_means(tr, s, k, w)
                for tr, (k, w) in zip(batches, plan) if k > 0])
            return sqrt_w * (jnp.log(jnp.maximum(pred, 1e-12))
                             - jnp.log(r_obs_w))

        theta0 = jnp.zeros((1,))
    elif residual == "analytic":
        def resid(theta):
            return _window_residuals_analytic(theta, params, lam_w,
                                              r_obs_w, sqrt_w)

        # ONE program over (candidate x window): seed where the grid is
        # least wrong.  alpha across (0, 1); log s_scale in +-20% when
        # it is being fitted at all.
        ca = jnp.linspace(-2.5, 2.5, n_candidates)   # logit space
        if fit_scale:
            cs = jnp.linspace(-0.2, 0.2, n_candidates)
            cand = jnp.stack(jnp.meshgrid(cs, ca, indexing="ij"),
                             -1).reshape(-1, 2)
        else:
            cand = ca[:, None]
        grid_rms = jax.vmap(lambda t: jnp.sum(resid(t) ** 2))(cand)
        theta0 = cand[jnp.argmin(grid_rms)]
    else:
        raise ValueError(f"unknown residual path: {residual}")

    def gn_step(theta, _):
        r = resid(theta)
        j = jax.jacfwd(resid)(theta)
        jtj = j.T @ j
        g = j.T @ r
        delta = jnp.linalg.solve(
            jtj + _GN_DAMPING * jnp.eye(theta.shape[0]), g)
        # trust region: a log-space step never exceeds 0.5
        delta = jnp.clip(delta, -0.5, 0.5)
        return theta - delta, None

    theta, _ = jax.lax.scan(gn_step, theta0, None, length=n_iters)
    rms = jnp.sqrt(jnp.sum(resid(theta) ** 2))
    if residual == "maxplus":
        s_scale = jnp.exp(theta[0])
        pred = jnp.concatenate([
            _replay_window_means(tr, s_scale, k, w)
            for tr, (k, w) in zip(batches, plan) if k > 0])
        keep = jnp.concatenate([jnp.arange(k) > 0
                                for k, _ in plan if k > 0])
        alpha = fit_alpha(_scale_service(params, s_scale),
                          jnp.where(keep, lam_w, 0.0),
                          jnp.where(keep, pred, jnp.nan))
    else:
        s_scale = jnp.exp(theta[0]) if fit_scale else jnp.asarray(1.0)
        alpha = jax.nn.sigmoid(theta[-1])
    return s_scale, alpha, rms


def calibrate(
    traces: Union[TraceRecord, Sequence[TraceRecord]],
    *,
    n_windows: int = 16,
    do_refine: bool = True,
    n_iters: int = 20,
    residual: str = "analytic",
    fit_scale: bool = False,
) -> CalibratedParams:
    """Moment-match then refine: the full fitting pipeline.

    Returns :class:`CalibratedParams` whose ``params`` carry the refined
    scale, ready for `capacity.plan_capacity` / `sweep.SweepGrid.build`.
    """
    base = fit_moments(traces)
    lam_w, r_obs_w, counts = measure.window_stats(traces, n_windows)
    if not do_refine:
        alpha = fit_alpha(base, lam_w, r_obs_w)
        pred = _soft_mean_response(lam_w, base, alpha)
        rms = jnp.sqrt(jnp.mean((jnp.log(pred) - jnp.log(r_obs_w)) ** 2))
        return CalibratedParams(params=base, alpha=alpha,
                                s_scale=jnp.asarray(1.0),
                                residual_rms=rms)
    s_scale, alpha, rms = refine(
        base, lam_w, r_obs_w, counts, n_iters=n_iters, residual=residual,
        traces=traces, fit_scale=fit_scale, n_windows=n_windows)
    return CalibratedParams(
        params=_scale_service(base, s_scale), alpha=alpha,
        s_scale=s_scale, residual_rms=rms)
