"""Trace-driven calibration: measure -> fit -> validate -> plan.

The paper's model is only as good as its parameterization (Secs. 4-6:
every Table 5/6 number comes from an instrumented run).  This package
closes that loop for the repro:

  measure  — `TraceRecord` + harnesses (instrumented toy engine,
             ground-truth simulator traces, the streaming reservoir tap)
  fit      — closed-form moment matching of the Eq-1 decomposition plus
             Gauss-Newton refinement of a service scale and the Sec-3.4
             imbalance blend, all as XLA programs
  validate — held-out predicted-vs-measured-vs-simulated error report

`plan_from_trace` is the one-call wiring: hand it a trace and get a
Section-6 capacity plan from freshly calibrated parameters.  For grid
what-ifs, ``sweep.SweepGrid.build(base=cal.to_server_params(), ...)``
drops a calibration straight into `sweep`/`planner.plan_over_grid`.
"""

from repro.calibrate.fit import (  # noqa: F401
    CalibratedParams,
    calibrate,
    fit_alpha,
    fit_moments,
    refine,
)
from repro.calibrate.measure import (  # noqa: F401
    TraceRecord,
    concat_traces,
    measure_engine_trace,
    simulate_trace,
    trace_from_tap,
    window_stats,
)
from repro.calibrate.validate import (  # noqa: F401
    ValidationReport,
    calibrate_and_validate,
    validate,
)

__all__ = [
    "TraceRecord",
    "simulate_trace",
    "measure_engine_trace",
    "trace_from_tap",
    "concat_traces",
    "window_stats",
    "CalibratedParams",
    "fit_moments",
    "fit_alpha",
    "refine",
    "calibrate",
    "ValidationReport",
    "validate",
    "calibrate_and_validate",
    "plan_from_trace",
]


def plan_from_trace(traces, target_rate_qps: float, slo_seconds: float,
                    **calibrate_kwargs):
    """Measure -> fit -> plan in one call.

    Calibrates from ``traces`` and answers the paper's Section-6 manager
    question for the calibrated system.  Returns
    (:class:`CalibratedParams`, :class:`repro.core.capacity.CapacityPlan`).
    """
    from repro.core import capacity

    cal = calibrate(traces, **calibrate_kwargs)
    plan = capacity.plan_capacity(cal.to_server_params(), target_rate_qps,
                                  slo_seconds)
    return cal, plan
