"""Jitted wrapper: model cache layout -> kernel layout."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import (
    DEFAULT_BLOCK_K,
    decode_attention_pallas,
)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(
    q: jax.Array,        # (B, 1, H, D)
    k_cache: jax.Array,  # (B, S, KV, D)
    v_cache: jax.Array,  # (B, S, KV, D)
    length: jax.Array,   # () int32 — last valid position (inclusive)
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, one, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    g = h // kv

    qk = q.reshape(b, kv, g, d).reshape(b * kv, g, d)
    kk = jnp.moveaxis(k_cache, 2, 1).reshape(b * kv, s, d)
    vk = jnp.moveaxis(v_cache, 2, 1).reshape(b * kv, s, d)
    bk = min(block_k, s)
    out = decode_attention_pallas(
        qk, kk, vk, jnp.asarray(length, jnp.int32), block_k=bk,
        interpret=interpret)
    return out.reshape(b, 1, h, d)
