"""Pallas TPU kernel: GQA decode attention (one query step vs a KV cache).

The decode hot loop is memory-bound: it streams the whole KV cache once
per token.  The kernel fuses the q.K dot, online softmax, and prob.V
accumulation so each KV block is read from HBM exactly once with zero
intermediate HBM traffic — the roofline-optimal schedule for this op.

Layout: q (B*KV, G, D) — all q heads of one kv group as MXU rows;
        k/v (B*KV, S, D); out (B*KV, G, D).
Grid (B*KV, S/bk), kv-block dimension sequential with VMEM running state.
A `length` scalar in SMEM masks cache positions >= the valid length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_K = 512

_NEG_INF = float("-inf")


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, scale: float):
    kj = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (G, D)
    k = k_ref[0]                                   # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (G, bk)

    pos = kj * block_k + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(pos <= len_ref[0], s, _NEG_INF)  # mask past valid length

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention_pallas(
    q: jax.Array,        # (B*KV, G, D)
    k: jax.Array,        # (B*KV, S, D)
    v: jax.Array,        # (B*KV, S, D)
    length: jax.Array,   # () int32 — last valid cache position (inclusive)
    *,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bkv, g, d = q.shape
    _, s, _ = k.shape
    assert s % block_k == 0, (s, block_k)
    grid = (bkv, s // block_k)
    scale = d ** -0.5

    kernel = functools.partial(_decode_kernel, block_k=block_k, scale=scale)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, g, d), lambda h, j, len_ref: (h, 0, 0)),
                pl.BlockSpec((1, block_k, d),
                             lambda h, j, len_ref: (h, j, 0)),
                pl.BlockSpec((1, block_k, d),
                             lambda h, j, len_ref: (h, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, g, d), lambda h, j, len_ref: (h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bkv, g, d), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(length.reshape(1), q, k, v)
