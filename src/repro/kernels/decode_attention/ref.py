"""Pure-jnp oracle for decode attention."""

from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, length):
    """q (B*KV, G, D), k/v (B*KV, S, D), length () -> (B*KV, G, D)."""
    d = q.shape[-1]
    s = jnp.einsum("hgd,hsd->hgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    valid = jnp.arange(k.shape[1]) <= length
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hgs,hsd->hgd", p, v.astype(jnp.float32)).astype(
        q.dtype)
