"""Pure-jnp oracle for EmbeddingBag (the recsys.embedding_bag op)."""

from __future__ import annotations

import jax.numpy as jnp


def embedding_bag_ref(table, ids, counts):
    """table (R,D), ids (BF,M), counts (BF,) -> (BF,D) mean-pooled."""
    vecs = jnp.take(table, ids, axis=0).astype(jnp.float32)  # (BF,M,D)
    mask = (jnp.arange(ids.shape[1])[None, :]
            < counts[:, None]).astype(jnp.float32)
    s = jnp.sum(vecs * mask[..., None], axis=1)
    return (s / jnp.maximum(counts[:, None], 1)).astype(table.dtype)
