"""Pallas TPU kernel: EmbeddingBag (multi-hot gather + mean pool).

JAX has no native EmbeddingBag; the jnp formulation (take + masked mean)
round-trips every gathered row through HBM.  This kernel uses
scalar-prefetched ids to DMA exactly the needed table rows into VMEM and
accumulates the bag mean in-register, so each output row is written once
and no (B, F, M, D) intermediate ever exists — on TPU the ids are
available at DMA-issue time (scalar prefetch), which is the TPU-native
replacement for the GPU's per-thread gather.

Grid (B*F, M): one table row per step, revisiting the same output block
across the sequential bag dimension.  ids/mask live in SMEM (prefetched);
the table row index_map picks block ids[b, m] of a (rows/1, D)-blocked
table — i.e. the DMA engine does the gather.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params


def _bag_kernel(ids_ref, cnt_ref, table_ref, o_ref, acc_scr, *, bag: int):
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    bf = pl.program_id(0)
    valid = m < cnt_ref[bf]
    row = table_ref[...].astype(jnp.float32)       # (1, D)
    acc_scr[...] += jnp.where(valid, row, 0.0)

    @pl.when(m == bag - 1)
    def _finalize():
        denom = jnp.maximum(cnt_ref[bf], 1).astype(jnp.float32)
        o_ref[...] = (acc_scr[...] / denom).astype(o_ref.dtype)


def embedding_bag_pallas(
    table: jax.Array,    # (R, D)
    ids: jax.Array,      # (B*F, M) int32 — row ids (masked entries: 0)
    counts: jax.Array,   # (B*F,) int32 — valid entries per bag
    *,
    interpret: bool = False,
) -> jax.Array:
    bf, m = ids.shape
    r, d = table.shape
    grid = (bf, m)

    kernel = functools.partial(_bag_kernel, bag=m)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, d),
                    lambda b, m, ids_ref, cnt_ref: (ids_ref[b, m], 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, d), lambda b, m, ids_ref, cnt_ref: (b, 0)),
            scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((bf, d), table.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(ids, counts, table)
