"""Jitted wrapper matching repro.models.recsys.embedding_bag semantics."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag.kernel import embedding_bag_pallas


@functools.partial(jax.jit, static_argnames=("interpret",))
def embedding_bag(
    table: jax.Array,  # (R, D)
    ids: jax.Array,    # (B, F, M) globalized row ids
    mask: jax.Array,   # (B, F, M) bool
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """(B, F, D) mean-pooled bags — drop-in for the jnp formulation.

    Assumes valid ids are contiguous at the front of each bag (the data
    pipeline's layout); masked tail entries are ignored via counts.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, f, m = ids.shape
    counts = jnp.sum(mask, axis=-1).reshape(b * f).astype(jnp.int32)
    flat_ids = jnp.where(mask, ids, 0).reshape(b * f, m).astype(jnp.int32)
    out = embedding_bag_pallas(table, flat_ids, counts,
                               interpret=interpret)
    return out.reshape(b, f, -1)
