"""Pallas TPU kernel: causal GQA flash attention (forward).

Adaptation notes (GPU flash -> TPU, DESIGN.md):
  * the online-softmax recurrence is identical, but tiling follows the TPU
    memory hierarchy: Q/K/V blocks are DMA'd HBM->VMEM by BlockSpecs and
    the (bq x bk) score tile feeds the 128x128 MXU directly — block sizes
    default to 128/256 so every matmul dim is MXU-aligned;
  * instead of warp-level reductions, running (m, l, acc) live in VMEM
    scratch across the sequential kv-block grid dimension;
  * GQA is expressed by an index_map that sends n_rep consecutive q-head
    rows to the same kv head — no KV duplication in HBM.

Layout: q (B*H, Sq, D), k/v (B*KV, Sk, D); grid (B*H, Sq/bq, Sk/bk) with
the kv dimension sequential ("arbitrary") and the rest parallel.

VMEM: q/k/v/out blocks + scratch =
(bq + 2*bk + bq) * D * 4B + bq*(D+2)*4B ~= 0.8 MiB at defaults — far under
budget, leaving room for the scheduler to double-buffer the K/V streams.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 256

_NEG_INF = float("-inf")


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, block_q: int, block_k: int, scale: float,
                  causal: bool):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                          # (bq, D)
    k = k_ref[0]                          # (bk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale     # (bq, bk)

    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        kpos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(qpos >= kpos, s, _NEG_INF)

    m_prev = m_scr[...]                   # (bq, 1)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                # (bq, bk)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jax.lax.dot_general(
                        p.astype(v_ref.dtype), v_ref[0],
                        (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,     # (B*H, Sq, D)
    k: jax.Array,     # (B*KV, Sk, D)
    v: jax.Array,     # (B*KV, Sk, D)
    *,
    n_rep: int,       # H // KV (GQA replication factor)
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, d = q.shape
    bkv, sk, _ = k.shape
    assert bh == bkv * n_rep, (bh, bkv, n_rep)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk)
    grid = (bh, sq // block_q, sk // block_k)
    scale = d ** -0.5

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, scale=scale,
        causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda h, i, j, n_rep=n_rep: (h // n_rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
