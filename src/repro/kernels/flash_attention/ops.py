"""Jitted wrapper: (B, S, H, D) model layout -> kernel layout and back."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_pallas,
)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(
    q: jax.Array,     # (B, Sq, H, D)
    k: jax.Array,     # (B, Sk, KV, D)
    v: jax.Array,     # (B, Sk, KV, D)
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, sq, h, d = q.shape
    _, sk, kv, _ = k.shape
    n_rep = h // kv

    # (B, S, H, D) -> (B*H, S, D) with q heads grouped by kv head so the
    # kernel's h // n_rep index_map hits the right kv row.
    qk = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, d)
    kk = jnp.moveaxis(k, 2, 1).reshape(b * kv, sk, d)
    vk = jnp.moveaxis(v, 2, 1).reshape(b * kv, sk, d)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    out = flash_attention_pallas(
        qk, kk, vk, n_rep=n_rep, causal=causal, block_q=bq, block_k=bk,
        interpret=interpret)
    return jnp.moveaxis(out.reshape(b, h, sq, d), 1, 2)
