"""Pure-jnp oracle for GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, n_rep: int, causal: bool = True):
    """q (B*H, Sq, D), k/v (B*KV, Sk, D) -> (B*H, Sq, D), fp32 softmax."""
    bh, sq, d = q.shape
    k = jnp.repeat(k, n_rep, axis=0)
    v = jnp.repeat(v, n_rep, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(
        q.dtype)
