"""Jitted public wrapper for the max-plus scan Pallas kernel.

Handles arbitrary leading shapes, pads the scan axis with the semiring
identity (a = -inf, b = 0), and picks interpret mode automatically off-TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.maxplus_scan.kernel import (
    DEFAULT_BLOCK_LEN,
    DEFAULT_ROW_TILE,
    maxplus_scan_pallas,
)


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_len", "row_tile",
                                             "interpret"))
def maxplus_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Inclusive (max, +) scan along the last axis; any leading shape."""
    if interpret is None:
        interpret = _auto_interpret()
    orig_shape = a.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    a2 = a.reshape(rows, n)
    b2 = b.reshape(rows, n)

    pad_n = (-n) % block_len
    pad_r = (-rows) % row_tile
    if pad_n or pad_r:
        a2 = jnp.pad(a2, ((0, pad_r), (0, pad_n)),
                     constant_values=-jnp.inf)
        b2 = jnp.pad(b2, ((0, pad_r), (0, pad_n)), constant_values=0.0)

    out_a, out_b = maxplus_scan_pallas(
        a2, b2, block_len=block_len, row_tile=row_tile, interpret=interpret)
    out_a = out_a[:rows, :n].reshape(orig_shape)
    out_b = out_b[:rows, :n].reshape(orig_shape)
    return out_a, out_b
