"""Jitted public wrapper for the max-plus scan Pallas kernel.

Handles arbitrary leading shapes, pads the scan axis with the semiring
identity (a = -inf, b = 0), and picks interpret mode automatically off-TPU.
"""

from __future__ import annotations

import functools
import logging

import jax
import jax.numpy as jnp

from repro.kernels.maxplus_scan.kernel import (
    DEFAULT_BLOCK_LEN,
    DEFAULT_ROW_TILE,
    maxplus_scan_pallas,
    maxplus_segment_scan_pallas,
)

SCAN_IMPLS = ("auto", "xla", "pallas")

_logger = logging.getLogger(__name__)
_logged_auto = False


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def resolve_scan_impl(impl: str = "auto") -> str:
    """Resolve the scan backend: "auto" -> "pallas" on TPU, else "xla".

    Interpret-mode Pallas is strictly slower than
    ``jax.lax.associative_scan`` off-TPU, so "auto" (now the default of
    the simulator entry points) only picks the kernel on real TPU
    hardware.  Pass "xla" or "pallas" explicitly to override.  Logs the
    auto choice once per process.
    """
    global _logged_auto
    if impl not in SCAN_IMPLS:
        raise ValueError(f"unknown scan impl {impl!r}; choose one of "
                         f"{SCAN_IMPLS}")
    if impl != "auto":
        return impl
    resolved = "pallas" if jax.default_backend() == "tpu" else "xla"
    if not _logged_auto:
        _logger.info("maxplus scan impl=auto resolved to %r (backend %r)",
                     resolved, jax.default_backend())
        _logged_auto = True
    return resolved


@functools.partial(jax.jit, static_argnames=("block_len", "row_tile",
                                             "interpret"))
def maxplus_scan(
    a: jax.Array,
    b: jax.Array,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Inclusive (max, +) scan along the last axis; any leading shape."""
    if interpret is None:
        interpret = _auto_interpret()
    orig_shape = a.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    a2 = a.reshape(rows, n)
    b2 = b.reshape(rows, n)

    pad_n = (-n) % block_len
    pad_r = (-rows) % row_tile
    if pad_n or pad_r:
        a2 = jnp.pad(a2, ((0, pad_r), (0, pad_n)),
                     constant_values=-jnp.inf)
        b2 = jnp.pad(b2, ((0, pad_r), (0, pad_n)), constant_values=0.0)

    out_a, out_b = maxplus_scan_pallas(
        a2, b2, block_len=block_len, row_tile=row_tile, interpret=interpret)
    out_a = out_a[:rows, :n].reshape(orig_shape)
    out_b = out_b[:rows, :n].reshape(orig_shape)
    return out_a, out_b


@functools.partial(jax.jit, static_argnames=("block_len", "row_tile",
                                             "interpret"))
def maxplus_segment_scan(
    a: jax.Array,
    b: jax.Array,
    f: jax.Array,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Segmented inclusive (max, +) scan along the last axis.

    ``f`` is boolean (or 0/1) reset flags: True starts a new segment, so
    the scan never looks back across a flagged element.  Used by the
    fused replicated engine: all r replica subsequences of a routed chunk
    are compacted into contiguous segments of one row and scanned in a
    single kernel pass.  Any leading shape; padding uses the semiring
    identity (a = -inf, b = 0, f = 0), which cannot disturb real lanes.
    """
    if interpret is None:
        interpret = _auto_interpret()
    orig_shape = a.shape
    n = orig_shape[-1]
    rows = 1
    for d in orig_shape[:-1]:
        rows *= d
    a2 = a.reshape(rows, n)
    b2 = b.reshape(rows, n)
    f2 = f.astype(a.dtype).reshape(rows, n)

    pad_n = (-n) % block_len
    pad_r = (-rows) % row_tile
    if pad_n or pad_r:
        a2 = jnp.pad(a2, ((0, pad_r), (0, pad_n)),
                     constant_values=-jnp.inf)
        b2 = jnp.pad(b2, ((0, pad_r), (0, pad_n)), constant_values=0.0)
        f2 = jnp.pad(f2, ((0, pad_r), (0, pad_n)), constant_values=0.0)

    out_a, out_b = maxplus_segment_scan_pallas(
        a2, b2, f2, block_len=block_len, row_tile=row_tile,
        interpret=interpret)
    out_a = out_a[:rows, :n].reshape(orig_shape)
    out_b = out_b[:rows, :n].reshape(orig_shape)
    return out_a, out_b


def maxplus_scan_seeded(
    a: jax.Array,
    b: jax.Array,
    carry_a: jax.Array,
    carry_b: jax.Array | None = None,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Inclusive (max, +) scan seeded by the carry of everything earlier.

    The streaming simulator's chunk entry point: ``(carry_a, carry_b)`` is
    the composed affine map of all previous chunks (for FCFS chaining,
    ``carry_a`` is the last completion time and ``carry_b`` defaults to 0,
    the identity offset).  Because affine max-plus maps compose
    associatively, seeding is one post-composition on top of the unseeded
    scan — the Pallas grid itself is unchanged:

        out_a' = max(out_a, carry_a + out_b),   out_b' = carry_b + out_b

    ``carry_a``/``carry_b`` broadcast against ``a.shape[:-1]``.
    """
    out_a, out_b = maxplus_scan(a, b, block_len=block_len,
                                row_tile=row_tile, interpret=interpret)
    carry_a = jnp.asarray(carry_a)
    if carry_b is None:
        carry_b = jnp.zeros_like(carry_a)
    out_a = jnp.maximum(out_a, carry_a[..., None] + out_b)
    out_b = jnp.asarray(carry_b)[..., None] + out_b
    return out_a, out_b
