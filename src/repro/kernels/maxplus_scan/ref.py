"""Pure-jnp oracles for the max-plus scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return jnp.maximum(a2, a1 + b2), b1 + b2


def maxplus_scan_ref(a: jax.Array, b: jax.Array):
    """O(log n)-depth oracle via jax.lax.associative_scan."""
    return jax.lax.associative_scan(maxplus_combine, (a, b), axis=-1)


def maxplus_scan_sequential(a: jax.Array, b: jax.Array):
    """O(n) sequential oracle via lax.scan — the definitional recurrence."""

    def step(carry, ab):
        c = maxplus_combine(carry, ab)
        return c, c

    init = (jnp.full(a.shape[:-1], -jnp.inf, a.dtype),
            jnp.zeros(b.shape[:-1], b.dtype))
    _, (out_a, out_b) = jax.lax.scan(
        step, init, (jnp.moveaxis(a, -1, 0), jnp.moveaxis(b, -1, 0)))
    return jnp.moveaxis(out_a, 0, -1), jnp.moveaxis(out_b, 0, -1)
