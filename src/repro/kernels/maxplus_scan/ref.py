"""Pure-jnp oracles for the max-plus scan kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def maxplus_combine(x, y):
    a1, b1 = x
    a2, b2 = y
    return jnp.maximum(a2, a1 + b2), b1 + b2


def maxplus_scan_ref(a: jax.Array, b: jax.Array):
    """O(log n)-depth oracle via jax.lax.associative_scan."""
    return jax.lax.associative_scan(maxplus_combine, (a, b), axis=-1)


def maxplus_scan_sequential(a: jax.Array, b: jax.Array):
    """O(n) sequential oracle via lax.scan — the definitional recurrence."""

    def step(carry, ab):
        c = maxplus_combine(carry, ab)
        return c, c

    init = (jnp.full(a.shape[:-1], -jnp.inf, a.dtype),
            jnp.zeros(b.shape[:-1], b.dtype))
    _, (out_a, out_b) = jax.lax.scan(
        step, init, (jnp.moveaxis(a, -1, 0), jnp.moveaxis(b, -1, 0)))
    return jnp.moveaxis(out_a, 0, -1), jnp.moveaxis(out_b, 0, -1)


def maxplus_segment_combine(x, y):
    """Segmented (max, +) combine: a reset flag truncates the lookback.

    Elements are (a, b, f) with f "this map starts a new segment".  When
    the later operand contains a reset, the earlier map is discarded —
    this is the standard segmented-scan lift of an associative combine,
    and it stays associative.  Flags may be bool or float 0/1.
    """
    a1, b1, f1 = x
    a2, b2, f2 = y
    cut = f2 > 0 if jnp.issubdtype(jnp.asarray(f2).dtype, jnp.floating) \
        else f2
    a = jnp.where(cut, a2, jnp.maximum(a2, a1 + b2))
    b = jnp.where(cut, b2, b1 + b2)
    f = jnp.maximum(f1, f2) if jnp.issubdtype(
        jnp.asarray(f1).dtype, jnp.floating) else jnp.logical_or(f1, f2)
    return a, b, f


def maxplus_segment_scan_ref(a: jax.Array, b: jax.Array, f: jax.Array):
    """O(log n)-depth segmented oracle via jax.lax.associative_scan."""
    out_a, out_b, _ = jax.lax.associative_scan(
        maxplus_segment_combine, (a, b, f), axis=-1)
    return out_a, out_b


def maxplus_segment_scan_sequential(a: jax.Array, b: jax.Array,
                                    f: jax.Array):
    """O(n) sequential segmented oracle — the definitional recurrence."""

    def step(carry, abf):
        c = maxplus_segment_combine(carry, abf)
        return c, c

    init = (jnp.full(a.shape[:-1], -jnp.inf, a.dtype),
            jnp.zeros(b.shape[:-1], b.dtype),
            jnp.zeros(f.shape[:-1], f.dtype))
    _, (out_a, out_b, _) = jax.lax.scan(
        step, init, (jnp.moveaxis(a, -1, 0), jnp.moveaxis(b, -1, 0),
                     jnp.moveaxis(f, -1, 0)))
    return jnp.moveaxis(out_a, 0, -1), jnp.moveaxis(out_b, 0, -1)
