"""Pallas TPU kernel: blockwise inclusive scan in the (max, +) semiring.

The FCFS queueing recurrence C_i = max(a_i, C_{i-1} + b_i) composes
associatively over (a, b) pairs (see repro.core.simulator).  This kernel
scans along the last axis of (rows, length) inputs:

  * grid = (row_tiles, length_blocks); the length dimension is sequential
    ("arbitrary") so a VMEM carry persists across blocks of one row tile,
    while row tiles are embarrassingly parallel.
  * within a block: Hillis-Steele doubling scan (log2(block_len) vector
    steps) — each step is a lane-shifted max/add, which maps onto the VPU's
    8x128 vector registers with no MXU involvement.
  * the carry (a, b) of all previous blocks is composed on top, then
    updated from the block's last column.

VMEM budget: 4 buffers x row_tile x block_len x 4B (in/out a,b) + 2 carry
columns.  Default (8, 512) tile = 8 * 512 * 4 * 4B = 64 KiB — far under
the ~16 MiB/core VMEM, so several row tiles can stay resident and the
kernel is bandwidth-bound end to end (it is a pure streaming pass).

TPU is the target; CPU validation runs with interpret=True.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_LEN = 512
DEFAULT_ROW_TILE = 8

_NEG_INF = float("-inf")


def _shift_right(x: jax.Array, k: int, fill: float) -> jax.Array:
    """x[:, i] <- x[:, i-k], filling the first k columns."""
    pad = jnp.full((x.shape[0], k), fill, dtype=x.dtype)
    return jnp.concatenate([pad, x[:, :-k]], axis=1)


def _maxplus_block_kernel(a_ref, b_ref, out_a_ref, out_b_ref,
                          carry_a_ref, carry_b_ref, *, block_len: int):
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init_carry():
        carry_a_ref[...] = jnp.full_like(carry_a_ref, _NEG_INF)
        carry_b_ref[...] = jnp.zeros_like(carry_b_ref)

    a = a_ref[...]
    b = b_ref[...]

    # Hillis-Steele doubling: x[i] = combine(x[i-k], x[i]) for k = 1,2,4...
    # combine((a1,b1) earlier, (a2,b2) later) = (max(a2, a1+b2), b1+b2).
    k = 1
    while k < block_len:
        a_prev = _shift_right(a, k, _NEG_INF)
        b_prev = _shift_right(b, k, 0.0)
        a = jnp.maximum(a, a_prev + b)
        b = b_prev + b
        k *= 2

    ca = carry_a_ref[...]  # (row_tile, 1)
    cb = carry_b_ref[...]
    out_a = jnp.maximum(a, ca + b)
    out_b = cb + b
    out_a_ref[...] = out_a
    out_b_ref[...] = out_b
    carry_a_ref[...] = out_a[:, -1:]
    carry_b_ref[...] = out_b[:, -1:]


def _maxplus_segment_block_kernel(a_ref, b_ref, f_ref, out_a_ref,
                                  out_b_ref, carry_a_ref, carry_b_ref,
                                  *, block_len: int):
    """Segmented variant: f = 1 resets the scan (replica segment head).

    Same Hillis-Steele doubling as `_maxplus_block_kernel`, lifted to the
    segmented combine: when the later operand contains a reset, the
    earlier map is discarded.  Flags are float 0/1 (VPU-friendly); the
    flag lane composes by max (logical or).  The cross-block carry needs
    no flag lane — the carry is always the *earlier* operand of the
    combine, whose flag is never consumed.  This is what lets one kernel
    launch cover all r replica subsequences of a routed chunk after they
    have been compacted into contiguous segments.
    """
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init_carry():
        carry_a_ref[...] = jnp.full_like(carry_a_ref, _NEG_INF)
        carry_b_ref[...] = jnp.zeros_like(carry_b_ref)

    a = a_ref[...]
    b = b_ref[...]
    f = f_ref[...]

    k = 1
    while k < block_len:
        a_prev = _shift_right(a, k, _NEG_INF)
        b_prev = _shift_right(b, k, 0.0)
        f_prev = _shift_right(f, k, 0.0)
        cut = f > 0.0
        a = jnp.where(cut, a, jnp.maximum(a, a_prev + b))
        b = jnp.where(cut, b, b_prev + b)
        f = jnp.maximum(f, f_prev)
        k *= 2

    ca = carry_a_ref[...]  # (row_tile, 1)
    cb = carry_b_ref[...]
    cut = f > 0.0
    out_a = jnp.where(cut, a, jnp.maximum(a, ca + b))
    out_b = jnp.where(cut, b, cb + b)
    out_a_ref[...] = out_a
    out_b_ref[...] = out_b
    carry_a_ref[...] = out_a[:, -1:]
    carry_b_ref[...] = out_b[:, -1:]


def maxplus_scan_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Inclusive max-plus scan along axis -1 of (rows, length) arrays.

    Both dims must already be padded to multiples of (row_tile, block_len);
    `ops.maxplus_scan` handles padding/reshaping for arbitrary shapes.
    """
    rows, length = a.shape
    assert rows % row_tile == 0 and length % block_len == 0, (rows, length)
    grid = (rows // row_tile, length // block_len)

    spec = pl.BlockSpec((row_tile, block_len), lambda r, l: (r, l))
    kernel = functools.partial(_maxplus_block_kernel, block_len=block_len)
    out_a, out_b = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct(b.shape, b.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((row_tile, 1), a.dtype),
            pltpu.VMEM((row_tile, 1), b.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)
    return out_a, out_b


def maxplus_segment_scan_pallas(
    a: jax.Array,
    b: jax.Array,
    f: jax.Array,
    *,
    block_len: int = DEFAULT_BLOCK_LEN,
    row_tile: int = DEFAULT_ROW_TILE,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Segmented inclusive max-plus scan along axis -1.

    ``f`` holds float 0/1 reset flags (1 = this element starts a new
    segment).  Shapes/dtypes must match ``a``; both dims must be padded
    to (row_tile, block_len) multiples — `ops.maxplus_segment_scan`
    handles arbitrary shapes.
    """
    rows, length = a.shape
    assert rows % row_tile == 0 and length % block_len == 0, (rows, length)
    grid = (rows // row_tile, length // block_len)

    spec = pl.BlockSpec((row_tile, block_len), lambda r, l: (r, l))
    kernel = functools.partial(_maxplus_segment_block_kernel,
                               block_len=block_len)
    out_a, out_b = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct(b.shape, b.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((row_tile, 1), a.dtype),
            pltpu.VMEM((row_tile, 1), b.dtype),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, f)
    return out_a, out_b
