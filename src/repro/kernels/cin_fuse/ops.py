"""Jitted wrapper for the fused CIN layer."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.cin_fuse.kernel import DEFAULT_BLOCK_B, cin_layer_pallas


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def cin_layer(
    xk: jax.Array,   # (B, Hk, D)
    x0: jax.Array,   # (B, m, D)
    w: jax.Array,    # (Hk*m, O)
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = xk.shape[0]
    bb = min(block_b, b)
    pad = (-b) % bb
    if pad:
        xk = jnp.pad(xk, ((0, pad), (0, 0), (0, 0)))
        x0 = jnp.pad(x0, ((0, pad), (0, 0), (0, 0)))
    out = cin_layer_pallas(xk, x0, w, block_b=bb, interpret=interpret)
    return out[:b]
