"""Pure-jnp oracle: the unfused CIN layer from repro.models.recsys."""

from __future__ import annotations

import jax.numpy as jnp


def cin_layer_ref(xk, x0, w):
    """xk (B,Hk,D), x0 (B,m,D), w (Hk*m,O) -> (B,O,D)."""
    b, hk, d = xk.shape
    m = x0.shape[1]
    outer = jnp.einsum("bhd,bmd->bhmd", xk, x0)
    return jnp.einsum("bhmd,hmo->bod", outer, w.reshape(hk, m, -1))
