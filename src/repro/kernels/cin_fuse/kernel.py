"""Pallas TPU kernel: fused xDeepFM CIN layer.

The jnp CIN layer materializes the outer product (B, Hk, m, D) before
compressing with W — at serve_bulk scale that intermediate is the memory
bottleneck (roofline: xdeepfm cells are memory-bound).  Per output
element: y[b,o,d] = sum_{h,m} xk[b,h,d] * x0[b,m,d] * W[h,m,o].

Fusion: for one (batch-block, d) the contraction is
    y[:, :, d] = (xk[:, :, d] outer x0[:, :, d]) @ W_flat
and the outer product lives only in VMEM.  We tile over (B/bb, D) with W
resident; each step does bb small (Hk x m) outers + one (bb, Hk*m) x
(Hk*m, O) MXU matmul.  HBM traffic: read xk/x0 once, write y once —
the (B, Hk, m, D) tensor never exists.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.compat import tpu_compiler_params

DEFAULT_BLOCK_B = 256


def _cin_kernel(xk_ref, x0_ref, w_ref, o_ref):
    xk = xk_ref[...]                     # (bb, Hk, 1)
    x0 = x0_ref[...]                     # (bb, m, 1)
    w = w_ref[...]                       # (Hk*m, O)
    bb, hk, _ = xk.shape
    m = x0.shape[1]
    outer = (xk[:, :, None, 0] * x0[:, None, :, 0]).reshape(bb, hk * m)
    o_ref[...] = jax.lax.dot_general(
        outer, w, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)[..., None]


def cin_layer_pallas(
    xk: jax.Array,   # (B, Hk, D)
    x0: jax.Array,   # (B, m, D)
    w: jax.Array,    # (Hk*m, O)
    *,
    block_b: int = DEFAULT_BLOCK_B,
    interpret: bool = False,
) -> jax.Array:
    b, hk, d = xk.shape
    _, m, _ = x0.shape
    o = w.shape[1]
    assert b % block_b == 0, (b, block_b)
    grid = (b // block_b, d)

    return pl.pallas_call(
        _cin_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, hk, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((block_b, m, 1), lambda i, j: (i, 0, j)),
            pl.BlockSpec((hk * m, o), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, o, 1), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, o, d), xk.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xk, x0, w)
