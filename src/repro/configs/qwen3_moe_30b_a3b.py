"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, MoESpec

FULL = LMConfig(
    name="qwen3-moe-30b-a3b", n_layers=48, d_model=2048, n_heads=32,
    n_kv_heads=4, d_ff=768, vocab_size=151936, d_head=128, qk_norm=True,
    moe=MoESpec(n_experts=128, top_k=8, d_expert=768).padded(16))

SMOKE = LMConfig(
    name="qwen3-moe-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=512, d_head=16, qk_norm=True, dtype="float32",
    vocab_pad_multiple=64,
    moe=MoESpec(n_experts=8, top_k=2, d_expert=96).padded(4))

SPEC = ArchSpec(
    arch_id="qwen3-moe-30b-a3b", family="lm", config=FULL, smoke_config=SMOKE,
    shapes=LM_SHAPES, source="hf:Qwen/Qwen3-30B-A3B",
    notes="128 experts top-8, GQA kv=4, qk_norm")
