"""--arch <id> registry over the 10 assigned architectures."""

from __future__ import annotations

import importlib

from repro.configs.base import ArchSpec

_MODULES = {
    "qwen3-moe-30b-a3b": "repro.configs.qwen3_moe_30b_a3b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "qwen3-1.7b": "repro.configs.qwen3_1_7b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "dimenet": "repro.configs.dimenet",
    "deepfm": "repro.configs.deepfm",
    "xdeepfm": "repro.configs.xdeepfm",
    "autoint": "repro.configs.autoint",
    "mind": "repro.configs.mind",
}


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).SPEC


def list_archs() -> list[str]:
    return sorted(_MODULES)


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) pair — the 40 dry-run cells."""
    cells = []
    for a in list_archs():
        spec = get_arch(a)
        for s in spec.shapes:
            cells.append((a, s.name))
    return cells
