"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES

FULL = LMConfig(
    name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
    n_kv_heads=8, d_ff=33792, vocab_size=256000, d_head=128)

SMOKE = LMConfig(
    name="command-r-smoke", n_layers=2, d_model=64, n_heads=8, n_kv_heads=2,
    d_ff=160, vocab_size=512, d_head=8, dtype="float32", vocab_pad_multiple=64)

SPEC = ArchSpec(
    arch_id="command-r-plus-104b", family="lm", config=FULL,
    smoke_config=SMOKE, shapes=LM_SHAPES,
    source="hf:CohereForAI/c4ai-command-r-v01",
    notes="dense 104B, GQA kv=8, no bias")
