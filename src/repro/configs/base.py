"""Config dataclasses for every architecture family + input-shape specs.

One `ArchSpec` per assigned architecture lives in src/repro/configs/<id>.py;
the registry maps ``--arch <id>`` to it.  Every spec carries both the FULL
published configuration (exercised only via the dry-run) and a REDUCED
smoke configuration (one CPU forward/train step in tests).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

__all__ = ["MoESpec", "LMConfig", "GNNConfig", "RecsysConfig", "ShapeSpec",
           "ArchSpec", "LM_SHAPES", "GNN_SHAPES", "RECSYS_SHAPES"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_expert: int               # per-expert FFN hidden size
    capacity_factor: float = 1.25
    n_experts_padded: int = 0   # padded up for even expert-parallel sharding

    def padded(self, multiple: int) -> "MoESpec":
        pad = (-self.n_experts) % multiple
        return dataclasses.replace(
            self, n_experts_padded=self.n_experts + pad)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                   # dense FFN hidden (MoE: per-expert = moe.d_expert)
    vocab_size: int
    d_head: int = 128
    qk_norm: bool = False
    rope_theta: float = 1e6
    moe: Optional[MoESpec] = None
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 2048
    # execution knobs (not architecture): layer loop as lax.scan (compact
    # HLO) vs Python unroll (accurate cost analysis for the dry-run);
    # attn_chunk > 0 enables blockwise flash-style attention; unroll_attn
    # unrolls the chunk loops too (dry-run only).
    scan_layers: bool = True
    scan_unroll: int = 1       # lax.scan unroll factor for the layer loop
    attn_chunk: int = 0
    unroll_attn: bool = False

    @property
    def vocab_padded(self) -> int:
        return self.vocab_size + (-self.vocab_size) % self.vocab_pad_multiple

    @property
    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model FLOPs)."""
        d, h, kv, dh = self.d_model, self.n_heads, self.n_kv_heads, self.d_head
        attn = d * h * dh + 2 * d * kv * dh + h * dh * d
        if self.moe is not None:
            ffn = 3 * d * self.moe.d_expert * self.moe.n_experts
            ffn += d * self.moe.n_experts          # router
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d             # norms
        emb = (1 if self.tie_embeddings else 2) * self.vocab_size * d
        return self.n_layers * per_layer + emb

    @property
    def n_active_params(self) -> int:
        """Activated params per token (MoE: only top_k experts count)."""
        if self.moe is None:
            return self.n_params
        d = self.d_model
        full_ffn = 3 * d * self.moe.d_expert * self.moe.n_experts
        act_ffn = 3 * d * self.moe.d_expert * self.moe.top_k
        return self.n_params - self.n_layers * (full_ffn - act_ffn)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    d_out: int = 1
    cutoff: float = 5.0
    triplet_budget_factor: int = 4   # triplets per edge budget
    dtype: str = "bfloat16"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    interaction: str                      # fm | cin | self-attn | multi-interest
    n_sparse: int = 39
    embed_dim: int = 10
    field_vocabs: Tuple[int, ...] = ()    # per-field vocab sizes
    mlp: Tuple[int, ...] = (400, 400, 400)
    cin_layers: Tuple[int, ...] = ()
    n_attn_layers: int = 0
    n_heads: int = 0
    d_attn: int = 0
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 50                    # behavior sequence (MIND)
    item_vocab: int = 1_000_000           # MIND item universe
    multi_hot: int = 4                    # avg ids per multi-hot field
    dtype: str = "bfloat16"

    @property
    def total_rows(self) -> int:
        return sum(self.field_vocabs) + (
            self.item_vocab if self.interaction == "multi-interest" else 0)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""

    name: str
    kind: str                    # train | prefill | decode | graph | recsys
    dims: Dict[str, int]

    def __getitem__(self, k):
        return self.dims[k]


LM_SHAPES = (
    ShapeSpec("train_4k", "train", dict(seq_len=4096, global_batch=256)),
    ShapeSpec("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
    ShapeSpec("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ShapeSpec("long_500k", "decode", dict(seq_len=524288, global_batch=1)),
)

GNN_SHAPES = (
    ShapeSpec("full_graph_sm", "graph",
              dict(n_nodes=2708, n_edges=10556, d_feat=1433)),
    ShapeSpec("minibatch_lg", "graph",
              dict(n_nodes=232965, n_edges=114615892, batch_nodes=1024,
                   fanout1=15, fanout2=10,
                   # sampled subgraph actually computed per step:
                   # 1024 + 1024*15 + 1024*15*10 nodes; edges = 15360+153600
                   sub_nodes=169984, sub_edges=168960, d_feat=602)),
    ShapeSpec("ogb_products", "graph",
              dict(n_nodes=2449029, n_edges=61859140, d_feat=100)),
    ShapeSpec("molecule", "graph",
              dict(n_nodes=30, n_edges=64, batch=128)),
)

RECSYS_SHAPES = (
    ShapeSpec("train_batch", "recsys_train", dict(batch=65536)),
    ShapeSpec("serve_p99", "recsys_serve", dict(batch=512)),
    ShapeSpec("serve_bulk", "recsys_serve", dict(batch=262144)),
    ShapeSpec("retrieval_cand", "recsys_retrieval",
              dict(batch=1, n_candidates=1_000_000)),
)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                 # lm | gnn | recsys
    config: object              # LMConfig | GNNConfig | RecsysConfig
    smoke_config: object        # reduced same-family config
    shapes: Tuple[ShapeSpec, ...]
    source: str = ""
    notes: str = ""
