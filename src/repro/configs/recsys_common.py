"""Shared Criteo-style field vocabulary (39 sparse fields).

26 categorical cardinalities follow the published Criteo-Kaggle statistics;
the 13 'dense' features are bucketized to 1000 bins each (standard DLRM
preprocessing), giving ~40.6M embedding rows total.
"""

CRITEO_CAT = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145, 5683,
    8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4, 7046547, 18, 15,
    286181, 105, 142572)
DENSE_BUCKETS = (1000,) * 13
CRITEO_39 = DENSE_BUCKETS + CRITEO_CAT

SMOKE_FIELDS_6 = (50, 50, 200, 200, 30, 30)
