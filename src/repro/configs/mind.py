"""mind [arXiv:1904.08030]."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES

FULL = RecsysConfig(
    name="mind", interaction="multi-interest", embed_dim=64, n_interests=4,
    capsule_iters=3, hist_len=50, item_vocab=1_000_000, field_vocabs=())

SMOKE = RecsysConfig(
    name="mind-smoke", interaction="multi-interest", embed_dim=16,
    n_interests=3, capsule_iters=3, hist_len=10, item_vocab=256,
    field_vocabs=(), dtype="float32")

SPEC = ArchSpec(
    arch_id="mind", family="recsys", config=FULL, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES, source="arXiv:1904.08030",
    notes="4 interest capsules, 3 routing iters; retrieval over 1M items")
