"""deepfm [arXiv:1703.04247]."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES
from repro.configs.recsys_common import CRITEO_39, SMOKE_FIELDS_6

FULL = RecsysConfig(
    name="deepfm", interaction="fm", n_sparse=39, embed_dim=10,
    field_vocabs=CRITEO_39, mlp=(400, 400, 400))

SMOKE = RecsysConfig(
    name="deepfm-smoke", interaction="fm", n_sparse=6, embed_dim=8,
    field_vocabs=SMOKE_FIELDS_6, mlp=(32, 32), dtype="float32")

SPEC = ArchSpec(
    arch_id="deepfm", family="recsys", config=FULL, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES, source="arXiv:1703.04247",
    notes="FM + deep MLP 400-400-400")
