"""dimenet [arXiv:2003.03123]."""
from repro.configs.base import ArchSpec, GNNConfig, GNN_SHAPES

FULL = GNNConfig(
    name="dimenet", n_blocks=6, d_hidden=128, n_bilinear=8,
    n_spherical=7, n_radial=6)

SMOKE = GNNConfig(
    name="dimenet-smoke", n_blocks=2, d_hidden=32, n_bilinear=4,
    n_spherical=4, n_radial=3, dtype="float32")

SPEC = ArchSpec(
    arch_id="dimenet", family="gnn", config=FULL, smoke_config=SMOKE,
    shapes=GNN_SHAPES, source="arXiv:2003.03123",
    notes="directional message passing; triplet gather regime; "
          "non-geometric graphs use synthesized positions (DESIGN.md §5)")
