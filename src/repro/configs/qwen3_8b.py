"""qwen3-8b [hf:Qwen/Qwen3-8B]."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES

FULL = LMConfig(
    name="qwen3-8b", n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936, d_head=128, qk_norm=True)

SMOKE = LMConfig(
    name="qwen3-8b-smoke", n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=192, vocab_size=512, d_head=16, qk_norm=True, dtype="float32",
    vocab_pad_multiple=64)

SPEC = ArchSpec(
    arch_id="qwen3-8b", family="lm", config=FULL, smoke_config=SMOKE,
    shapes=LM_SHAPES, source="hf:Qwen/Qwen3-8B",
    notes="dense, qk_norm, GQA kv=8")
