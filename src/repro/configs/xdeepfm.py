"""xdeepfm [arXiv:1803.05170]."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES
from repro.configs.recsys_common import CRITEO_39, SMOKE_FIELDS_6

FULL = RecsysConfig(
    name="xdeepfm", interaction="cin", n_sparse=39, embed_dim=10,
    field_vocabs=CRITEO_39, mlp=(400, 400), cin_layers=(200, 200, 200))

SMOKE = RecsysConfig(
    name="xdeepfm-smoke", interaction="cin", n_sparse=6, embed_dim=8,
    field_vocabs=SMOKE_FIELDS_6, mlp=(32,), cin_layers=(16, 16),
    dtype="float32")

SPEC = ArchSpec(
    arch_id="xdeepfm", family="recsys", config=FULL, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES, source="arXiv:1803.05170",
    notes="CIN 200-200-200 + MLP 400-400")
