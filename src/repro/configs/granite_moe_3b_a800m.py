"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from repro.configs.base import ArchSpec, LMConfig, LM_SHAPES, MoESpec

# 24 heads => d_head = 1536/24 = 64; heads not divisible by model=16 so
# attention is replicated under TP (DESIGN.md §4) — experts carry the TP.
FULL = LMConfig(
    name="granite-moe-3b-a800m", n_layers=32, d_model=1536, n_heads=24,
    n_kv_heads=8, d_ff=512, vocab_size=49155, d_head=64,
    moe=MoESpec(n_experts=40, top_k=8, d_expert=512).padded(16))

SMOKE = LMConfig(
    name="granite-moe-smoke", n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    d_ff=64, vocab_size=512, d_head=8, dtype="float32", vocab_pad_multiple=64,
    moe=MoESpec(n_experts=5, top_k=2, d_expert=64).padded(2))

SPEC = ArchSpec(
    arch_id="granite-moe-3b-a800m", family="lm", config=FULL,
    smoke_config=SMOKE, shapes=LM_SHAPES,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    notes="40 experts (padded to 48) top-8, GQA kv=8")
