"""autoint [arXiv:1810.11921]."""
from repro.configs.base import ArchSpec, RecsysConfig, RECSYS_SHAPES
from repro.configs.recsys_common import CRITEO_39, SMOKE_FIELDS_6

FULL = RecsysConfig(
    name="autoint", interaction="self-attn", n_sparse=39, embed_dim=16,
    field_vocabs=CRITEO_39, n_attn_layers=3, n_heads=2, d_attn=32)

SMOKE = RecsysConfig(
    name="autoint-smoke", interaction="self-attn", n_sparse=6, embed_dim=8,
    field_vocabs=SMOKE_FIELDS_6, n_attn_layers=2, n_heads=2, d_attn=8,
    dtype="float32")

SPEC = ArchSpec(
    arch_id="autoint", family="recsys", config=FULL, smoke_config=SMOKE,
    shapes=RECSYS_SHAPES, source="arXiv:1810.11921",
    notes="3 self-attn layers, 2 heads, d_attn=32")
