"""Mixture-of-Experts FFN: top-k routing with capacity-bounded scatter
dispatch (GShard/Switch lineage), expert-parallel over the ``model`` axis.

Dispatch is scatter-based rather than one-hot-einsum based: tokens are
assigned slot positions inside each expert's capacity buffer via a
per-expert running count (cumsum over a small (S*k, E) one-hot), then
scattered into an (E, C, d) buffer.  This never materializes the
(S, E, C) dispatch tensor — the buffer is the only intermediate, and with
E sharded over ``model`` and tokens sharded over ``data`` the scatter/gather
pair lowers to the expected all_to_all exchange.

Padding experts (for even sharding, e.g. granite's 40 -> 48) are masked to
-inf in the router so they receive zero probability mass.

The fork-join view (DESIGN.md §5): the dispatch fan-out and the combine
fan-in are exactly the paper's broker broadcast/merge; expert hot-spotting
under Zipfian routing is the disk-cache imbalance; the capacity factor is
the knob that trades the H_E straggler tax against dropped tokens.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoESpec
from repro.launch.sharding import constrain
from repro.models.layers import _dense_init

Array = jax.Array


def init_moe(key, d_model: int, spec: MoESpec, dtype) -> dict:
    e = spec.n_experts_padded or spec.n_experts
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": _dense_init(kr, (d_model, e), jnp.float32, scale=0.02),
        "w_gate": _dense_init(kg, (e, d_model, spec.d_expert), dtype),
        "w_up": _dense_init(ku, (e, d_model, spec.d_expert), dtype),
        "w_down": _dense_init(kd, (e, spec.d_expert, d_model), dtype),
    }


def _capacity(s_tokens: int, spec: MoESpec) -> int:
    e = spec.n_experts_padded or spec.n_experts
    c = int(s_tokens * spec.top_k * spec.capacity_factor / e) + 1
    return max(c, 1)


def moe_ffn(params: dict, spec: MoESpec, x: Array) -> tuple[Array, Array]:
    """x: (B, S, d) -> (same, aux_loss).  Routing group = one batch row."""
    b, s, d = x.shape
    e = spec.n_experts_padded or spec.n_experts
    k = spec.top_k
    c = _capacity(s, spec)

    logits = (x.astype(jnp.float32) @ params["router"])          # (B,S,E)
    if e != spec.n_experts:
        pad_mask = jnp.arange(e) >= spec.n_experts
        logits = jnp.where(pad_mask, -jnp.inf, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                       # (B,S,k)
    top_p = top_p / jnp.maximum(
        jnp.sum(top_p, axis=-1, keepdims=True), 1e-9)

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * P_e
    density = jnp.mean(
        jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    router_prob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(density * router_prob) * e

    tok = jnp.repeat(jnp.arange(s), k)

    def route_one(x_row, e_row, p_row):
        """One routing group: x (S,d) -> slot-major buffer + inverse map.

        Dispatch is expressed SLOT-MAJOR: tok_map (E,C) holds the token id
        owning each expert slot (park = S for empty/dropped), so filling
        the buffer is a plain gather `x[tok_map]`.  Under expert-parallel
        sharding this keeps the dispatch collective-free (each expert
        shard gathers only its own slots) and the combine a scatter-add
        whose cross-shard part is a small (S,d) psum — versus the naive
        token-major scatter/gather pair that makes GSPMD all-gather the
        whole (E,C,d) buffer on both sides (measured 14.6 GB/step/device
        on granite train_4k; see EXPERIMENTS §Perf).
        """
        ef = e_row.reshape(-1)                                   # (S*k,)
        pf = p_row.reshape(-1)
        onehot = jax.nn.one_hot(ef, e, dtype=jnp.int32)          # (S*k,E)
        pos = jnp.cumsum(onehot, axis=0) - onehot                # exclusive
        slot = jnp.take_along_axis(pos, ef[:, None], axis=1)[:, 0]
        keep = slot < c
        ef_park = jnp.where(keep, ef, e)       # dropped -> padded row e
        slot = jnp.minimum(slot, c - 1)
        tok_map = jnp.full((e + 1, c), s, jnp.int32)
        tok_map = tok_map.at[ef_park, slot].set(tok)[:e]         # (E,C)
        w_map = jnp.zeros((e + 1, c), jnp.float32)
        w_map = w_map.at[ef_park, slot].set(pf)[:e]              # (E,C)
        x_pad = jnp.concatenate(
            [x_row, jnp.zeros((1, d), x_row.dtype)], axis=0)
        return x_pad[tok_map], tok_map, w_map

    buf, tok_map, w_map = jax.vmap(route_one)(x, top_e, top_p)
    buf = constrain(buf, "batch", "experts", None, None)

    h = jnp.einsum("becd,edf->becf", buf, params["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("becd,edf->becf", buf, params["w_up"])
    y = jnp.einsum("becf,efd->becd", h, params["w_down"])
    y = constrain(y, "batch", "experts", None, None)

    def combine_one(y_b, tok_map_b, w_b):
        z = jnp.zeros((s + 1, d), y_b.dtype)                     # row s = park
        z = z.at[tok_map_b.reshape(-1)].add(
            y_b.reshape(-1, d) * w_b.reshape(-1, 1).astype(y_b.dtype))
        return z[:s]

    out = jax.vmap(combine_one)(y, tok_map, w_map)
    return constrain(out, "batch", "seq", "embed"), aux
