"""Decoder-only LM supporting all five assigned transformer archs.

Params are *stacked over layers* (leading L axis on every layer tensor) and
the layer stack runs under `jax.lax.scan` with rematerialization — this
keeps the HLO size O(1) in depth (essential for compiling 64-layer models
on the 512-device dry-run host) and matches how production frameworks
(MaxText et al.) structure deep stacks.

Three entry points per the assigned shapes:
  * ``train_step_loss``  — causal LM loss, full-sequence attention,
  * ``prefill``          — chunked attention, returns logits + KV caches,
  * ``decode_step``      — one token against (possibly mesh-sharded) caches.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import LMConfig
from repro.launch.sharding import constrain
from repro.models import layers as L
from repro.models import moe as moe_lib

Array = jax.Array


def _dims(cfg: LMConfig) -> L.AttnDims:
    return L.AttnDims(
        d_model=cfg.d_model, n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
        qk_norm=cfg.qk_norm, rope_theta=cfg.rope_theta)


def _dtype(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


# -------------------------------------------------------------------------
# init
# -------------------------------------------------------------------------

def init_params(key, cfg: LMConfig) -> dict:
    dt = _dtype(cfg)
    dims = _dims(cfg)
    k_emb, k_head, k_layers = jax.random.split(key, 3)

    def init_layer(k):
        ka, km, = jax.random.split(k, 2)
        p = {
            "ln_attn": L.init_rmsnorm(cfg.d_model, dt),
            "ln_mlp": L.init_rmsnorm(cfg.d_model, dt),
            "attn": L.init_attention(ka, dims, dt),
        }
        if cfg.moe is not None:
            p["moe"] = moe_lib.init_moe(km, cfg.d_model, cfg.moe, dt)
        else:
            p["mlp"] = L.init_mlp(km, cfg.d_model, cfg.d_ff, dt)
        return p

    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    stacked = jax.vmap(init_layer)(layer_keys)

    params = {
        "embed": L._dense_init(k_emb, (cfg.vocab_padded, cfg.d_model), dt,
                               scale=0.02),
        "final_norm": L.init_rmsnorm(cfg.d_model, dt),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(
            k_head, (cfg.d_model, cfg.vocab_padded), dt)
    return params


# -------------------------------------------------------------------------
# blocks
# -------------------------------------------------------------------------

def _layer_slice(params_layers, i: int):
    return jax.tree.map(lambda x: x[i], params_layers)


def _block_train(cfg: LMConfig, layer_params: dict, x: Array
                 ) -> tuple[Array, Array]:
    dims = _dims(cfg)
    h = L.attention_train(layer_params["attn"], dims,
                          L.rmsnorm(layer_params["ln_attn"], x),
                          chunk=cfg.attn_chunk, unroll=cfg.unroll_attn)
    x = x + h
    y = L.rmsnorm(layer_params["ln_mlp"], x)
    if cfg.moe is not None:
        f, aux = moe_lib.moe_ffn(layer_params["moe"], cfg.moe, y)
    else:
        f, aux = L.mlp_swiglu(layer_params["mlp"], y), jnp.zeros((), jnp.float32)
    return constrain(x + f, "batch", "seq", "embed"), aux


def _embed(params, cfg: LMConfig, tokens: Array) -> Array:
    # The embed table is COLUMN-sharded ("embed_cols" -> model): a gather
    # from a row(vocab)-sharded table makes GSPMD materialize the full
    # (B,S,D) with zeros on every shard and all-reduce (tens of GB at
    # 256k vocab); column sharding keeps the gather local per d-slice.
    emb = constrain(params["embed"], "embed_rows", "embed_cols")
    x = emb[tokens]
    return constrain(x, "batch", "seq", "embed")


def _logits(params, cfg: LMConfig, x: Array) -> Array:
    x = L.rmsnorm(params["final_norm"], x)
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    return constrain(logits, "batch", "seq_q", "vocab")


# -------------------------------------------------------------------------
# train
# -------------------------------------------------------------------------

def forward_train(params, cfg: LMConfig, tokens: Array,
                  remat: bool = True) -> tuple[Array, Array]:
    """tokens (B, S) -> (logits (B,S,Vp), aux_loss)."""
    x = _embed(params, cfg, tokens)

    def body(x, layer_params):
        y, aux = _block_train(cfg, layer_params, x)
        return y, aux

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, auxes = jax.lax.scan(body, x, params["layers"],
                                unroll=cfg.scan_unroll)
        aux = jnp.mean(auxes)
    else:  # Python unroll: accurate dry-run cost analysis, same math
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            x, aux_i = body(x, _layer_slice(params["layers"], i))
            aux = aux + aux_i / cfg.n_layers
    return _logits(params, cfg, x), aux


def cross_entropy_sharded(logits: Array, labels: Array) -> Array:
    """CE that never gathers the vocab axis (stays vocab-sharded).

    take_along_axis over a vocab-sharded logp would force GSPMD to
    all-gather a (B,S,V) fp32 tensor (tens of GB at 152k vocab); instead
    the label logit is extracted by a fused compare-and-reduce over the
    sharded axis and the normalizer via logsumexp — both lower to cheap
    per-shard reductions + a scalar-per-token all-reduce.
    """
    v = logits.shape[-1]
    x = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(x, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[..., 0]
    iota = jax.lax.broadcasted_iota(jnp.int32, (v,), 0)
    sel = labels[..., None] == iota                      # (B,S,V) fused
    correct = jnp.sum(jnp.where(sel, x, 0.0), axis=-1)
    nll = lse - correct
    mask = labels >= 0
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def forward_hidden(params, cfg: LMConfig, tokens: Array,
                   remat: bool = True) -> tuple[Array, Array]:
    """Like forward_train but stops before the LM head: (x, aux)."""
    x = _embed(params, cfg, tokens)

    def body(x, layer_params):
        return _block_train(cfg, layer_params, x)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, auxes = jax.lax.scan(body, x, params["layers"],
                                unroll=cfg.scan_unroll)
        aux = jnp.mean(auxes)
    else:
        aux = jnp.zeros((), jnp.float32)
        for i in range(cfg.n_layers):
            x, aux_i = body(x, _layer_slice(params["layers"], i))
            aux = aux + aux_i / cfg.n_layers
    return L.rmsnorm(params["final_norm"], x), aux


def chunked_lm_loss(params, cfg: LMConfig, x: Array, labels: Array,
                    chunk: int = 2048) -> Array:
    """LM head + CE in sequence chunks, rematerialized per chunk.

    The full (B,S,V) logits tensor (GBs at 152k-256k vocab) never exists:
    each chunk's logits are produced, reduced to per-token nll, and freed;
    backward recomputes the chunk matmul.  Sum-reduced then normalized so
    chunking is exact.
    """
    head = params.get("lm_head")
    if head is None:
        head = params["embed"].T
    b, s, _ = x.shape
    chunk = min(chunk, s)
    assert s % chunk == 0

    @jax.checkpoint
    def piece(xc, lc):
        logits = constrain(xc @ head, "batch", "seq_q", "vocab")
        xf = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(jnp.max(xf, axis=-1, keepdims=True))
        lse = jnp.log(jnp.sum(jnp.exp(xf - m), axis=-1)) + m[..., 0]
        iota = jax.lax.broadcasted_iota(jnp.int32, (logits.shape[-1],), 0)
        correct = jnp.sum(jnp.where(lc[..., None] == iota, xf, 0.0), -1)
        mask = lc >= 0
        return jnp.sum((lse - correct) * mask), jnp.sum(mask)

    total, count = jnp.zeros(()), jnp.zeros(())
    for i in range(s // chunk):  # static unroll: exact dry-run cost
        sl = slice(i * chunk, (i + 1) * chunk)
        t, c = piece(x[:, sl], labels[:, sl])
        total = total + t
        count = count + c
    return total / jnp.maximum(count, 1)


def train_step_loss(params, cfg: LMConfig, tokens: Array, labels: Array,
                    *, aux_weight: float = 0.01) -> Array:
    """Causal LM cross-entropy (+ MoE aux loss), mean over tokens."""
    x, aux = forward_hidden(params, cfg, tokens)
    loss = chunked_lm_loss(params, cfg, x, labels)  # labels < 0 masked
    return loss + aux_weight * aux


# -------------------------------------------------------------------------
# serving: prefill + decode
# -------------------------------------------------------------------------

def init_kv_cache(cfg: LMConfig, batch: int, max_seq: int) -> dict:
    dt = _dtype(cfg)
    shape = (cfg.n_layers, batch, max_seq, cfg.n_kv_heads, cfg.d_head)
    return {
        "k": constrain(jnp.zeros(shape, dt),
                       None, "kv_batch", "kv_seq", "kv_heads", None),
        "v": constrain(jnp.zeros(shape, dt),
                       None, "kv_batch", "kv_seq", "kv_heads", None),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(params, cfg: LMConfig, tokens: Array, *, chunk: int = 2048,
            remat: bool = True) -> tuple[Array, dict]:
    """Chunked-attention prefill; returns (last-position logits, caches)."""
    b, s = tokens.shape
    x = _embed(params, cfg, tokens)
    dims = _dims(cfg)

    def body(x, layer_params):
        h, k, v = L.attention_prefill_chunked(
            layer_params["attn"], dims,
            L.rmsnorm(layer_params["ln_attn"], x), chunk=chunk,
            unroll=cfg.unroll_attn)
        x = x + h
        y = L.rmsnorm(layer_params["ln_mlp"], x)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_ffn(layer_params["moe"], cfg.moe, y)
        else:
            f = L.mlp_swiglu(layer_params["mlp"], y)
        return constrain(x + f, "batch", "seq", "embed"), (k, v)

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, params["layers"],
                                   unroll=cfg.scan_unroll)
    else:
        all_k, all_v = [], []
        for i in range(cfg.n_layers):
            x, (k, v) = body(x, _layer_slice(params["layers"], i))
            all_k.append(k)
            all_v.append(v)
        ks = jnp.stack(all_k)
        vs = jnp.stack(all_v)
    logits = _logits(params, cfg, x[:, -1:, :])
    cache = {
        "k": constrain(ks, None, "kv_batch", "kv_seq", "kv_heads", None),
        "v": constrain(vs, None, "kv_batch", "kv_seq", "kv_heads", None),
        "len": jnp.asarray(s, jnp.int32),
    }
    return logits, cache


def decode_step(params, cfg: LMConfig, tokens: Array, cache: dict
                ) -> tuple[Array, dict]:
    """tokens (B, 1) + caches -> (logits (B,1,Vp), updated caches).

    Layer scan carries the per-layer cache slices; the cache stays sharded
    per the ``kv_*`` logical rules throughout.
    """
    b = tokens.shape[0]
    x = _embed(params, cfg, tokens)
    dims = _dims(cfg)
    cache_len = cache["len"]

    def body(x, scanned):
        layer_params, k_c, v_c = scanned
        h, k_c, v_c = L.attention_decode(
            layer_params["attn"], dims,
            L.rmsnorm(layer_params["ln_attn"], x), k_c, v_c, cache_len)
        x = x + h
        y = L.rmsnorm(layer_params["ln_mlp"], x)
        if cfg.moe is not None:
            f, _ = moe_lib.moe_ffn(layer_params["moe"], cfg.moe, y)
        else:
            f = L.mlp_swiglu(layer_params["mlp"], y)
        return x + f, (k_c, v_c)

    if cfg.scan_layers:
        x, (ks, vs) = jax.lax.scan(body, x, (params["layers"],
                                             cache["k"], cache["v"]),
                                   unroll=cfg.scan_unroll)
    else:
        ks, vs = cache["k"], cache["v"]
        for i in range(cfg.n_layers):
            x, (k_i, v_i) = body(
                x, (_layer_slice(params["layers"], i), ks[i], vs[i]))
            ks = jax.lax.dynamic_update_index_in_dim(ks, k_i, i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, v_i, i, 0)
    logits = _logits(params, cfg, x)
    new_cache = {
        "k": constrain(ks, None, "kv_batch", "kv_seq", "kv_heads", None),
        "v": constrain(vs, None, "kv_batch", "kv_seq", "kv_heads", None),
        "len": cache_len + 1,
    }
    return logits, new_cache
