"""DimeNet (Klicpera et al., arXiv:2003.03123) in JAX.

Directional message passing with radial Bessel + spherical-harmonic bases
and the original bilinear interaction (num_bilinear = 8 per the assigned
config).  Message passing is the segment-sum regime: triplet gather ->
bilinear -> scatter to edges -> scatter to nodes.

Basis functions:
  * radial: e_RBF,n(d) = sqrt(2/c) * sin(n pi d / c) / d         (n=1..Nr)
  * spherical: a_SBF,ln(d, alpha) = j_l(z_ln d / c) * Y_l(alpha) where
    z_ln is the n-th root of the spherical Bessel function j_l and
    Y_l(alpha) ∝ P_l(cos alpha).  j_l and P_l are evaluated by their
    stable recurrences; the roots are precomputed host-side (scipy brentq).

For non-geometric assigned graphs (ogb_products etc.) the data pipeline
synthesizes distances/angles (DESIGN.md §5) — the model consumes
(dist, angle) regardless of their provenance.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.launch.sharding import constrain
from repro.models.gnn_common import GraphBatch, segment_sum
from repro.models.layers import _dense_init

Array = jax.Array


# -------------------------------------------------------------------------
# Bases
# -------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def spherical_bessel_roots(n_spherical: int, n_radial: int) -> np.ndarray:
    """(L, N) roots z_ln of j_l, found by bracketed bisection on [a, b].

    j_0 roots are n*pi; roots of successive orders interlace, which gives
    brackets for scipy.optimize.brentq.
    """
    from scipy import optimize, special

    roots = np.zeros((n_spherical, n_radial))
    roots[0] = np.arange(1, n_radial + 1) * np.pi
    for l in range(1, n_spherical):
        prev = np.concatenate([roots[l - 1], [roots[l - 1, -1] + np.pi]])
        # need n_radial roots of j_l; they interlace prev's roots
        found = []
        lo = prev[0]
        grid = np.concatenate([[l + 1e-3], prev])
        for i in range(len(grid) - 1):
            a, b = grid[i] + 1e-9, grid[i + 1] - 1e-9
            fa = special.spherical_jn(l, a)
            fb = special.spherical_jn(l, b)
            if fa * fb < 0:
                found.append(optimize.brentq(
                    lambda z: special.spherical_jn(l, z), a, b))
            if len(found) == n_radial:
                break
        while len(found) < n_radial:  # extend search past the last bracket
            a = (found[-1] if found else l + 1.0) + 1e-3
            b = a + np.pi
            fa, fb = special.spherical_jn(l, a), special.spherical_jn(l, b)
            while fa * fb > 0:
                a, b = b, b + np.pi
                fa, fb = special.spherical_jn(l, a), special.spherical_jn(l, b)
            found.append(optimize.brentq(
                lambda z: special.spherical_jn(l, z), a, b))
        roots[l] = found[:n_radial]
    return roots


def radial_bessel(dist: Array, n_radial: int, cutoff: float) -> Array:
    """(E,) -> (E, Nr) radial Bessel basis with cosine envelope."""
    d = jnp.maximum(dist, 1e-6)[:, None]
    n = jnp.arange(1, n_radial + 1, dtype=jnp.float32)
    basis = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d
    env = 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0, 1)) + 1.0)
    return basis * env[:, None]


def _spherical_jn(l_max: int, z: Array) -> Array:
    """j_l(z) for l = 0..l_max-1 via upward recurrence; (L, ...) output."""
    z = jnp.maximum(z, 1e-6)
    j0 = jnp.sin(z) / z
    out = [j0]
    if l_max > 1:
        j1 = jnp.sin(z) / z**2 - jnp.cos(z) / z
        out.append(j1)
        jm, jc = j0, j1
        for l in range(1, l_max - 1):
            jn = (2 * l + 1) / z * jc - jm
            out.append(jn)
            jm, jc = jc, jn
    return jnp.stack(out, axis=0)


def _legendre(l_max: int, x: Array) -> Array:
    """P_l(x) for l = 0..l_max-1 via Bonnet recurrence; (L, ...) output."""
    p0 = jnp.ones_like(x)
    out = [p0]
    if l_max > 1:
        p1 = x
        out.append(p1)
        pm, pc = p0, p1
        for l in range(1, l_max - 1):
            pn = ((2 * l + 1) * x * pc - l * pm) / (l + 1)
            out.append(pn)
            pm, pc = pc, pn
    return jnp.stack(out, axis=0)


def spherical_basis(dist_kj: Array, angle: Array, cfg: GNNConfig) -> Array:
    """(T,) x (T,) -> (T, L*Nr) directional basis a_SBF."""
    roots = jnp.asarray(
        spherical_bessel_roots(cfg.n_spherical, cfg.n_radial),
        jnp.float32)                                  # (L, Nr)
    scaled = roots[None] * (jnp.clip(dist_kj, 0, cfg.cutoff) / cfg.cutoff
                            )[:, None, None]          # (T, L, Nr)
    # evaluate all orders then take the matching-l diagonal
    t = dist_kj.shape[0]
    jl_all = _spherical_jn(
        cfg.n_spherical, scaled.reshape(t, -1))       # (L, T, L*Nr)
    jl_all = jl_all.reshape(cfg.n_spherical, t, cfg.n_spherical,
                            cfg.n_radial)
    radial = jnp.stack(
        [jl_all[l, :, l, :] for l in range(cfg.n_spherical)], axis=1)
    pl = _legendre(cfg.n_spherical, jnp.cos(angle))   # (L, T)
    sbf = radial * jnp.transpose(pl)[:, :, None]      # (T, L, Nr)
    return sbf.reshape(t, cfg.n_spherical * cfg.n_radial)


# -------------------------------------------------------------------------
# Model
# -------------------------------------------------------------------------

def init_params(key, cfg: GNNConfig, d_feat: int) -> dict:
    dt = jnp.dtype(cfg.dtype)
    h, nb = cfg.d_hidden, cfg.n_bilinear
    s = cfg.n_spherical * cfg.n_radial
    keys = iter(jax.random.split(key, 8 + cfg.n_blocks))

    def block_init(k):
        ks = jax.random.split(k, 8)
        return {
            "w_sbf": _dense_init(ks[0], (s, nb), dt),
            "w_kj": _dense_init(ks[1], (h, h), dt),
            "w_ji": _dense_init(ks[2], (h, h), dt),
            "bilinear": (jax.random.normal(ks[3], (h, nb, h), jnp.float32)
                         / np.sqrt(nb * h)).astype(dt),
            "w_rbf": _dense_init(ks[4], (cfg.n_radial, h), dt),
            "w_out1": _dense_init(ks[5], (h, h), dt),
            "w_out2": _dense_init(ks[6], (h, h), dt),
            "w_node": _dense_init(ks[7], (h, h), dt),
        }

    params = {
        "feat_proj": _dense_init(next(keys), (d_feat, h), dt),
        "w_rbf0": _dense_init(next(keys), (cfg.n_radial, h), dt),
        "w_msg0": _dense_init(next(keys), (3 * h, h), dt),
        "blocks": jax.vmap(block_init)(
            jax.random.split(next(keys), cfg.n_blocks)),
        "w_readout1": _dense_init(next(keys), (h, h), dt),
        "w_readout2": _dense_init(next(keys), (h, cfg.d_out), dt),
    }
    return params


def forward(params: dict, cfg: GNNConfig, g: GraphBatch) -> Array:
    """GraphBatch -> (n_graphs, d_out) predictions."""
    act = jax.nn.silu
    n = g.n_nodes
    dt = params["feat_proj"].dtype

    feat = g.node_feat.astype(dt)
    h_node = act(feat @ params["feat_proj"])                   # (N, h)
    h_node = constrain(h_node, "nodes", None)

    rbf = radial_bessel(g.edge_dist, cfg.n_radial, cfg.cutoff).astype(dt)
    rbf = constrain(rbf, "edges", None)
    sbf = spherical_basis(g.edge_dist[g.tri_kj], g.tri_angle, cfg).astype(dt)
    sbf = sbf * g.tri_mask[:, None].astype(dt)
    sbf = constrain(sbf, "triplets", None)

    # embedding block: m_ji = act(W [rbf ; h_j ; h_i])
    m = act(jnp.concatenate(
        [rbf @ params["w_rbf0"], h_node[g.edge_src], h_node[g.edge_dst]],
        axis=-1) @ params["w_msg0"])                           # (E, h)
    m = m * g.edge_mask[:, None].astype(dt)
    m = constrain(m, "edges", None)

    def block(m, bp):
        # directional message over triplets (k->j->i)
        x_kj = act(m @ bp["w_kj"])[g.tri_kj]                   # (T, h)
        sw = sbf @ bp["w_sbf"]                                 # (T, nb)
        tri = jnp.einsum("tb,tl,ibl->ti", sw, x_kj, bp["bilinear"])
        tri = constrain(tri, "triplets", None)
        agg = segment_sum(tri * g.tri_mask[:, None].astype(dt),
                          g.tri_ji, m.shape[0])                # (E, h)
        m_new = act(m @ bp["w_ji"]) + agg
        m_new = act(m_new @ bp["w_out1"]) * g.edge_mask[:, None].astype(dt)

        # per-block output: edges -> nodes, gated by rbf
        gate = rbf @ bp["w_rbf"]
        contrib = segment_sum(m_new * gate, g.edge_dst, n)
        node_out = act(contrib @ bp["w_node"]) @ bp["w_out2"]
        return m_new, node_out

    node_outs = []
    for i in range(cfg.n_blocks):                      # <= 6 blocks: unrolled
        bp = jax.tree.map(lambda x: x[i], params["blocks"])
        m, node_out = block(m, bp)
        node_outs.append(node_out)
    node_repr = jnp.sum(jnp.stack(node_outs), axis=0)          # (N, h)
    node_repr = constrain(node_repr, "nodes", None)

    out = act(node_repr @ params["w_readout1"]) @ params["w_readout2"]
    per_graph = segment_sum(out, g.node_graph, g.n_graphs)
    return per_graph


def train_step_loss(params: dict, cfg: GNNConfig, g: GraphBatch,
                    targets: Array) -> Array:
    """MSE regression over per-graph targets."""
    pred = forward(params, cfg, g)
    return jnp.mean((pred.astype(jnp.float32)
                     - targets.astype(jnp.float32)) ** 2)
