"""RecSys architectures: DeepFM, xDeepFM (CIN), AutoInt, MIND.

The shared substrate is the huge sparse embedding layer: one stacked table
(total_rows, D) with per-field offsets (DLRM layout), row-sharded over the
``model`` mesh axis.  JAX has no native EmbeddingBag — lookup is
``jnp.take`` + mean over the multi-hot axis (the masked-mean formulation of
segment_sum for fixed bag width), which IS the system's embedding-bag op;
the Pallas kernel in repro.kernels.embedding_bag is the fused TPU version.

Fork-join view (DESIGN.md §5): a row-sharded lookup forks one query across
table shards and joins on the gather — precisely the paper's index-server
pattern, with Zipf-skewed key popularity playing the posting-list role.

Interactions:
  * DeepFM  — FM pairwise term via the 0.5*((sum v)^2 - sum v^2) identity
              + deep MLP (arXiv:1703.04247)
  * xDeepFM — Compressed Interaction Network, explicit vector-wise crosses
              (arXiv:1803.05170)
  * AutoInt — multi-head self-attention over field embeddings
              (arXiv:1810.11921)
  * MIND    — multi-interest capsules with dynamic routing over the user
              behavior sequence + label-aware attention (arXiv:1904.08030)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.launch.sharding import constrain
from repro.models.layers import _dense_init

Array = jax.Array


# -------------------------------------------------------------------------
# Embedding substrate
# -------------------------------------------------------------------------

def field_offsets(cfg: RecsysConfig) -> np.ndarray:
    return np.concatenate([[0], np.cumsum(cfg.field_vocabs)]).astype(np.int64)


def padded_rows(n: int, multiple: int = 2048) -> int:
    """Round table rows up for even row-sharding over the model axis."""
    return n + (-n) % multiple


def init_embedding(key, cfg: RecsysConfig, dim: Optional[int] = None) -> dict:
    dt = jnp.dtype(cfg.dtype)
    dim = dim or cfg.embed_dim
    rows = padded_rows(int(sum(cfg.field_vocabs)))
    k1, k2 = jax.random.split(key)
    return {
        "table": _dense_init(k1, (rows, dim), dt, scale=0.01),
        "wide": _dense_init(k2, (rows, 1), dt, scale=0.01),
    }


def embedding_bag(table: Array, ids: Array, mask: Array) -> Array:
    """(rows, D) x (B, F, M) multi-hot ids -> (B, F, D) mean-pooled.

    ids are already globalized (field offset added).  Masked mean over the
    bag axis M — torch.nn.EmbeddingBag(mode='mean') semantics.
    """
    table = constrain(table, "rows", None)
    vecs = jnp.take(table, ids, axis=0)                 # (B, F, M, D)
    m = mask[..., None].astype(vecs.dtype)
    s = jnp.sum(vecs * m, axis=2)
    return s / jnp.maximum(jnp.sum(m, axis=2), 1.0)


def _mlp_init(key, sizes, dt):
    ws = []
    for i, (a, b) in enumerate(zip(sizes[:-1], sizes[1:])):
        k = jax.random.fold_in(key, i)
        ws.append({"w": _dense_init(k, (a, b), dt),
                   "b": jnp.zeros((b,), dt)})
    return ws


def _mlp_apply(ws, x, final_act: bool = False):
    for i, layer in enumerate(ws):
        x = x @ layer["w"] + layer["b"]
        if i < len(ws) - 1 or final_act:
            x = jax.nn.relu(x)
        x = constrain(x, "batch", "mlp") if x.ndim == 2 else x
    return x


# -------------------------------------------------------------------------
# DeepFM
# -------------------------------------------------------------------------

def init_deepfm(key, cfg: RecsysConfig) -> dict:
    k1, k2 = jax.random.split(key)
    dt = jnp.dtype(cfg.dtype)
    sizes = (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,)
    return {"embedding": init_embedding(k1, cfg),
            "mlp": _mlp_init(k2, sizes, dt)}


def fm_interaction(v: Array) -> Array:
    """(B, F, D) -> (B,) second-order FM term."""
    s = jnp.sum(v, axis=1)
    sq = jnp.sum(v * v, axis=1)
    return 0.5 * jnp.sum(s * s - sq, axis=-1)


def deepfm_logits(params, cfg: RecsysConfig, ids: Array, mask: Array
                  ) -> Array:
    v = embedding_bag(params["embedding"]["table"], ids, mask)
    v = constrain(v, "batch", "fields", "embed")
    wide = jnp.sum(
        embedding_bag(params["embedding"]["wide"], ids, mask), axis=(1, 2))
    fm = fm_interaction(v)
    deep = _mlp_apply(params["mlp"], v.reshape(v.shape[0], -1))[:, 0]
    return (wide + fm + deep).astype(jnp.float32)


# -------------------------------------------------------------------------
# xDeepFM (CIN)
# -------------------------------------------------------------------------

def init_xdeepfm(key, cfg: RecsysConfig) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    cin = []
    h_prev = cfg.n_sparse
    for i, h in enumerate(cfg.cin_layers):
        cin.append(_dense_init(jax.random.fold_in(k3, i),
                               (h_prev * cfg.n_sparse, h), dt))
        h_prev = h
    sizes = (cfg.n_sparse * cfg.embed_dim,) + cfg.mlp + (1,)
    return {
        "embedding": init_embedding(k1, cfg),
        "mlp": _mlp_init(k2, sizes, dt),
        "cin": cin,
        "cin_out": _dense_init(k4, (sum(cfg.cin_layers), 1), dt),
    }


def cin_interaction(params, cfg: RecsysConfig, v: Array) -> Array:
    """Compressed Interaction Network: (B, F, D) -> (B,)."""
    x0 = v                                             # (B, m, D)
    xk = v
    pooled = []
    for w in params["cin"]:
        outer = jnp.einsum("bhd,bmd->bhmd", xk, x0)    # (B, Hk, m, D)
        b, hk, m, d = outer.shape
        xk = jnp.einsum("bhmd,hmo->bod",
                        outer, w.reshape(hk, m, -1))   # (B, Hk+1, D)
        pooled.append(jnp.sum(xk, axis=-1))            # (B, Hk+1)
    p = jnp.concatenate(pooled, axis=-1)
    return (p @ params["cin_out"])[:, 0]


def xdeepfm_logits(params, cfg: RecsysConfig, ids: Array, mask: Array
                   ) -> Array:
    v = embedding_bag(params["embedding"]["table"], ids, mask)
    v = constrain(v, "batch", "fields", "embed")
    wide = jnp.sum(
        embedding_bag(params["embedding"]["wide"], ids, mask), axis=(1, 2))
    cin = cin_interaction(params, cfg, v)
    deep = _mlp_apply(params["mlp"], v.reshape(v.shape[0], -1))[:, 0]
    return (wide + cin + deep).astype(jnp.float32)


# -------------------------------------------------------------------------
# AutoInt
# -------------------------------------------------------------------------

def init_autoint(key, cfg: RecsysConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    d_attn_total = cfg.n_heads * cfg.d_attn
    layers = []
    d_in = cfg.embed_dim
    for i in range(cfg.n_attn_layers):
        k = jax.random.fold_in(k2, i)
        kq, kk, kv, kr = jax.random.split(k, 4)
        layers.append({
            "wq": _dense_init(kq, (d_in, d_attn_total), dt),
            "wk": _dense_init(kk, (d_in, d_attn_total), dt),
            "wv": _dense_init(kv, (d_in, d_attn_total), dt),
            "w_res": _dense_init(kr, (d_in, d_attn_total), dt),
        })
        d_in = d_attn_total
    return {
        "embedding": init_embedding(k1, cfg),
        "layers": layers,
        "out": _dense_init(k3, (cfg.n_sparse * d_in, 1), dt),
    }


def autoint_logits(params, cfg: RecsysConfig, ids: Array, mask: Array
                   ) -> Array:
    v = embedding_bag(params["embedding"]["table"], ids, mask)
    x = constrain(v, "batch", "fields", "embed")       # (B, F, D)
    for lp in params["layers"]:
        b, f, d = x.shape
        q = (x @ lp["wq"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        k = (x @ lp["wk"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        vv = (x @ lp["wv"]).reshape(b, f, cfg.n_heads, cfg.d_attn)
        att = jax.nn.softmax(
            jnp.einsum("bfhd,bghd->bhfg", q, k) / np.sqrt(cfg.d_attn), -1)
        o = jnp.einsum("bhfg,bghd->bfhd", att, vv).reshape(b, f, -1)
        x = jax.nn.relu(o + x @ lp["w_res"])
    wide = jnp.sum(
        embedding_bag(params["embedding"]["wide"], ids, mask), axis=(1, 2))
    return (wide + (x.reshape(x.shape[0], -1) @ params["out"])[:, 0]
            ).astype(jnp.float32)


# -------------------------------------------------------------------------
# MIND (multi-interest capsules)
# -------------------------------------------------------------------------

def init_mind(key, cfg: RecsysConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "item_table": _dense_init(
            k1, (padded_rows(cfg.item_vocab), cfg.embed_dim), dt,
            scale=0.01),
        "bilinear_s": _dense_init(k2, (cfg.embed_dim, cfg.embed_dim), dt),
        "out_mlp": _mlp_init(k3, (cfg.embed_dim, cfg.embed_dim * 2,
                                  cfg.embed_dim), dt),
    }


def _squash(x: Array, axis: int = -1) -> Array:
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_user_interests(params, cfg: RecsysConfig, hist_ids: Array,
                        hist_mask: Array) -> Array:
    """Behavior history (B, H) -> interest capsules (B, K, D).

    B2I dynamic routing: logits b (B, K, H) updated over capsule_iters;
    routing weights do NOT receive gradients through the iterations
    (stop_gradient, per the paper's routing).
    """
    table = constrain(params["item_table"], "rows", None)
    e = jnp.take(table, hist_ids, axis=0)              # (B, H, D)
    e = e * hist_mask[..., None].astype(e.dtype)
    es = e @ params["bilinear_s"]                      # shared bilinear map
    b_init = jnp.zeros((e.shape[0], cfg.n_interests, e.shape[1]),
                       jnp.float32)

    def routing_iter(b_logits):
        w = jax.nn.softmax(b_logits, axis=1)           # over capsules
        w = w * hist_mask[:, None, :]
        z = jnp.einsum("bkh,bhd->bkd", w.astype(es.dtype),
                       jax.lax.stop_gradient(es))
        u = _squash(z)
        delta = jnp.einsum("bkd,bhd->bkh", u,
                           jax.lax.stop_gradient(es)).astype(jnp.float32)
        return b_logits + delta

    b_final = b_init
    for _ in range(cfg.capsule_iters):                 # 3 iters: unrolled
        b_final = routing_iter(b_final)
    w = jax.nn.softmax(b_final, axis=1) * hist_mask[:, None, :]
    u = _squash(jnp.einsum("bkh,bhd->bkd", w.astype(es.dtype), es))
    u = _mlp_apply(params["out_mlp"], u)
    return constrain(u, "batch", None, "embed")        # (B, K, D)


def _label_aware_logits(u: Array, cand: Array) -> Array:
    """Label-aware attention score of interests u (B,K,D) against
    candidates cand (B,K-broadcastable,C,D) or (C,D) WITHOUT materializing
    a (B,C,D) attended-user tensor: since the final score is
    <att-weighted u, t>, it equals sum_k att[b,k,c] * <u[b,k], t[c]>."""
    scores = jnp.einsum("bkd,cd->bkc", u, cand).astype(jnp.float32)
    att = jax.nn.softmax(scores ** 2, axis=1)          # pow-2, per paper
    return jnp.sum(att * scores, axis=1)               # (B, C)


def mind_train_logits(params, cfg: RecsysConfig, hist_ids: Array,
                      hist_mask: Array, target_ids: Array,
                      neg_ids: Optional[Array] = None) -> Array:
    """Sampled-softmax logits: column 0 = positive, rest = shared sampled
    negatives (B, 1 + N).  With neg_ids None, falls back to in-batch
    negatives (B, B) with the diagonal positive."""
    u = mind_user_interests(params, cfg, hist_ids, hist_mask)   # (B, K, D)
    if neg_ids is None:
        t = jnp.take(params["item_table"], target_ids, axis=0)
        logits = _label_aware_logits(u, t)
        return constrain(logits, "batch", "cand")
    pos = jnp.take(params["item_table"], target_ids, axis=0)   # (B, D)
    neg = jnp.take(params["item_table"], neg_ids, axis=0)      # (N, D)
    pos_scores = jnp.einsum("bkd,bd->bk", u, pos).astype(jnp.float32)
    pos_att = jax.nn.softmax(pos_scores ** 2, axis=1)
    pos_logit = jnp.sum(pos_att * pos_scores, axis=1)[:, None]
    neg_logits = _label_aware_logits(u, neg)                   # (B, N)
    out = jnp.concatenate([pos_logit, neg_logits], axis=1)
    return constrain(out, "batch", "cand")


def mind_retrieve(params, cfg: RecsysConfig, hist_ids: Array,
                  hist_mask: Array, cand_ids: Array, k: int = 100
                  ) -> tuple[Array, Array]:
    """Score one user's interests against a candidate set; top-k.

    cand_ids (C,) with C up to 10^6 — a batched matmul over the sharded
    candidate axis, NOT a loop (retrieval_cand cell).
    """
    u = mind_user_interests(params, cfg, hist_ids, hist_mask)   # (B, K, D)
    cand = jnp.take(params["item_table"], cand_ids, axis=0)     # (C, D)
    cand = constrain(cand, "cand", None)
    scores = jnp.einsum("bkd,cd->bkc", u, cand)
    best = jnp.max(scores, axis=1).astype(jnp.float32)          # (B, C)
    best = constrain(best, "batch", "cand")
    return jax.lax.top_k(best, k)


# -------------------------------------------------------------------------
# Shared losses
# -------------------------------------------------------------------------

def ctr_loss(logits: Array, labels: Array) -> Array:
    """Binary cross-entropy with logits."""
    z = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(z))))


def sampled_softmax_loss(logits: Array, *, inbatch: bool = True) -> Array:
    """inbatch=True: (B,B) logits, diagonal positive.  Otherwise (B,1+N)
    sampled-negative logits with the positive in column 0."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    if inbatch:
        return -jnp.mean(jnp.diagonal(logp))
    return -jnp.mean(logp[:, 0])
