"""GNN substrate: padded graph batches + segment-op message passing.

JAX has no native sparse message passing — per the kernel taxonomy, the
scatter/gather over an edge index IS part of the system.  Graphs are
carried in fixed-size (padded, masked) buffers so every step jits; the
neighbor sampler (data.graph_sampler) produces these for minibatch
training on large graphs.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["GraphBatch", "segment_sum", "segment_mean", "segment_softmax"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Padded graph(s) with triplet structure for directional MP.

    Edge e: src[e] -> dst[e] with length dist[e].
    Triplet t: (k -> j) then (j -> i); tri_kj/tri_ji are EDGE ids, and
    angle[t] is the angle between the two edge directions at j.
    node_graph maps nodes to graph ids for batched readout.
    """

    node_feat: Array     # (N, F) float — or (N,) int atom numbers
    edge_src: Array      # (E,) int32
    edge_dst: Array      # (E,) int32
    edge_dist: Array     # (E,) float32
    edge_mask: Array     # (E,) bool
    tri_kj: Array        # (T,) int32 — edge id of (k->j)
    tri_ji: Array        # (T,) int32 — edge id of (j->i)
    tri_angle: Array     # (T,) float32
    tri_mask: Array      # (T,) bool
    node_graph: Array    # (N,) int32
    n_graphs: int = dataclasses.field(metadata=dict(static=True), default=1)

    @property
    def n_nodes(self) -> int:
        return self.node_feat.shape[0]

    @property
    def n_edges(self) -> int:
        return self.edge_src.shape[0]


def segment_sum(data: Array, segment_ids: Array, num_segments: int) -> Array:
    return jax.ops.segment_sum(data, segment_ids, num_segments)


def segment_mean(data: Array, segment_ids: Array, num_segments: int) -> Array:
    s = jax.ops.segment_sum(data, segment_ids, num_segments)
    ones = jnp.ones(data.shape[:1], data.dtype)
    n = jax.ops.segment_sum(ones, segment_ids, num_segments)
    return s / jnp.maximum(n, 1.0)[..., None]


def segment_softmax(logits: Array, segment_ids: Array, num_segments: int
                    ) -> Array:
    m = jax.ops.segment_max(logits, segment_ids, num_segments)
    z = jnp.exp(logits - m[segment_ids])
    denom = jax.ops.segment_sum(z, segment_ids, num_segments)
    return z / jnp.maximum(denom[segment_ids], 1e-30)
