"""Transformer building blocks: RMSNorm, RoPE, GQA attention, SwiGLU.

Pure functions over explicit param pytrees (no framework): ``init_*``
builds params, ``apply``-style functions consume them.  All activations
carry logical sharding constraints (repro.launch.sharding) so the same
code runs on CPU tests and on the 512-chip dry-run meshes.

Attention comes in three flavors matching the assigned shapes:
  * full causal (train_4k) — plain einsum softmax, scores (B,H,S,S),
  * chunked/blockwise causal (prefill_32k) — lax.scan over KV blocks with
    running max/sum (flash-style in pure JAX; no S^2 tensor materialized),
  * decode (decode_32k / long_500k) — one query step against a KV cache
    whose sequence axis may be sharded across the mesh; the softmax
    reductions over the sharded axis lower to all-reduces.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.launch.sharding import constrain

Array = jax.Array


# -------------------------------------------------------------------------
# init helpers
# -------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# -------------------------------------------------------------------------
# RMSNorm
# -------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


# -------------------------------------------------------------------------
# RoPE
# -------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float) -> Array:
    return theta ** (-jnp.arange(0, d_head, 2, jnp.float32) / d_head)


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs   # (...,S,D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------------
# GQA attention
# -------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool
    rope_theta: float


def init_attention(key, dims: AttnDims, dtype) -> dict:
    kq, kk, kv, ko, kn1, kn2 = jax.random.split(key, 6)
    d, h, kvh, dh = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.d_head
    p = {
        "wq": _dense_init(kq, (d, h * dh), dtype),
        "wk": _dense_init(kk, (d, kvh * dh), dtype),
        "wv": _dense_init(kv, (d, kvh * dh), dtype),
        "wo": _dense_init(ko, (h * dh, d), dtype),
    }
    if dims.qk_norm:
        p["q_norm"] = init_rmsnorm(dh, dtype)
        p["k_norm"] = init_rmsnorm(dh, dtype)
    return p


def _project_qkv(params, dims: AttnDims, x: Array, positions: Array):
    b, s, _ = x.shape
    h, kvh, dh = dims.n_heads, dims.n_kv_heads, dims.d_head
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, kvh, dh)
    v = (x @ params["wv"]).reshape(b, s, kvh, dh)
    if dims.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    q = apply_rope(q, positions, dims.rope_theta)
    k = apply_rope(k, positions, dims.rope_theta)
    q = constrain(q, "batch", "seq_q", "heads", None)
    k = constrain(k, "batch", "seq_q", "kv_heads", None)
    v = constrain(v, "batch", "seq_q", "kv_heads", None)
    return q, k, v


def _gqa_scores(q: Array, k: Array, groups: int) -> Array:
    """(B,Sq,H,D) x (B,Sk,KV,D) -> (B,KV,G,Sq,Sk), H = KV*G."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, sq, kvh, groups, dh)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * (dh ** -0.5)


def _gqa_output(probs: Array, v: Array) -> Array:
    """(B,KV,G,Sq,Sk) x (B,Sk,KV,D) -> (B,Sq,H,D)."""
    b, kvh, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, kvh * g, v.shape[-1])


def _chunk_step(qi, kj, vj, m, l, acc, qi_idx, kj_idx, chunk, g, dtype):
    """One flash block: update running (max, sum, acc) with block (qi, kj)."""
    sc = _gqa_scores(qi, kj, g).astype(jnp.float32)      # (B,KV,G,C,C)
    if kj_idx is not None:                               # causal masking
        qpos = qi_idx * chunk + jnp.arange(chunk)
        kpos = kj_idx * chunk + jnp.arange(chunk)
        causal = qpos[:, None] >= kpos[None, :]
        sc = jnp.where(causal, sc, -jnp.inf)
    m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
    alpha = jnp.exp(m - m_new)
    pr = jnp.exp(sc - m_new[..., None])
    l_new = l * alpha + jnp.sum(pr, axis=-1)
    acc_new = (acc * alpha[..., None].astype(dtype)
               + jnp.einsum("bkgqs,bskd->bkgqd", pr.astype(dtype), vj))
    return m_new, l_new, acc_new


def _chunked_causal_attention(qc, kc, vc, dims: AttnDims, chunk: int,
                              unroll: bool, dtype) -> Array:
    """qc/kc/vc: (B, n_chunks, C, H|KV, D) -> out (B, S, H*D).

    The flash-attention recurrence in pure JAX: no S x S tensor exists in
    the HLO.  unroll=True emits static Python loops *skipping acausal
    blocks entirely* (the dry-run path — accurate cost analysis, ~half the
    block-pairs); unroll=False uses lax.scan/map (compact HLO for runtime).
    """
    b, n_chunks, _, _, dh = qc.shape
    g = dims.n_heads // dims.n_kv_heads
    kvh = dims.n_kv_heads

    def init(qi_shape_b=b):
        m0 = jnp.full((b, kvh, g, chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, g, chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, g, chunk, dh), dtype)
        return m0, l0, a0

    def finalize(m, l, acc):
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(dtype)
        return jnp.moveaxis(out, 3, 1).reshape(b, chunk, -1)

    if unroll:
        # remat each block-pair: backward recomputes the (bq x bk) probs
        # per pair instead of holding every pair's fp32 tile live (cuts
        # the attention live-set by ~n_chunks).
        step = jax.checkpoint(functools.partial(
            _chunk_step, chunk=chunk, g=g, dtype=dtype),
            static_argnums=(6, 7))
        outs = []
        for qi_idx in range(n_chunks):
            qi = qc[:, qi_idx]
            m, l, acc = init()
            for kj_idx in range(qi_idx + 1):     # causal: skip kj > qi
                m, l, acc = step(qi, kc[:, kj_idx], vc[:, kj_idx],
                                 m, l, acc, qi_idx, kj_idx)
            outs.append(finalize(m, l, acc))
        return jnp.concatenate(outs, axis=1)

    def outer(qi_idx):
        qi = qc[:, qi_idx]

        def inner(carry, kj_idx):
            m, l, acc = carry
            return _chunk_step(qi, kc[:, kj_idx], vc[:, kj_idx],
                               m, l, acc, qi_idx, kj_idx, chunk, g,
                               dtype), None

        (m, l, acc), _ = jax.lax.scan(inner, init(), jnp.arange(n_chunks))
        return finalize(m, l, acc)

    outs = jax.lax.map(outer, jnp.arange(n_chunks))      # (N,B,C,HD)
    return jnp.moveaxis(outs, 0, 1).reshape(b, n_chunks * chunk, -1)


def attention_train(params, dims: AttnDims, x: Array, *, chunk: int = 0,
                    unroll: bool = False) -> Array:
    """Causal self-attention for training.

    chunk == 0 (or chunk >= S): reference full-softmax path (small models,
    oracle for tests).  Otherwise the blockwise flash-style path — the
    production configuration for train_4k and up.
    """
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, dims, x, positions)
    g = dims.n_heads // dims.n_kv_heads

    if chunk and chunk < s:
        assert s % chunk == 0, (s, chunk)
        n = s // chunk
        qc = q.reshape(b, n, chunk, dims.n_heads, dims.d_head)
        kc = k.reshape(b, n, chunk, dims.n_kv_heads, dims.d_head)
        vc = v.reshape(b, n, chunk, dims.n_kv_heads, dims.d_head)
        out = _chunked_causal_attention(qc, kc, vc, dims, chunk, unroll,
                                        x.dtype)
    else:
        scores = _gqa_scores(q, k, g).astype(jnp.float32)
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask, scores, -jnp.inf)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = _gqa_output(probs, v).reshape(b, s, -1)
    out = out @ params["wo"]
    return constrain(out, "batch", "seq", "embed")


def attention_prefill_chunked(params, dims: AttnDims, x: Array,
                              chunk: int = 2048, unroll: bool = False
                              ) -> tuple[Array, Array, Array]:
    """Blockwise causal attention returning (out, K, V) to seed the cache."""
    b, s, _ = x.shape
    assert s % chunk == 0, (s, chunk)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, dims, x, positions)
    n_chunks = s // chunk
    dh = dims.d_head

    qc = q.reshape(b, n_chunks, chunk, dims.n_heads, dh)
    kc = k.reshape(b, n_chunks, chunk, dims.n_kv_heads, dh)
    vc = v.reshape(b, n_chunks, chunk, dims.n_kv_heads, dh)
    out = _chunked_causal_attention(qc, kc, vc, dims, chunk, unroll,
                                    x.dtype)
    out = out @ params["wo"]
    return constrain(out, "batch", "seq", "embed"), k, v


def attention_decode(params, dims: AttnDims, x: Array,
                     k_cache: Array, v_cache: Array,
                     cache_len: Array) -> tuple[Array, Array, Array]:
    """One decode step: x (B,1,D) against cache (B,S,KV,Dh).

    The cache sequence axis may be sharded ("kv_seq"); max/sum reductions
    over it become all-reduces under GSPMD — the fork-join join of the
    serving model.
    """
    b = x.shape[0]
    positions = jnp.full((b, 1), cache_len, jnp.int32)
    q, k_new, v_new = _project_qkv(params, dims, x, positions)

    # write the new KV at cache_len (static ring-buffer style update)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), cache_len, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), cache_len, axis=1)
    k_cache = constrain(k_cache, "kv_batch", "kv_seq", "kv_heads", None)
    v_cache = constrain(v_cache, "kv_batch", "kv_seq", "kv_heads", None)

    g = dims.n_heads // dims.n_kv_heads
    scores = _gqa_scores(q, k_cache, g).astype(jnp.float32)  # (B,KV,G,1,S)
    valid = jnp.arange(k_cache.shape[1]) <= cache_len
    scores = jnp.where(valid[None, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_output(probs, v_cache).reshape(b, 1, -1)
    out = out @ params["wo"]
    return constrain(out, "batch", None, "embed"), k_cache, v_cache


# -------------------------------------------------------------------------
# SwiGLU MLP
# -------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d_model, d_ff), dtype),
        "w_up": _dense_init(k2, (d_model, d_ff), dtype),
        "w_down": _dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_swiglu(params: dict, x: Array) -> Array:
    h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    h = constrain(h, "batch", "seq_q", "ffn")
    out = h @ params["w_down"]
    return constrain(out, "batch", "seq", "embed")
