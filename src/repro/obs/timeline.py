"""Streaming telemetry timelines for the fork-join simulator.

The paper's methodology is *measurement*: per-server busy times, the
broker's share, the Sec 3.4 service-time imbalance.  The streaming
engine of `repro.core.simulator` emits end-of-run aggregates only, so a
saturating replica, a JSQ-vs-round-robin gap, or a flash crowd blowing
the SLO all vanish into one mean.  This module adds the time axis back
— without giving up the streaming-memory guarantee.

:class:`TelemetrySpec` is an opt-in *static* knob on
``simulate_fork_join(_batch)`` / ``sweep_simulated``: it is a plain
frozen dataclass (hashable, NOT a pytree) so it rides the jit cache key,
and ``telemetry=None`` (the default) compiles to the bit-identical
pre-telemetry program — the scan carry only grows the per-bin
accumulators when a spec is present.

:class:`Timeline` is what comes back, on ``SimResult.timeline``: per
time-bin counts and busy-seconds accumulated *inside* the existing
``lax.scan`` carry (the PR 2 streaming-stats pattern — O(n_bins) state,
never O(horizon)).  Queries are binned by ARRIVAL time on the absolute
simulation clock; warmup queries are included by design (the whole point
is observing transients).  Derived views are the operational-analysis
quantities, which obey exact laws the tests self-check:

    utilization  U = busy / bin_width      (and U = X * S, Eq 3)
    queue depth  L = resp_sum / bin_width  (Little: L = lambda * W)

:func:`timeline_from_trace` bins a measured/tapped
`repro.calibrate.measure.TraceRecord` with the same conventions, so
measured engines and simulated ones render on one dashboard.  (It
duck-types the record — arrays in, arrays out — so this module never
imports the calibrate package and stays import-cycle-free below the
simulator.)
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = ["TelemetrySpec", "Timeline", "timeline_from_trace",
           "DEFAULT_TIMELINE_BINS"]

DEFAULT_TIMELINE_BINS = 64


@dataclasses.dataclass(frozen=True)
class TelemetrySpec:
    """Static description of the timeline a simulation should record.

    n_bins: time bins over the horizon.  State and output are O(n_bins).
    horizon_seconds: wall-clock span covered by the bins.  Default None
        derives it per scenario as ``n_queries / mean_rate`` (the
        expected makespan); arrivals past the horizon clamp into the
        last bin.
    slo_seconds: response-time objective for the per-bin violation
        count.  None disables the SLO tally (the field stays zero).

    Plain frozen dataclass on purpose: instances are hashable and feed
    ``jax.jit`` static arguments directly.
    """

    n_bins: int = DEFAULT_TIMELINE_BINS
    horizon_seconds: Optional[float] = None
    slo_seconds: Optional[float] = None

    def __post_init__(self):
        if self.n_bins < 1:
            raise ValueError(f"need at least one bin; got {self.n_bins}")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Timeline:
    """Per-time-bin telemetry of a fork-join run (a pytree of arrays).

    Every field carries the run's scenario shape ``(...)`` in front,
    then the bin axis B; replica-resolved fields add ``r`` (and ``p``).
    Queries land in the bin of their ARRIVAL time; busy-seconds land in
    the bin of the query that generated them (exact conservation: the
    busy totals equal the summed service times, see tests).

    bin_seconds:   (...,)          width of one bin
    count:         (..., B)        arrivals per bin (warmup included)
    resp_sum:      (..., B)        summed response seconds per bin
    busy_broker:   (..., B, r)     broker busy-seconds per replica
    busy_server:   (..., B, r, p)  index-server busy-seconds
    replica_count: (..., B, r)     queries routed to each replica
    hit_count:     (..., B)        result-cache hits (zeros, no cache)
    slo_count:     (..., B)        responses above the SLO (zeros if
                                   the spec carried no slo_seconds)
    active_sum:    (..., B)        summed active-replica counts of each
                                   bin's arrivals (autoscaled runs only;
                                   None otherwise — like
                                   ``SimResult.timeline`` itself, a None
                                   field contributes no pytree leaves)
    up_sum:        (..., B)        summed up-replica counts of each bin's
                                   arrivals (fault-injected runs only)
    spill_sum:     (..., B)        arrivals failed over to a non-primary
                                   replica, per bin (fault + r > 1 only)
    degraded_sum:  (..., B)        partial-quorum (degraded) responses,
                                   per arrival bin (fault + broker
                                   timeout only)
    """

    bin_seconds: Array
    count: Array
    resp_sum: Array
    busy_broker: Array
    busy_server: Array
    replica_count: Array
    hit_count: Array
    slo_count: Array
    active_sum: Optional[Array] = None
    up_sum: Optional[Array] = None
    spill_sum: Optional[Array] = None
    degraded_sum: Optional[Array] = None

    @property
    def n_bins(self) -> int:
        return self.count.shape[-1]

    @property
    def _n(self) -> Array:
        return jnp.maximum(self.count, 1.0)

    @property
    def throughput(self) -> Array:
        """(..., B) arrivals per second — operational X per bin."""
        return self.count / self.bin_seconds[..., None]

    @property
    def utilization(self) -> Array:
        """(..., B, r, p) server utilization U = busy / bin width."""
        return self.busy_server / self.bin_seconds[..., None, None, None]

    @property
    def broker_utilization(self) -> Array:
        """(..., B, r) broker utilization per replica."""
        return self.busy_broker / self.bin_seconds[..., None, None]

    @property
    def mean_response(self) -> Array:
        """(..., B) mean response of the queries arriving in each bin."""
        return self.resp_sum / self._n

    @property
    def queue_depth(self) -> Array:
        """(..., B) time-average population by Little's law.

        L = lambda * W = (count / bin) * (resp_sum / count)
          = resp_sum / bin_seconds — response-seconds are
        population-seconds, attributed to the arrival bin.
        """
        return self.resp_sum / self.bin_seconds[..., None]

    @property
    def hit_fraction(self) -> Array:
        """(..., B) result-cache hit share of each bin's arrivals."""
        return self.hit_count / self._n

    @property
    def slo_violation_fraction(self) -> Array:
        """(..., B) share of each bin's arrivals breaking the SLO."""
        return self.slo_count / self._n

    @property
    def imbalance_share(self) -> Array:
        """(..., B) largest single-replica share of each bin's arrivals.

        1/r is perfect balance; 1.0 means one replica took everything —
        the routing-quality signal that separates JSQ from round-robin
        under bursty load.
        """
        return jnp.max(self.replica_count, axis=-1) / self._n

    @property
    def active_replicas(self) -> Array:
        """(..., B) mean active replica count over each bin's arrivals.

        The autoscaler trajectory: ``active_sum`` is the per-arrival
        active count summed per bin, so dividing by the bin's arrivals
        gives the arrival-weighted mean fleet size.  Only present on
        autoscaled runs (``ClusterSpec(autoscale=...)``).
        """
        if self.active_sum is None:
            raise ValueError("no active-replica channel: this timeline "
                             "came from a run without autoscale")
        return self.active_sum / self._n

    @property
    def up_replicas(self) -> Array:
        """(..., B) mean up-replica count over each bin's arrivals.

        The availability trajectory: outage windows show up as dips
        below the provisioned r.  Only present on fault-injected runs
        (``ClusterSpec(fault=...)``).
        """
        if self.up_sum is None:
            raise ValueError("no up-replica channel: this timeline came "
                             "from a run without fault injection")
        return self.up_sum / self._n

    @property
    def spill_fraction(self) -> Array:
        """(..., B) share of each bin's arrivals failed over.

        A spilled query reached a *surviving* replica instead of its
        primary — load concentration on survivors during an outage.
        Only present on fault-injected runs with r > 1.
        """
        if self.spill_sum is None:
            raise ValueError("no spill channel: this timeline came from "
                             "a run without fault injection (or r == 1)")
        return self.spill_sum / self._n

    @property
    def degraded_fraction(self) -> Array:
        """(..., B) share of each bin's arrivals answered degraded.

        Degraded = the broker timed out and returned a partial-quorum
        (k-of-p) result.  Only present on fault-injected runs with a
        ``broker_timeout_seconds``.
        """
        if self.degraded_sum is None:
            raise ValueError("no degraded channel: this timeline came "
                             "from a run without a broker timeout")
        return self.degraded_sum / self._n

    @property
    def mean_service_per_query(self) -> Array:
        """(..., B) busy-seconds per arrival, summed over servers.

        The S in the per-bin operational check U = X * S: utilization
        summed over a replica's servers equals throughput times this.
        """
        return (jnp.sum(self.busy_server, axis=(-2, -1))
                + jnp.sum(self.busy_broker, axis=-1)) / self._n


def timeline_from_trace(
    arrival: Array,
    response: Array,
    spec: TelemetrySpec,
    *,
    broker_busy: Optional[Array] = None,
    server_busy: Optional[Array] = None,
    server_hit: Optional[Array] = None,
    assign: Optional[Array] = None,
    r: int = 1,
) -> Timeline:
    """Bin a materialized sample path into a :class:`Timeline`.

    arrival/response: (n,) per-query seconds; broker_busy: (n,) broker
    service seconds; server_busy: (n, p) per-server service seconds;
    server_hit: (n,) or (n, p) cache-hit indicator; assign: (n,) replica
    of each query (defaults to replica 0).  Binning and conservation
    conventions match the streaming engine exactly: bin by arrival time,
    clamp past-horizon arrivals into the last bin, include everything.

    The arguments duck-type `repro.calibrate.measure.TraceRecord` — see
    ``TraceRecord.to_timeline`` for the one-call bridge.
    """
    arrival = jnp.asarray(arrival)
    response = jnp.asarray(response)
    dtype = response.dtype
    n = arrival.shape[0]
    B = spec.n_bins
    horizon = (spec.horizon_seconds if spec.horizon_seconds is not None
               else float(jnp.max(arrival)) * (1.0 + 1e-6) + 1e-30)
    bin_w = jnp.asarray(horizon / B, dtype)
    bins = jnp.clip((arrival / bin_w).astype(jnp.int32), 0, B - 1)
    asg = (jnp.zeros((n,), jnp.int32) if assign is None
           else jnp.asarray(assign, jnp.int32))
    one = jnp.ones((n,), dtype)

    count = jnp.zeros((B,), dtype).at[bins].add(one)
    resp_sum = jnp.zeros((B,), dtype).at[bins].add(response)
    replica_count = jnp.zeros((B, r), dtype).at[bins, asg].add(one)
    if broker_busy is not None:
        busy_broker = jnp.zeros((B, r), dtype).at[bins, asg].add(
            jnp.asarray(broker_busy, dtype))
    else:
        busy_broker = jnp.zeros((B, r), dtype)
    if server_busy is not None:
        sb = jnp.asarray(server_busy, dtype)
        p = sb.shape[-1]
        busy_server = jnp.zeros((B, r, p), dtype).at[bins, asg].add(sb)
    else:
        busy_server = jnp.zeros((B, r, 0), dtype)
    if server_hit is not None:
        hit = jnp.asarray(server_hit, dtype)
        if hit.ndim > 1:            # per-(query, server) -> per-query mean
            hit = jnp.mean(hit, axis=-1)
        hit_count = jnp.zeros((B,), dtype).at[bins].add(hit)
    else:
        hit_count = jnp.zeros((B,), dtype)
    if spec.slo_seconds is not None:
        slo_count = jnp.zeros((B,), dtype).at[bins].add(
            (response > spec.slo_seconds).astype(dtype))
    else:
        slo_count = jnp.zeros((B,), dtype)
    return Timeline(bin_seconds=bin_w, count=count, resp_sum=resp_sum,
                    busy_broker=busy_broker, busy_server=busy_server,
                    replica_count=replica_count, hit_count=hit_count,
                    slo_count=slo_count)
