"""Observability for the simulated search engine.

Three layers, one per way of looking at a running cluster:

  * `repro.obs.timeline` — streaming per-time-bin telemetry
    (:class:`TelemetrySpec` / :class:`Timeline`), accumulated inside the
    simulator's scan carry and self-checkable against the operational
    laws U = X*S and L = lambda*W.
  * `repro.obs.trace_export` — span traces: a tapped/simulated sample
    path rendered as Chrome-trace JSON (open in chrome://tracing or
    Perfetto) showing the broker -> fork -> join structure per query.
  * `repro.obs.profile` — XLA-level profiling hooks: compile time,
    `cost_analysis()` flops/bytes and `memory_analysis()` peaks of the
    kernel stack and entry points, as structured `ProfileRecord`s that
    the benchmarks embed in every BENCH_*.json.

``python -m repro.obs.report`` renders all three as a text dashboard.

Import discipline: this package root re-exports ONLY the timeline layer
— `repro.core.simulator` imports it, so anything heavier (trace export
and profiling import calibrate/kernels, which import the simulator)
must stay behind its own submodule import to keep the import graph
acyclic.
"""

from repro.obs.timeline import (  # noqa: F401
    DEFAULT_TIMELINE_BINS,
    TelemetrySpec,
    Timeline,
    timeline_from_trace,
)

__all__ = ["TelemetrySpec", "Timeline", "timeline_from_trace",
           "DEFAULT_TIMELINE_BINS"]
