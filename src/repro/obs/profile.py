"""XLA-level profiling hooks: compile time, flops/bytes, peak memory.

Every benchmark in this repo used to hand-roll its own
``.lower().compile().memory_analysis()`` incantation (and each asserted
a different subset).  This module is the one home for that dance:

    rec = profile_jit(fn, *args, name="streaming")   # ProfileRecord
    rec.to_json()                                    # BENCH_*.json block

:func:`profile_jit` lowers + compiles the function (timing it), reads
``cost_analysis()`` (flops, bytes accessed) and ``memory_analysis()``
(argument/output/temp bytes) off the compiled artifact, then times a few
executions and keeps the median.  Everything is best-effort across jax
versions: older releases return ``[dict]`` from cost_analysis, some
backends omit fields — missing numbers surface as 0.0, never as crashes.

:func:`profile_kernels` profiles the Pallas kernel stack
(`maxplus_scan` / `maxplus_segment_scan`) on a representative shape —
the records `repro.roofline.report.kernel_roofline` places on a
machine roofline, and the profile block CI embeds in BENCH_kernels runs.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["ProfileRecord", "profile_jit", "profile_kernels"]


@dataclasses.dataclass(frozen=True)
class ProfileRecord:
    """One compiled program's compile/run/cost/memory breakdown.

    ``flops``/``bytes_accessed`` are XLA's per-device cost-analysis
    numbers for ONE execution; ``peak_bytes`` is the standard
    argument+output+temp proxy for the live working set.  ``run_s`` is
    the median of the timed executions (0.0 if none were requested).
    """

    name: str
    compile_s: float
    run_s: float
    flops: float
    bytes_accessed: float
    argument_bytes: float
    output_bytes: float
    temp_bytes: float

    @property
    def peak_bytes(self) -> float:
        return self.argument_bytes + self.output_bytes + self.temp_bytes

    @property
    def arithmetic_intensity(self) -> float:
        """flops per byte accessed — the roofline x-coordinate."""
        return self.flops / max(self.bytes_accessed, 1.0)

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "compile_s": self.compile_s,
            "run_s": self.run_s,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "argument_bytes": self.argument_bytes,
            "output_bytes": self.output_bytes,
            "temp_bytes": self.temp_bytes,
            "peak_bytes": self.peak_bytes,
        }

    @classmethod
    def from_json(cls, d: dict) -> "ProfileRecord":
        return cls(**{f.name: d[f.name]
                      for f in dataclasses.fields(cls)})


def _cost_dict(compiled) -> dict:
    try:
        cost = compiled.cost_analysis()
    except Exception:               # backend without cost analysis
        return {}
    if isinstance(cost, list):      # older jax returns [dict]
        cost = cost[0] if cost else {}
    return cost or {}


def _mem_field(compiled, field: str) -> float:
    try:
        return float(getattr(compiled.memory_analysis(), field, 0) or 0)
    except Exception:               # backend without memory analysis
        return 0.0


def profile_jit(fn: Callable, *args: Any, name: Optional[str] = None,
                n_runs: int = 3, **kwargs: Any) -> ProfileRecord:
    """Compile ``fn(*args, **kwargs)`` and record its cost breakdown.

    ``fn`` may be a plain callable (it is jitted here) or an
    already-jitted function — anything with AOT ``.lower()``.  Compile
    time covers lowering + compilation of a cold cache; ``n_runs`` timed
    executions (after one untimed warmup that also validates the
    program runs) yield the median ``run_s``.  ``n_runs=0`` skips
    execution entirely — compile/cost/memory still come back, which is
    how CI profiles programs too big to run on its workers.
    """
    if not hasattr(fn, "lower"):
        fn = jax.jit(fn)
    if name is None:
        name = getattr(fn, "__name__", repr(fn))
    t0 = time.perf_counter()
    compiled = fn.lower(*args, **kwargs).compile()
    compile_s = time.perf_counter() - t0

    cost = _cost_dict(compiled)
    run_s = 0.0
    if n_runs > 0:
        runner = compiled
        try:
            jax.block_until_ready(runner(*args, **kwargs))   # warmup
        except TypeError:
            # the AOT artifact rejects static kwargs; fall back to the
            # jitted callable (the warmup absorbs its re-trace)
            runner = fn
            jax.block_until_ready(runner(*args, **kwargs))
        times = []
        for _ in range(n_runs):
            t0 = time.perf_counter()
            jax.block_until_ready(runner(*args, **kwargs))
            times.append(time.perf_counter() - t0)
        times.sort()
        run_s = times[len(times) // 2]

    return ProfileRecord(
        name=name,
        compile_s=compile_s,
        run_s=run_s,
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        argument_bytes=_mem_field(compiled, "argument_size_in_bytes"),
        output_bytes=_mem_field(compiled, "output_size_in_bytes"),
        temp_bytes=_mem_field(compiled, "temp_size_in_bytes"),
    )


def profile_kernels(rows: int = 64, cols: int = 4096,
                    n_runs: int = 3) -> list[ProfileRecord]:
    """Profile the (max, +) kernel stack on a representative shape.

    rows x cols mirrors a streaming chunk's (S * r * (p + 1), chunk)
    flattening.  Both the plain scan and the segmented variant (8-way
    segments, the fused replicated engine's workhorse) are profiled
    through the SAME dispatch the simulator uses, so the records
    describe the kernels as deployed, not a synthetic microbenchmark.
    """
    from repro.kernels.maxplus_scan import ops as mp_ops

    a = jnp.linspace(0.0, 1.0, rows * cols).reshape(rows, cols)
    b = jnp.full((rows, cols), 0.01)
    flags = (jnp.arange(cols)[None, :] % (cols // 8) == 0)
    flags = jnp.broadcast_to(flags, (rows, cols))
    return [
        profile_jit(mp_ops.maxplus_scan, a, b,
                    name="maxplus_scan", n_runs=n_runs),
        profile_jit(mp_ops.maxplus_segment_scan, a, b, flags,
                    name="maxplus_segment_scan", n_runs=n_runs),
    ]
