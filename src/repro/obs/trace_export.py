"""Span traces: a fork-join sample path as Chrome-trace/Perfetto JSON.

Timelines (`repro.obs.timeline`) aggregate; span traces *show the
queries*.  This module materializes a routed sample path — per query:
dispatch, broker service (the paper lumps broadcast+merge there), each
index server's service, the join — and renders it in the Trace Event
Format that chrome://tracing and ui.perfetto.dev load natively:

  * one *process* per replica (pid = replica index),
  * one *thread* per FCFS queue (tid 0 = broker, tid 1..p = servers),
  * ``ph: "X"`` complete spans for service intervals — FCFS makes them
    provably disjoint per queue, which :func:`validate_chrome_trace`
    checks,
  * ``ph: "b"/"e"`` async events spanning each query's whole
    arrival -> join lifetime (lifetimes overlap; async events may).

Span export materializes O(n_queries) state by design — it is the
microscope for bounded windows (thousands of queries around an
incident), not the streaming telescope.  Use timelines for horizons.

The exporter has two front doors: :func:`simulate_spans` re-runs the
simulator's topology with full per-query recording (flash-crowd
replays, any r/routing), and :func:`spans_from_trace` renders a
measured `repro.calibrate.measure.TraceRecord` (single-replica, the
instrumented toy engine's output) — same event schema either way.
"""

from __future__ import annotations

import dataclasses
import json
import pathlib
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess
from repro.core.queueing import ServerParams, service_time_server
from repro.core.simulator import (
    ROUTING_POLICIES,
    _jsq_route,
    fcfs_completion_times_routed,
)

Array = jax.Array

__all__ = ["SpanTrace", "simulate_spans", "spans_from_trace",
           "export_chrome_trace", "validate_chrome_trace"]

_US = 1e6                       # trace-event timestamps are microseconds


@dataclasses.dataclass(frozen=True)
class SpanTrace:
    """A materialized routed sample path, ready for event rendering.

    arrival/response: (n,) seconds; broker_busy: (n,); server_busy
    (n, p); broker_done: (n,) broker-queue completion; completions:
    (p, n) server-queue completions; assign: (n,) replica per query.
    """

    arrival: np.ndarray
    response: np.ndarray
    broker_busy: np.ndarray
    server_busy: np.ndarray
    broker_done: np.ndarray
    completions: np.ndarray
    assign: np.ndarray
    r: int

    @property
    def n_queries(self) -> int:
        return self.arrival.shape[0]

    @property
    def p(self) -> int:
        return self.server_busy.shape[1]

    def to_events(self) -> list[dict]:
        """Render as Trace Event Format event dicts (microseconds)."""
        events = []
        for k in range(self.r):
            events.append({"ph": "M", "name": "process_name", "pid": k,
                           "tid": 0,
                           "args": {"name": f"replica {k}"}})
            events.append({"ph": "M", "name": "thread_name", "pid": k,
                           "tid": 0, "args": {"name": "broker"}})
            for j in range(self.p):
                events.append({"ph": "M", "name": "thread_name",
                               "pid": k, "tid": 1 + j,
                               "args": {"name": f"server {j}"}})
        arr, resp = self.arrival, self.response
        brk_b, brk_d = self.broker_busy, self.broker_done
        srv_b, comp = self.server_busy, self.completions
        asg = self.assign
        for i in range(self.n_queries):
            pid = int(asg[i])
            events.append({"ph": "b", "cat": "query", "id": i,
                           "name": f"q{i}", "pid": pid, "tid": 0,
                           "ts": float(arr[i]) * _US})
            events.append({"ph": "X", "name": "broker",
                           "cat": "service", "pid": pid, "tid": 0,
                           "ts": float(brk_d[i] - brk_b[i]) * _US,
                           "dur": float(brk_b[i]) * _US,
                           "args": {"query": i}})
            for j in range(self.p):
                events.append({"ph": "X", "name": f"server {j}",
                               "cat": "service", "pid": pid,
                               "tid": 1 + j,
                               "ts": float(comp[j, i]
                                           - srv_b[i, j]) * _US,
                               "dur": float(srv_b[i, j]) * _US,
                               "args": {"query": i}})
            events.append({"ph": "e", "cat": "query", "id": i,
                           "name": f"q{i}", "pid": pid, "tid": 0,
                           "ts": float(arr[i] + resp[i]) * _US})
        return events


def simulate_spans(
    key: Array,
    arrival: Union[ArrivalProcess, float],
    n_queries: int,
    params: ServerParams,
    *,
    r: int = 1,
    routing: str = "round_robin",
    impl: str = "xla",
) -> SpanTrace:
    """Materialize a routed fork-join sample path for span export.

    Same topology as the streaming engine — dispatcher routes each query
    to one of ``r`` replicas (``routing`` in "round_robin" | "random" |
    "jsq"), each a broker + p-server fork-join over exponential services
    — but every interval is kept, because the whole point is looking at
    them.  Arrival gaps come per-query from the profile (flash crowds
    shorter than a streaming chunk still render).
    """
    from repro.calibrate.measure import _sample_arrivals

    if routing not in ROUTING_POLICIES:
        raise ValueError(f"unknown routing policy {routing!r}; choose "
                         f"one of {ROUTING_POLICIES}")
    p = int(params.p)
    proc = (arrival if isinstance(arrival, ArrivalProcess)
            else ArrivalProcess.stationary(float(arrival)))
    dtype = jnp.result_type(float)
    k_arr, k_brk, k_srv, k_route = jax.random.split(key, 4)

    arr = _sample_arrivals(k_arr, proc, n_queries).astype(dtype)
    brk = (jax.random.exponential(k_brk, (n_queries,), dtype)
           * jnp.asarray(params.s_broker, dtype))
    srv = (jax.random.exponential(k_srv, (n_queries, p), dtype)
           * jnp.asarray(service_time_server(params), dtype))

    if r == 1 or routing == "round_robin":
        asg = jnp.arange(n_queries, dtype=jnp.int32) % r
    elif routing == "random":
        asg = jax.random.randint(k_route, (n_queries,), 0, r,
                                 jnp.int32)
    else:                                            # jsq
        gaps = jnp.diff(arr, prepend=arr[:1] * 0.0)
        asg, _ = _jsq_route(
            jnp.zeros((1, r, p), dtype), gaps[None, :],
            jnp.moveaxis(srv, -1, 0)[None], jnp.ones((1, n_queries),
                                                     dtype), r, dtype)
        asg = asg[0].astype(jnp.int32)

    broker_done, _ = fcfs_completion_times_routed(
        arr, brk, asg, r, impl=impl)
    fork = jnp.broadcast_to(broker_done[None, :], (p, n_queries))
    asg_p = jnp.broadcast_to(asg[None, :], (p, n_queries))
    completions, _ = fcfs_completion_times_routed(
        fork, srv.T, asg_p, r, impl=impl)
    response = jnp.max(completions, axis=0) - arr

    return SpanTrace(
        arrival=np.asarray(arr), response=np.asarray(response),
        broker_busy=np.asarray(brk), server_busy=np.asarray(srv),
        broker_done=np.asarray(broker_done),
        completions=np.asarray(completions),
        assign=np.asarray(asg), r=r)


def spans_from_trace(trace, *, impl: str = "xla") -> SpanTrace:
    """Span-render a measured `TraceRecord` (single replica).

    The record carries arrivals, responses and busy times; the queue
    completions are the max-plus replay of the busy times — the same
    replay `measure_engine_trace` used to derive the responses, so the
    spans are exactly the measured system's reconstruction.
    """
    from repro.core.simulator import fcfs_completion_times

    arr = trace.arrival - trace.arrival[0]
    brk = trace.broker_busy
    srv = trace.server_busy
    n, p = srv.shape
    broker_done = fcfs_completion_times(arr, brk, impl=impl)
    fork = jnp.broadcast_to(broker_done[None, :], (p, n))
    completions = fcfs_completion_times(fork, srv.T, impl=impl)
    return SpanTrace(
        arrival=np.asarray(arr), response=np.asarray(trace.response),
        broker_busy=np.asarray(brk), server_busy=np.asarray(srv),
        broker_done=np.asarray(broker_done),
        completions=np.asarray(completions),
        assign=np.zeros((n,), np.int32), r=1)


def export_chrome_trace(
    spans_or_events: Union[SpanTrace, list],
    path: Union[str, pathlib.Path],
) -> pathlib.Path:
    """Write Trace Event Format JSON loadable by chrome://tracing.

    The JSON object form (``{"traceEvents": [...]}``) with
    ``displayTimeUnit: "ms"`` — Perfetto and Chrome both accept it.
    """
    events = (spans_or_events.to_events()
              if isinstance(spans_or_events, SpanTrace)
              else list(spans_or_events))
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
    return path


def validate_chrome_trace(
    source: Union[str, pathlib.Path, dict],
    *,
    check_overlap: bool = True,
) -> dict:
    """Schema-check a Chrome-trace JSON; raise ValueError on violations.

    Checks the Trace Event Format contract this exporter relies on:
    the ``traceEvents`` envelope; per-phase required keys; nonnegative
    durations; balanced ``b``/``e`` async pairs per (cat, id); and —
    because FCFS queues serve one query at a time — that no two ``X``
    spans on the same (pid, tid) lane overlap (``check_overlap``).
    Returns summary counts for dashboards/CI logs.
    """
    if isinstance(source, (str, pathlib.Path)):
        with open(source) as f:
            obj = json.load(f)
    else:
        obj = source
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("not a Chrome trace: missing 'traceEvents'")
    events = obj["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("'traceEvents' must be a non-empty list")

    counts: dict = {"X": 0, "b": 0, "e": 0, "M": 0}
    lanes: dict = {}
    asyncs: dict = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: not a dict with 'ph'")
        ph = ev["ph"]
        if ph not in counts:
            raise ValueError(f"event {i}: unsupported phase {ph!r}")
        counts[ph] += 1
        if "pid" not in ev:
            raise ValueError(f"event {i}: missing 'pid'")
        if ph == "M":
            continue
        if not isinstance(ev.get("ts"), (int, float)):
            raise ValueError(f"event {i}: 'ts' must be a number")
        if ph == "X":
            if "name" not in ev:
                raise ValueError(f"event {i}: X span missing 'name'")
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: X span needs dur >= 0")
            lanes.setdefault((ev["pid"], ev.get("tid")), []).append(
                (float(ev["ts"]), float(dur)))
        else:                                          # "b" / "e"
            if "id" not in ev or "cat" not in ev:
                raise ValueError(
                    f"event {i}: async {ph!r} needs 'cat' and 'id'")
            asyncs[(ev["cat"], ev["id"])] = \
                asyncs.get((ev["cat"], ev["id"]), 0) + (
                    1 if ph == "b" else -1)
    unbalanced = {k: v for k, v in asyncs.items() if v != 0}
    if unbalanced:
        raise ValueError(f"unbalanced async b/e pairs: "
                         f"{sorted(unbalanced)[:5]}")
    if check_overlap:
        for (pid, tid), spans in lanes.items():
            spans.sort()
            end = -np.inf
            for ts, dur in spans:
                # FCFS lanes are disjoint up to float32 rounding of the
                # absolute clock (ulp grows with ts)
                tol = 0.5 + 4e-7 * abs(ts)
                if ts < end - tol:
                    raise ValueError(
                        f"overlapping X spans on lane pid={pid} "
                        f"tid={tid} at ts={ts}")
                end = max(end, ts + dur)
    counts["lanes"] = len(lanes)
    counts["async_pairs"] = len(asyncs)
    return counts
