"""Text dashboard for the observability layer.

    PYTHONPATH=src python -m repro.obs.report [--scenario flash] [...]

Renders, in order:

  * sparkline timelines — per-bin throughput, server/broker utilization,
    queue depth, SLO violations, routing imbalance — from a streaming
    run with ``telemetry=TelemetrySpec(...)``;
  * operational-law self-checks — the binned telemetry must satisfy
    U = X * S (utilization law, paper Eq 3) and L = lambda * W
    (Little's law) *identically per bin*, because all three sides are
    measured from the same arrivals.  The dashboard recomputes both
    sides and prints the worst relative deviation (f32 rounding only);
  * a profile table — compile time, flops, bytes, peak memory of the
    Pallas kernel stack via `repro.obs.profile`;
  * optionally (``--trace-json out.json``) a span-trace export of the
    same scenario, schema-validated on the spot.

Every rendering helper is importable (the example and tests reuse
them); only ``main`` touches argparse.
"""

from __future__ import annotations

import argparse
from typing import Optional, Sequence

import numpy as np

_BLOCKS = "▁▂▃▄▅▆▇█"


def sparkline(values: Sequence[float], lo: Optional[float] = None,
              hi: Optional[float] = None) -> str:
    """Unicode sparkline of a 1-D series (NaN renders as a space)."""
    v = np.asarray(values, dtype=np.float64)
    finite = v[np.isfinite(v)]
    lo = float(finite.min()) if lo is None and finite.size else (lo or 0.0)
    hi = float(finite.max()) if hi is None and finite.size else (hi or 1.0)
    span = hi - lo
    out = []
    for x in v:
        if not np.isfinite(x):
            out.append(" ")
            continue
        t = 0.0 if span <= 0 else (x - lo) / span
        out.append(_BLOCKS[min(len(_BLOCKS) - 1,
                               max(0, int(t * len(_BLOCKS))))])
    return "".join(out)


def render_timeline(tl, label: str = "") -> str:
    """Multi-row sparkline panel for one scenario's Timeline."""
    tl_np = lambda x: np.asarray(x)  # noqa: E731
    util = tl_np(tl.utilization)            # (B, r, p)
    rows = []
    if label:
        rows.append(f"== timeline: {label} ==")
    bin_s = float(np.asarray(tl.bin_seconds))
    rows.append(f"  {tl.n_bins} bins x {bin_s:.3g}s")

    def line(name, series, fmt="{:.3g}"):
        s = np.asarray(series, np.float64)
        f = s[np.isfinite(s)]
        rng = (f"[{fmt.format(f.min())}, {fmt.format(f.max())}]"
               if f.size else "[empty]")
        rows.append(f"  {name:<14} {sparkline(s)}  {rng}")

    line("throughput", tl_np(tl.throughput))
    line("util (srv avg)", util.mean(axis=(1, 2)))
    line("util (srv max)", util.max(axis=(1, 2)))
    line("util (broker)", tl_np(tl.broker_utilization).mean(axis=1))
    line("queue depth", tl_np(tl.queue_depth))
    line("mean resp (s)", tl_np(tl.mean_response))
    if float(tl_np(tl.slo_count).sum()) > 0:
        line("SLO viol frac", tl_np(tl.slo_violation_fraction))
    if util.shape[1] > 1:
        line("imbalance", tl_np(tl.imbalance_share))
    if float(tl_np(tl.hit_count).sum()) > 0:
        line("cache hits", tl_np(tl.hit_fraction))
    if getattr(tl, "active_sum", None) is not None:
        line("active repl", tl_np(tl.active_replicas))
    if getattr(tl, "up_sum", None) is not None:
        line("up replicas", tl_np(tl.up_replicas))
    if (getattr(tl, "spill_sum", None) is not None
            and float(tl_np(tl.spill_sum).sum()) > 0):
        line("spill frac", tl_np(tl.spill_fraction))
    if (getattr(tl, "degraded_sum", None) is not None
            and float(tl_np(tl.degraded_sum).sum()) > 0):
        line("degraded frac", tl_np(tl.degraded_fraction))
    return "\n".join(rows)


def oplaw_check(tl) -> tuple[str, float]:
    """Self-check U = X * S and L = lambda * W on a Timeline.

    Both laws are *identities* of the binned accumulators (busy-seconds
    and response-seconds are attributed to the arrival bin), so the
    deviation is pure float rounding.  Returns (report, worst relative
    deviation over non-empty bins).
    """
    count = np.asarray(tl.count, np.float64)
    busy = np.asarray(tl.busy_server, np.float64).sum(axis=(1, 2)) \
        + np.asarray(tl.busy_broker, np.float64).sum(axis=1)
    resp = np.asarray(tl.resp_sum, np.float64)
    bin_s = float(np.asarray(tl.bin_seconds))
    occupied = count > 0

    # U = X * S: busy/bin == (count/bin) * (busy/count)
    x = count / bin_s
    s = busy / np.maximum(count, 1.0)
    u_direct = busy / bin_s
    u_law = x * s
    dev_u = np.abs(u_direct - u_law) / np.maximum(np.abs(u_direct), 1e-12)
    # L = lambda * W: resp_sum/bin == (count/bin) * (resp_sum/count)
    l_direct = resp / bin_s
    l_law = x * (resp / np.maximum(count, 1.0))
    dev_l = np.abs(l_direct - l_law) / np.maximum(np.abs(l_direct), 1e-12)

    worst = float(max(dev_u[occupied].max(initial=0.0),
                      dev_l[occupied].max(initial=0.0)))
    lines = [
        "== operational-law self-checks ==",
        f"  U = X*S   worst per-bin rel dev: {dev_u[occupied].max(initial=0.0):.2e}",
        f"  L = lam*W worst per-bin rel dev: {dev_l[occupied].max(initial=0.0):.2e}",
        f"  ({int(occupied.sum())}/{count.size} occupied bins; both laws "
        "are identities of the arrival-binned accumulators)",
    ]
    return "\n".join(lines), worst


def render_profiles(records) -> str:
    """Fixed-width table of ProfileRecords."""
    rows = ["== kernel/entry-point profiles ==",
            f"  {'name':<24} {'compile_s':>9} {'run_ms':>8} "
            f"{'Mflops':>9} {'MB moved':>9} {'peak MB':>8} {'F/B':>6}"]
    for r in records:
        rows.append(
            f"  {r.name:<24} {r.compile_s:>9.3f} {r.run_s * 1e3:>8.2f} "
            f"{r.flops / 1e6:>9.2f} {r.bytes_accessed / 1e6:>9.2f} "
            f"{r.peak_bytes / 1e6:>8.2f} {r.arithmetic_intensity:>6.2f}")
    return "\n".join(rows)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="repro.obs text dashboard (timelines, operational-"
                    "law self-checks, kernel profiles)")
    ap.add_argument("--scenario", choices=("stationary", "flash"),
                    default="flash")
    ap.add_argument("--lam", type=float, default=24.0,
                    help="base arrival rate (qps)")
    ap.add_argument("--r", type=int, default=3, help="replicas")
    ap.add_argument("--routing", default="jsq",
                    choices=("round_robin", "random", "jsq"))
    ap.add_argument("--n-queries", type=int, default=20_000)
    ap.add_argument("--bins", type=int, default=48)
    ap.add_argument("--slo", type=float, default=0.7,
                    help="SLO seconds for the violation timeline")
    ap.add_argument("--no-profile", action="store_true",
                    help="skip the kernel profiling table")
    ap.add_argument("--trace-json", default=None,
                    help="also export + validate a span trace here")
    args = ap.parse_args(argv)

    import jax

    from repro.core import capacity, simulator
    from repro.core.arrivals import ArrivalProcess
    from repro.core.cluster import ClusterSpec
    from repro.obs import profile as obs_profile
    from repro.obs.timeline import TelemetrySpec

    params = capacity.TABLE5_PARAMS
    if args.scenario == "flash":
        horizon = args.n_queries / (args.lam * 1.6)
        proc = ArrivalProcess.flash_crowd(
            args.lam, burst_starts=0.35 * horizon,
            burst_seconds=0.2 * horizon, burst_multiplier=4.0,
            period_seconds=horizon, bin_seconds=horizon / 64)
        label = (f"flash crowd (lam {args.lam:g} qps x4 burst, "
                 f"r={args.r}, {args.routing})")
    else:
        proc = ArrivalProcess.stationary(args.lam)
        label = f"stationary lam {args.lam:g} qps, r={args.r}"

    spec = TelemetrySpec(n_bins=args.bins, slo_seconds=args.slo)
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(0), proc, args.n_queries, params,
        cluster=ClusterSpec(r=args.r, routing=args.routing),
        telemetry=spec)
    print(render_timeline(res.timeline, label))
    print()
    report, worst = oplaw_check(res.timeline)
    print(report)
    if worst > 1e-3:
        raise SystemExit(f"operational-law self-check FAILED "
                         f"(worst dev {worst:.2e} > 1e-3)")

    if not args.no_profile:
        print()
        print(render_profiles(obs_profile.profile_kernels()))

    if args.trace_json is not None:
        from repro.obs import trace_export
        n_span = min(args.n_queries, 2000)
        spans = trace_export.simulate_spans(
            jax.random.PRNGKey(0), proc, n_span, params,
            r=args.r, routing=args.routing)
        path = trace_export.export_chrome_trace(spans, args.trace_json)
        counts = trace_export.validate_chrome_trace(path)
        print(f"\nspan trace: {path} ({counts['X']} spans, "
              f"{counts['async_pairs']} query lifetimes, "
              f"{counts['lanes']} lanes) — schema OK")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
