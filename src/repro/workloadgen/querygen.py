"""Query workload generator matching the paper's characterization (Sec 4).

Builds a *query universe* (unique queries with Zipf popularity, lengths
from Table 2, terms Zipf-distributed over the vocabulary) and samples query
streams from it.  Defaults are the TodoBR measurements: query popularity
alpha = 0.82, term popularity alpha = 0.98, length distribution
{1: 0.32, 2: 0.41, >=3: 0.27}.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WorkloadConfig", "QueryUniverse", "build_universe",
           "sample_query_stream", "TODOBR", "RADIX"]


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    name: str
    n_unique_queries: int = 50_000
    vocab_size: int = 50_000
    query_zipf_alpha: float = 0.82
    term_zipf_alpha: float = 0.98
    # P(len = 1), P(len = 2), remainder spread over 3..max_len
    p_len1: float = 0.32
    p_len2: float = 0.41
    max_len: int = 6
    seed: int = 0


TODOBR = WorkloadConfig("todobr", query_zipf_alpha=0.82,
                        term_zipf_alpha=0.98, p_len1=0.32, p_len2=0.41)
RADIX = WorkloadConfig("radix", query_zipf_alpha=0.89,
                       term_zipf_alpha=1.09, p_len1=0.35, p_len2=0.43)


@dataclasses.dataclass
class QueryUniverse:
    config: WorkloadConfig
    terms: np.ndarray        # (U, max_len) int32, padded with -1
    lengths: np.ndarray      # (U,)
    popularity: np.ndarray   # (U,) sampling probabilities (Zipf)


def _zipf_cdf(n: int, alpha: float) -> np.ndarray:
    w = np.arange(1, n + 1, dtype=np.float64) ** (-alpha)
    return np.cumsum(w / w.sum())


def build_universe(config: WorkloadConfig) -> QueryUniverse:
    rng = np.random.default_rng(config.seed)
    u, v, ml = config.n_unique_queries, config.vocab_size, config.max_len

    # lengths from the Table-2 distribution, tail geometric over 3..max
    p3 = 1.0 - config.p_len1 - config.p_len2
    tail = np.array([0.5 ** i for i in range(ml - 2)])
    tail = tail / tail.sum() * p3
    probs = np.concatenate([[config.p_len1, config.p_len2], tail])
    lengths = rng.choice(np.arange(1, ml + 1), size=u, p=probs)

    term_cdf = _zipf_cdf(v, config.term_zipf_alpha)
    terms = np.full((u, ml), -1, dtype=np.int32)
    for i in range(u):
        l_i = lengths[i]
        # draw distinct terms for one query
        t = np.unique(np.searchsorted(term_cdf, rng.random(l_i * 3)))[:l_i]
        while len(t) < l_i:
            t = np.unique(np.concatenate(
                [t, np.searchsorted(term_cdf, rng.random(l_i))]))[:l_i]
        terms[i, :l_i] = np.minimum(t, v - 1)

    q_w = np.arange(1, u + 1, dtype=np.float64) ** (-config.query_zipf_alpha)
    popularity = q_w / q_w.sum()
    return QueryUniverse(config=config, terms=terms,
                         lengths=lengths.astype(np.int32),
                         popularity=popularity)


def sample_query_stream(
    universe: QueryUniverse, n_queries: int, *, seed: int = 1
) -> tuple[np.ndarray, np.ndarray]:
    """(query_ids, padded term matrix) for a Zipf-popular stream."""
    rng = np.random.default_rng(seed)
    cdf = np.cumsum(universe.popularity)
    qids = np.searchsorted(cdf, rng.random(n_queries)).astype(np.int64)
    qids = np.minimum(qids, len(cdf) - 1)
    return qids, universe.terms[qids]
