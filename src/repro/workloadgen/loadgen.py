"""Open-loop load generation: Poisson arrivals, diurnal modulation, folding.

Reproduces the temporal structure of Figs 3-5: within a stable one-hour
window arrivals are homogeneous Poisson (exponential gaps, Sec 4.2); across
a day/week the rate follows a diurnal profile; the *folding* procedure
merges corresponding windows to boost the rate (Table 3: TodoBR Monday
0.69 qps -> 23.58 qps folded, a ~34x boost = 243 days / 7-day window).
"""

from __future__ import annotations

import numpy as np

__all__ = ["poisson_arrivals", "diurnal_arrivals", "fold", "WEEK_SECONDS"]

WEEK_SECONDS = 7 * 24 * 3600.0


def poisson_arrivals(rate: float, duration: float, *, seed: int = 0
                     ) -> np.ndarray:
    """Homogeneous Poisson arrival timestamps on [0, duration)."""
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * duration)
    return np.sort(rng.random(n) * duration)


def diurnal_arrivals(
    base_rate: float,
    days: int,
    *,
    peak_hour: float = 15.0,
    peak_to_trough: float = 4.0,
    weekend_factor: float = 0.7,
    seed: int = 0,
) -> np.ndarray:
    """Inhomogeneous Poisson arrivals with daily + weekly structure.

    rate(t) = base * daily(t) * weekly(t); daily is a raised cosine peaking
    at ``peak_hour`` with the given peak/trough ratio; weekends are scaled
    by ``weekend_factor`` (TodoBR profile; Radix used >1).  Sampled by
    thinning.
    """
    rng = np.random.default_rng(seed)
    duration = days * 86400.0
    r = peak_to_trough
    amp = (r - 1.0) / (r + 1.0)

    def rate_fn(t):
        hour = (t % 86400.0) / 3600.0
        daily = 1.0 + amp * np.cos((hour - peak_hour) / 24.0 * 2 * np.pi)
        dow = (t // 86400.0) % 7
        weekly = np.where(dow >= 5, weekend_factor, 1.0)
        return base_rate * daily * weekly

    lam_max = base_rate * (1.0 + amp) * max(1.0, weekend_factor)
    n = rng.poisson(lam_max * duration)
    t = np.sort(rng.random(n) * duration)
    keep = rng.random(n) < rate_fn(t) / lam_max
    return t[keep]


def fold(timestamps: np.ndarray, window: float = WEEK_SECONDS
         ) -> tuple[np.ndarray, float]:
    """Paper Sec 4.2 folding: merge all windows; returns (folded, boost)."""
    folded = np.sort(np.mod(timestamps, window))
    duration = timestamps.max() - timestamps.min()
    return folded, float(np.ceil(duration / window))
