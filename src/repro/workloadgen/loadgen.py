"""Open-loop load generation: Poisson arrivals, diurnal modulation, folding.

Reproduces the temporal structure of Figs 3-5: within a stable one-hour
window arrivals are homogeneous Poisson (exponential gaps, Sec 4.2); across
a day/week the rate follows a diurnal profile; the *folding* procedure
merges corresponding windows to boost the rate (Table 3: TodoBR Monday
0.69 qps -> 23.58 qps folded, a ~34x boost = 243 days / 7-day window).

Built on the same :class:`repro.core.arrivals.ArrivalProcess` the streaming
simulator consumes: :func:`diurnal_rates` produces the weekly hourly
profile once (in JAX), :func:`diurnal_process` wraps it for the simulator
(`simulate_fork_join(key, diurnal_process(...), ...)`), and
:func:`diurnal_arrivals` samples concrete timestamps from the *same* binned
profile by thinning — generator and simulator can no longer disagree about
what "the daily peak" is.  Host-side timestamp positions stay numpy
float64 (float32 would quantize long windows; see `poisson_arrivals`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arrivals import ArrivalProcess

__all__ = [
    "poisson_arrivals",
    "diurnal_rates",
    "diurnal_process",
    "diurnal_arrivals",
    "replay_process",
    "fold",
    "WEEK_SECONDS",
]

WEEK_SECONDS = 7 * 24 * 3600.0
_WEEK_HOURS = 7 * 24


def poisson_arrivals(rate: float, duration: float, *, seed: int = 0
                     ) -> np.ndarray:
    """Homogeneous Poisson arrival timestamps on [0, duration).

    Timestamps are drawn host-side in float64: a float32 uniform only has
    2^-24 resolution, which would quantize a 243-day fold window to
    ~1.25 s steps and generate masses of zero gaps.
    """
    rng = np.random.default_rng(seed)
    n = rng.poisson(rate * duration)
    return np.sort(rng.random(n) * duration)


def diurnal_rates(
    base_rate: float = 1.0,
    *,
    peak_hour: float = 15.0,
    peak_to_trough: float = 4.0,
    weekend_factor: float = 0.7,
) -> jax.Array:
    """(168,) weekly hourly-binned rate profile, in qps.

    rate(hour) = base * daily * weekly; daily is a raised cosine peaking at
    ``peak_hour`` with the given peak/trough ratio (evaluated at bin
    centers); weekends are scaled by ``weekend_factor`` (TodoBR profile;
    Radix used >1).
    """
    hours = jnp.arange(_WEEK_HOURS, dtype=jnp.result_type(float))
    hour_of_day = hours % 24.0 + 0.5
    dow = hours // 24.0
    r = peak_to_trough
    amp = (r - 1.0) / (r + 1.0)
    daily = 1.0 + amp * jnp.cos((hour_of_day - peak_hour) / 24.0
                                * 2.0 * jnp.pi)
    weekly = jnp.where(dow >= 5, weekend_factor, 1.0)
    return base_rate * daily * weekly


def diurnal_process(
    base_rate: float,
    *,
    peak_hour: float = 15.0,
    peak_to_trough: float = 4.0,
    weekend_factor: float = 0.7,
    bin_seconds: float = 3600.0,
) -> ArrivalProcess:
    """The weekly diurnal profile as a simulator-ready arrival process.

    ``bin_seconds`` rescales time: 3600 is the real week; smaller values
    compress it, which lets a modest simulated horizon cover full
    diurnal/weekly cycles (handy for sweep-scale what-ifs).
    """
    rates = diurnal_rates(base_rate, peak_hour=peak_hour,
                          peak_to_trough=peak_to_trough,
                          weekend_factor=weekend_factor)
    return ArrivalProcess.piecewise(rates, bin_seconds)


def diurnal_arrivals(
    base_rate: float,
    days: int,
    *,
    peak_hour: float = 15.0,
    peak_to_trough: float = 4.0,
    weekend_factor: float = 0.7,
    seed: int = 0,
) -> np.ndarray:
    """Inhomogeneous Poisson arrivals with daily + weekly structure.

    Sampled by thinning against the binned :func:`diurnal_rates` profile —
    exactly the rate function the streaming simulator sees.  Timestamps
    are float64 (see :func:`poisson_arrivals`); only the thinning
    probabilities go through the JAX profile.
    """
    proc = diurnal_process(base_rate, peak_hour=peak_hour,
                           peak_to_trough=peak_to_trough,
                           weekend_factor=weekend_factor)
    duration = days * 86400.0
    lam_max = float(proc.peak_rate)
    rng = np.random.default_rng(seed)
    n = rng.poisson(lam_max * duration)
    t = np.sort(rng.random(n) * duration)
    keep = rng.random(n) < np.asarray(proc.rate_at(jnp.asarray(t))) / lam_max
    return t[keep]


def replay_process(timestamps: np.ndarray) -> ArrivalProcess:
    """A measured (or folded) timestamp trace as an arrival process."""
    return ArrivalProcess.from_trace(jnp.asarray(timestamps))


def fold(timestamps: np.ndarray, window: float = WEEK_SECONDS
         ) -> tuple[np.ndarray, float]:
    """Paper Sec 4.2 folding: merge all windows; returns (folded, boost)."""
    folded = np.sort(np.mod(timestamps, window))
    duration = timestamps.max() - timestamps.min()
    return folded, float(np.ceil(duration / window))
