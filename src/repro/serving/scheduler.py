"""Request scheduling with queueing-model-driven straggler mitigation.

A continuous-batching scheduler: requests queue FCFS, steps retire up to
``max_batch`` requests, and hedged duplicates fire when a request's wait
exceeds the model-derived threshold t* = R ln p (launch.elastic) — the
paper's H_p mathematics turned into a serving policy.  The scheduler is
simulation-friendly: it advances on an injected clock so tests and the
DES can drive it deterministically.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Callable, List, Optional

from repro.launch.elastic import hedge_threshold

__all__ = ["Request", "StepStats", "ContinuousBatcher"]


@dataclasses.dataclass
class Request:
    req_id: int
    arrival: float
    payload: object = None
    start: Optional[float] = None
    finish: Optional[float] = None
    hedged: bool = False

    @property
    def latency(self) -> Optional[float]:
        return None if self.finish is None else self.finish - self.arrival


@dataclasses.dataclass
class StepStats:
    t: float
    batch: int
    queued: int
    hedges_fired: int


class ContinuousBatcher:
    """FCFS queue + batched steps + hedging.

    step_time_fn(batch_size) -> seconds models the serving cell (from the
    roofline planner or measured); p_shards sizes the hedge threshold.
    """

    def __init__(self, *, max_batch: int, step_time_fn: Callable[[int], float],
                 p_shards: int = 1, hedge: bool = True):
        self.max_batch = max_batch
        self.step_time_fn = step_time_fn
        self.queue: deque[Request] = deque()
        self.done: List[Request] = []
        self.stats: List[StepStats] = []
        self.hedge = hedge
        self._mean_service = step_time_fn(max_batch) / max(max_batch, 1)
        self.hedge_threshold = hedge_threshold(self._mean_service, p_shards)
        self.hedges_fired = 0

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def run_until(self, t_end: float, now: float = 0.0) -> float:
        """Serve queued requests until t_end; returns the clock.

        Batches only form strictly before ``t_end`` and only over requests
        that have already arrived; idle-skipping to a next arrival at or
        beyond ``t_end`` clamps the clock to ``t_end`` instead of jumping
        past the horizon (and thereby serving future requests).  The
        returned clock exceeds ``t_end`` only when the last batch — which
        started before the horizon — finishes after it, so chained calls
        (``now=previous return``) never double-book the server.
        """
        t = now
        while self.queue and t < t_end:
            batch: List[Request] = []
            while self.queue and len(batch) < self.max_batch:
                r = self.queue[0]
                if r.arrival > t:
                    break
                batch.append(self.queue.popleft())
            if not batch:
                nxt = self.queue[0].arrival
                if nxt >= t_end:
                    t = t_end  # next arrival beyond the horizon: stay idle
                    break
                t = nxt
                continue
            hedges = 0
            if self.hedge:
                for r in batch:
                    if t - r.arrival > self.hedge_threshold and not r.hedged:
                        r.hedged = True   # duplicate dispatched to a replica
                        hedges += 1
            self.hedges_fired += hedges
            dt = self.step_time_fn(len(batch))
            # a hedged request completes at the min of two iid services —
            # expected service halves (Exp residual memorylessness)
            for r in batch:
                r.start = t
                r.finish = t + (dt * 0.5 if r.hedged else dt)
                self.done.append(r)
            self.stats.append(StepStats(t=t, batch=len(batch),
                                        queued=len(self.queue),
                                        hedges_fired=hedges))
            t += dt
        return t

    def latencies(self) -> List[float]:
        return [r.latency for r in self.done if r.latency is not None]
