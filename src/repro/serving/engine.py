"""Batched LM serving engine: prefill + decode with a shared KV pool.

A minimal production-shaped serving loop for the LM archs: requests carry
prompts; the engine prefills into a fixed-slot KV cache and decodes all
active slots in lockstep (continuous batching at the step level).  The
capacity model from repro.core.planner sizes how many of these engines a
fleet needs — examples/plan_llm_serving.py.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.models import transformer as T

__all__ = ["LMServer"]


@dataclasses.dataclass
class _Slot:
    req_id: int = -1
    remaining: int = 0
    tokens: list = dataclasses.field(default_factory=list)


class LMServer:
    """Fixed-slot continuous-batching decode server (greedy sampling)."""

    def __init__(self, cfg: LMConfig, params, *, slots: int = 4,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.slots = [_Slot() for _ in range(slots)]
        self.cache = T.init_kv_cache(cfg, slots, max_seq)
        self._decode = jax.jit(
            lambda p, t, c: T.decode_step(p, cfg, t, c))
        self.completed: List[dict] = []

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s.remaining <= 0:
                return i
        return None

    def admit(self, req_id: int, prompt: np.ndarray, max_new: int) -> bool:
        """Prefill a prompt into a free slot; False if server full."""
        i = self._free_slot()
        if i is None:
            return False
        # per-slot prefill (single-row) seeds that slot's cache lines
        logits, cache = T.prefill(self.params, self.cfg,
                                  jnp.asarray(prompt[None, :]),
                                  chunk=min(len(prompt), 8))
        s = len(prompt)
        self.cache["k"] = self.cache["k"].at[:, i, :s].set(cache["k"][:, 0])
        self.cache["v"] = self.cache["v"].at[:, i, :s].set(cache["v"][:, 0])
        nxt = int(jnp.argmax(logits[0, -1]))
        self.slots[i] = _Slot(req_id=req_id, remaining=max_new,
                              tokens=list(prompt) + [nxt])
        return True

    def step(self) -> int:
        """One lockstep decode over all active slots; returns #active."""
        active = [i for i, s in enumerate(self.slots) if s.remaining > 0]
        if not active:
            return 0
        # lockstep cache_len: the maximum prompt+generated so far; slots
        # use causal masking via cache length (single shared len keeps the
        # engine simple; a per-slot length mask is the production variant)
        cur = jnp.asarray([self.slots[i].tokens[-1] if s.remaining > 0
                           else 0 for i, s in enumerate(self.slots)],
                          jnp.int32)[:, None]
        self.cache["len"] = jnp.asarray(
            max(len(self.slots[i].tokens) for i in active) - 1, jnp.int32)
        logits, self.cache = self._decode(self.params, cur, self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i in active:
            s = self.slots[i]
            s.tokens.append(int(nxt[i]))
            s.remaining -= 1
            if s.remaining == 0:
                self.completed.append(
                    dict(req_id=s.req_id, tokens=s.tokens))
        return len(active)
