"""Vectorized what-if sweep engine (paper Sec 6 at grid scale).

The paper answers "will configuration X keep response time under the
constraint?" one scenario at a time.  This module evaluates a dense
Cartesian grid

    lambda x p x cpu-speedup x disk-speedup x cache-hit-ratio x replicas

(the replica axis optionally swapped for a tuple of elastic
`AutoscalePolicy` values — a POLICY axis, simulation-only) as a SINGLE
XLA program, two ways:

  * analytical — the Eq 7 bounds from `repro.core.queueing`, which already
    broadcast, evaluated over the broadcasted grid.  Tens of thousands of
    scenarios cost one fused elementwise kernel.
  * simulation — the STREAMING chunked engine of `repro.core.simulator`:
    per distinct p, all L*C*D*H scenarios' sample paths run as one
    `lax.scan` over query chunks (optionally on the `maxplus_scan` Pallas
    grid), carrying only per-(scenario, server) max-plus state plus
    streaming statistics.  Peak memory is scenarios x p x chunk floats —
    independent of n_queries — so grids 10-100x larger than the old
    materializing path fit, quantile surfaces (p95/p99) come out next to
    the means, and an `ArrivalProcess` profile makes every scenario's
    load time-varying (diurnal/weekly peaks).

On top sits constraint-satisfying frontier extraction: "for each arrival
rate, the cheapest configuration with R <= SLO", where R can be the
analytic upper bound, the simulated mean, or a simulated quantile such as
p95 (exposed to planners via `repro.core.planner.plan_over_grid`).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro import compat
from repro.core import capacity, queueing, simulator
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec, resolve_cluster
from repro.core.faults import FaultSpec
from repro.core.queueing import ServerParams
from repro.launch.elastic import AutoscalePolicy

Array = jax.Array
ArrayLike = Union[Array, Sequence[float], float]

__all__ = [
    "SweepGrid",
    "SweepResult",
    "SimSweepResult",
    "Frontier",
    "sweep_analytical",
    "sweep_simulated",
    "default_config_cost",
    "extract_frontier",
]

def _axis(x: ArrayLike) -> Array:
    return jnp.atleast_1d(jnp.asarray(x, jnp.float32))


@dataclasses.dataclass(frozen=True)
class SweepGrid:
    """A dense what-if grid over the paper's Section-6 knobs.

    Axis order is fixed: (lam, p, cpu, disk, hit, r).  ``base`` supplies
    the measured per-server times that the cpu/disk speedups divide
    (paper convention: CPU k-times faster divides every CPU time by k);
    its ``p``/``hit`` fields are ignored in favor of the grid axes.  The
    broker is CPU-bound and grows with p per the paper's linear fit,
    unless ``broker_from_p=False`` pins it to ``base.s_broker``.

    ``r`` is the replica axis (Sec 6 ``replicas_needed`` as a grid
    dimension): ``lam`` stays the TOTAL arrival rate and each replica is
    planned at ``lam / r``.  ``result_cache=(hit_r, s_cache)`` threads
    the Eq 8 broker-level result cache through both evaluation paths
    (conservative un-thinned mixture analytically; a mechanistic
    dispatcher cache queue in the simulator).

    ``autoscale`` replaces the replica axis with a POLICY axis: a tuple
    of `repro.launch.elastic.AutoscalePolicy` values becomes the grid's
    6th dimension (``r`` must stay at its default — each policy's
    ``max_r`` sets provisioning).  Policy grids are simulation-only
    (the Eq 7/8 bounds have no notion of a time-varying fleet), and
    :func:`extract_frontier` prices their cells by observed
    replica-seconds instead of a static replica count.

    ``fault`` likewise replaces the replica axis with a FAULT-SCENARIO
    axis: a tuple of `repro.core.faults.FaultSpec` values (None entries
    are the fault-free baseline) becomes the 6th dimension, every cell
    running at the single fixed replica count on the ``r`` axis.  Fault
    grids are simulation-only too — the analytic bounds assume every
    replica is up — and answer "same hardware, which failure scenarios
    still meet the SLO?" in one dispatch sweep.
    """

    lam: Array
    p: Array
    cpu: Array
    disk: Array
    hit: Array
    base: ServerParams
    broker_from_p: bool = True
    r: Array = dataclasses.field(
        default_factory=lambda: jnp.ones((1,), jnp.float32))
    result_cache: Optional[tuple[float, float]] = None
    autoscale: Optional[tuple[AutoscalePolicy, ...]] = None
    fault: Optional[tuple[Optional[FaultSpec], ...]] = None

    def __post_init__(self):
        if self.fault is not None:
            fts = (tuple(self.fault)
                   if isinstance(self.fault, (tuple, list))
                   else (self.fault,))
            if not fts:
                raise ValueError("fault= needs at least one scenario "
                                 "(or None for a fault-free grid)")
            for ft in fts:
                if ft is not None and not isinstance(ft, FaultSpec):
                    raise TypeError(
                        "fault must hold FaultSpec (or None) values; "
                        f"got {type(ft).__name__}")
            if self.autoscale is not None:
                raise ValueError(
                    "autoscale and fault both claim the grid's 6th "
                    "axis; sweep one at a time")
            if self.r.shape[0] != 1:
                raise ValueError(
                    "a fault grid replaces the replica axis; give r ONE "
                    "value (the fixed replica count every scenario "
                    "runs at)")
            object.__setattr__(self, "fault", fts)
        if self.autoscale is None:
            return
        pols = (tuple(self.autoscale)
                if isinstance(self.autoscale, (tuple, list))
                else (self.autoscale,))
        if not pols:
            raise ValueError("autoscale= needs at least one policy "
                             "(or None for a static grid)")
        for pol in pols:
            if not isinstance(pol, AutoscalePolicy):
                raise TypeError(
                    "autoscale must hold AutoscalePolicy values; got "
                    f"{type(pol).__name__}")
        if self.r.shape[0] != 1 or float(self.r[0]) != 1.0:
            raise ValueError(
                "a policy grid replaces the replica axis; leave r at "
                "its default (each policy's max_r sets provisioning)")
        object.__setattr__(self, "autoscale", pols)

    @classmethod
    def build(cls, *, lam: ArrayLike, p: ArrayLike = 100.0,
              cpu: ArrayLike = 1.0, disk: ArrayLike = 1.0,
              hit: ArrayLike = None, memory: int = 1,
              base: Optional[ServerParams] = None,
              broker_from_p: bool = True,
              r: ArrayLike = 1.0,
              result_cache: Optional[tuple[float, float]] = None,
              autoscale=None,
              fault=None,
              ) -> "SweepGrid":
        """Grid from explicit axes; defaults come from Table 6 ``memory``."""
        if base is None:
            s_hit, s_miss, s_disk, h = capacity.MEMORY_TABLE[memory]
            base = ServerParams(p=100, s_broker=capacity.broker_service_time(100),
                                s_hit=s_hit, s_miss=s_miss, s_disk=s_disk,
                                hit=h)
        if hit is None:
            hit = base.hit
        return cls(lam=_axis(lam), p=_axis(p), cpu=_axis(cpu),
                   disk=_axis(disk), hit=_axis(hit), base=base,
                   broker_from_p=broker_from_p, r=_axis(r),
                   result_cache=result_cache, autoscale=autoscale,
                   fault=fault)

    @property
    def shape(self) -> tuple[int, ...]:
        if self.autoscale is not None:
            last = len(self.autoscale)
        elif self.fault is not None:
            last = len(self.fault)
        else:
            last = self.r.shape[0]
        return (self.lam.shape[0], self.p.shape[0], self.cpu.shape[0],
                self.disk.shape[0], self.hit.shape[0], last)

    @property
    def n_scenarios(self) -> int:
        n = 1
        for d in self.shape:
            n *= d
        return n

    def broadcast(self) -> tuple[Array, ServerParams]:
        """(lam, params) with every field shaped to broadcast over `shape`.

        ``lam`` is the total arrival rate; divide by :meth:`lam_replica`'s
        denominator (the broadcast ``r`` axis) for per-replica rates.
        """
        lam = self.lam.reshape(-1, 1, 1, 1, 1, 1)
        p = self.p.reshape(1, -1, 1, 1, 1, 1)
        cpu = self.cpu.reshape(1, 1, -1, 1, 1, 1)
        disk = self.disk.reshape(1, 1, 1, -1, 1, 1)
        hit = self.hit.reshape(1, 1, 1, 1, -1, 1)
        if self.broker_from_p:
            s_broker = capacity.broker_service_time(p) / cpu
        else:
            s_broker = jnp.asarray(self.base.s_broker, jnp.float32) / cpu
        params = ServerParams(
            p=p,
            s_broker=s_broker,
            s_hit=jnp.asarray(self.base.s_hit, jnp.float32) / cpu,
            s_miss=jnp.asarray(self.base.s_miss, jnp.float32) / cpu,
            s_disk=jnp.asarray(self.base.s_disk, jnp.float32) / disk,
            hit=hit,
        )
        return lam, params

    def lam_replica(self) -> Array:
        """Per-replica arrival rate, broadcastable over `shape`."""
        if self.autoscale is not None:
            raise ValueError(
                "per-replica rates are undefined on a policy grid: the "
                "active replica count varies over time (simulate instead)")
        lam, _ = self.broadcast()
        return lam / self.r.reshape(1, 1, 1, 1, 1, -1)

    def broadcast_full(self) -> tuple[Array, ServerParams]:
        """Like `broadcast`, but every array materialized to `shape`.

        The returned ``lam`` is still the TOTAL rate (the simulator's
        dispatcher does the splitting).
        """
        lam, params = self.broadcast()
        shape = self.shape
        full = {
            f.name: jnp.broadcast_to(
                jnp.asarray(getattr(params, f.name), jnp.float32), shape)
            for f in dataclasses.fields(ServerParams)
        }
        return jnp.broadcast_to(lam, shape), ServerParams(**full)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Dense response surfaces, all shaped `grid.shape` = (L,P,C,D,H,R)."""

    grid: SweepGrid
    response_lower: Array   # Eq 7 lower bound (s); +inf where saturated
    response_upper: Array   # Eq 7 upper bound (s); the planning metric
    utilization: Array      # index-server utilization lambda * S

    @property
    def response(self) -> Array:
        """The conservative (paper-default) planning surface."""
        return self.response_upper

    @property
    def feasible_fraction(self) -> Array:
        return jnp.mean(jnp.isfinite(self.response_upper))

    def quantile(self, q: float) -> Array:
        """Analytic q-percentile upper estimate over the grid (Sec 7).

        Mirrors :meth:`SimSweepResult.quantile` so frontier extraction can
        target tail latency against either surface.  With a grid-level
        result cache the surface is the Eq-8-style mixture of the no-cache
        quantile and the cache queue's exponential quantile (an upper
        blend — the true quantile of a mixture is below it in the tail).
        """
        _, params = self.grid.broadcast()
        lam_rep = self.grid.lam_replica()
        surf = queueing.response_time_quantile_upper(lam_rep, params, q)
        if self.grid.result_cache is not None:
            hit_r, s_cache = self.grid.result_cache
            r_cache = queueing.mm1_residence_time(lam_rep, s_cache)
            t_cache = -r_cache * jnp.log1p(-jnp.asarray(q, jnp.float32))
            surf = surf * (1.0 - hit_r) + t_cache * hit_r
        return jnp.broadcast_to(surf, self.grid.shape)


def _check_sweep_mesh(mesh) -> tuple[str, int]:
    """Validate a scenario-sharding mesh; returns (axis_name, n_devices).

    Both sweep paths shard over ONE named axis (scenarios are
    embarrassingly parallel), so the mesh must be 1-D — build it with
    `repro.launch.mesh.make_sweep_mesh`.
    """
    if len(mesh.axis_names) != 1:
        raise ValueError(
            f"scenario sharding needs a 1-D mesh; got axes "
            f"{tuple(mesh.axis_names)} (build one with "
            "repro.launch.mesh.make_sweep_mesh)")
    return mesh.axis_names[0], int(mesh.devices.size)


@functools.partial(jax.jit, static_argnames=("result_cache",))
def _bounds_surface(lam: Array, params: ServerParams,
                    result_cache=None):
    lo, hi = queueing.response_time_bounds(lam, params)
    if result_cache is not None:
        hit_r, s_cache = result_cache
        # upper: the Eq 8 mixture (queueing.apply_result_cache is the one
        # home of the convention: conservative, load NOT thinned).  That
        # conservatism is only valid UPWARD — for the lower bound both
        # legs use the mechanistically thinned rates (hits really do
        # bypass the servers), so lo stays a genuine lower bound.
        hi = queueing.apply_result_cache(hi, lam, hit_r, s_cache)
        lo_thin, _ = queueing.response_time_bounds(
            lam * (1.0 - hit_r), params)
        r_cache_thin = queueing.mm1_residence_time(lam * hit_r, s_cache)
        lo = lo_thin * (1.0 - hit_r) + r_cache_thin * hit_r
    util = queueing.utilization(lam, queueing.service_time_server(params))
    return lo, hi, util


def sweep_analytical(grid: SweepGrid, *, mesh=None) -> SweepResult:
    """Evaluate Eq 7/Eq 8 bounds over the whole grid as one jitted call.

    Replicated cells are evaluated at the per-replica rate ``lam / r``
    (replication splits arrivals evenly — the paper's linear-gain
    assumption, which `sweep_simulated` cross-checks under real routing).

    ``mesh`` — a 1-D device mesh from `repro.launch.mesh.make_sweep_mesh`
    — shards the flattened scenario axis across devices with
    `compat.shard_map`: the bounds are pure elementwise math, so an
    N-scenario grid splits into N/n_devices-sized shards with zero
    communication.  The grid is padded (edge-replicated) to a device
    multiple and the padding sliced off, so any grid size works.  This is
    how the million-scenario planning surfaces in
    ``examples/global_sweep.py`` are evaluated.
    """
    if grid.autoscale is not None:
        raise ValueError(
            "sweep_analytical cannot evaluate a policy grid: the Eq 7/8 "
            "bounds assume a fixed replica count (use sweep_simulated)")
    if grid.fault is not None:
        raise ValueError(
            "sweep_analytical cannot evaluate a fault grid: the Eq 7/8 "
            "bounds assume every replica is up (use sweep_simulated)")
    lam_rep = grid.lam_replica()
    _, params = grid.broadcast()
    shape = grid.shape
    if mesh is None:
        lo, hi, util = _bounds_surface(lam_rep, params, grid.result_cache)
        return SweepResult(
            grid=grid,
            response_lower=jnp.broadcast_to(lo, shape),
            response_upper=jnp.broadcast_to(hi, shape),
            utilization=jnp.broadcast_to(util, shape),
        )

    axis, n_dev = _check_sweep_mesh(mesh)
    n = grid.n_scenarios
    pad = (-n) % n_dev

    def flat(x):
        x = jnp.broadcast_to(jnp.asarray(x, jnp.float32), shape).reshape(-1)
        return jnp.pad(x, (0, pad), mode="edge") if pad else x

    lam_flat = flat(lam_rep)
    params_flat = ServerParams(**{
        f.name: flat(getattr(params, f.name))
        for f in dataclasses.fields(ServerParams)})
    spec = PartitionSpec(axis)
    fn = functools.partial(_bounds_surface, result_cache=grid.result_cache)
    lo, hi, util = compat.shard_map(
        fn, mesh=mesh, in_specs=(spec, spec), out_specs=spec,
        check_vma=False)(lam_flat, params_flat)
    unflat = lambda x: x[:n].reshape(shape)  # noqa: E731
    return SweepResult(
        grid=grid,
        response_lower=unflat(lo),
        response_upper=unflat(hi),
        utilization=unflat(util),
    )


@dataclasses.dataclass(frozen=True)
class SimSweepResult:
    """Streaming-simulated surfaces: mean, spread AND quantiles.

    ``stats`` is a :class:`repro.core.simulator.SimResult` whose fields
    all carry the full grid shape (L,P,C,D,H,R) in front (the histogram
    has one trailing bin axis), so every summary the streaming engine
    accumulates is available as a dense surface.
    """

    grid: SweepGrid
    stats: simulator.SimResult

    @property
    def mean(self) -> Array:
        return self.stats.mean_response

    @property
    def response(self) -> Array:
        """The default planning surface for frontier extraction."""
        return self.mean

    @property
    def std(self) -> Array:
        return self.stats.std_response

    def quantile(self, q: float) -> Array:
        """q-quantile response surface, shaped `grid.shape`."""
        return self.stats.quantile(q)

    @property
    def sample_response(self) -> Array:
        """(L,P,C,D,H,R, tap_size) reservoir sample of per-query responses.

        NaN-padded when a scenario saw fewer post-warmup queries than the
        tap size; empty trailing axis unless the sweep ran with
        ``tap_size > 0``.  This is calibration's trace source for swept
        simulated systems (`repro.calibrate.measure.traces_from_sweep`).
        """
        return self.stats.tap_response


def _sharded_batch(run, mesh, key, proc: ArrivalProcess,
                   params: ServerParams) -> simulator.SimResult:
    """Scenario-shard one (p, r) batch dispatch over a 1-D mesh.

    ``run(key, proc, params)`` is the already-parameterized batch entry
    (all static knobs bound).  The slab's scenario axis is padded
    (edge-replicated) to a device multiple, every leading-axis input is
    sharded with one ``PartitionSpec(axis)``, and each device draws from
    its OWN key (``jax.random.split(key, n_devices)``) — so sharded
    surfaces are statistically equivalent but not bit-identical to the
    unsharded ones.  Every `SimResult` leaf leads with the scenario
    axis, so a single spec works as the out-spec pytree prefix; padded
    scenarios are sliced off before returning.
    """
    axis, n_dev = _check_sweep_mesh(mesh)
    n_slab = proc.rates.shape[0]
    pad = (-n_slab) % n_dev
    rates = jnp.pad(proc.rates, ((0, pad), (0, 0)), mode="edge") \
        if pad else proc.rates
    params = jax.tree_util.tree_map(
        lambda x: jnp.pad(x, ((0, pad),), mode="edge"), params) \
        if pad else params
    keys = jax.random.split(key, n_dev)
    bin_seconds = proc.bin_seconds
    spec = PartitionSpec(axis)

    def shard_fn(keys_d, rates_d, params_d):
        proc_d = ArrivalProcess.piecewise(rates_d, bin_seconds)
        return run(keys_d[0], proc_d, params_d)

    res = compat.shard_map(
        shard_fn, mesh=mesh, in_specs=(spec, spec, spec),
        out_specs=spec, check_vma=False)(keys, rates, params)
    return jax.tree_util.tree_map(lambda x: x[:n_slab], res)


def _static_count(x, axis_name: str) -> int:
    v = int(round(float(x)))
    if abs(v - float(x)) > 1e-3:
        raise ValueError(
            f"simulation needs integer {axis_name} counts; got {x} "
            "(the analytical path accepts fractional values)")
    return v


def sweep_simulated(
    grid: SweepGrid,
    key: Array,
    *,
    n_queries: int = 20_000,
    mode: str = "exponential",
    impl: str = "xla",
    warmup_fraction: float = 0.1,
    chunk_size: int = simulator.DEFAULT_CHUNK,
    hist_bins: int = simulator.DEFAULT_HIST_BINS,
    tap_size: int = 0,
    profile: Optional[Array] = None,
    profile_bin_seconds: float = 3600.0,
    cluster: Optional[ClusterSpec] = None,
    routing: Optional[str] = None,
    replica_impl: Optional[str] = None,
    telemetry: Optional[simulator.TelemetrySpec] = None,
    mesh=None,
) -> SimSweepResult:
    """Streaming-simulated response surfaces over the grid.

    One streaming dispatch per distinct (p, r) pair (static shapes);
    within a dispatch all L*C*D*H scenarios run as one `lax.scan` over
    query chunks.  Peak memory is n_scenarios_per_dispatch * r * p *
    chunk_size floats — the total query count only adds scan iterations,
    so `n_queries` can be 10-100x what the old materializing path could
    hold.

    ``cluster=ClusterSpec(...)`` supplies the per-dispatch topology
    (routing policy, result cache, replica engine); the grid's own axes
    supply what varies, so ``ClusterSpec.r`` must stay at its default
    (the ``grid.r`` axis is the replica sweep) and
    ``ClusterSpec.autoscale`` must be None (policies go on
    ``SweepGrid(autoscale=...)`` so they form a sweep axis).  The loose
    ``routing=`` / ``replica_impl=`` keywords keep working through the
    `repro.core.cluster.resolve_cluster` deprecation shim.  A
    ``result_cache`` may live on the spec or on the grid but not both.

    Replicated cells (``grid.r``) run the dispatcher topology under
    the spec's routing ("round_robin" | "random" | "jsq"); each
    scenario's lam stays the total rate, so the surface directly
    cross-checks the analytical ``lam / r`` splitting assumption,
    imbalance included.  The effective ``result_cache`` switches on the
    simulator's mechanistic Eq 8 dispatcher cache in every dispatch.

    ``grid.autoscale`` swaps the replica axis for a POLICY axis: one
    dispatch per `AutoscalePolicy`, each provisioning ``max_r`` replicas
    with the policy deciding how many are active per chunk.  Every cell
    then carries ``stats.replica_seconds`` / ``stats.elapsed_seconds``
    (the autoscaler's cost integral), which `extract_frontier` uses to
    price policies by time-averaged fleet size.

    ``grid.fault`` swaps the replica axis for a FAULT-SCENARIO axis
    instead: one dispatch per `repro.core.faults.FaultSpec` (None
    entries are the fault-free baseline), every cell at the grid's one
    fixed replica count.  Simulation-only like policy grids; the cells'
    ``stats.spill_count`` / ``degraded_count`` channels come back with
    the grid shape, so degraded-vs-full-quorum frontiers read straight
    off the sweep (see ``examples/failover_stress.py``).

    ``profile`` makes the load non-stationary: a (n_bins,) relative-rate
    curve (e.g. `repro.workloadgen.loadgen.diurnal_rates`) that tiles with
    period ``n_bins * profile_bin_seconds``.  It is normalized to mean 1,
    so the grid's lam axis stays the *time-averaged* rate and the peak
    rate is ``lam * max(profile)/mean(profile)``.

    ``tap_size > 0`` carries the simulator's bounded reservoir tap through
    every scenario, surfacing a uniform sample of raw per-query response
    times on :attr:`SimSweepResult.sample_response` (calibration's trace
    source) without re-materializing sample paths.

    ``replica_impl`` passes through to the simulator: "fused" (default)
    routes + compacts + segment-scans each chunk in one kernel pass with
    r-independent peak memory; "masked" is the r-times-the-work oracle.

    ``telemetry=TelemetrySpec(...)`` streams the per-time-bin
    `repro.obs.timeline.Timeline` through every dispatch: the
    ``stats.timeline`` leaves come back with the full grid shape in
    front (e.g. utilization is (L,P,C,D,H,R, n_bins, r, p)).  None (the
    default) is the bit-identical pre-telemetry program.

    ``mesh`` — a 1-D device mesh from `repro.launch.mesh.make_sweep_mesh`
    — shards each dispatch's L*C*D*H scenario slab across devices via
    `compat.shard_map` (scenarios never communicate, so the program is
    pure SPMD).  Slabs are padded (edge-replicated) to a device multiple
    and sliced back; each device streams its shard with its OWN PRNG key,
    so sharded surfaces are statistically equivalent, not bit-identical,
    to unsharded ones.
    """
    spec = resolve_cluster(cluster, routing=routing,
                           replica_impl=replica_impl,
                           caller="sweep_simulated")
    if spec.r != 1:
        raise ValueError(
            "sweep_simulated takes replica counts from the grid's r "
            "axis; leave ClusterSpec.r at its default")
    if spec.autoscale is not None:
        raise ValueError(
            "autoscale policies form a sweep axis: put them on "
            "SweepGrid(autoscale=...) rather than the ClusterSpec")
    if spec.fault is not None:
        raise ValueError(
            "fault scenarios form a sweep axis: put them on "
            "SweepGrid(fault=...) rather than the ClusterSpec")
    if spec.result_cache is not None and grid.result_cache is not None:
        raise ValueError(
            "result_cache given on both the ClusterSpec and the grid; "
            "keep exactly one")
    cache = (spec.result_cache if spec.result_cache is not None
             else grid.result_cache)
    policies = grid.autoscale
    faults = grid.fault
    if telemetry is not None and policies is not None:
        max_rs = {pol.max_r for pol in policies}
        if len(max_rs) > 1:
            raise ValueError(
                "telemetry timelines stack a per-replica axis across "
                "policy cells, so every policy needs the same max_r; "
                f"got {sorted(max_rs)}")
    shape = grid.shape
    lam_full, params_full = grid.broadcast_full()

    # hoisted slab extraction: ONE moveaxis/reshape per field up front —
    # (L,P,C,D,H,R) -> (P, R, L*C*D*H) — so every (p, r) dispatch just
    # indexes a row instead of re-gathering its slab from the 6-D tensor
    def slab(x):
        return jnp.moveaxis(x, (1, 5), (0, 1)).reshape(
            shape[1], shape[5], -1)

    lam_slabs = slab(lam_full)
    field_slabs = {f.name: slab(getattr(params_full, f.name))
                   for f in dataclasses.fields(ServerParams)}
    if profile is not None:
        base_proc = ArrivalProcess.piecewise(
            jnp.asarray(profile), profile_bin_seconds).normalized()

    n_p, n_cfg = grid.p.shape[0], shape[5]
    # host-side reads of the static axes: np.asarray on the concrete
    # grid arrays stays concrete even under an ambient trace, whereas
    # grid.p[i] would become a tracer and break float() — this keeps
    # sweep_simulated runnable under jax.eval_shape (the staticcheck
    # shape contract) with an abstract lam axis
    p_axis = np.asarray(grid.p)
    r_axis = None if policies is not None else np.asarray(grid.r)
    # flat indexing (no reshape) keeps both legacy uint32 and new-style
    # typed PRNG keys working: split always yields a 1-D sequence of keys
    keys = jax.random.split(key, n_p * n_cfg)

    def dispatch(k, lam_ij, params_ij, p: int, cell: ClusterSpec):
        """The single batch entry shared by every (p, config) cell.

        All cells with equal static (p, cell) and slab shape reuse one
        compiled program (jit caches on statics + avals); sharding wraps
        the SAME bound entry in `_sharded_batch`, so the mesh path and
        the local path cannot drift apart.
        """
        arrival = (ArrivalProcess.stationary(lam_ij) if profile is None
                   else base_proc.scaled_by(lam_ij))
        # profile-fidelity chunk clamp happens HERE, host-side, where the
        # rates are still concrete — under shard_map they are tracers and
        # the simulator's internal clamp deliberately no-ops
        chunk = simulator._clamp_chunk_for_profile(
            arrival, max(1, min(chunk_size, n_queries)))
        run = functools.partial(
            simulator.simulate_fork_join_batch, n_queries=n_queries,
            p=p, mode=mode, impl=impl, warmup_fraction=warmup_fraction,
            chunk_size=chunk, hist_bins=hist_bins, tap_size=tap_size,
            cluster=cell, telemetry=telemetry)
        if mesh is None:
            return run(k, arrival, params_ij)
        return _sharded_batch(run, mesh, k, arrival, params_ij)

    def fill_fault_channels(res, r: int):
        """Zero-filled fault channels for the ``fault=None`` baseline cell.

        A fault axis may mix FaultSpec cells with a fault-free baseline;
        the baseline's SimResult carries ``None`` in the fault slots,
        which would break the pytree stack across cells.  Materialize
        the semantically-equal constants instead: nothing spilled or
        degraded, every replica up for every arrival.
        """
        if res.spill_count is not None:
            return res
        z = jnp.zeros_like(res.count)
        kw = dict(spill_count=z, unavail_count=z, degraded_count=z)
        if res.timeline is not None and res.timeline.up_sum is None:
            tl = res.timeline
            kw["timeline"] = dataclasses.replace(
                tl, up_sum=tl.count * float(r),
                spill_sum=jnp.zeros_like(tl.count),
                degraded_sum=jnp.zeros_like(tl.count))
        return dataclasses.replace(res, **kw)

    p_slabs = []
    for i in range(n_p):
        p = _static_count(p_axis[i], "server")
        cfg_slabs = []
        for j in range(n_cfg):
            if policies is not None:
                cell = ClusterSpec(routing=spec.routing,
                                   result_cache=cache,
                                   replica_impl=spec.replica_impl,
                                   autoscale=policies[j])
            elif faults is not None:
                cell = ClusterSpec(r=_static_count(r_axis[0], "replica"),
                                   routing=spec.routing,
                                   result_cache=cache,
                                   replica_impl=spec.replica_impl,
                                   fault=faults[j])
            else:
                cell = ClusterSpec(r=_static_count(r_axis[j], "replica"),
                                   routing=spec.routing,
                                   result_cache=cache,
                                   replica_impl=spec.replica_impl)
            params_ij = ServerParams(
                **{n: v[i, j] for n, v in field_slabs.items()})
            res = dispatch(keys[i * n_cfg + j], lam_slabs[i, j],
                           params_ij, p, cell)
            if faults is not None:
                res = fill_fault_channels(res, cell.r)
            slab_shape = (shape[0], shape[2], shape[3], shape[4])
            cfg_slabs.append(jax.tree_util.tree_map(
                lambda x: x.reshape(slab_shape + x.shape[1:]), res))
        # stack the replica/policy axis behind (L,C,D,H) -> axis 4
        p_slabs.append(jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=4), *cfg_slabs))
    # stack the p axis into position 1 -> (L,P,C,D,H,R)
    stats = jax.tree_util.tree_map(
        lambda *xs: jnp.stack(xs, axis=1), *p_slabs)
    return SimSweepResult(grid=grid, stats=stats)


def default_config_cost(p: Array, cpu: Array, disk: Array,
                        hit: Array) -> Array:
    """Illustrative hardware cost: servers are the unit.

    Each server costs 1 baseline, plus 0.5 per unit of extra CPU speed,
    0.25 per unit of extra disk speed, and up to 1.0 for the memory that
    buys a high disk-cache hit ratio.  Replace via the ``cost_fn``
    argument of :func:`extract_frontier` for a real procurement model.
    """
    per_server = (1.0 + 0.5 * (cpu - 1.0) + 0.25 * (disk - 1.0)
                  + 1.0 * hit)
    return p * per_server


@dataclasses.dataclass(frozen=True)
class Frontier:
    """Per-lambda cheapest feasible configuration (all arrays (L,)).

    On a policy grid ``r`` is the chosen policy's MEAN ACTIVE replica
    count (``replica_seconds / elapsed_seconds`` — generally fractional)
    and ``autoscale`` holds the chosen `AutoscalePolicy` per rate;
    otherwise ``autoscale`` is None and ``r`` is the static count.  On a
    fault grid ``fault`` holds the chosen cell's `FaultSpec` (or None
    for the fault-free baseline cell) per rate — the harshest-surviving
    scenario when the surface is fed through a min, or simply the
    cheapest feasible cell under the default argmin.
    """

    lam: Array
    feasible: Array    # bool: any config meets the SLO at this rate
    cost: Array        # cost of the chosen config; +inf if infeasible
    p: Array
    cpu: Array
    disk: Array
    hit: Array
    response: Array    # targeted-surface response of the chosen config (s)
    r: Array = None    # replicas of the chosen config ((L,); 1s pre-grid)
    autoscale: Optional[tuple[AutoscalePolicy, ...]] = None
    fault: Optional[tuple[Optional[FaultSpec], ...]] = None

    def describe(self, i: int) -> str:
        if not bool(self.feasible[i]):
            return (f"lam={float(self.lam[i]):g} qps: INFEASIBLE "
                    f"anywhere on the grid")
        if self.autoscale is not None:
            pol = self.autoscale[i]
            rep_s = (f" autoscale {pol.min_r}..{pol.max_r}"
                     f" @{pol.target_utilization:.0%}"
                     f" (mean active {float(self.r[i]):.2f})")
        else:
            reps = 1 if self.r is None else int(round(float(self.r[i])))
            rep_s = f" x{reps} replicas" if reps != 1 else ""
            if self.fault is not None:
                ft = self.fault[i]
                rep_s += (" (fault-free)" if ft is None
                          else f" under {ft!r}")
        return (f"lam={float(self.lam[i]):g} qps: p={float(self.p[i]):g} "
                f"cpu x{float(self.cpu[i]):g} disk x{float(self.disk[i]):g} "
                f"hit={float(self.hit[i]):.2f}{rep_s} -> "
                f"R<={float(self.response[i]) * 1e3:.0f} ms "
                f"(cost {float(self.cost[i]):.1f})")


def extract_frontier(
    result: Union[SweepResult, SimSweepResult],
    slo_seconds: float,
    *,
    cost_fn: Optional[Callable[[Array, Array, Array, Array], Array]] = None,
    surface: Optional[Array] = None,
    quantile: Optional[float] = None,
) -> Frontier:
    """Cheapest config whose response surface meets the SLO, per lambda.

    The targeted surface defaults to ``result.response`` (the Eq 7 upper
    bound for analytical sweeps, the simulated mean for streaming sweeps).
    Pass ``quantile=0.95`` to plan against tail latency instead — "the
    cheapest configuration whose p95 survives the load" — or hand any
    precomputed ``surface`` shaped `grid.shape`.

    Fully vectorized: the (P,C,D,H,R) config-cost tensor is masked by the
    feasibility surface and argmin-reduced per arrival rate.  ``cost_fn``
    prices ONE replica's hardware (p, cpu, disk, hit); replication
    multiplies it — r copies of the cluster cost r times as much.

    On a policy grid the replica multiplier is not a grid constant: each
    cell is priced by its OBSERVED time-averaged fleet size
    ``replica_seconds / elapsed_seconds`` (the autoscaler's cost
    integral), so "cheapest" means fewest replica-seconds per second —
    directly comparable to a static-r plan's ``cost * r`` at the same
    SLO compliance.
    """
    grid = result.grid
    if surface is None:
        surface = (result.quantile(quantile) if quantile is not None
                   else result.response)
    cost_fn = cost_fn or default_config_cost
    costs = cost_fn(
        grid.p.reshape(-1, 1, 1, 1),
        grid.cpu.reshape(1, -1, 1, 1),
        grid.disk.reshape(1, 1, -1, 1),
        grid.hit.reshape(1, 1, 1, -1),
    )
    costs = jnp.broadcast_to(costs, grid.shape[1:5])
    if grid.autoscale is not None:
        stats = getattr(result, "stats", None)
        if stats is None or stats.replica_seconds is None:
            raise ValueError(
                "a policy grid prices configurations by simulated "
                "replica-seconds; extract the frontier from a "
                "sweep_simulated result")
        eff_r = stats.replica_seconds / jnp.maximum(
            stats.elapsed_seconds, 1e-30)             # (L,P,C,D,H,A)
        costs_full = costs[None, :, :, :, :, None] * eff_r
    else:
        eff_r = None
        costs_full = (costs[..., None]
                      * grid.r.reshape(1, 1, 1, 1, -1))[None]

    feasible = surface <= slo_seconds                     # (L,P,C,D,H,R)
    masked = jnp.where(feasible, costs_full, jnp.inf)
    flat = masked.reshape(grid.shape[0], -1)
    best = jnp.argmin(flat, axis=1)
    best_cost = jnp.take_along_axis(flat, best[:, None], axis=1)[:, 0]

    ip, ic, id_, ih, ir = jnp.unravel_index(best, grid.shape[1:])
    chosen_resp = jnp.take_along_axis(
        surface.reshape(grid.shape[0], -1),
        best[:, None], axis=1)[:, 0]
    any_feasible = jnp.isfinite(best_cost)
    chosen_fault = None
    if grid.autoscale is not None:
        chosen_r = jnp.take_along_axis(
            eff_r.reshape(grid.shape[0], -1), best[:, None], axis=1)[:, 0]
        chosen_pol = tuple(grid.autoscale[int(t)] for t in np.asarray(ir))
    elif grid.fault is not None:
        # fault cells all run at the one fixed replica count; the 6th
        # index picks the failure scenario, not the fleet size
        chosen_r = jnp.broadcast_to(grid.r[:1], ir.shape)
        chosen_pol = None
        chosen_fault = tuple(grid.fault[int(t)] for t in np.asarray(ir))
    else:
        chosen_r = grid.r[ir]
        chosen_pol = None
    return Frontier(
        lam=grid.lam,
        feasible=any_feasible,
        cost=best_cost,
        p=grid.p[ip],
        cpu=grid.cpu[ic],
        disk=grid.disk[id_],
        hit=grid.hit[ih],
        response=chosen_resp,
        r=chosen_r,
        autoscale=chosen_pol,
        fault=chosen_fault,
    )
