"""Workload characterization (paper Section 4).

Five distribution families exactly as evaluated in the paper — Exponential,
Gamma, Weibull, Lognormal, Pareto — with MLE fitting, their CDFs, and the
paper's two goodness-of-fit criteria (sum of squared differences between
empirical and model CDFs, and the Kolmogorov-Smirnov statistic).

Plus: Zipf popularity sampling/fitting (Fig 2) and the log *folding*
procedure (Sec 4.2) that boosts a dataset's arrival rate while preserving
its distributional shape.

Everything is jnp and jit-friendly; fits use fixed-iteration Newton steps
(no data-dependent Python control flow) so they can run inside scans and
be vmapped over many one-hour windows at once.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

__all__ = [
    "DistFit",
    "fit_exponential",
    "fit_gamma",
    "fit_weibull",
    "fit_lognormal",
    "fit_pareto",
    "fit_all",
    "ks_statistic",
    "ssq_statistic",
    "best_fit",
    "zipf_probs",
    "sample_zipf",
    "fit_zipf_alpha",
    "rank_frequencies",
    "fold_timestamps",
    "sample_poisson_arrivals",
    "empirical_cdf_points",
]

_NEWTON_ITERS = 25


@dataclasses.dataclass(frozen=True)
class DistFit:
    """A fitted distribution: name, parameter pytree, and its CDF."""

    name: str
    params: Dict[str, Array]
    cdf: Callable[[Array], Array] = dataclasses.field(compare=False)

    def __repr__(self) -> str:  # params as floats for readability
        p = {k: float(v) for k, v in self.params.items()}
        return f"DistFit({self.name}, {p})"


# --------------------------------------------------------------------------
# MLE fits. Each returns a DistFit whose cdf closes over fitted params.
# --------------------------------------------------------------------------

def fit_exponential(x: Array) -> DistFit:
    """f(t) = (1/mu) exp(-t/mu); MLE mu = mean (paper footnote 6)."""
    mu = jnp.mean(x)
    return DistFit("exponential", {"mu": mu}, lambda t: 1.0 - jnp.exp(-t / mu))


def fit_gamma(x: Array) -> DistFit:
    """Gamma(k, theta) via Newton on  ln k - psi(k) = s."""
    x = jnp.asarray(x, jnp.float32)
    mean = jnp.mean(x)
    s = jnp.log(mean) - jnp.mean(jnp.log(x))
    s = jnp.maximum(s, 1e-6)
    k0 = (3.0 - s + jnp.sqrt((s - 3.0) ** 2 + 24.0 * s)) / (12.0 * s)

    def newton(k, _):
        f = jnp.log(k) - jax.scipy.special.digamma(k) - s
        fp = 1.0 / k - jax.scipy.special.polygamma(1, k)
        k = jnp.clip(k - f / fp, 1e-4, 1e6)
        return k, None

    k, _ = jax.lax.scan(newton, k0, None, length=_NEWTON_ITERS)
    theta = mean / k
    return DistFit(
        "gamma", {"k": k, "theta": theta},
        lambda t: jax.scipy.special.gammainc(k, jnp.maximum(t, 0.0) / theta))


def fit_weibull(x: Array) -> DistFit:
    """Weibull(k, lam) via Newton on the profile-likelihood shape equation."""
    x = jnp.asarray(x, jnp.float32)
    lx = jnp.log(x)
    mlx = jnp.mean(lx)

    def g(k):
        # numerically stable weighted means of log x under weights x^k
        w = jnp.exp(k * (lx - jnp.max(lx)))
        sw = jnp.sum(w)
        return jnp.sum(w * lx) / sw - 1.0 / k - mlx

    k0 = jnp.asarray(1.0, jnp.float32)

    def newton(k, _):
        f = g(k)
        fp = jax.grad(g)(k)
        k = jnp.clip(k - f / fp, 1e-3, 1e3)
        return k, None

    k, _ = jax.lax.scan(newton, k0, None, length=_NEWTON_ITERS)
    lam = jnp.mean(x ** k) ** (1.0 / k)
    return DistFit(
        "weibull", {"k": k, "lam": lam},
        lambda t: 1.0 - jnp.exp(-jnp.maximum(t / lam, 0.0) ** k))


def fit_lognormal(x: Array) -> DistFit:
    lx = jnp.log(jnp.asarray(x, jnp.float32))
    mu = jnp.mean(lx)
    sigma = jnp.maximum(jnp.std(lx), 1e-6)
    return DistFit(
        "lognormal", {"mu": mu, "sigma": sigma},
        lambda t: 0.5 * (1.0 + jax.scipy.special.erf(
            (jnp.log(jnp.maximum(t, 1e-30)) - mu) / (sigma * jnp.sqrt(2.0)))))


def fit_pareto(x: Array) -> DistFit:
    """Pareto(x_m, alpha), x_m = min(x); MLE alpha = n / sum ln(x/x_m)."""
    x = jnp.asarray(x, jnp.float32)
    xm = jnp.min(x)
    alpha = x.shape[0] / jnp.maximum(jnp.sum(jnp.log(x / xm)), 1e-6)
    return DistFit(
        "pareto", {"xm": xm, "alpha": alpha},
        lambda t: jnp.where(t >= xm, 1.0 - (xm / jnp.maximum(t, xm)) ** alpha, 0.0))


def fit_all(x: Array) -> Dict[str, DistFit]:
    """All five families of Sec 4.2/4.3."""
    return {
        f.name: f
        for f in (fit_exponential(x), fit_gamma(x), fit_weibull(x),
                  fit_lognormal(x), fit_pareto(x))
    }


# --------------------------------------------------------------------------
# Goodness of fit (paper Sec 4.2): SSQ of CDF differences + KS statistic.
# --------------------------------------------------------------------------

def empirical_cdf_points(x: Array) -> tuple[Array, Array]:
    xs = jnp.sort(x)
    n = xs.shape[0]
    ecdf = (jnp.arange(1, n + 1, dtype=jnp.float32)) / n
    return xs, ecdf


def ks_statistic(x: Array, fit: DistFit) -> Array:
    """Kolmogorov-Smirnov D = sup |F_emp - F_model| over the sample."""
    xs = jnp.sort(x)
    n = xs.shape[0]
    f = fit.cdf(xs)
    hi = jnp.arange(1, n + 1, dtype=jnp.float32) / n
    lo = jnp.arange(0, n, dtype=jnp.float32) / n
    return jnp.maximum(jnp.max(jnp.abs(f - hi)), jnp.max(jnp.abs(f - lo)))


def ssq_statistic(x: Array, fit: DistFit) -> Array:
    """Sum of squared differences between the empirical and model CDFs."""
    xs, ecdf = empirical_cdf_points(x)
    return jnp.sum((fit.cdf(xs) - ecdf) ** 2)


def best_fit(x: Array, criterion: str = "ks") -> tuple[str, Dict[str, Array]]:
    """Name + per-family statistic; lowest statistic wins."""
    stat = ks_statistic if criterion == "ks" else ssq_statistic
    fits = fit_all(x)
    stats = {name: stat(x, f) for name, f in fits.items()}
    winner = min(stats, key=lambda k: float(stats[k]))
    return winner, stats


# --------------------------------------------------------------------------
# Zipf popularity (paper Fig 2): Prob(E_n) ∝ n^-alpha.
# --------------------------------------------------------------------------

def zipf_probs(n_elements: int, alpha: float) -> Array:
    ranks = jnp.arange(1, n_elements + 1, dtype=jnp.float32)
    w = ranks ** (-alpha)
    return w / jnp.sum(w)


def sample_zipf(key: Array, n_elements: int, alpha: float, shape) -> Array:
    """Inverse-CDF sampling of Zipf ranks (0-based element ids)."""
    cdf = jnp.cumsum(zipf_probs(n_elements, alpha))
    u = jax.random.uniform(key, shape)
    return jnp.searchsorted(cdf, u).astype(jnp.int32)


def rank_frequencies(ids: Array, n_elements: int) -> Array:
    """Frequency of each element, sorted descending (rank-frequency curve)."""
    counts = jnp.zeros((n_elements,), jnp.int32).at[ids].add(1)
    return jnp.sort(counts)[::-1]


def fit_zipf_alpha(freqs_desc: Array, min_count: int = 5) -> Array:
    """Slope of the log-log rank-frequency line (paper's fitting method).

    Weighted least squares over ranks whose count >= min_count (the deep
    tail of 1-count elements otherwise biases the slope).
    """
    n = freqs_desc.shape[0]
    ranks = jnp.arange(1, n + 1, dtype=jnp.float32)
    mask = (freqs_desc >= min_count).astype(jnp.float32)
    x = jnp.log(ranks)
    y = jnp.log(jnp.maximum(freqs_desc.astype(jnp.float32), 1e-9))
    w = mask / jnp.maximum(jnp.sum(mask), 1.0)
    xm = jnp.sum(w * x)
    ym = jnp.sum(w * y)
    slope = jnp.sum(w * (x - xm) * (y - ym)) / jnp.maximum(
        jnp.sum(w * (x - xm) ** 2), 1e-9)
    return -slope  # alpha


# --------------------------------------------------------------------------
# Folding (paper Sec 4.2) and Poisson arrival synthesis.
# --------------------------------------------------------------------------

def fold_timestamps(timestamps: Array, window: float) -> tuple[Array, Array]:
    """Fold arrivals modulo ``window`` and sort.

    Returns (folded_sorted_timestamps, boost_factor) where boost_factor is
    the arrival-rate multiplier = ceil(duration / window) merged windows.
    """
    t = jnp.asarray(timestamps)
    folded = jnp.sort(jnp.mod(t, window))
    duration = jnp.max(t) - jnp.min(t)
    boost = jnp.ceil(duration / window)
    return folded, boost


def sample_poisson_arrivals(key: Array, lam: float, n: int) -> Array:
    """n arrival timestamps of a rate-lam Poisson process (cumsum of Exp)."""
    gaps = jax.random.exponential(key, (n,)) / lam
    return jnp.cumsum(gaps)
