"""Queueing-network performance model for vertical search engines.

Implements the analytical model of Badue et al., "Capacity Planning for
Vertical Search Engines" (2010), Section 5:

  * Eq 1 — index-server service time with disk-cache decomposition
  * Eq 2/4 — open-network MVA residence time (M/M/1):  R = S / (1 - lambda S)
  * Eq 3 — utilization U = lambda S
  * Eq 6 — Nelson-Tantawi fork-join upper bound: R_cluster <= H_p R_server
  * Eq 7 — two-sided bound on system response time
  * Eq 8 — application-level result-cache extension

All functions are pure jnp and broadcast over their inputs, so a whole
what-if grid (lambda x scenario x p) evaluates as one XLA program.
Saturated operating points (lambda S >= 1) return +inf rather than
negative residence times.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

Array = jax.Array
ArrayLike = Union[Array, float]

__all__ = [
    "ServerParams",
    "harmonic_number",
    "service_time_server",
    "mm1_residence_time",
    "utilization",
    "fork_join_lower_bound",
    "fork_join_upper_bound",
    "fork_join_interpolation",
    "response_time_bounds",
    "apply_result_cache",
    "response_time_with_result_cache",
    "saturation_rate",
    "expected_max_exponential",
    "response_time_quantile_upper",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ServerParams:
    """Model input parameters (paper Table 4).

    Times are in *seconds*; ``lam`` (the arrival rate) in queries/second.
    Any field may be a scalar or an array — everything broadcasts.
    Registered as a pytree so it can flow through jit/vmap/scan.
    """

    p: ArrayLike            # number of index servers
    s_broker: ArrayLike     # broker CPU service time per query
    s_hit: ArrayLike        # CPU time, full disk-cache hit
    s_miss: ArrayLike       # CPU time, query touching disk
    s_disk: ArrayLike       # disk time per query
    hit: ArrayLike          # P(full disk-cache hit)

    def scale(self, *, memory=None, cpu: float = 1.0, disk: float = 1.0) -> "ServerParams":
        """Apply a Section-6 style upgrade: CPU/disk `x times faster`.

        ``memory`` is not a scalar knob — larger memory changes (s_hit,
        s_miss, s_disk, hit) jointly; callers pass a re-measured
        ``ServerParams`` for that (see `repro.core.capacity.MEMORY_TABLE`).
        """
        if memory is not None:
            raise ValueError(
                "memory upgrades require re-measured parameters; use "
                "capacity.scenario_params(memory=...) instead")
        return dataclasses.replace(
            self,
            s_broker=jnp.asarray(self.s_broker) / cpu,
            s_hit=jnp.asarray(self.s_hit) / cpu,
            s_miss=jnp.asarray(self.s_miss) / cpu,
            s_disk=jnp.asarray(self.s_disk) / disk,
        )


def harmonic_number(p: ArrayLike) -> Array:
    """H_p = 1 + 1/2 + ... + 1/p, valid for real p via digamma.

    H_p = digamma(p + 1) + gamma.  Exact for integer p (matches the
    paper's Eq 6) and smooth in-between so the capacity planner can
    differentiate through the number of servers.
    """
    p = jnp.asarray(p, dtype=jnp.float32)
    euler_gamma = 0.57721566490153286
    return jax.scipy.special.digamma(p + 1.0) + euler_gamma


def expected_max_exponential(p: ArrayLike, mean: ArrayLike) -> Array:
    """E[max of p iid Exp(mean)] = H_p * mean — the origin of Eq 6.

    The join of a fork-join stage waits for the slowest of p servers;
    under full imbalance the per-server residence times behave as iid
    exponentials and the synchronization cost is exactly H_p.
    """
    return harmonic_number(p) * jnp.asarray(mean)


def service_time_server(params: ServerParams) -> Array:
    """Eq 1:  S_server = hit*S_hit + (1-hit)*(S_miss + S_disk)."""
    hit = jnp.asarray(params.hit)
    return hit * jnp.asarray(params.s_hit) + (1.0 - hit) * (
        jnp.asarray(params.s_miss) + jnp.asarray(params.s_disk))


def utilization(lam: ArrayLike, service_time: ArrayLike) -> Array:
    """Eq 3:  U = lambda * S."""
    return jnp.asarray(lam) * jnp.asarray(service_time)


def mm1_residence_time(lam: ArrayLike, service_time: ArrayLike) -> Array:
    """Eq 2/4:  R = S / (1 - lambda*S); +inf at/over saturation."""
    s = jnp.asarray(service_time, dtype=jnp.float32)
    rho = jnp.asarray(lam) * s
    r = s / (1.0 - rho)
    return jnp.where(rho < 1.0, r, jnp.inf)


def fork_join_lower_bound(lam: ArrayLike, params: ServerParams) -> Array:
    """Lower bound: ignore the join — R_cluster >= R_server (Sec 5.2.2).

    This is the Chowdhury & Pass model the paper argues under-estimates.
    """
    return mm1_residence_time(lam, service_time_server(params))


def fork_join_upper_bound(lam: ArrayLike, params: ServerParams) -> Array:
    """Eq 6 (Nelson-Tantawi): R_cluster <= H_p * R_server."""
    return harmonic_number(params.p) * fork_join_lower_bound(lam, params)


def fork_join_interpolation(lam: ArrayLike, params: ServerParams) -> Array:
    """Nelson & Tantawi (1988) refined approximation for p >= 2.

    R_p ~= [ H_p/H_2 + 4 rho (p-1)/(11 p) (1 - H_p/H_2) * ... ] — we use
    the standard two-server-exact scaling form:

        R_p ≈ ( H_p / H_2 ) * [ 1 + rho/2 * (p - 1)/p * 4/11 ] * R_2
        R_2 = (12 - rho) / (88 - 41 rho... )

    The literature form actually used (Nelson-Tantawi Eq. 22):
        R_2 = (12 - rho) / (8 (1 - rho)) * S    (exact for p = 2)
        R_p ≈ [ H_p/H_2 + 4 rho/11 * (p-1)/p * (1 - H_p/H_2) ] ... — to
    avoid transcription risk we expose the *scaled-harmonic* estimate

        R_p ≈ (H_p / H_2) * (4/3) * [ (12 - rho) / (8 (1-rho)) - 1.5 ] * S
              + R_mm1 ... (degenerates poorly)

    Keeping the model honest: this function returns the widely used
    approximation  R_p ≈ [H_p + rho * (H_p - 1) * 0.5] / (1 + rho*0.5)
    * R_server, which is exact at rho→0 (order statistics of service
    times only) and approaches H_p * R_server as rho→1.  It always lies
    within the paper's Eq 7 bounds; tests assert that invariant.
    """
    lam = jnp.asarray(lam)
    s = service_time_server(params)
    rho = jnp.clip(lam * s, 0.0, 1.0 - 1e-6)
    hp = harmonic_number(params.p)
    r1 = mm1_residence_time(lam, s)
    # blend weight grows with utilization: light load -> join cost is the
    # order-statistic of *service* times (H_p on S); heavy load -> the
    # order-statistic of full residence times (H_p on R).
    blend = rho
    return (1.0 - blend) * (hp * s + (r1 - s)) + blend * hp * r1


def broker_residence_time(lam: ArrayLike, params: ServerParams) -> Array:
    """Eq 4 applied to the broker."""
    return mm1_residence_time(lam, params.s_broker)


def response_time_bounds(lam: ArrayLike, params: ServerParams) -> tuple[Array, Array]:
    """Eq 7:  (R_server + R_broker,  H_p R_server + R_broker)."""
    r_broker = broker_residence_time(lam, params)
    lo = fork_join_lower_bound(lam, params) + r_broker
    hi = fork_join_upper_bound(lam, params) + r_broker
    return lo, hi


def apply_result_cache(
    response: ArrayLike,
    lam: ArrayLike,
    hit_result: ArrayLike,
    s_broker_cache_hit: ArrayLike,
) -> Array:
    """The Eq 8 blend, applicable to ANY response surface:

    R_cached = R * (1 - hit_r) + R_broker_cache * hit_r

    where R_broker_cache is the M/M/1 residence of the broker's cache
    queue at the full (un-thinned, conservative as in the paper) arrival
    rate.  This is THE one place the Eq 8 mixture convention lives —
    `repro.core.sweep` applies it to both bounds of whole grids.
    """
    hit_r = jnp.asarray(hit_result)
    r_cache = mm1_residence_time(lam, s_broker_cache_hit)
    return jnp.asarray(response) * (1.0 - hit_r) + r_cache * hit_r


def response_time_with_result_cache(
    lam: ArrayLike,
    params: ServerParams,
    hit_result: ArrayLike,
    s_broker_cache_hit: ArrayLike,
) -> Array:
    """Eq 8: upper bound with application-level result caching at the broker.

    R <= (H_p R_server + R_broker) (1 - hit_r) + R_broker_cache * hit_r

    Conservative as in the paper: lambda is NOT thinned at the index
    servers (the cache only short-circuits the response-time path).
    """
    _, hi = response_time_bounds(lam, params)
    return apply_result_cache(hi, lam, hit_result, s_broker_cache_hit)


def saturation_rate(params: ServerParams) -> Array:
    """Largest sustainable lambda: min(1/S_server, 1/S_broker)."""
    s = service_time_server(params)
    return jnp.minimum(1.0 / s, 1.0 / jnp.asarray(params.s_broker))


def erlang_c(lam: ArrayLike, service_time: ArrayLike, c: int) -> Array:
    """M/M/c waiting probability (Erlang C).

    Supports the paper's stated future work: index servers with multiple
    processing threads.  Stable iff lam * S < c.
    """
    lam = jnp.asarray(lam, jnp.float32)
    s = jnp.asarray(service_time, jnp.float32)
    a = lam * s                       # offered load (erlangs)
    rho = a / c
    # sum_{k<c} a^k/k! via cumulative products (static c)
    terms = [jnp.ones_like(a)]
    for k in range(1, c):
        terms.append(terms[-1] * a / k)
    s0 = sum(terms)
    top = terms[-1] * a / c / jnp.maximum(1.0 - rho, 1e-9)
    pw = top / (s0 + top)
    return jnp.where(rho < 1.0, pw, jnp.ones_like(pw))


def mmc_residence_time(lam: ArrayLike, service_time: ArrayLike,
                       c: int) -> Array:
    """M/M/c mean response: S + P_wait * S / (c - lam*S)."""
    lam = jnp.asarray(lam, jnp.float32)
    s = jnp.asarray(service_time, jnp.float32)
    pw = erlang_c(lam, s, c)
    w = pw * s / jnp.maximum(c - lam * s, 1e-9)
    return jnp.where(lam * s < c, s + w, jnp.inf)


def response_time_bounds_mmc(lam: ArrayLike, params: "ServerParams",
                             threads: int) -> tuple[Array, Array]:
    """Eq 7 with multi-threaded index servers (M/M/c per server).

    The fork-join structure is unchanged; each server's residence time is
    the M/M/c response instead of M/M/1 — the paper's future-work model.
    """
    s = service_time_server(params)
    r_server = mmc_residence_time(lam, s, threads)
    r_broker = mm1_residence_time(lam, params.s_broker)
    lo = r_server + r_broker
    hi = harmonic_number(params.p) * r_server + r_broker
    return lo, hi


def two_phase_response_upper(
    lam: ArrayLike,
    params: "ServerParams",
    *,
    s_docserver: ArrayLike,
    p_docservers: ArrayLike,
) -> Array:
    """Both query phases (paper Sec 1): index retrieval + snippet/title
    generation at a cluster of document servers.

    Phase 2 "has a roughly constant cost, independent of the size of the
    collection": each query touches the k document servers holding its
    top answers; modeled as one more fork-join stage of M/M/1 servers
    with service s_docserver, H_{p_doc}-bounded like phase 1.
    """
    _, hi1 = response_time_bounds(lam, params)
    r_doc = mm1_residence_time(lam, s_docserver)
    return hi1 + harmonic_number(p_docservers) * r_doc


def response_time_quantile_upper(
    lam: ArrayLike, params: ServerParams, q: ArrayLike
) -> Array:
    """q-percentile upper estimate (paper Sec 7 'future work').

    Treat the cluster residence time as the max of p iid exponentials
    with mean R_server: F(t) = (1 - exp(-t/R))^p, so
    t_q = -R * ln(1 - q^(1/p)).  Broker M/M/1 response is exponential
    with mean R_broker: add its q-quantile.  An upper estimate in the
    same spirit as Eq 7 (independence + exponentiality assumptions).
    """
    q = jnp.asarray(q, dtype=jnp.float32)
    r_server = fork_join_lower_bound(lam, params)
    p = jnp.asarray(params.p, dtype=jnp.float32)
    t_cluster = -r_server * jnp.log1p(-jnp.power(q, 1.0 / p))
    r_broker = broker_residence_time(lam, params)
    t_broker = -r_broker * jnp.log1p(-q)
    return t_cluster + t_broker
