"""Fault injection: the one description of everything that can break.

The paper's queueing model (and the streaming engine up to PR 9) assumes
every broker, index server and replica is permanently up.  Production
verticals are sized for the opposite question — *one replica down at
global peak, do the survivors hold the SLO?* — and answer it with
degraded operation: failover routing, partial-quorum (k-of-p) result
merging, hedged retries.  :class:`FaultSpec` is the frozen, hashable
description of those failure modes, carried on
:class:`repro.core.cluster.ClusterSpec` as ``fault=`` and compiled into
the streaming scan exactly like ``autoscale=``:

    spec = ClusterSpec(r=3, fault=FaultSpec(outages=((0, 120.0, 300.0),)))
    res = simulate_fork_join(key, lam, n, params, cluster=spec)
    res.availability, res.spill_fraction

Four orthogonal failure channels:

* **Replica outages** — deterministic windows (``outages``: tuples of
  ``(replica, start_s, end_s)`` in simulated time) and/or a stochastic
  per-replica two-state Markov process (``mtbf_seconds`` /
  ``mttr_seconds``: per query step of length dt an up replica fails
  w.p. 1 - exp(-dt/MTBF), a down one repairs w.p. 1 - exp(-dt/MTTR) —
  memoryless, so the process is exact for any interarrival spacing).
  Down replicas receive no new queries: oblivious policies spill to the
  next surviving replica, JSQ masks them out of the argmin, and
  in-flight work keeps draining (same semantics as autoscale scale-in).
* **Degraded servers** — ``degraded``: tuples of ``(server, factor)``
  multiplying that server column's service times on every replica (a
  slow disk or thermally throttled CPU on one index partition; the
  fork-join join then pays the straggler tax of Eq 6 for it).
* **Partial-quorum merge** — ``broker_timeout_seconds`` with
  ``quorum_k``: the broker waits for all p servers up to the timeout;
  past it, it returns with whatever has arrived as soon as at least k
  answers are in (the k-th order statistic of the per-server completion
  times).  Such responses are *degraded* (missing partitions) and are
  counted separately in ``SimResult.degraded_fraction``.
* **Hedged retries** — ``hedge_after_seconds`` fires a duplicate
  fork-join to spare capacity once the join has straggled that long
  past the broker fork; ``hedge_attempts`` duplicates back off
  geometrically by ``hedge_backoff``.  Duplicates carry fresh service
  draws (salted RNG stream) and are served off-queue — an optimistic
  spare-capacity model, the response-side counterpart of Eq 6's
  `hedge_threshold`.

The recurrence behind the outage mask (:func:`fault_scan`) is strictly
per-query with the carry threaded through, so it is chunking-invariant
by construction (property-tested in tests/test_faults.py), and all
stochastic draws come from a dedicated salted stream so a fault-free
run's RNG plan is untouched.  ``FaultSpec=None`` compiles to the
bit-identical pre-fault program; an all-up spec (no outages, factors of
1, infinite timeout) is bit-identical in every shared statistic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

__all__ = ["FaultSpec", "fault_init", "fault_scan"]


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static description of injected faults and degradation policy.

    outages:      ``((replica, start_s, end_s), ...)`` deterministic
                  outage windows in simulated time; the replica index is
                  taken modulo the provisioned count.
    mtbf_seconds: mean time between failures of the stochastic
                  per-replica outage process (None disables it).
    mttr_seconds: mean time to repair for the stochastic process.
    degraded:     ``((server, factor), ...)`` — multiply server
                  column ``server``'s service times by ``factor`` on
                  every replica (slow disk / degraded CPU).
    broker_timeout_seconds: broker patience past the fork; None means
                  full quorum always (wait for all p servers).
    quorum_k:     answers required before the timeout may cut the join
                  short (defaults to 1 when a timeout is set).
    hedge_after_seconds: straggle time after the broker fork before a
                  hedged duplicate fork fires (None disables hedging).
    hedge_backoff: geometric delay factor between successive duplicates.
    hedge_attempts: number of duplicates the broker may fire.

    Instances are frozen and hashable (tuple fields are coerced) so a
    spec rides the simulator's jit cache as a static argument, exactly
    like ``AutoscalePolicy`` and ``TelemetrySpec``.
    """

    outages: tuple = ()
    mtbf_seconds: Optional[float] = None
    mttr_seconds: float = 60.0
    degraded: tuple = ()
    broker_timeout_seconds: Optional[float] = None
    quorum_k: Optional[int] = None
    hedge_after_seconds: Optional[float] = None
    hedge_backoff: float = 2.0
    hedge_attempts: int = 1

    def __post_init__(self):
        object.__setattr__(self, "outages", tuple(
            (int(i), float(s), float(e)) for i, s, e in self.outages))
        object.__setattr__(self, "degraded", tuple(
            (int(i), float(f)) for i, f in self.degraded))
        for i, s, e in self.outages:
            if i < 0:
                raise ValueError(f"outage replica index {i} < 0")
            if not e > s:
                raise ValueError(
                    f"outage window ({s}, {e}) must have end > start")
        for i, f in self.degraded:
            if i < 0:
                raise ValueError(f"degraded server index {i} < 0")
            if not f > 0.0:
                raise ValueError(f"slowdown factor must be > 0; got {f}")
        if self.mtbf_seconds is not None and not self.mtbf_seconds > 0.0:
            raise ValueError("mtbf_seconds must be > 0 or None")
        if not float(self.mttr_seconds) > 0.0:
            raise ValueError("mttr_seconds must be > 0")
        if (self.broker_timeout_seconds is not None
                and not self.broker_timeout_seconds > 0.0):
            raise ValueError("broker_timeout_seconds must be > 0 or None")
        if self.quorum_k is not None and int(self.quorum_k) < 1:
            raise ValueError(f"quorum_k must be >= 1; got {self.quorum_k}")
        if (self.hedge_after_seconds is not None
                and not self.hedge_after_seconds > 0.0):
            raise ValueError("hedge_after_seconds must be > 0 or None")
        if not float(self.hedge_backoff) >= 1.0:
            raise ValueError("hedge_backoff must be >= 1")
        if int(self.hedge_attempts) < 1:
            raise ValueError("hedge_attempts must be >= 1")

    @property
    def has_outages(self) -> bool:
        """True when any replica can ever be down."""
        return bool(self.outages) or self.mtbf_seconds is not None

    @property
    def wants_rng(self) -> bool:
        """True when the spec consumes random draws (salted stream)."""
        return (self.mtbf_seconds is not None
                or self.hedge_after_seconds is not None)

    def quorum(self, p: int) -> int:
        """Effective k for a p-way fork (``quorum_k`` clipped to p)."""
        k = 1 if self.quorum_k is None else int(self.quorum_k)
        return min(max(k, 1), int(p))

    def hedge_delays(self) -> tuple:
        """Fire times of the duplicate forks, relative to the fork."""
        if self.hedge_after_seconds is None:
            return ()
        base = float(self.hedge_after_seconds)
        back = float(self.hedge_backoff)
        delays, t = [], 0.0
        for j in range(int(self.hedge_attempts)):
            t += base * back ** j
            delays.append(t)
        return tuple(delays)


def fault_init(spec: FaultSpec, n_scen: int, r: int):
    """Initial outage carry: per-replica up state, all up at t=0."""
    import jax.numpy as jnp
    return (jnp.ones((n_scen, r), jnp.int32),)


def fault_scan(spec: FaultSpec, r: int, carry, t_arr, gaps, u=None):
    """Per-query replica-up mask over one block of queries.

    t_arr: (S, n) absolute arrival times (for the deterministic outage
    windows); gaps: (S, n) interarrival seconds (hazard exposure of the
    stochastic process); u: (S, n, r) uniforms from the salted fault
    stream, required iff ``spec.mtbf_seconds`` is set.  The stochastic
    recurrence is strictly per-query with the carry threaded through,
    so splitting a stream into blocks and chaining the carry yields the
    SAME masks as one monolithic call (chunking-invariant, mirroring
    `repro.launch.elastic.autoscale_scan`).

    Returns ``(new_carry, up (S, n, r) bool)`` — ``up[s, i, j]`` is
    whether replica j can accept query i in scenario s.
    """
    import jax
    import jax.numpy as jnp

    n_scen, n = t_arr.shape
    up = jnp.ones((n_scen, n, r), bool)

    for idx, start, end in spec.outages:
        in_win = (t_arr >= start) & (t_arr < end)            # (S, n)
        hit = jnp.arange(r) == (idx % r)                     # (r,)
        up = up & ~(in_win[:, :, None] & hit[None, None, :])

    if spec.mtbf_seconds is None:
        return carry, up

    if u is None:
        raise ValueError("fault_scan needs uniforms u when mtbf_seconds "
                         "is set")
    mtbf = float(spec.mtbf_seconds)
    mttr = float(spec.mttr_seconds)

    def step(c, inp):
        (st,) = c
        gap, u_q = inp                                       # (S,), (S, r)
        p_fail = 1.0 - jnp.exp(-gap / mtbf)                  # (S,)
        p_fix = 1.0 - jnp.exp(-gap / mttr)
        st = jnp.where(st > 0,
                       (u_q >= p_fail[:, None]).astype(jnp.int32),
                       (u_q < p_fix[:, None]).astype(jnp.int32))
        return (st,), st

    xs = (gaps.T, jnp.moveaxis(u, 1, 0))                     # (n, S[, r])
    carry, st_seq = jax.lax.scan(step, carry, xs)            # (n, S, r)
    return carry, up & (jnp.moveaxis(st_seq, 0, 1) > 0)
