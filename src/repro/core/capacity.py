"""Capacity planning engine (paper Section 6).

Encodes the paper's measured parameter tables (Table 5 validation cluster,
Table 6 100-server case study with 1x..4x main memory) and the Scenario 1-6
what-if machinery: resource upgrades, SLO solving, replication sizing, and
the application-level result cache (Eq 8).

All sweeps evaluate as single XLA programs over (lambda-grid x scenario).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import queueing
from repro.core.cluster import ClusterSpec, resolve_cluster
from repro.core.faults import FaultSpec
from repro.core.queueing import ServerParams
from repro.launch.elastic import AutoscalePolicy

Array = jax.Array

__all__ = [
    "TABLE5_PARAMS",
    "MEMORY_TABLE",
    "broker_service_time",
    "scenario_params",
    "upper_bound_curve",
    "max_rate_under_slo",
    "replicas_needed",
    "CapacityPlan",
    "plan_capacity",
    "upgrade_grid",
]

_MS = 1e-3

# --- Paper Table 5: validation cluster (8 servers, b = 1.25M pages) -------
TABLE5_PARAMS = ServerParams(
    p=8, s_broker=0.52 * _MS, s_hit=9.20 * _MS, s_miss=10.04 * _MS,
    s_disk=28.08 * _MS, hit=0.17)

TABLE5_SBROKER = {2: 0.33 * _MS, 4: 0.39 * _MS, 8: 0.52 * _MS}

# --- Paper Table 6: case-study parameters, p=100, b = 10M pages -----------
# Keyed by main-memory size as a multiple of the reference machine.
# (s_hit, s_miss, s_disk, hit)
MEMORY_TABLE = {
    1: (28.23 * _MS, 35.31 * _MS, 66.03 * _MS, 0.02),
    2: (33.38 * _MS, 33.77 * _MS, 35.89 * _MS, 0.09),
    3: (34.57 * _MS, 32.66 * _MS, 30.48 * _MS, 0.15),
    4: (34.68 * _MS, 32.04 * _MS, 26.14 * _MS, 0.18),
}


def broker_service_time(p) -> Array:
    """Paper's broker fit: S_broker = 3.18e-2 * p + 0.265  (milliseconds).

    R^2 = 0.99999 on the Table 5 measurements; gives 3.45 ms at p = 100.
    """
    p = jnp.asarray(p, jnp.float32)
    return (3.18e-2 * p + 0.265) * _MS


def scenario_params(
    *, memory: int = 1, cpu: float = 1.0, disk: float = 1.0, p: int = 100,
) -> ServerParams:
    """Build Section-6 scenario parameters.

    memory in {1,2,3,4} selects the re-measured Table 6 column; cpu/disk
    are speedup factors applied per the paper (divide CPU times by ``cpu``,
    disk time by ``disk``; the broker is CPU-bound so it scales with cpu).
    """
    s_hit, s_miss, s_disk, hit = MEMORY_TABLE[memory]
    return ServerParams(
        p=p,
        s_broker=broker_service_time(p) / cpu,
        s_hit=s_hit / cpu,
        s_miss=s_miss / cpu,
        s_disk=s_disk / disk,
        hit=hit,
    )


# Named paper scenarios (Section 6 / Figure 12).
def scenario(name: str, p: int = 100) -> ServerParams:
    table = {
        "baseline": dict(memory=1),
        "memory+disks": dict(memory=4, disk=4.0),
        "memory+cpus": dict(memory=4, cpu=4.0),
        "cpus+disks": dict(memory=1, cpu=4.0, disk=4.0),
        "memory+cpus+disks": dict(memory=4, cpu=4.0, disk=4.0),
    }
    return scenario_params(p=p, **table[name])


def upper_bound_curve(lam_grid: Array, params: ServerParams) -> Array:
    """Eq 7 upper bound over a lambda grid (one XLA program)."""
    _, hi = queueing.response_time_bounds(lam_grid, params)
    return hi


def max_rate_under_slo(
    params: ServerParams,
    slo_seconds: float,
    *,
    result_cache: Optional[tuple[float, float]] = None,
    iters: int = 60,
) -> Array:
    """Largest lambda with upper-bound response time <= SLO (bisection).

    result_cache: optional (hit_result, s_broker_cache_hit) enabling Eq 8.
    R(lambda) is monotone increasing up to saturation, so bisection on
    [0, saturation_rate) is exact to float precision.
    """
    lam_max = queueing.saturation_rate(params) * (1.0 - 1e-6)

    def response(lam):
        if result_cache is None:
            _, hi = queueing.response_time_bounds(lam, params)
            return hi
        hit_r, s_cache = result_cache
        return queueing.response_time_with_result_cache(
            lam, params, hit_r, s_cache)

    lo = jnp.asarray(0.0)
    hi = lam_max

    def body(state, _):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        ok = response(mid) <= slo_seconds
        return (jnp.where(ok, mid, lo), jnp.where(ok, hi, mid)), None

    (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=iters)
    # infeasible SLO (even lambda->0 exceeds it) -> 0
    feasible = response(jnp.asarray(1e-6)) <= slo_seconds
    return jnp.where(feasible, lo, 0.0)


def replicas_needed(
    params: ServerParams,
    target_rate: float,
    slo_seconds: float,
    *,
    result_cache: Optional[tuple[float, float]] = None,
) -> tuple[Array, Array]:
    """Cluster replicas to serve target_rate within the SLO (Sec 6).

    Replication splits arrivals evenly; gains are linear per the paper.
    Returns (n_replicas, per_replica_rate).
    """
    per_replica = max_rate_under_slo(params, slo_seconds,
                                     result_cache=result_cache)
    n = jnp.ceil(jnp.asarray(target_rate) / jnp.maximum(per_replica, 1e-9))
    return n.astype(jnp.int32), per_replica


@dataclasses.dataclass(frozen=True)
class CapacityPlan:
    """Output of plan_capacity — the manager-facing answer (Sec 5, Q i-iii).

    ``response_simulated_ms``/``response_simulated_p95_ms`` are filled
    when the plan was cross-checked by the replicated streaming simulator
    (``plan_capacity(..., simulate=True)``): the planned topology —
    ``n_replicas`` dispatcher-routed copies of the p-server cluster,
    result cache included — run at the full target rate.

    ``autoscale``/``mean_active_replicas`` are filled when the cross
    check ran an elastic fleet (``cluster=ClusterSpec(autoscale=...)``):
    the policy that was simulated and the time-averaged active replica
    count it actually used — comparing it to ``n_replicas`` (the static
    Sec-6 answer, which stays the provisioning headline) quantifies the
    elastic saving.

    ``survive_faults``/``response_faulted_p95_ms`` are the N+k
    survivability extension (``plan_capacity(..., survive_faults=k)``):
    the fleet is provisioned with k spare replicas so the SLO holds
    with k replicas down, and — when the simulated cross-check ran —
    ``response_faulted_p95_ms`` is the observed p95 of exactly that
    degraded scenario (k replicas held down for the whole run, failover
    routing spilling their share to the survivors).
    """

    n_replicas: int
    servers_per_replica: int
    total_servers: int
    per_replica_rate_qps: float
    response_upper_ms: float
    response_lower_ms: float
    utilization: float
    response_simulated_ms: Optional[float] = None
    response_simulated_p95_ms: Optional[float] = None
    routing: Optional[str] = None
    autoscale: Optional[AutoscalePolicy] = None
    mean_active_replicas: Optional[float] = None
    survive_faults: int = 0
    response_faulted_p95_ms: Optional[float] = None


def plan_capacity(
    params: ServerParams,
    target_rate: float,
    slo_seconds: float,
    *,
    cluster: Optional[ClusterSpec] = None,
    result_cache: Optional[tuple[float, float]] = None,
    simulate: bool = False,
    key=None,
    routing: Optional[str] = None,
    n_queries: int = 60_000,
    mode: str = "exponential",
    survive_faults: int = 0,
) -> CapacityPlan:
    """Section-6 sizing, optionally cross-checked by simulation.

    The analytical path is unchanged: ``replicas_needed`` sizes the
    cluster off the Eq 7/Eq 8 upper bound.  ``simulate=True``
    additionally runs the replicated streaming simulator
    (`repro.core.simulator.simulate_fork_join` with ``r=n_replicas`` and
    the same result cache) at the FULL target rate, so the plan's
    headline numbers carry a mechanistic sanity check of the even-split
    assumption under an actual routing policy.

    ``cluster=ClusterSpec(...)`` supplies the topology knobs (routing,
    result cache, replica engine, autoscale policy); its ``r`` must stay
    at the default — sizing the fleet is this function's job.  The loose
    ``routing=`` / ``result_cache=`` keywords keep working through the
    `repro.core.cluster.resolve_cluster` deprecation shim.

    With ``autoscale=AutoscalePolicy(...)`` on the spec the simulated
    cross-check runs THAT elastic fleet instead of ``n_replicas`` static
    copies (the policy's ``max_r`` sets provisioning), and the plan
    reports the policy plus its time-averaged ``mean_active_replicas``
    — the replica-seconds integral that makes "elastic vs static" a
    like-for-like cost comparison.  Policies need the simulator, so
    ``simulate=False`` with an autoscale policy is an error.

    ``survive_faults=k`` is the N+k survivability criterion (the
    ROADMAP's "one replica down at global peak" question, k=1): the
    fleet is sized so the SLO still holds with k replicas down — the
    Eq 7/8 bound is evaluated at the SURVIVOR rate ``target_rate /
    (n - k)`` and ``n`` gains k spares, so the plan is always at least
    as conservative as the fault-free one (equal at k=0).  With
    ``simulate=True`` the cross-check runs exactly that degraded
    scenario — k replicas held down for the whole run via a
    `repro.core.faults.FaultSpec` outage window, failover spilling
    their share to survivors — and if the observed p95 still misses
    the SLO (routing imbalance the even-split bound can't see), the
    fleet is grown further until it holds.  The plan only accepts a
    configuration whose simulated faulted p95 meets the SLO
    (``response_faulted_p95_ms``).
    """
    spec = resolve_cluster(cluster, routing=routing,
                           result_cache=result_cache,
                           caller="plan_capacity")
    if spec.r != 1:
        raise ValueError(
            "plan_capacity sizes the fleet itself; leave ClusterSpec.r "
            "at its default")
    if spec.autoscale is not None and not simulate:
        raise ValueError(
            "an autoscale policy only affects the simulated cross-check "
            "(the Eq 7/8 sizing is static); pass simulate=True")
    k_down = int(survive_faults)
    if k_down < 0:
        raise ValueError(f"survive_faults must be >= 0; got {survive_faults}")
    if k_down and spec.autoscale is not None:
        raise ValueError(
            "survive_faults sizes a static fleet; with an autoscale "
            "policy the max_r provisioning is the policy's job — plan "
            "the two separately")
    if k_down and spec.fault is not None:
        raise ValueError(
            "survive_faults synthesizes its own k-replicas-down "
            "FaultSpec; a ClusterSpec.fault would double-inject — give "
            "one or the other")
    cache = spec.result_cache
    n, per_replica = replicas_needed(
        params, target_rate, slo_seconds, result_cache=cache)
    # N+k: the bound must hold at the SURVIVOR rate target / n_base, so
    # provisioning gains k spares on top of the fault-free answer
    n_i = int(n) + k_down
    rate = float(target_rate) / max(int(n), 1)
    lo, hi = queueing.response_time_bounds(rate, params)
    if cache is not None:
        hi = queueing.response_time_with_result_cache(
            rate, params, *cache)
    p = int(jnp.asarray(params.p))
    util = queueing.utilization(rate, queueing.service_time_server(params))
    sim_ms = sim_p95_ms = mean_active = faulted_p95_ms = None
    _SIM_REPLICA_CAP = 256
    sim_r = (spec.autoscale.max_r if spec.autoscale is not None else n_i)
    feasible = float(per_replica) > 1e-9 or spec.autoscale is not None
    if simulate and feasible and sim_r <= _SIM_REPLICA_CAP:
        from repro.core import simulator  # deferred: planner-only dep
        key = jax.random.PRNGKey(0) if key is None else key
        sim_spec = (spec if spec.autoscale is not None
                    else dataclasses.replace(spec, r=n_i))
        sim = simulator.simulate_fork_join(
            key, float(target_rate), n_queries, params, mode=mode,
            cluster=sim_spec)
        sim_ms = float(sim.mean_response) * 1e3
        sim_p95_ms = float(sim.quantile(0.95)) * 1e3
        if spec.autoscale is not None:
            mean_active = float(sim.mean_active_replicas)
        if k_down:
            # the survivability check proper: k replicas held down for
            # the WHOLE run (the peak-coincident worst case), failover
            # spilling their share to the survivors.  The even-split
            # bound already sized for this; the simulation additionally
            # sees routing imbalance, so grow the fleet if p95 misses.
            horizon = 2.0 * n_queries / max(float(target_rate), 1e-9)
            down = FaultSpec(
                outages=tuple((j, 0.0, horizon) for j in range(k_down)))
            for _ in range(4):
                ft_spec = dataclasses.replace(spec, r=n_i, fault=down)
                ft = simulator.simulate_fork_join(
                    key, float(target_rate), n_queries, params,
                    mode=mode, cluster=ft_spec)
                faulted_p95_ms = float(ft.quantile(0.95)) * 1e3
                if (faulted_p95_ms <= slo_seconds * 1e3
                        or n_i >= _SIM_REPLICA_CAP):
                    break
                n_i += 1
    elif simulate:
        import warnings
        reason = ("infeasible SLO" if float(per_replica) <= 1e-9
                  else f"above the {_SIM_REPLICA_CAP}-replica simulation "
                       "cap")
        warnings.warn(
            f"skipping the simulated cross-check: the plan needs {sim_r} "
            f"replicas ({reason}); run simulate_fork_join directly with "
            "a smaller chunk_size if you really want this",
            UserWarning, stacklevel=2)
    return CapacityPlan(
        n_replicas=n_i,
        servers_per_replica=p,
        total_servers=n_i * p,
        per_replica_rate_qps=rate,
        response_upper_ms=float(hi) * 1e3,
        response_lower_ms=float(lo) * 1e3,
        utilization=float(util),
        response_simulated_ms=sim_ms,
        response_simulated_p95_ms=sim_p95_ms,
        routing=spec.routing if sim_ms is not None else None,
        autoscale=spec.autoscale if sim_ms is not None else None,
        mean_active_replicas=mean_active,
        survive_faults=k_down,
        response_faulted_p95_ms=faulted_p95_ms,
    )


def upgrade_grid(
    lam: float,
    *,
    memory: int = 1,
    cpu_speeds: Array = None,
    disk_speeds: Array = None,
    p: int = 100,
    result_cache: Optional[tuple[float, float]] = None,
) -> Array:
    """Fig 13/14 surface: upper-bound R over (cpu_speed x disk_speed)."""
    cpu_speeds = jnp.asarray(
        cpu_speeds if cpu_speeds is not None else jnp.linspace(1, 4, 7))
    disk_speeds = jnp.asarray(
        disk_speeds if disk_speeds is not None else jnp.linspace(1, 4, 7))
    s_hit, s_miss, s_disk, hit = MEMORY_TABLE[memory]
    cs = cpu_speeds[:, None]
    ds = disk_speeds[None, :]
    params = ServerParams(
        p=p,
        s_broker=broker_service_time(p) / cs,
        s_hit=s_hit / cs,
        s_miss=s_miss / cs,
        s_disk=s_disk / ds,
        hit=hit,
    )
    if result_cache is None:
        _, hi = queueing.response_time_bounds(lam, params)
        return hi
    return queueing.response_time_with_result_cache(lam, params, *result_cache)
