"""ClusterSpec: the one static description of the simulated topology.

The engine entry points historically grew one keyword per topology
feature — ``r=``, ``routing=``, ``result_cache=``, ``replica_impl=`` —
each re-threaded by hand through ``sweep_simulated``, ``plan_capacity``
and ``calibrate.validate``.  :class:`ClusterSpec` consolidates them
(plus the autoscaler, the feature that forced the redesign) into ONE
frozen, hashable object that rides the jit cache as a single static
argument:

    from repro.core.cluster import ClusterSpec
    from repro.launch.elastic import AutoscalePolicy

    spec = ClusterSpec(r=4, routing="jsq", result_cache=(0.3, 2e-3))
    res = simulate_fork_join(key, lam, n, params, cluster=spec)

    elastic = ClusterSpec(routing="jsq",
                          autoscale=AutoscalePolicy(min_r=1, max_r=6))

The loose keywords keep working through :func:`resolve_cluster` — a
deprecation shim that builds the spec and warns once per process — and
``repro.staticcheck`` rule RPR006 flags in-repo use of them outside
this shim.  ``ClusterSpec()`` (all defaults) resolves to exactly the
old defaults, so ``cluster=None`` call sites compile the bit-identical
pre-redesign program.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Optional

from repro.core.faults import FaultSpec
from repro.launch.elastic import AutoscalePolicy

__all__ = ["ClusterSpec", "ROUTING_POLICIES", "REPLICA_IMPLS",
           "resolve_cluster"]

ROUTING_POLICIES = ("round_robin", "random", "jsq")
REPLICA_IMPLS = ("fused", "masked")


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Static topology of the simulated search cluster.

    r:            replica count (each replica = broker + p servers).
                  With ``autoscale`` set, leave at the default — the
                  engine provisions ``autoscale.max_r`` and the policy
                  decides how many are active.
    routing:      dispatcher policy, one of ``ROUTING_POLICIES``.
    result_cache: ``(hit_r, s_cache)`` broker-level result cache of
                  Eq 8, or None.
    replica_impl: "fused" (segment-compacted scan, default) or
                  "masked" (full-stream re-scan oracle).
    autoscale:    optional :class:`AutoscalePolicy` making the active
                  replica count time-varying inside the scan.
    fault:        optional :class:`repro.core.faults.FaultSpec` injecting
                  replica outages, degraded servers, a partial-quorum
                  broker timeout and hedged retries into the scan.

    Instances are frozen and hashable (``result_cache`` is coerced to a
    float tuple) so a spec is a valid ``jax.jit`` static argument.
    """

    r: int = 1
    routing: str = "round_robin"
    result_cache: Optional[tuple[float, float]] = None
    replica_impl: str = "fused"
    autoscale: Optional[AutoscalePolicy] = None
    fault: Optional[FaultSpec] = None

    def __post_init__(self):
        object.__setattr__(self, "r", int(self.r))
        if self.result_cache is not None:
            hit_r, s_cache = self.result_cache
            object.__setattr__(self, "result_cache",
                               (float(hit_r), float(s_cache)))
        if self.r < 1:
            raise ValueError(f"need at least one replica; got r={self.r}")
        if self.routing not in ROUTING_POLICIES:
            raise ValueError(f"unknown routing policy {self.routing!r}; "
                             f"choose one of {ROUTING_POLICIES}")
        if self.replica_impl not in REPLICA_IMPLS:
            raise ValueError(
                f"unknown replica_impl {self.replica_impl!r}; choose "
                f"one of {REPLICA_IMPLS}")
        if self.autoscale is not None:
            if not isinstance(self.autoscale, AutoscalePolicy):
                raise TypeError("autoscale must be an AutoscalePolicy; "
                                f"got {type(self.autoscale).__name__}")
            if self.r != 1:
                raise ValueError(
                    "with autoscale= the engine provisions "
                    "autoscale.max_r replicas; leave r at its default "
                    f"(got r={self.r})")
        if self.fault is not None and not isinstance(self.fault, FaultSpec):
            raise TypeError("fault must be a repro.core.faults.FaultSpec; "
                            f"got {type(self.fault).__name__}")

    @property
    def engine_r(self) -> int:
        """Replicas the engine provisions (max_r under autoscaling)."""
        return (self.autoscale.max_r if self.autoscale is not None
                else self.r)


# the shim warns ONCE per process (not per call site): legacy keywords
# are everywhere in downstream code and a warning storm helps nobody.
# Tests reset this flag to assert the warning fires.
_warned_legacy = False


def resolve_cluster(cluster: Optional[ClusterSpec] = None, *,
                    r: Optional[int] = None,
                    routing: Optional[str] = None,
                    result_cache: Optional[tuple[float, float]] = None,
                    replica_impl: Optional[str] = None,
                    caller: str = "simulate_fork_join") -> ClusterSpec:
    """Deprecation shim: legacy loose keywords -> one ClusterSpec.

    Entry points declare the old keywords with ``None`` sentinels and
    funnel them here.  Passing both ``cluster=`` and a legacy keyword
    is an error (no silent precedence); legacy keywords alone build the
    equivalent spec and emit a single process-wide DeprecationWarning.
    """
    legacy = {k: v for k, v in (("r", r), ("routing", routing),
                                ("result_cache", result_cache),
                                ("replica_impl", replica_impl))
              if v is not None}
    if cluster is not None:
        if legacy:
            raise TypeError(
                f"{caller}() got both cluster= and deprecated keyword(s) "
                f"{sorted(legacy)}; move them onto the ClusterSpec")
        if not isinstance(cluster, ClusterSpec):
            raise TypeError("cluster must be a ClusterSpec; got "
                            f"{type(cluster).__name__}")
        return cluster
    if not legacy:
        return ClusterSpec()
    global _warned_legacy
    if not _warned_legacy:
        warnings.warn(
            f"{caller}({'/'.join(sorted(legacy))}=...) is deprecated; "
            "pass cluster=ClusterSpec(...) instead (the loose topology "
            "keywords will be removed)", DeprecationWarning, stacklevel=3)
        _warned_legacy = True
    return ClusterSpec(
        r=1 if r is None else r,
        routing="round_robin" if routing is None else routing,
        result_cache=result_cache,
        replica_impl="fused" if replica_impl is None else replica_impl)
