"""Core contribution: queueing-network capacity planning for search engines.

Modules:
  queueing   — the analytical model (Eq 1-8, fork-join bounds)
  workload   — characterization: distribution fits, Zipf, folding
  imbalance  — mechanistic disk-cache model of service-time imbalance
  capacity   — Section-6 what-if engine, SLO solver, replication planner
  simulator  — (max,+) discrete-event simulator (validation instrument)
  planner    — capacity planning for ML serving from compiled dry-run costs
"""

from repro.core.queueing import (  # noqa: F401
    ServerParams,
    harmonic_number,
    service_time_server,
    mm1_residence_time,
    utilization,
    fork_join_lower_bound,
    fork_join_upper_bound,
    response_time_bounds,
    response_time_with_result_cache,
    saturation_rate,
)
