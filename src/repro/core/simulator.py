"""Massively parallel discrete-event simulator for fork-join search clusters.

The paper validates its model on an 8-node physical cluster and leaves
"simulation-based analysis ... for larger clusters with thousands of index
servers" as future work.  This module delivers that in JAX.

Key idea: FCFS queueing is a linear recurrence in the (max, +) semiring.
With arrival times A_i (sorted) and service times S_i, the completion time

    C_i = S_i + max(A_i, C_{i-1})  =  max(a_i, C_{i-1} + b_i),
          a_i = A_i + S_i,  b_i = S_i

and the affine maps c -> max(a, c + b) compose associatively:

    (a1,b1) then (a2,b2)  =  (max(a2, a1 + b2), b1 + b2)

so an entire M/M/1 sample path is one `jax.lax.associative_scan` (O(log n)
depth), a p-server fork-join cluster is a batch dimension, and millions of
queries x thousands of servers simulate in one XLA program.  A Pallas TPU
kernel for the blockwise scan lives in `repro.kernels.maxplus_scan`.

Simulated system (paper Fig 8): broker FCFS queue -> fork to p index-server
FCFS queues -> join (max over servers) -> response = join - arrival.
Service-time generators cover three regimes:

  * "exponential" — iid Exp(S_server) per (query, server): the model's
    assumption, full imbalance across servers.
  * "cache"       — per-(query, server) Bernoulli(hit) mixture of
    Exp(s_hit) vs Exp(s_miss)+Exp(s_disk): the mechanistic story of Sec 3.4.
  * "balanced"    — identical service time for all servers per query: the
    Chowdhury & Pass assumption the paper argues against.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.queueing import ServerParams, service_time_server

Array = jax.Array

__all__ = [
    "maxplus_combine",
    "fcfs_completion_times",
    "SimResult",
    "simulate_fork_join",
    "simulate_fork_join_batch",
    "simulate_mmc",
    "sample_service_times",
    "sample_service_times_batch",
]


def maxplus_combine(x, y):
    """Associative composition of affine max-plus maps; y is *later*."""
    a1, b1 = x
    a2, b2 = y
    return jnp.maximum(a2, a1 + b2), b1 + b2


def fcfs_completion_times(arrivals: Array, services: Array,
                          impl: str = "xla") -> Array:
    """Completion times of an FCFS single-server queue.

    arrivals: (..., n) nondecreasing along the last axis.
    services: (..., n) positive.
    impl: "xla" (associative_scan) or "pallas" (TPU kernel; interpret=True
    on CPU) — both compute the identical recurrence.
    """
    a = arrivals + services
    b = services
    if impl == "pallas":
        from repro.kernels.maxplus_scan import ops as mp_ops
        out_a, _ = mp_ops.maxplus_scan(a, b)
        return out_a
    out_a, _ = jax.lax.associative_scan(maxplus_combine, (a, b), axis=-1)
    return out_a


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimResult:
    """Per-query response times plus the summary stats the paper reports."""

    response: Array          # (n_queries,) end-to-end response time
    server_residence: Array  # (n_queries,) residence at ONE tagged server
    cluster_residence: Array  # (n_queries,) fork-join (max over servers)
    broker_residence: Array  # (n_queries,)

    @property
    def mean_response(self) -> Array:
        return jnp.mean(self.response)

    @property
    def mean_server_residence(self) -> Array:
        return jnp.mean(self.server_residence)

    @property
    def mean_cluster_residence(self) -> Array:
        return jnp.mean(self.cluster_residence)

    def quantile(self, q: float) -> Array:
        return jnp.quantile(self.response, q)


def sample_service_times(
    key: Array, n_queries: int, p: int, params: ServerParams, mode: str
) -> Array:
    """(p, n_queries) per-server service times under the chosen regime."""
    s_mean = service_time_server(params)
    if mode == "exponential":
        return jax.random.exponential(key, (p, n_queries)) * s_mean
    if mode == "balanced":
        one = jax.random.exponential(key, (1, n_queries)) * s_mean
        return jnp.broadcast_to(one, (p, n_queries))
    if mode == "cache":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        is_hit = jax.random.bernoulli(k1, params.hit, (p, n_queries))
        t_hit = jax.random.exponential(k2, (p, n_queries)) * params.s_hit
        t_miss = (jax.random.exponential(k3, (p, n_queries)) * params.s_miss
                  + jax.random.exponential(k4, (p, n_queries)) * params.s_disk)
        return jnp.where(is_hit, t_hit, t_miss)
    raise ValueError(f"unknown service mode: {mode}")


def simulate_fork_join(
    key: Array,
    lam: float,
    n_queries: int,
    params: ServerParams,
    *,
    p: Optional[int] = None,
    mode: str = "exponential",
    impl: str = "xla",
    warmup_fraction: float = 0.1,
) -> SimResult:
    """Simulate the full broker + p-server fork-join network (Fig 8).

    The broker is visited once per query with service S_broker (the paper
    lumps broadcast+merge); its completions are the fork times.  Each index
    server runs an independent FCFS queue over the forked stream.  The join
    waits for the slowest server.  Warmup queries are masked out of the
    returned samples by replacing them with the post-warmup mean (keeps
    shapes static for jit).
    """
    p = int(params.p) if p is None else p  # static before tracing
    return _simulate_fork_join(key, lam, n_queries, params, p, mode, impl,
                               warmup_fraction)


@functools.partial(
    jax.jit, static_argnames=("n_queries", "p", "mode", "impl",
                              "warmup_fraction"))
def _simulate_fork_join(
    key: Array,
    lam: float,
    n_queries: int,
    params: ServerParams,
    p: int,
    mode: str,
    impl: str,
    warmup_fraction: float,
) -> SimResult:
    k_arr, k_brk, k_srv = jax.random.split(key, 3)

    gaps = jax.random.exponential(k_arr, (n_queries,)) / lam
    arrivals = jnp.cumsum(gaps)

    s_broker = (jax.random.exponential(k_brk, (n_queries,))
                * jnp.asarray(params.s_broker))
    broker_done = fcfs_completion_times(arrivals, s_broker, impl=impl)
    broker_residence = broker_done - arrivals

    services = sample_service_times(k_srv, n_queries, p, params, mode)
    fork_times = jnp.broadcast_to(broker_done, (p, n_queries))
    completions = fcfs_completion_times(fork_times, services, impl=impl)

    join = jnp.max(completions, axis=0)
    response = join - arrivals
    cluster_residence = join - broker_done
    server_residence = completions[0] - broker_done

    n_warm = int(n_queries * warmup_fraction)
    mask = jnp.arange(n_queries) >= n_warm

    def masked(x):
        mean = jnp.sum(jnp.where(mask, x, 0.0)) / jnp.maximum(
            jnp.sum(mask), 1)
        return jnp.where(mask, x, mean)

    return SimResult(
        response=masked(response),
        server_residence=masked(server_residence),
        cluster_residence=masked(cluster_residence),
        broker_residence=masked(broker_residence),
    )


def sample_service_times_batch(
    key: Array, n_scenarios: int, n_queries: int, p: int,
    params: ServerParams, mode: str,
) -> Array:
    """(n_scenarios, p, n_queries) service times; params fields are (S,).

    The batched counterpart of :func:`sample_service_times` used by the
    what-if sweep engine: every scenario gets independent randomness but
    scenario-specific means/hit ratios, in one sampling pass.
    """
    shape = (n_scenarios, p, n_queries)
    s_mean = service_time_server(params)[:, None, None]
    if mode == "exponential":
        return jax.random.exponential(key, shape) * s_mean
    if mode == "balanced":
        one = jax.random.exponential(key, (n_scenarios, 1, n_queries))
        return jnp.broadcast_to(one * s_mean, shape)
    if mode == "cache":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        hit = jnp.asarray(params.hit)[:, None, None]
        is_hit = jax.random.bernoulli(k1, jnp.broadcast_to(hit, shape))
        t_hit = (jax.random.exponential(k2, shape)
                 * jnp.asarray(params.s_hit)[:, None, None])
        t_miss = (jax.random.exponential(k3, shape)
                  * jnp.asarray(params.s_miss)[:, None, None]
                  + jax.random.exponential(k4, shape)
                  * jnp.asarray(params.s_disk)[:, None, None])
        return jnp.where(is_hit, t_hit, t_miss)
    raise ValueError(f"unknown service mode: {mode}")


def simulate_fork_join_batch(
    key: Array,
    lam: Array,
    params: ServerParams,
    n_queries: int,
    *,
    p: int,
    mode: str = "exponential",
    impl: str = "xla",
    warmup_fraction: float = 0.1,
) -> Array:
    """Mean response time of S fork-join scenarios in one XLA program.

    ``lam`` and every ``params`` field are (S,) vectors describing S
    independent scenarios that all share the SAME static server count
    ``p`` (grids over p dispatch one batch per distinct p — see
    `repro.core.sweep`).  With ``impl="pallas"`` the (S, p, n) and (S, n)
    FCFS recurrences flatten onto the row axis of `maxplus_scan`, so all
    S * (p + 1) sample paths run as a single Pallas grid.

    Memory scales as S * p * n_queries floats — size grids accordingly.
    """
    return _simulate_fork_join_batch(key, lam, params, n_queries, p, mode,
                                     impl, warmup_fraction)


@functools.partial(
    jax.jit, static_argnames=("n_queries", "p", "mode", "impl",
                              "warmup_fraction"))
def _simulate_fork_join_batch(
    key: Array,
    lam: Array,
    params: ServerParams,
    n_queries: int,
    p: int,
    mode: str,
    impl: str,
    warmup_fraction: float,
) -> Array:
    n_scen = lam.shape[0]
    k_arr, k_brk, k_srv = jax.random.split(key, 3)

    gaps = jax.random.exponential(
        k_arr, (n_scen, n_queries)) / lam[:, None]
    arrivals = jnp.cumsum(gaps, axis=-1)

    s_broker = (jax.random.exponential(k_brk, (n_scen, n_queries))
                * jnp.asarray(params.s_broker)[:, None])
    broker_done = fcfs_completion_times(arrivals, s_broker, impl=impl)

    services = sample_service_times_batch(
        k_srv, n_scen, n_queries, p, params, mode)
    fork_times = jnp.broadcast_to(
        broker_done[:, None, :], (n_scen, p, n_queries))
    completions = fcfs_completion_times(fork_times, services, impl=impl)

    join = jnp.max(completions, axis=1)
    response = join - arrivals

    n_warm = int(n_queries * warmup_fraction)
    mask = (jnp.arange(n_queries) >= n_warm)[None, :]
    return (jnp.sum(jnp.where(mask, response, 0.0), axis=-1)
            / jnp.maximum(jnp.sum(mask, axis=-1), 1))


@functools.partial(jax.jit, static_argnames=("c",))
def simulate_mmc(arrivals: Array, services: Array, c: int) -> Array:
    """M/M/c FCFS via the Kiefer-Wolfowitz workload-vector recursion.

    State w = sorted vector of the c servers' remaining work at an arrival.
    On arrival i: start delay = w[0]; after assigning service S_i to the
    least-loaded server and advancing time by the next interarrival gap:

        w' = sort( (w + S_i e_1) - gap )_+

    Supports the paper's stated future work (multi-threaded index servers).
    Returns response times (delay + own service).
    """
    gaps = jnp.diff(arrivals, prepend=arrivals[:1] * 0.0)

    def step(w, inp):
        gap, s = inp
        w = jnp.maximum(w - gap, 0.0)          # advance to this arrival
        delay = w[0]
        w = w.at[0].add(s)                     # assign to least loaded
        w = jnp.sort(w)
        return w, delay + s

    _, resp = jax.lax.scan(step, jnp.zeros((c,), services.dtype),
                           (gaps, services))
    return resp
