"""Streaming max-plus discrete-event simulator for fork-join search clusters.

The paper validates its model on an 8-node physical cluster and leaves
"simulation-based analysis ... for larger clusters with thousands of index
servers" as future work.  This module delivers that in JAX.

Key idea: FCFS queueing is a linear recurrence in the (max, +) semiring.
With arrival times A_i (sorted) and service times S_i, the completion time

    C_i = S_i + max(A_i, C_{i-1})  =  max(a_i, C_{i-1} + b_i),
          a_i = A_i + S_i,  b_i = S_i

and the affine maps c -> max(a, c + b) compose associatively:

    (a1,b1) then (a2,b2)  =  (max(a2, a1 + b2), b1 + b2)

so a whole sample path is one associative scan — and, because the maps
compose, FCFS state *streams*: the engine scans fixed-size query chunks
with ``jax.lax.scan``, carrying only the per-(scenario, server) last
completion times plus running statistics (count, sum, sum of squares and
a fixed-bin log histogram of response times for quantiles).  Peak memory
is S x p x chunk floats regardless of the total query count, so grids
10-100x larger than the old materializing engine fit, and simulated
horizons of millions of queries stream through unchanged.  Within a chunk
the scan runs either as `jax.lax.associative_scan` or as the Pallas TPU
kernel (`repro.kernels.maxplus_scan`), seeded from the carry via its
``maxplus_scan_seeded`` entry point.

Arrivals come from an :class:`repro.core.arrivals.ArrivalProcess`:
stationary Poisson, piecewise-rate diurnal/weekly profiles (each chunk
draws gaps at the rate read off at its start time — the paper's
Section 4.2 "homogeneous within a window" structure), or a replayed
trace.  Scalar rates are promoted to stationary processes, so existing
call sites keep working.

Simulated system (paper Fig 8): broker FCFS queue -> fork to p index-server
FCFS queues -> join (max over servers) -> response = join - arrival.

Replication (paper Sec 6, ``replicas_needed``): with ``r > 1`` the network
grows a front-end dispatcher that routes each query to ONE of r identical
replicas, each a full broker + p-server fork-join.  Routing policies:

  * "round_robin" — query i goes to replica i mod r (deterministic);
  * "random"      — iid uniform replica choice (Poisson thinning);
  * "jsq"         — join-shortest-queue on *carried per-replica work*: a
    fluid backlog tracker (per-replica, per-server remaining seconds)
    rides in the scan carry, and each query picks the replica whose
    slowest server frees up first.

The replicated network runs FUSED by default (``replica_impl="fused"``):
routing choices become an integer assignment per query, each chunk is
compacted so every replica's queries are contiguous (a pure reshape for
round-robin when chunk % r == 0; a stable sort otherwise), and ONE
segmented (max, +) scan per queue level covers all r replicas — each
query is scanned once on its own replica's queues, so per-chunk work is
S x p x chunk elements *independent of r* and the working set shrinks by
the same factor.  Per-replica carries seed the segment heads and are
read back off the segment ends, so the streaming chunk chain is
unchanged.  ``replica_impl="masked"`` keeps the original oracle: every
replica re-scans the FULL stream with zero-service "phantoms" for
queries routed elsewhere (a phantom C_i = max(A_i, C_{i-1}) can never
delay a later real query since arrivals are nondecreasing —
max(A_j, max(A_i, C)) = max(A_j, C) for A_j >= A_i).  The same argument
shows the two implementations produce identical sample paths in exact
arithmetic; the masked path costs ~r x more and survives only as the
equality-test reference.

An optional broker-level result cache (``result_cache=(hit_r, s_cache)``)
short-circuits service: each query is a cache hit with probability hit_r
and is then served by its replica's broker-cache FCFS queue with
Exp(s_cache) service instead of forking to the index servers — the
mechanistic counterpart of Eq 8, placed exactly where the paper puts it
(at each cluster's broker, so the analytic Eq 8 term at lam / r and the
simulated cache queue describe the same system).  Unlike the paper's
conservative bound the simulator DOES thin the index-server load, so
simulated means sit at or below the Eq 8 bound.

Topology lives on ONE static argument: ``cluster=ClusterSpec(r=...,
routing=..., result_cache=..., replica_impl=..., autoscale=...)`` (see
`repro.core.cluster`).  The loose keywords of the same names keep
working through a once-warning deprecation shim.

Elastic autoscaling (``ClusterSpec(autoscale=AutoscalePolicy(...))``)
makes the ACTIVE replica count time-varying: the engine provisions
``max_r`` replicas, and the HPA-shaped controller of
`repro.launch.elastic` rides the scan carry — per query it drains a
fluid backlog, accumulates utilization feedback, and at each decision
interval steps the active count inside [min_r, max_r].  Routing only
targets active replicas (round-robin wraps at n_active, random thins
over n_active, JSQ masks inactive candidates); scale-out replicas start
cold (their carries sit at the drained state) and scale-in replicas
drain in-flight work before going quiet.  The run additionally
accumulates the cost integral ``SimResult.replica_seconds`` (and
``elapsed_seconds``), which is what the policy sweeps in
`repro.core.sweep` price.

Service-time generators cover three regimes:

  * "exponential" — iid Exp(S_server) per (query, server): the model's
    assumption, full imbalance across servers.
  * "cache"       — per-(query, server) Bernoulli(hit) mixture of
    Exp(s_hit) vs Exp(s_miss)+Exp(s_disk): the mechanistic story of Sec 3.4.
  * "balanced"    — identical service time for all servers per query: the
    Chowdhury & Pass assumption the paper argues against.

RNG plan: all randomness for chunk c comes from ``fold_in(key, c)`` via
:func:`chunk_random_draws` — one canonical plan used by the streaming
engine and by any monolithic reference reconstruction, so the two are
comparable sample-path-for-sample-path.
"""

from __future__ import annotations

import dataclasses
import functools
import math
import warnings
from typing import Optional, Union

import jax
import jax.numpy as jnp

from repro.core import queueing
from repro.core.arrivals import ArrivalProcess
from repro.core.cluster import ClusterSpec, ROUTING_POLICIES, \
    resolve_cluster
from repro.core.faults import FaultSpec, fault_init, fault_scan
from repro.core.queueing import ServerParams, service_time_server
from repro.launch.elastic import AutoscalePolicy, autoscale_init, \
    autoscale_scan
from repro.obs.timeline import TelemetrySpec, Timeline

Array = jax.Array

__all__ = [
    "maxplus_combine",
    "fcfs_completion_times",
    "fcfs_completion_times_routed",
    "ArrivalProcess",
    "ClusterSpec",
    "AutoscalePolicy",
    "FaultSpec",
    "SimResult",
    "simulate_fork_join",
    "simulate_fork_join_batch",
    "simulate_mmc",
    "sample_service_times_batch",
    "chunk_random_draws",
    "TelemetrySpec",
    "Timeline",
    "DEFAULT_CHUNK",
    "DEFAULT_HIST_BINS",
    "ROUTING_POLICIES",
]

DEFAULT_CHUNK = 4096
DEFAULT_HIST_BINS = 256
# salts for auxiliary RNG streams: folded on top of the per-chunk key
# AFTER chunk_random_draws' fold, so enabling the tap, random routing, or
# the result cache never perturbs the canonical gap/broker/service draws
_TAP_SALT = 0x7EE5
_ROUTE_SALT = 0x2077
_CACHE_SALT = 0xCA8E
_FAULT_SALT = 0xFA17
# log-histogram span, in decades around the per-scenario analytic scale
_HIST_DECADES_BELOW = 3.0
_HIST_DECADES_TOTAL = 6.0


def maxplus_combine(x, y):
    """Associative composition of affine max-plus maps; y is *later*."""
    a1, b1 = x
    a2, b2 = y
    return jnp.maximum(a2, a1 + b2), b1 + b2


def fcfs_completion_times(arrivals: Array, services: Array,
                          impl: str = "auto",
                          carry: Optional[Array] = None) -> Array:
    """Completion times of an FCFS single-server queue.

    arrivals: (..., n) nondecreasing along the last axis.
    services: (..., n) positive.
    impl: "xla" (associative_scan) or "pallas" (TPU kernel; interpret=True
    on CPU) — both compute the identical recurrence.  The default
    "auto" picks "pallas" on real TPU hardware and "xla" everywhere
    else (interpret-mode Pallas is slower than associative_scan); see
    `repro.kernels.maxplus_scan.ops.resolve_scan_impl`.
    carry: optional (...,) completion time of the work *before* this
    block; seeding composes it on top of the scan, which is how the
    streaming engine chains chunks.
    """
    if impl == "auto":
        from repro.kernels.maxplus_scan.ops import resolve_scan_impl
        impl = resolve_scan_impl(impl)
    a = arrivals + services
    b = services
    if impl == "pallas":
        from repro.kernels.maxplus_scan import ops as mp_ops
        if carry is None:
            out_a, _ = mp_ops.maxplus_scan(a, b)
        else:
            out_a, _ = mp_ops.maxplus_scan_seeded(a, b, carry)
        return out_a
    out_a, out_b = jax.lax.associative_scan(maxplus_combine, (a, b), axis=-1)
    if carry is not None:
        out_a = jnp.maximum(out_a, jnp.asarray(carry)[..., None] + out_b)
    return out_a


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SimResult:
    """Streaming summary statistics of a fork-join simulation.

    Every field carries the run's scenario shape in front (scalar for a
    single-scenario run, ``(S,)`` for batches, the full grid shape after a
    sweep).  Warmup queries are *discarded* from every accumulator — no
    mean-substitution masking, so quantiles are unbiased.

    Quantiles come from a fixed-bin logarithmic response-time histogram:
    ``hist[..., k]`` counts responses in
    ``[exp(log_lo + k*step), exp(log_lo + (k+1)*step))``; under/overflow
    is clamped into the edge bins.

    ``tap_response`` is the ROADMAP's bounded tap: a uniform reservoir
    sample (without replacement) of per-query post-warmup response times,
    carried through the scan at fixed size instead of re-materializing the
    sample path.  Slots not yet filled hold NaN; ``tap_size=0`` (the
    default) disables the tap at zero cost.  `repro.calibrate.measure`
    consumes it as the trace source for simulated systems.

    ``timeline`` is the opt-in per-time-bin telemetry of
    `repro.obs.timeline`: None unless the run passed a
    :class:`TelemetrySpec` (None contributes no pytree leaves, so every
    existing consumer and the eval_shape contract see the same tree).

    ``replica_seconds`` / ``elapsed_seconds`` are the autoscaler's cost
    integral — provisioned replica-seconds and simulated wall seconds
    over the whole run (warmup included; provisioning is paid for from
    t=0).  None unless the run carried an
    :class:`~repro.launch.elastic.AutoscalePolicy`, following the
    timeline convention.

    ``spill_count`` / ``unavail_count`` / ``degraded_count`` are the
    fault channels (None unless the run carried a
    :class:`~repro.core.faults.FaultSpec`, same convention): post-warmup
    queries re-routed off a down replica, queries arriving with NO
    surviving replica to route to, and partial-quorum (k-of-p) results
    cut short by the broker timeout.  The derived ``availability`` /
    ``spill_fraction`` / ``degraded_fraction`` are what capacity plans
    gate on.
    """

    count: Array           # post-warmup samples per scenario
    sum_response: Array
    sumsq_response: Array
    sum_broker: Array      # broker residence sum
    sum_cluster: Array     # fork-join (max over servers) residence sum
    sum_server: Array      # residence at ONE tagged server
    hist: Array            # (..., n_bins) response-time histogram counts
    hist_log_lo: Array     # (...,) ln(lowest bin edge, seconds)
    hist_log_step: Array   # (...,) ln(bin edge ratio)
    tap_response: Array    # (..., tap_size) reservoir sample of responses
    timeline: Optional[Timeline] = None  # per-bin telemetry (see obs)
    replica_seconds: Optional[Array] = None  # integral of active r dt
    elapsed_seconds: Optional[Array] = None  # integral of dt (valid)
    spill_count: Optional[Array] = None      # failover-spilled queries
    unavail_count: Optional[Array] = None    # no surviving replica
    degraded_count: Optional[Array] = None   # k-of-p partial results

    @property
    def _n(self) -> Array:
        return jnp.maximum(self.count, 1.0)

    @property
    def mean_response(self) -> Array:
        return self.sum_response / self._n

    @property
    def var_response(self) -> Array:
        m = self.mean_response
        return jnp.maximum(self.sumsq_response / self._n - m * m, 0.0)

    @property
    def std_response(self) -> Array:
        return jnp.sqrt(self.var_response)

    @property
    def tap_size(self) -> int:
        return self.tap_response.shape[-1]

    @property
    def mean_active_replicas(self) -> Array:
        """Time-average active replica count of an autoscaled run."""
        if self.replica_seconds is None:
            raise ValueError("no autoscaler ran: replica_seconds is only "
                             "recorded under ClusterSpec(autoscale=...)")
        return self.replica_seconds / jnp.maximum(self.elapsed_seconds,
                                                  1e-30)

    def _fault_channel(self, name: str) -> Array:
        val = getattr(self, name)
        if val is None:
            raise ValueError(
                f"no faults were injected: {name} is only recorded "
                "under ClusterSpec(fault=FaultSpec(...))")
        return val

    @property
    def availability(self) -> Array:
        """Fraction of post-warmup queries that found a live replica."""
        return 1.0 - self._fault_channel("unavail_count") / self._n

    @property
    def spill_fraction(self) -> Array:
        """Fraction of queries failed over off a down replica."""
        return self._fault_channel("spill_count") / self._n

    @property
    def degraded_fraction(self) -> Array:
        """Fraction of responses returned on a k-of-p partial quorum."""
        return self._fault_channel("degraded_count") / self._n

    @property
    def mean_broker_residence(self) -> Array:
        return self.sum_broker / self._n

    @property
    def mean_cluster_residence(self) -> Array:
        return self.sum_cluster / self._n

    @property
    def mean_server_residence(self) -> Array:
        return self.sum_server / self._n

    def quantile(self, q: float) -> Array:
        """q-quantile of the response time from the streaming histogram.

        Resolution is one log bin (~2.7% at the default 256 bins over 6
        decades); interpolation inside the bin is log-linear.
        """
        n_bins = self.hist.shape[-1]
        cum = jnp.cumsum(self.hist, axis=-1)
        target = jnp.asarray(q) * self.count
        k = jnp.sum(cum < target[..., None], axis=-1)
        k = jnp.clip(k, 0, n_bins - 1)
        cum_before = jnp.where(
            k > 0,
            jnp.take_along_axis(cum, jnp.maximum(k - 1, 0)[..., None],
                                axis=-1)[..., 0],
            0.0)
        in_bin = jnp.take_along_axis(self.hist, k[..., None],
                                     axis=-1)[..., 0]
        frac = jnp.clip((target - cum_before) / jnp.maximum(in_bin, 1.0),
                        0.0, 1.0)
        return jnp.exp(self.hist_log_lo + (k + frac) * self.hist_log_step)


def sample_service_times_batch(
    key: Array, n_scenarios: int, n_queries: int, p: int,
    params: ServerParams, mode: str,
) -> Array:
    """(n_scenarios, p, n_queries) service times; params fields are (S,).

    The one service-time sampler: every scenario gets independent
    randomness but scenario-specific means/hit ratios, in one pass.
    """
    shape = (n_scenarios, p, n_queries)
    s_mean = service_time_server(params)[:, None, None]
    if mode == "exponential":
        return jax.random.exponential(key, shape) * s_mean
    if mode == "balanced":
        one = jax.random.exponential(key, (n_scenarios, 1, n_queries))
        return jnp.broadcast_to(one * s_mean, shape)
    if mode == "cache":
        k1, k2, k3, k4 = jax.random.split(key, 4)
        hit = jnp.asarray(params.hit)[:, None, None]
        is_hit = jax.random.bernoulli(k1, jnp.broadcast_to(hit, shape))
        t_hit = (jax.random.exponential(k2, shape)
                 * jnp.asarray(params.s_hit)[:, None, None])
        t_miss = (jax.random.exponential(k3, shape)
                  * jnp.asarray(params.s_miss)[:, None, None]
                  + jax.random.exponential(k4, shape)
                  * jnp.asarray(params.s_disk)[:, None, None])
        return jnp.where(is_hit, t_hit, t_miss)
    raise ValueError(f"unknown service mode: {mode}")


def chunk_random_draws(key: Array, chunk_idx, n_scen: int, chunk: int,
                       p: int, params: ServerParams, mode: str,
                       *, with_gaps: bool = True):
    """The canonical per-chunk RNG plan: ``fold_in(key, chunk_idx)``.

    Returns (unit-rate gap draws (S, chunk), unit-mean broker draws
    (S, chunk), service times (S, p, chunk)).  The streaming engine and
    any monolithic reference reconstruction MUST both use this function,
    so their sample paths agree draw-for-draw.  ``with_gaps=False`` skips
    the gap draw (trace replay supplies its own gaps); the broker/service
    subkeys are independent splits, so the other draws are unchanged.
    """
    kc = jax.random.fold_in(key, chunk_idx)
    k_arr, k_brk, k_srv = jax.random.split(kc, 3)
    u_gaps = (jax.random.exponential(k_arr, (n_scen, chunk))
              if with_gaps else None)
    u_broker = jax.random.exponential(k_brk, (n_scen, chunk))
    services = sample_service_times_batch(k_srv, n_scen, chunk, p, params,
                                          mode)
    return u_gaps, u_broker, services


def _vec_params(params: ServerParams) -> ServerParams:
    """Every field at least rank-1 (leading scenario axis)."""
    return ServerParams(**{
        f.name: jnp.atleast_1d(jnp.asarray(getattr(params, f.name)))
        for f in dataclasses.fields(ServerParams)})


def _as_batch_process(arrival: Union[ArrivalProcess, Array, float]
                      ) -> ArrivalProcess:
    """Promote a scalar/vector rate or 1-D process to (S, n_bins) rates."""
    if isinstance(arrival, ArrivalProcess):
        if arrival.rates.ndim == 1:
            return dataclasses.replace(arrival, rates=arrival.rates[None, :])
        if arrival.rates.ndim != 2:
            raise ValueError("ArrivalProcess rates must be (n_bins,) or "
                             f"(S, n_bins); got {arrival.rates.shape}")
        return arrival
    lam = jnp.atleast_1d(jnp.asarray(arrival))
    return ArrivalProcess.stationary(lam)


def _check_trace(proc: ArrivalProcess, n_queries: int) -> None:
    if proc.trace_gaps is not None and proc.trace_gaps.shape[0] < n_queries:
        raise ValueError(
            f"trace has {proc.trace_gaps.shape[0]} arrivals but "
            f"n_queries={n_queries}; shorten the horizon or fold/extend "
            "the trace")


_MIN_PROFILE_CHUNK = 64


def _clamp_chunk_for_profile(proc: ArrivalProcess, chunk: int) -> int:
    """Keep a chunk's expected duration near one profile bin.

    The engine reads the arrival rate once per chunk (at its start time);
    if a chunk spans many profile bins, the diurnal curve is undersampled
    and time-varying results bias low.  For multi-bin profiles, cap the
    chunk at the expected number of queries in the *slowest* bin so every
    bin gets visited — floored at ``_MIN_PROFILE_CHUNK`` so a near-empty
    trough bin cannot degenerate the scan into per-query steps.  A
    ``UserWarning`` reports the clamp (it trades scan iterations for
    profile fidelity; pass a coarser profile or a smaller ``chunk_size``
    to silence it).  Stationary and trace-driven processes are exempt
    (the rate never changes / gaps are exact); traced rates are left
    untouched (call the jitted core directly to opt out).
    """
    if proc.trace_gaps is not None or proc.n_bins == 1:
        return chunk
    try:
        # where-mask (not boolean indexing) so tracer rates fail on the
        # float() below with ConcretizationTypeError — under an ambient
        # trace (eval_shape, shard_map) the clamp deliberately no-ops
        # and callers clamp host-side (see repro.core.sweep)
        pos = jnp.where(proc.rates > 0, proc.rates, jnp.inf)
        min_rate = float(jnp.min(pos))
        bin_s = float(proc.bin_seconds)
    except jax.errors.ConcretizationTypeError:
        return chunk
    if not math.isfinite(min_rate):
        min_rate = 0.0
    if min_rate <= 0.0:
        return chunk
    clamped = max(_MIN_PROFILE_CHUNK, int(min_rate * bin_s))
    if clamped < chunk:
        warnings.warn(
            f"chunk_size clamped {chunk} -> {clamped} so each ~"
            f"{bin_s:g}s profile bin is sampled (slowest bin expects "
            f"~{min_rate * bin_s:.0f} queries); more scan iterations, "
            "faithful diurnal shape", UserWarning, stacklevel=3)
        return clamped
    return chunk


def _routing_assign(routing: str, r: int, key: Array, c_idx, gidx,
                    n_scen: int, chunk: int,
                    n_act: Optional[Array] = None,
                    up: Optional[Array] = None):
    """(S, chunk) integer replica assignment for oblivious policies.

    Returns ``(assign, spill, unavail)``; ``assign`` is None for "jsq"
    (its choice needs the carried work state and is computed inside the
    scan body).  Round-robin assigns by GLOBAL query index, so the
    assignment is invariant to how the stream is chunked.

    ``n_act`` (autoscaling): per-query active replica count (S, chunk).
    Oblivious policies then target only the active fleet — round-robin
    wraps the global index at n_active, random thins uniformly over
    n_active — so inactive replicas receive no new work and drain.

    ``up`` (fault injection): per-query replica-up mask (S, chunk, r)
    from `repro.core.faults.fault_scan`.  Failover spills a query
    raw-routed to a down replica onto the next surviving (and active)
    replica cyclically — the smallest offset j with up[(raw + j) % r] —
    which preserves round-robin's even split over the survivors.
    ``spill`` marks re-routed queries, ``unavail`` queries for which no
    active replica was up (those keep their raw assignment: the
    dispatcher has nowhere better to send them, and the availability
    channel records the incident).  Both are None when ``up`` is None,
    and the assignment is bit-identical to the fault-free one.
    """
    if routing == "round_robin":
        if n_act is not None:
            raw = gidx[None, :].astype(jnp.int32) % n_act
        else:
            raw = jnp.broadcast_to((gidx % r)[None, :], (n_scen, chunk))
    elif routing == "random":
        k_route = jax.random.fold_in(
            jax.random.fold_in(key, c_idx), _ROUTE_SALT)
        if n_act is not None:
            u = jax.random.uniform(k_route, (n_scen, chunk))
            raw = jnp.minimum((u * n_act).astype(jnp.int32), n_act - 1)
        else:
            raw = jax.random.randint(k_route, (n_scen, chunk), 0, r)
    else:
        return None, None, None
    if up is None:
        return raw, None, None
    ok = up
    if n_act is not None:
        ok = ok & (jnp.arange(r)[None, None, :] < n_act[:, :, None])
    cand = (raw[:, :, None] + jnp.arange(r)[None, None, :]) % r
    ok_c = jnp.take_along_axis(ok, cand, axis=-1)     # (S, chunk, r)
    j = jnp.argmax(ok_c, axis=-1).astype(jnp.int32)   # first ok offset
    any_ok = jnp.any(ok_c, axis=-1)
    assign = jnp.where(any_ok, (raw + j) % r, raw)
    return assign, any_ok & (j > 0), ~any_ok


def _jsq_route(w: Array, gaps: Array, services: Array, live: Array,
               r: int, dtype,
               n_act: Optional[Array] = None,
               up: Optional[Array] = None):
    """Join-shortest-queue on carried per-replica work (fluid backlog).

    w: (S, r, p) remaining seconds of work per replica server, measured
    at the previous arrival.  For each query (a cheap sequential scan —
    JSQ is state-dependent, so this is irreducible): drain every tracker
    by the interarrival gap, pick the replica whose *slowest* server
    frees first (the join is what the query waits for), and add the
    query's drawn per-server service times to that replica's trackers.
    ``live`` zeroes the work deposit for queries that never reach a
    replica (result-cache hits).  ``n_act`` (autoscaling): per-query
    active replica count (S, chunk); inactive replicas are masked out
    of the argmin — no new work — but their trackers keep draining,
    which is exactly the scale-in semantics (in-flight work finishes).
    ``up`` (fault injection): per-query replica-up mask (S, chunk, r);
    down replicas are masked out of the argmin exactly like inactive
    ones, and the step additionally reports whether the fault mask
    overrode the fault-free choice (``spill``) or left no candidate at
    all (``unavail``; the query then takes the fault-free choice — the
    dispatcher has nowhere better to send it).
    Returns ``(choice, work)`` — plus ``(spill, unavail)`` when ``up``
    is given — where choice is the (S, chunk) integer replica pick; the
    work state rides in the outer scan carry, so JSQ pressure persists
    across chunks; both the masked and the fused replicated paths
    consume the same choice stream.
    """
    faulty = up is not None

    def step(w, inp):
        if faulty:
            gap, svc, lv, upq = inp[:4]          # upq: (S, r)
            act = inp[4] if n_act is not None else None
        elif n_act is not None:
            gap, svc, lv, act = inp
        else:
            gap, svc, lv = inp                   # (S,), (S, p), (S,)
        w = jnp.maximum(w - gap[:, None, None], 0.0)
        backlog = jnp.max(w, axis=-1)            # (S, r) slowest server
        if n_act is not None:
            active = jnp.arange(r)[None, :] < act[:, None]
            backlog = jnp.where(active, backlog, jnp.inf)
        choice = jnp.argmin(backlog, axis=-1)    # (S,)
        if faulty:
            raw = choice
            bl_up = jnp.where(upq > 0, backlog, jnp.inf)
            any_up = jnp.any(jnp.isfinite(bl_up), axis=-1)
            choice = jnp.where(any_up, jnp.argmin(bl_up, axis=-1), raw)
            raw_up = jnp.take_along_axis(
                upq, raw[:, None], axis=-1)[:, 0] > 0
            out = (choice, any_up & ~raw_up, ~any_up)
        else:
            out = choice
        oh = (choice[:, None] == jnp.arange(r)[None, :]).astype(dtype)
        w = w + (oh * lv[:, None])[:, :, None] * svc[:, None, :]
        return w, out

    xs = (gaps.T, jnp.moveaxis(services, -1, 0), live.T)
    if faulty:
        xs = xs + (jnp.moveaxis(up.astype(jnp.int32), 1, 0),)
    if n_act is not None:
        xs = xs + (n_act.T,)
    w, out_seq = jax.lax.scan(step, w, xs)       # leaves: (chunk, S)
    if faulty:
        choice_seq, spill_seq, unav_seq = out_seq
        return choice_seq.T, w, spill_seq.T, unav_seq.T
    return out_seq.T, w


def _fcfs_segmented(arrivals: Array, services: Array, flags: Array,
                    carry_per_q: Optional[Array], impl: str) -> Array:
    """FCFS completions of many queues packed as contiguous segments.

    The fused replicated engine compacts each chunk's queries into
    per-replica contiguous runs along the last axis; ``flags`` marks the
    first element of each run.  A segmented (max, +) scan then computes
    every queue's sample path in ONE pass over chunk elements — this is
    the kernel-level fusion that replaces r masked re-scans of the full
    stream.  ``carry_per_q`` holds each element's queue carry (the
    completion time of that queue's prior work), pre-composed at segment
    heads: seeding the head and resetting there is exactly seeding the
    whole segment.  ``impl`` picks `jax.lax.associative_scan` ("xla") or
    the Pallas segmented kernel ("pallas"; interpret mode off-TPU).
    """
    a = arrivals + services
    b = services
    flags = jnp.broadcast_to(flags, a.shape)
    if carry_per_q is not None:
        a = jnp.where(flags, jnp.maximum(a, carry_per_q + b), a)
    if impl == "pallas":
        from repro.kernels.maxplus_scan import ops as mp_ops
        out_a, _ = mp_ops.maxplus_segment_scan(a, b, flags)
        return out_a
    from repro.kernels.maxplus_scan.ref import maxplus_segment_combine
    out_a, _, _ = jax.lax.associative_scan(
        maxplus_segment_combine, (a, b, flags), axis=-1)
    return out_a


def fcfs_completion_times_routed(
    arrivals: Array, services: Array, assign: Array, r: int,
    *, impl: str = "auto", carry: Optional[Array] = None,
) -> tuple[Array, Array]:
    """Completions of r parallel FCFS queues with per-query routing.

    arrivals: (..., n) nondecreasing; services: (..., n) positive;
    assign: (..., n) integers in [0, r) — each query joins the FCFS queue
    of its assigned replica, in arrival order.  carry: optional (..., r)
    completion time of each queue's prior work.

    Fused route-compaction (one scan over n elements instead of r masked
    re-scans over all n): stable-sort by assignment so each queue is a
    contiguous segment, seed segment heads from the carry, run one
    segmented (max, +) scan, and scatter completions back to arrival
    order.  Returns ``(completions (..., n), new_carry (..., r))`` where
    empty queues keep their old carry.
    """
    if impl == "auto":
        from repro.kernels.maxplus_scan.ops import resolve_scan_impl
        impl = resolve_scan_impl(impl)
    if r < 1:
        raise ValueError(f"need at least one queue; got r={r}")
    if carry is None:
        carry = jnp.full(assign.shape[:-1] + (r,), -jnp.inf,
                         arrivals.dtype)
    order = jnp.argsort(assign, axis=-1, stable=True)
    asg_s = jnp.take_along_axis(assign, order, axis=-1)
    flags = jnp.concatenate(
        [jnp.ones_like(asg_s[..., :1], dtype=bool),
         asg_s[..., 1:] != asg_s[..., :-1]], axis=-1)
    counts = jnp.sum(
        assign[..., None, :] == jnp.arange(r)[:, None], axis=-1)
    ends = jnp.clip(jnp.cumsum(counts, axis=-1) - 1, 0, None)
    arr_s = jnp.take_along_axis(arrivals, order, axis=-1)
    svc_s = jnp.take_along_axis(services, order, axis=-1)
    carry_q = jnp.take_along_axis(carry, asg_s, axis=-1)
    done_s = _fcfs_segmented(arr_s, svc_s, flags, carry_q, impl)
    new_carry = jnp.where(counts > 0,
                          jnp.take_along_axis(done_s, ends, axis=-1),
                          carry)
    inv = jnp.argsort(order, axis=-1, stable=True)
    return jnp.take_along_axis(done_s, inv, axis=-1), new_carry


@functools.partial(
    jax.jit, static_argnames=("n_queries", "p", "mode", "impl", "chunk",
                              "warmup_fraction", "hist_bins", "tap_size",
                              "r", "routing", "has_cache", "replica_impl",
                              "autoscale", "telemetry", "fault"))
def _simulate_stream(
    key: Array,
    proc: ArrivalProcess,
    params: ServerParams,
    cache_hit: Array,
    cache_service: Array,
    n_queries: int,
    p: int,
    mode: str,
    impl: str,
    chunk: int,
    warmup_fraction: float,
    hist_bins: int,
    tap_size: int = 0,
    r: int = 1,
    routing: str = "round_robin",
    has_cache: bool = False,
    replica_impl: str = "fused",
    autoscale: Optional[AutoscalePolicy] = None,
    telemetry: Optional[TelemetrySpec] = None,
    fault: Optional[FaultSpec] = None,
) -> SimResult:
    """The one chunked engine behind every fork-join entry point.

    ``r``/``routing``/``has_cache`` are static: the single-replica,
    no-cache compilation is EXACTLY the pre-replication program (same
    draws, same op order, bit-identical statistics).

    ``replica_impl`` selects the r > 1 engine: "fused" (default) runs the
    route-compacted path — each query scanned ONCE on its own replica's
    queues, ~r x less work — while "masked" keeps the original
    full-stream masked re-scans as a cross-check oracle.  Both consume
    the same routing choices and draws, so their sample paths agree
    query-for-query (exactly in exact arithmetic; see the equality tests
    in tests/test_replication.py).

    ``telemetry`` (static) turns on the per-time-bin accumulators of
    `repro.obs.timeline`.  It draws NO randomness and appends carry
    elements only when present, so ``telemetry=None`` is the
    bit-identical pre-telemetry program.  Timeline binning keys off an
    UNWRAPPED absolute clock carried alongside the period-wrapped
    ``t_origin`` (profiles wrap for rate lookups; telemetry must not).

    ``autoscale`` (static) makes the ACTIVE replica count time-varying
    inside [min_r, max_r] (callers provision r = max_r): the
    `repro.launch.elastic` controller scan runs per chunk on the
    carried feedback state, and the per-query active counts feed the
    routing policies.  Like telemetry it appends carry slots only when
    present — ``autoscale=None`` compiles the exact static-r program —
    and draws no randomness, so the canonical chunk plan is untouched.

    ``fault`` (static) injects the `repro.core.faults.FaultSpec`
    failure modes: per-query replica-up masks (deterministic windows +
    the MTBF/MTTR Markov process) flow into the routing policies as
    failover (down replicas get no new work; in-flight work drains,
    exactly the autoscale scale-in semantics), degraded-server factors
    rescale the canonical service draws, the broker timeout turns the
    join into a k-of-p order statistic, and hedged duplicates race the
    straggling join.  All fault randomness comes from the
    ``_FAULT_SALT`` stream and all fault carry slots append only when
    present, so ``fault=None`` compiles the bit-identical pre-fault
    program — and an all-up spec reproduces its statistics bitwise.
    """
    n_scen = proc.rates.shape[0]
    elastic = autoscale is not None
    faulty = fault is not None
    # sub-features gate their ops individually so an all-up spec keeps
    # every branch (and the fused fast path) of the fault-free program
    f_outage = faulty and fault.has_outages
    f_quorum = faulty and fault.broker_timeout_seconds is not None
    f_hedge = faulty and fault.hedge_after_seconds is not None
    n_chunks = -(-n_queries // chunk)
    n_warm = int(n_queries * warmup_fraction)
    dtype = jnp.result_type(float)

    if telemetry is not None:
        tl_bins = telemetry.n_bins
        if telemetry.horizon_seconds is not None:
            tl_horizon = jnp.full((n_scen,), telemetry.horizon_seconds,
                                  dtype)
        else:
            tl_horizon = jnp.broadcast_to(
                n_queries / jnp.maximum(
                    proc.mean_rate.astype(dtype), 1e-30), (n_scen,))
        tl_bin_w = tl_horizon / tl_bins
        tl_slo = (jnp.inf if telemetry.slo_seconds is None
                  else telemetry.slo_seconds)

    s_broker = jnp.broadcast_to(
        jnp.asarray(params.s_broker, dtype), (n_scen,))
    cache_hit = jnp.broadcast_to(jnp.asarray(cache_hit, dtype), (n_scen,))
    cache_service = jnp.broadcast_to(
        jnp.asarray(cache_service, dtype), (n_scen,))

    # Per-scenario histogram scale off the Eq 7 analytic ballpark so the
    # fixed bin budget lands where each scenario's mass actually is.  The
    # dispatcher splits arrivals over r replicas (and the result cache
    # short-circuits hits), so the per-replica operating point is
    # lam * (1 - hit_r) / r; both factors are exact no-ops at the
    # default r=1, hit_r=0.
    ref_rate = jnp.broadcast_to(proc.mean_rate.astype(dtype), (n_scen,))
    if has_cache:
        ref_rate = ref_rate * (1.0 - cache_hit)
    s_mean = jnp.broadcast_to(
        jnp.asarray(service_time_server(params), dtype), (n_scen,))
    _, hi = queueing.response_time_bounds(ref_rate / r, params)
    hi = jnp.broadcast_to(jnp.asarray(hi, dtype), (n_scen,))
    scale = jnp.where(jnp.isfinite(hi) & (hi > 0), hi, 100.0 * s_mean)
    ln10 = math.log(10.0)
    hist_log_lo = jnp.log(scale) - _HIST_DECADES_BELOW * ln10
    hist_log_step = jnp.full((n_scen,),
                             _HIST_DECADES_TOTAL * ln10 / hist_bins, dtype)

    has_trace = proc.trace_gaps is not None
    if has_trace:
        gaps_full = jnp.asarray(proc.trace_gaps, dtype)[:n_queries]
        pad = n_chunks * chunk - n_queries
        gap_chunks = jnp.pad(gaps_full, (0, pad),
                             constant_values=1.0).reshape(n_chunks, chunk)
        xs = (jnp.arange(n_chunks), gap_chunks)
    else:
        xs = jnp.arange(n_chunks)

    rows = jnp.arange(n_scen)[:, None]
    col = jnp.arange(chunk)
    period = jnp.asarray(proc.period_seconds, dtype)

    # Max-plus maps are translation-invariant, so the carry is REBASED to
    # each chunk's origin: completion state is stored relative to the last
    # arrival, and only the (period-wrapped) absolute clock `t_origin` is
    # kept for profile lookups.  Clock magnitudes therefore stay O(chunk
    # duration) forever — float32 accuracy is independent of the simulated
    # horizon, which is what lets millions of queries stream through.
    #
    # Replicated carry: c_brk is (S, r), c_srv and the JSQ work tracker
    # are (S, r, p), the cache queue's carry is (S,).  Unused trackers
    # (non-JSQ routing, cache off) are carried as constants and dead-code
    # eliminated by XLA.
    def body(carry, x):
        (t_origin, c_brk, c_srv, c_cache, w_jsq, count, s_resp, ss_resp,
         s_br, s_cl, s_sv, hist, tap_pri, tap_val) = carry[:14]
        off = 14
        if elastic:
            as_carry = carry[off:off + 5]
            rep_secs, elapsed = carry[off + 5:off + 7]
            off += 7
        if faulty:
            (f_up, f_tabs, s_spill, s_unav, s_degr) = carry[off:off + 5]
            off += 5
        if telemetry is not None:
            (t_abs, tm_count, tm_resp, tm_bb, tm_bs, tm_rc, tm_hit,
             tm_slo) = carry[off:off + 8]
            toff = off + 8
            if elastic:
                tm_act = carry[toff]
                toff += 1
            if faulty:
                tm_up, tm_spill, tm_degr = carry[toff:toff + 3]
        if has_trace:
            c_idx, trace_gaps_c = x
        else:
            c_idx = x
        u_gaps, u_brk, services = chunk_random_draws(
            key, c_idx, n_scen, chunk, p, params, mode,
            with_gaps=not has_trace)
        if has_trace:
            gaps = jnp.broadcast_to(trace_gaps_c[None, :],
                                    (n_scen, chunk)).astype(dtype)
        else:
            # the Sec 4.2 structure: homogeneous Poisson within the chunk,
            # at the profile rate read off at the chunk's start time
            rate = jnp.maximum(proc.rate_at(t_origin), 1e-30)
            gaps = u_gaps / rate[:, None]
        arrivals = jnp.cumsum(gaps, axis=-1)   # relative to chunk origin
        # the rebase shift below; captured BEFORE the fused branches
        # permute `arrivals` into replica-compacted layout
        last_arrival = arrivals[:, -1]
        gidx = c_idx * chunk + col

        if faulty:
            # Degraded servers: rescale the CANONICAL service draws (a
            # slow disk / throttled CPU on one index partition, on every
            # replica) before anything consumes them — the autoscaler's
            # demand feedback, telemetry's busy integrals and both
            # replica engines all see the degraded times.
            if fault.degraded:
                factors = [1.0] * p
                for srv, f in fault.degraded:
                    factors[srv % p] *= f
                services = services * jnp.asarray(
                    factors, dtype)[None, :, None]
            # Replica-up mask at each arrival, off the chunking-invariant
            # recurrence; stochastic transitions draw from the salted
            # fault stream so the canonical plan is untouched.
            k_fault = jax.random.fold_in(
                jax.random.fold_in(key, c_idx), _FAULT_SALT)
            u_fault = (jax.random.uniform(
                jax.random.fold_in(k_fault, 0), (n_scen, chunk, r))
                if fault.mtbf_seconds is not None else None)
            (f_up,), up_q = fault_scan(
                fault, r, (f_up,), f_tabs[:, None] + arrivals, gaps,
                u_fault)
            up_cnt = jnp.sum(up_q.astype(dtype), axis=-1)  # (S, chunk)
            f_tabs = f_tabs + last_arrival

        if has_cache:
            # Result-cache hits short-circuit at their replica's broker
            # cache: an FCFS queue with Exp(s_cache) service, zero
            # index-server work — the Eq 8 topology (per-cluster cache),
            # so the analytic term at lam / r describes the same queue.
            kc = jax.random.fold_in(
                jax.random.fold_in(key, c_idx), _CACHE_SALT)
            kh, ks = jax.random.split(kc)
            is_hit = jax.random.bernoulli(
                kh, jnp.broadcast_to(cache_hit[:, None], (n_scen, chunk)))
            miss_f = 1.0 - is_hit.astype(dtype)
            t_cache = (jax.random.exponential(ks, (n_scen, chunk))
                       * cache_service[:, None]
                       * is_hit.astype(dtype))
        else:
            miss_f = None

        s_broker_c = u_brk * s_broker[:, None]
        if elastic:
            # Controller feedback in chunk (arrival) order, BEFORE any
            # routing permutation: each query's server-seconds of demand
            # (misses only — hits never reach the index servers) plus
            # the valid-query mask, so the padded tail advances neither
            # the decision clock nor the cost integral.
            vf = (gidx < n_queries).astype(dtype)[None, :]
            dem = jnp.sum(services, axis=1)
            if has_cache:
                dem = dem * miss_f
            gaps_v = gaps * vf
            as_carry, n_act = autoscale_scan(
                autoscale, p, as_carry, gaps_v, dem * vf,
                up_frac=up_cnt / r if f_outage else None)
            n_act_f = n_act.astype(dtype)
            # the cost integral the policy sweeps price: provisioned
            # replica-seconds and wall seconds (warmup included — the
            # fleet is paid for from t=0)
            rep_secs = rep_secs + jnp.sum(n_act_f * gaps_v, axis=-1)
            elapsed = elapsed + jnp.sum(gaps_v, axis=-1)
        if telemetry is not None:
            # chunk-order captures BEFORE the fused branches permute or
            # rescale anything: arrival offsets plus each query's
            # EFFECTIVE demand (cache hits never reach broker/servers,
            # so misses-only is the busy time conservation requires)
            tm_arr = arrivals
            tm_svc = (services * miss_f[:, None, :] if has_cache
                      else services)
            tm_brk = s_broker_c * miss_f if has_cache else s_broker_c
            tm_hit_c = is_hit.astype(dtype) if has_cache else None
        def _quorum_join(completions, fork_base, axis):
            """Fork-join merge: full quorum, or k-of-p past the timeout.

            The broker waits for all p servers until ``fork_base +
            broker_timeout_seconds``; past it, it returns as soon as at
            least k answers are in (the k-th order statistic of the
            per-server completions).  Returns ``(join, degraded)``;
            with no timeout configured this is exactly ``max`` and
            ``degraded`` is None.  An infinite timeout keeps the select
            on the full-quorum side everywhere, so the join is bitwise
            the fault-free one.
            """
            full = jnp.max(completions, axis=axis)
            if not f_quorum:
                return full, None
            k = fault.quorum(p)
            if k >= p:
                return full, jnp.zeros(full.shape, bool)
            t_k = jnp.take(jnp.sort(completions, axis=axis), k - 1,
                           axis=axis)
            deadline = fork_base + fault.broker_timeout_seconds
            late = full > deadline
            return jnp.where(late, jnp.maximum(t_k, deadline), full), late

        degr = None
        # `perm` maps chunk-order (S, chunk) arrays into the layout the
        # fused branches compute in (replica-compacted); None = identity.
        # All streaming statistics are permutation-invariant (sums,
        # histogram scatter-adds, the priority-reservoir tap), so the
        # epilogue only needs mf / priorities / is_hit permuted the same
        # way as the responses.
        perm = None
        if r == 1:
            # single replica: EXACTLY the pre-replication program (the
            # miss mask is the only difference, and only with a cache)
            if has_cache:
                s_broker_c = s_broker_c * miss_f
                services = services * miss_f[:, None, :]
                cache_done = fcfs_completion_times(
                    arrivals, t_cache, impl=impl, carry=c_cache[:, 0])
                c_cache_new = (cache_done[:, -1])[:, None]
            broker_done = fcfs_completion_times(arrivals, s_broker_c,
                                                impl=impl, carry=c_brk[:, 0])
            fork = jnp.broadcast_to(broker_done[:, None, :],
                                    (n_scen, p, chunk))
            completions = fcfs_completion_times(fork, services, impl=impl,
                                                carry=c_srv[:, 0])
            join, degr = _quorum_join(completions, broker_done, axis=1)
            server0 = completions[:, 0, :]
            c_brk_new = (broker_done[:, -1])[:, None]
            c_srv_new = (completions[:, :, -1])[:, None, :]
            w_jsq_new = w_jsq
        else:
            live = miss_f if has_cache else jnp.ones_like(gaps)
            up_route = up_q if f_outage else None
            assign, spill_q, unav_q = _routing_assign(
                routing, r, key, c_idx, gidx, n_scen, chunk,
                n_act=n_act if elastic else None, up=up_route)
            if assign is None:  # jsq: needs the carried work state
                routed = _jsq_route(
                    w_jsq, gaps, services, live, r, dtype,
                    n_act=n_act if elastic else None, up=up_route)
                if up_route is None:
                    assign, w_jsq_new = routed
                else:
                    assign, w_jsq_new, spill_q, unav_q = routed
            else:
                w_jsq_new = w_jsq

        if telemetry is not None and r > 1:
            tm_asg = assign          # replica of each chunk-order query

        if r == 1:
            pass
        elif replica_impl == "masked":
            # Reference oracle: every replica scans the FULL stream;
            # phantom (zero-service) entries cannot delay later real
            # queries (see module doc).  ~r x redundant work — kept for
            # the fused-vs-masked equality tests.
            mask = (assign[:, None, :]
                    == jnp.arange(r)[None, :, None]).astype(dtype)
            # hits occupy their replica's cache queue; only misses enter
            # its broker + index servers
            mask_srv = mask * miss_f[:, None, :] if has_cache else mask
            arr_r = jnp.broadcast_to(arrivals[:, None, :],
                                     (n_scen, r, chunk))
            if has_cache:
                cache_done_r = fcfs_completion_times(
                    arr_r, t_cache[:, None, :] * mask, impl=impl,
                    carry=c_cache)
                cache_done = jnp.sum(cache_done_r * mask, axis=1)
                c_cache_new = cache_done_r[:, :, -1]
            broker_done_r = fcfs_completion_times(
                arr_r, s_broker_c[:, None, :] * mask_srv, impl=impl,
                carry=c_brk)
            fork = jnp.broadcast_to(broker_done_r[:, :, None, :],
                                    (n_scen, r, p, chunk))
            completions = fcfs_completion_times(
                fork, services[:, None, :, :] * mask_srv[:, :, None, :],
                impl=impl, carry=c_srv)
            join_r, degr_r = _quorum_join(completions,
                                          broker_done_r, axis=2)
            # read each query off its OWN replica's sample path
            broker_done = jnp.sum(broker_done_r * mask_srv, axis=1)
            join = jnp.sum(join_r * mask_srv, axis=1)
            if f_quorum:
                degr = jnp.sum(degr_r.astype(dtype) * mask_srv,
                               axis=1) > 0.0
            server0 = jnp.sum(completions[:, :, 0, :] * mask_srv, axis=1)
            c_brk_new = broker_done_r[:, :, -1]
            c_srv_new = completions[:, :, :, -1]
        elif (routing == "round_robin" and chunk % r == 0
              and not elastic and not f_outage):
            # Fused fast path: with chunk % r == 0 the round-robin
            # assignment is col % r every chunk, so compaction into
            # per-replica contiguous runs is a pure reshape — no sort.
            # (Autoscaled round-robin wraps at the time-varying active
            # count, and failover spills break the col % r pattern, so
            # both ride the general sorted path below.)
            # Each query is scanned ONCE on its own replica's queues:
            # chunk broker elements + p * chunk server elements total,
            # r x less work than the masked oracle.
            ct = chunk // r

            def to_rep(x):                       # (S, chunk) -> (S, r, ct)
                return x.reshape(n_scen, ct, r).swapaxes(-1, -2)

            def perm(x):
                return to_rep(jnp.broadcast_to(x, (n_scen, chunk))
                              ).reshape(n_scen, chunk)

            arr_q = to_rep(arrivals)
            svc_q = services.reshape(n_scen, p, ct, r).transpose(0, 3, 1, 2)
            brk_q = to_rep(s_broker_c)
            if has_cache:
                miss_q = to_rep(miss_f)
                brk_q = brk_q * miss_q
                svc_q = svc_q * miss_q[:, :, None, :]
                cache_done_q = fcfs_completion_times(
                    arr_q, to_rep(t_cache), impl=impl, carry=c_cache)
                cache_done = cache_done_q.reshape(n_scen, chunk)
                c_cache_new = cache_done_q[..., -1]
            broker_done_q = fcfs_completion_times(arr_q, brk_q, impl=impl,
                                                  carry=c_brk)
            fork = jnp.broadcast_to(broker_done_q[:, :, None, :],
                                    (n_scen, r, p, ct))
            completions = fcfs_completion_times(fork, svc_q, impl=impl,
                                                carry=c_srv)
            broker_done = broker_done_q.reshape(n_scen, chunk)
            join_q, degr_q = _quorum_join(completions,
                                          broker_done_q, axis=2)
            join = join_q.reshape(n_scen, chunk)
            if f_quorum:
                degr = degr_q.reshape(n_scen, chunk)
            server0 = completions[:, :, 0, :].reshape(n_scen, chunk)
            c_brk_new = broker_done_q[..., -1]
            c_srv_new = completions[..., -1]
            arrivals = arr_q.reshape(n_scen, chunk)
        else:
            # Fused general path (random, jsq, uneven round-robin):
            # stable-sort by replica so each replica's queries form a
            # contiguous segment, seed segment heads from the carries,
            # and run ONE segmented (max, +) scan per queue level.
            # Stable sort preserves arrival order within a replica, so
            # each segment IS that replica's FCFS arrival sequence.
            order = jnp.argsort(assign, axis=-1, stable=True)
            asg_s = jnp.take_along_axis(assign, order, axis=-1)
            flags = jnp.concatenate(
                [jnp.ones_like(asg_s[:, :1], dtype=bool),
                 asg_s[:, 1:] != asg_s[:, :-1]], axis=-1)
            counts = jnp.sum(
                assign[:, None, :] == jnp.arange(r)[None, :, None],
                axis=-1)                                  # (S, r)
            ends = jnp.clip(jnp.cumsum(counts, axis=-1) - 1, 0, None)

            def perm(x):
                return jnp.take_along_axis(
                    jnp.broadcast_to(x, (n_scen, chunk)), order, axis=-1)

            arrivals = perm(arrivals)
            svc_s = jnp.take_along_axis(services, order[:, None, :],
                                        axis=-1)
            brk_s = perm(s_broker_c)
            if has_cache:
                miss_s = perm(miss_f)
                brk_s = brk_s * miss_s
                svc_s = svc_s * miss_s[:, None, :]
                cache_done = _fcfs_segmented(
                    arrivals, perm(t_cache), flags,
                    jnp.take_along_axis(c_cache, asg_s, axis=-1), impl)
                c_cache_new = jnp.where(
                    counts > 0,
                    jnp.take_along_axis(cache_done, ends, axis=-1),
                    c_cache)
            broker_done = _fcfs_segmented(
                arrivals, brk_s, flags,
                jnp.take_along_axis(c_brk, asg_s, axis=-1), impl)
            fork = jnp.broadcast_to(broker_done[:, None, :],
                                    (n_scen, p, chunk))
            carry_srv_q = jnp.take_along_axis(
                jnp.swapaxes(c_srv, 1, 2), asg_s[:, None, :], axis=-1)
            completions = _fcfs_segmented(
                fork, svc_s, flags[:, None, :], carry_srv_q, impl)
            join, degr = _quorum_join(completions, broker_done, axis=1)
            server0 = completions[:, 0, :]
            c_brk_new = jnp.where(
                counts > 0,
                jnp.take_along_axis(broker_done, ends, axis=-1), c_brk)
            srv_ends = jnp.take_along_axis(completions, ends[:, None, :],
                                           axis=-1)       # (S, p, r)
            c_srv_new = jnp.where(counts[:, :, None] > 0,
                                  jnp.swapaxes(srv_ends, 1, 2), c_srv)

        if f_hedge:
            # Hedged retries: each attempt races the (possibly partial-
            # quorum) join with a duplicate fork fired a backoff delay
            # after the broker fork, served OFF-QUEUE by spare capacity
            # with fresh draws from the salted fault stream (optimistic:
            # duplicates add no queue load — the trade Eq 6's
            # `hedge_threshold` prices).  A response the hedge wins is a
            # full-quorum result, so it clears the degraded flag.
            cand = None
            for h_j, h_delay in enumerate(fault.hedge_delays()):
                k_h = jax.random.fold_in(k_fault, 1 + h_j)
                dup = jnp.max(jax.random.exponential(
                    k_h, (n_scen, p, chunk)), axis=1) * s_mean[:, None]
                if perm is not None:
                    dup = perm(dup)
                c = broker_done + h_delay + dup
                cand = c if cand is None else jnp.minimum(cand, c)
            if degr is not None:
                degr = degr & (join <= cand)
            join = jnp.minimum(join, cand)

        if has_cache:
            if perm is not None:
                is_hit = perm(is_hit)
            if degr is not None:
                degr = degr & ~is_hit   # hits never fork: always whole
            resp_cache = cache_done - arrivals
            response = jnp.where(is_hit, resp_cache, join - arrivals)
            broker_res = jnp.where(is_hit, resp_cache,
                                   broker_done - arrivals)
            cluster_res = jnp.where(is_hit, 0.0, join - broker_done)
            server_res = jnp.where(is_hit, 0.0, server0 - broker_done)
        else:
            response = join - arrivals
            broker_res = broker_done - arrivals
            cluster_res = join - broker_done
            server_res = server0 - broker_done
            c_cache_new = c_cache
        mf = ((gidx >= n_warm) & (gidx < n_queries)).astype(dtype)[None, :]
        mf0 = mf                 # chunk-order copy for chunk-order sums
        if perm is not None:
            mf = perm(mf)
        count = count + jnp.broadcast_to(jnp.sum(mf, -1), (n_scen,))
        s_resp = s_resp + jnp.sum(response * mf, -1)
        ss_resp = ss_resp + jnp.sum(response * response * mf, -1)
        s_br = s_br + jnp.sum(broker_res * mf, -1)
        s_cl = s_cl + jnp.sum(cluster_res * mf, -1)
        s_sv = s_sv + jnp.sum(server_res * mf, -1)
        if faulty:
            # spill/unavail live in chunk (arrival) order, the degraded
            # flag in the engine's (possibly permuted) layout; the sums
            # are permutation-invariant either way.
            if f_outage and r > 1:
                s_spill = s_spill + jnp.sum(
                    spill_q.astype(dtype) * mf0, -1)
                s_unav = s_unav + jnp.sum(
                    unav_q.astype(dtype) * mf0, -1)
            elif f_outage:       # r == 1: down means nowhere to route
                s_unav = s_unav + jnp.sum(
                    (1.0 - up_q[:, :, 0].astype(dtype)) * mf0, -1)
            if degr is not None:
                s_degr = s_degr + jnp.sum(degr.astype(dtype) * mf, -1)

        bins = jnp.clip(
            jnp.floor((jnp.log(jnp.maximum(response, 1e-30))
                       - hist_log_lo[:, None]) / hist_log_step[:, None]),
            0, hist_bins - 1).astype(jnp.int32)
        hist = hist.at[rows, bins].add(
            jnp.broadcast_to(mf, (n_scen, chunk)))

        if tap_size > 0:
            # Reservoir via random priorities (A-Res with equal weights):
            # every valid query gets an iid U(0,1) priority and the tap
            # keeps the tap_size largest seen so far — a uniform sample
            # without replacement, one top_k per chunk, O(tap) state.
            k_tap = jax.random.fold_in(
                jax.random.fold_in(key, c_idx), _TAP_SALT)
            pri = jax.random.uniform(k_tap, (n_scen, chunk), dtype)
            if perm is not None:
                pri = perm(pri)
            pri = jnp.where(mf > 0, pri, -jnp.inf)
            cat_pri = jnp.concatenate([tap_pri, pri], axis=-1)
            cat_val = jnp.concatenate(
                [tap_val, jnp.broadcast_to(response, (n_scen, chunk))],
                axis=-1)
            tap_pri, idx = jax.lax.top_k(cat_pri, tap_size)
            tap_val = jnp.take_along_axis(cat_val, idx, axis=-1)

        if telemetry is not None:
            # Timeline tallies (no RNG, so the canonical draw plan is
            # untouched).  Bin by arrival time on the UNWRAPPED absolute
            # clock; warmup is included by design (transients are the
            # signal), only the tail padding is excluded.  Arrivals are
            # nondecreasing within a chunk, so each bin is a CONTIGUOUS
            # run of queries: per-bin sums are differences of one
            # prefix sum read at the bin-edge positions (vmapped
            # searchsorted) — O(chunk) per channel, an order of
            # magnitude cheaper than scatter-adds or one-hot
            # contractions inside the scan, and the per-chunk total
            # telescopes exactly (conservation is bit-exact).
            t_arr = t_abs[:, None] + tm_arr          # (S, chunk), sorted
            # padded tail queries (gidx >= n_queries) are a SUFFIX of
            # the sorted chunk, so clamping the bin-edge positions at
            # n_valid excludes them for free — no valid-mask multiply
            # on any channel
            n_valid = jnp.clip(n_queries - c_idx * chunk, 0, chunk)
            edges = tl_bin_w[:, None] * jnp.arange(
                tl_bins, dtype=dtype)[None, :]        # (S, B)
            pos = jax.vmap(jnp.searchsorted)(t_arr, edges)
            pos = jnp.minimum(
                jnp.concatenate(
                    [pos, jnp.full((n_scen, 1), chunk, pos.dtype)],
                    axis=-1),
                n_valid)                              # (S, B + 1)

            # Two-level prefix sums: a full cumsum over the chunk is
            # multi-pass under XLA, but prefixes are only ever READ at
            # the B + 1 edge positions.  So: one pass of per-block
            # partial sums, a tiny cumsum over the ~chunk/blk blocks,
            # and a masked intra-block sum just at the edges — ~one
            # read of the data per channel instead of a scan.
            blk = 1
            while (blk < 128 and chunk % (blk * 2) == 0
                   and blk * (tl_bins + 1) < chunk):
                blk *= 2
            nb = chunk // blk
            e_blk = pos // blk                        # (S, B + 1)
            e_within = pos - e_blk * blk
            e_blk_c = jnp.minimum(e_blk, nb - 1)
            e_within = jnp.where(e_blk > e_blk_c, blk, e_within)
            intra_mask = (jnp.arange(blk) < e_within[..., None]
                          ).astype(dtype)             # (S, B + 1, blk)

            def bin_sums(w):
                """(S, ..., chunk) weights -> (S, ..., B) per-bin sums."""
                lead = (1,) * (w.ndim - 2)
                wb = w.reshape(w.shape[:-1] + (nb, blk))
                blocks = jnp.cumsum(jnp.sum(wb, axis=-1), axis=-1)
                eb = jnp.broadcast_to(
                    e_blk_c.reshape((n_scen,) + lead + (tl_bins + 1,)),
                    w.shape[:-1] + (tl_bins + 1,))
                pre = jnp.where(
                    eb > 0,
                    jnp.take_along_axis(blocks, jnp.maximum(eb - 1, 0),
                                        axis=-1),
                    jnp.zeros_like(blocks[..., :1]))
                wsel = jnp.take_along_axis(wb, eb[..., None], axis=-2)
                take = pre + jnp.sum(
                    wsel * intra_mask.reshape(
                        (n_scen,) + lead + (tl_bins + 1, blk)),
                    axis=-1)
                return take[..., 1:] - take[..., :-1]

            # counts need no cumsum at all: bins are contiguous runs, so
            # the per-bin count IS the difference of the edge positions
            cnt_inc = (pos[:, 1:] - pos[:, :-1]).astype(dtype)  # (S, B)
            tm_count = tm_count + cnt_inc
            if r == 1:
                # single replica: every per-replica channel collapses to
                # the plain one — skip the assignment mask entirely
                tm_rc = tm_rc + cnt_inc[:, :, None]
                tm_bb = tm_bb + bin_sums(tm_brk)[:, :, None]
                tm_bs = tm_bs + jnp.moveaxis(
                    bin_sums(tm_svc), -1, 1)[:, :, None, :]
            else:
                mask_a = (tm_asg[:, None, :]
                          == jnp.arange(r, dtype=jnp.int32)[None, :, None]
                          ).astype(dtype)             # (S, r, chunk)
                tm_rc = tm_rc + jnp.swapaxes(bin_sums(mask_a), 1, 2)
                tm_bb = tm_bb + jnp.swapaxes(
                    bin_sums(mask_a * tm_brk[:, None, :]), 1, 2)
                tm_bs = tm_bs + jnp.moveaxis(
                    bin_sums(mask_a[:, :, None, :]
                             * tm_svc[:, None, :, :]),
                    -1, 1)                            # (S, B, r, p)
            if has_cache:
                tm_hit = tm_hit + bin_sums(tm_hit_c)
            # response-side tallies live in the engine's layout — bring
            # them BACK to (sorted) chunk order via the inverse permute
            if perm is not None:
                inv = jnp.argsort(
                    perm(jnp.arange(chunk, dtype=jnp.int32)), axis=-1)
                resp_c = jnp.take_along_axis(
                    jnp.broadcast_to(response, (n_scen, chunk)), inv,
                    axis=-1)
            else:
                resp_c = response
            tm_resp = tm_resp + bin_sums(resp_c)
            tm_slo = tm_slo + bin_sums((resp_c > tl_slo).astype(dtype))
            if elastic:
                # the autoscaler trajectory: active fleet size summed
                # over each bin's arrivals (n_act is in chunk order)
                tm_act = tm_act + bin_sums(n_act_f)
            if faulty:
                # fault trajectory: surviving-replica count and spills
                # are in chunk order; the degraded flag rides the same
                # inverse permute as the responses
                tm_up = tm_up + bin_sums(up_cnt)
                if f_outage and r > 1:
                    tm_spill = tm_spill + bin_sums(spill_q.astype(dtype))
                if degr is not None:
                    dg = jnp.broadcast_to(degr.astype(dtype),
                                          (n_scen, chunk))
                    if perm is not None:
                        dg = jnp.take_along_axis(dg, inv, axis=-1)
                    tm_degr = tm_degr + bin_sums(dg)
            t_abs = t_abs + last_arrival

        shift = last_arrival
        c_brk_s = c_brk_new - shift[:, None]
        c_srv_s = c_srv_new - shift[:, None, None]
        c_cache_s = (c_cache_new - shift[:, None] if has_cache
                     else c_cache_new)
        if elastic or f_outage:
            # An inactive (or failed) replica receives no work, so its
            # rebased carry would drift toward -inf chunk after chunk.
            # Clamping at the chunk origin is EXACT — seeding
            # max(a, c + b) is unchanged for any c <= the segment head's
            # arrival, and arrivals are positive — and pins a fully
            # drained replica at 0, the same cold state a scale-out (or
            # repaired) replica starts from.
            c_brk_s = jnp.maximum(c_brk_s, 0.0)
            c_srv_s = jnp.maximum(c_srv_s, 0.0)
            if has_cache:
                c_cache_s = jnp.maximum(c_cache_s, 0.0)
        new_carry = ((t_origin + shift) % period,
                     c_brk_s, c_srv_s, c_cache_s,
                     w_jsq_new,
                     count, s_resp, ss_resp, s_br, s_cl, s_sv, hist,
                     tap_pri, tap_val)
        if elastic:
            new_carry = new_carry + tuple(as_carry) + (rep_secs, elapsed)
        if faulty:
            new_carry = new_carry + (f_up, f_tabs, s_spill, s_unav,
                                     s_degr)
        if telemetry is not None:
            new_carry = new_carry + (t_abs, tm_count, tm_resp, tm_bb,
                                     tm_bs, tm_rc, tm_hit, tm_slo)
            if elastic:
                new_carry = new_carry + (tm_act,)
            if faulty:
                new_carry = new_carry + (tm_up, tm_spill, tm_degr)
        return new_carry, None

    zeros = jnp.zeros((n_scen,), dtype)
    init = (zeros, jnp.zeros((n_scen, r), dtype),
            jnp.zeros((n_scen, r, p), dtype),
            jnp.zeros((n_scen, r), dtype),
            jnp.zeros((n_scen, r, p), dtype),
            zeros, zeros,
            zeros, zeros, zeros, zeros,
            jnp.zeros((n_scen, hist_bins), dtype),
            jnp.full((n_scen, tap_size), -jnp.inf, dtype),
            jnp.full((n_scen, tap_size), jnp.nan, dtype))
    if elastic:
        init = init + autoscale_init(autoscale, n_scen, dtype) \
            + (zeros, zeros)
    if faulty:
        init = init + fault_init(fault, n_scen, r) \
            + (zeros, zeros, zeros, zeros)
    if telemetry is not None:
        zb = jnp.zeros((n_scen, tl_bins), dtype)
        init = init + (zeros, zb, zb,
                       jnp.zeros((n_scen, tl_bins, r), dtype),
                       jnp.zeros((n_scen, tl_bins, r, p), dtype),
                       jnp.zeros((n_scen, tl_bins, r), dtype),
                       zb, zb)
        if elastic:
            init = init + (zb,)
        if faulty:
            init = init + (zb, zb, zb)
    final, _ = jax.lax.scan(body, init, xs)
    (t_last, c_brk, c_srv, c_cache, w_jsq, count, s_resp, ss_resp, s_br,
     s_cl, s_sv, hist, tap_pri, tap_val) = final[:14]
    off = 14
    rep_secs = elapsed = None
    if elastic:
        rep_secs, elapsed = final[off + 5:off + 7]
        off += 7
    spill = unavail = degraded = None
    if faulty:
        spill, unavail, degraded = final[off + 2:off + 5]
        off += 5

    timeline = None
    if telemetry is not None:
        (_, tm_count, tm_resp, tm_bb, tm_bs, tm_rc, tm_hit,
         tm_slo) = final[off:off + 8]
        toff = off + 8
        active_sum = None
        if elastic:
            active_sum = final[toff]
            toff += 1
        up_sum = spill_sum = degraded_sum = None
        if faulty:
            up_sum, spill_sum, degraded_sum = final[toff:toff + 3]
        timeline = Timeline(
            bin_seconds=tl_bin_w, count=tm_count, resp_sum=tm_resp,
            busy_broker=tm_bb, busy_server=tm_bs, replica_count=tm_rc,
            hit_count=tm_hit, slo_count=tm_slo,
            active_sum=active_sum, up_sum=up_sum, spill_sum=spill_sum,
            degraded_sum=degraded_sum)

    return SimResult(
        count=count, sum_response=s_resp, sumsq_response=ss_resp,
        sum_broker=s_br, sum_cluster=s_cl, sum_server=s_sv,
        hist=hist, hist_log_lo=hist_log_lo, hist_log_step=hist_log_step,
        tap_response=tap_val, timeline=timeline,
        replica_seconds=rep_secs, elapsed_seconds=elapsed,
        spill_count=spill, unavail_count=unavail,
        degraded_count=degraded)


def _cache_args(result_cache) -> tuple[Array, Array, bool]:
    """Normalize ``result_cache=(hit_r, s_cache)`` into engine inputs."""
    if result_cache is None:
        return jnp.asarray(0.0), jnp.asarray(0.0), False
    hit_r, s_cache = result_cache
    return jnp.asarray(hit_r), jnp.asarray(s_cache), True


def simulate_fork_join(
    key: Array,
    lam: Union[float, ArrivalProcess],
    n_queries: int,
    params: ServerParams,
    *,
    p: Optional[int] = None,
    mode: str = "exponential",
    impl: str = "auto",
    warmup_fraction: float = 0.1,
    chunk_size: int = DEFAULT_CHUNK,
    hist_bins: int = DEFAULT_HIST_BINS,
    tap_size: int = 0,
    cluster: Optional[ClusterSpec] = None,
    r: Optional[int] = None,
    routing: Optional[str] = None,
    result_cache: Optional[tuple[float, float]] = None,
    replica_impl: Optional[str] = None,
    telemetry: Optional[TelemetrySpec] = None,
) -> SimResult:
    """Simulate the full broker + p-server fork-join network (Fig 8).

    The broker is visited once per query with service S_broker (the paper
    lumps broadcast+merge); its completions are the fork times.  Each index
    server runs an independent FCFS queue over the forked stream, and the
    join waits for the slowest server.  ``lam`` is either a constant rate
    in qps or any :class:`ArrivalProcess` (diurnal profile, trace replay).
    Streams through ``chunk_size`` query chunks; warmup queries are
    discarded from the returned streaming statistics.  ``tap_size > 0``
    additionally carries a bounded reservoir sample of per-query response
    times (see :class:`SimResult`).

    Topology rides ONE static argument, ``cluster=ClusterSpec(...)``:

    * ``r > 1`` grows the network to the replicated topology (Sec 6): a
      front-end dispatcher routes each query to one of ``r`` full
      replicas under ``routing`` ("round_robin" | "random" | "jsq");
      ``lam`` stays the TOTAL arrival rate.
    * ``result_cache=(hit_r, s_cache)`` adds the broker-level result
      cache of Eq 8: hits are served by their routed replica's
      broker-cache FCFS queue with mean service ``s_cache`` and never
      fork to its index servers.
    * ``replica_impl`` picks the replicated engine ("fused" default;
      "masked" is the re-scan oracle — see :func:`_simulate_stream`).
    * ``autoscale=AutoscalePolicy(...)`` makes the active replica count
      time-varying; the result gains ``replica_seconds`` /
      ``elapsed_seconds`` and (with telemetry) the active-replica
      trajectory.
    * ``fault=FaultSpec(...)`` injects replica outages (failover spills
      to survivors), degraded servers, a partial-quorum broker timeout
      and hedged retries; the result gains ``spill_count`` /
      ``unavail_count`` / ``degraded_count`` and (with telemetry) the
      up/spill/degraded trajectories.  See `repro.core.faults`.

    The loose keywords ``r=`` / ``routing=`` / ``result_cache=`` /
    ``replica_impl=`` are DEPRECATED shims for the same fields (warn
    once; see `repro.core.cluster.resolve_cluster`).

    ``telemetry=TelemetrySpec(...)`` additionally streams the per-time-
    bin `repro.obs.timeline.Timeline` onto the result (None, the
    default, is the bit-identical pre-telemetry program).
    """
    spec = resolve_cluster(cluster, r=r, routing=routing,
                           result_cache=result_cache,
                           replica_impl=replica_impl,
                           caller="simulate_fork_join")
    from repro.kernels.maxplus_scan.ops import resolve_scan_impl
    impl = resolve_scan_impl(impl)  # concrete before the jit cache key
    p = int(params.p) if p is None else p  # static before tracing
    cache_hit, cache_service, has_cache = _cache_args(spec.result_cache)
    proc = _as_batch_process(lam)
    _check_trace(proc, n_queries)
    chunk = _clamp_chunk_for_profile(
        proc, max(1, min(chunk_size, n_queries)))
    res = _simulate_stream(key, proc, _vec_params(params), cache_hit,
                           cache_service, n_queries, p,
                           mode, impl, chunk, warmup_fraction, hist_bins,
                           tap_size, r=spec.engine_r, routing=spec.routing,
                           has_cache=has_cache,
                           replica_impl=spec.replica_impl,
                           autoscale=spec.autoscale, telemetry=telemetry,
                           fault=spec.fault)
    return jax.tree_util.tree_map(lambda x: x[0], res)


def simulate_fork_join_batch(
    key: Array,
    lam: Union[Array, ArrivalProcess],
    params: ServerParams,
    n_queries: int,
    *,
    p: int,
    mode: str = "exponential",
    impl: str = "auto",
    warmup_fraction: float = 0.1,
    chunk_size: int = DEFAULT_CHUNK,
    hist_bins: int = DEFAULT_HIST_BINS,
    tap_size: int = 0,
    cluster: Optional[ClusterSpec] = None,
    r: Optional[int] = None,
    routing: Optional[str] = None,
    result_cache: Optional[tuple[float, float]] = None,
    replica_impl: Optional[str] = None,
    telemetry: Optional[TelemetrySpec] = None,
) -> SimResult:
    """S fork-join scenarios in one XLA program; all stats are (S,).

    ``lam`` is an (S,) rate vector or an :class:`ArrivalProcess` with
    (S, n_bins) rates; every ``params`` field is (S,).  All scenarios
    share the SAME static topology ``cluster=ClusterSpec(...)`` and
    server count ``p`` (grids over p, r or autoscale policies dispatch
    one batch per distinct static config — see `repro.core.sweep`); the
    loose ``r=`` / ``routing=`` / ``result_cache=`` / ``replica_impl=``
    keywords are the deprecated shim.  With ``impl="pallas"`` the
    per-chunk (S, r, p, chunk) and (S, r, chunk) FCFS recurrences
    flatten onto the row axis of `maxplus_scan`, so all S * r * (p + 1)
    sample paths run as a single Pallas grid.

    Peak memory of the fused replicated engine is S * p * chunk_size
    floats — independent of ``n_queries`` AND of ``r`` (each query is
    scanned once, on its own replica); only the carries grow with r, at
    S * r * p scalars.  The "masked" oracle keeps the original
    S * r * p * chunk_size law.
    """
    spec = resolve_cluster(cluster, r=r, routing=routing,
                           result_cache=result_cache,
                           replica_impl=replica_impl,
                           caller="simulate_fork_join_batch")
    from repro.kernels.maxplus_scan.ops import resolve_scan_impl
    impl = resolve_scan_impl(impl)  # concrete before the jit cache key
    cache_hit, cache_service, has_cache = _cache_args(spec.result_cache)
    proc = _as_batch_process(lam)
    _check_trace(proc, n_queries)
    chunk = _clamp_chunk_for_profile(
        proc, max(1, min(chunk_size, n_queries)))
    return _simulate_stream(key, proc, params, cache_hit, cache_service,
                            n_queries, p, mode, impl,
                            chunk, warmup_fraction, hist_bins, tap_size,
                            r=spec.engine_r, routing=spec.routing,
                            has_cache=has_cache,
                            replica_impl=spec.replica_impl,
                            autoscale=spec.autoscale, telemetry=telemetry,
                            fault=spec.fault)


@functools.partial(jax.jit, static_argnames=("c",))
def simulate_mmc(arrivals: Array, services: Array, c: int) -> Array:
    """M/M/c FCFS via the Kiefer-Wolfowitz workload-vector recursion.

    State w = sorted vector of the c servers' remaining work at an arrival.
    On arrival i: start delay = w[0]; after assigning service S_i to the
    least-loaded server and advancing time by the next interarrival gap:

        w' = sort( (w + S_i e_1) - gap )_+

    Supports the paper's stated future work (multi-threaded index servers).
    Returns response times (delay + own service).
    """
    gaps = jnp.diff(arrivals, prepend=arrivals[:1] * 0.0)

    def step(w, inp):
        gap, s = inp
        w = jnp.maximum(w - gap, 0.0)          # advance to this arrival
        delay = w[0]
        w = w.at[0].add(s)                     # assign to least loaded
        w = jnp.sort(w)
        return w, delay + s

    _, resp = jax.lax.scan(step, jnp.zeros((c,), services.dtype),
                           (gaps, services))
    return resp
