"""Mechanistic model of per-query service-time imbalance (paper Sec 3.4).

The paper attributes imbalance among *homogeneous* index servers to
heterogeneous disk-cache behavior: for a given query some servers find the
needed inverted lists in the OS page cache while others go to disk.  Here we
model that mechanism analytically so the capacity planner can predict the
(hit, S_hit, S_miss, S_disk) decomposition of Eq 1 from first principles —
term popularity (Zipf), posting-list sizes, per-server memory, and the
number of servers p — instead of only from /proc measurements.

Cache model: Che's approximation for an LRU cache under the independent
reference model.  For object i with request rate lambda_i and size z_i, the
hit probability is  h_i = 1 - exp(-lambda_i * T_c)  where the
characteristic time T_c solves

    sum_i  z_i * (1 - exp(-lambda_i * T_c))  =  C        (cache bytes)

Document partitioning divides every posting list by p, so z_i(p) = z_i / p:
more servers (or more memory) => higher hit probability => *less* disk time
but (as the paper observes) a wider hit/miss split across servers until hit
saturates — the imbalance window.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import jax
import jax.numpy as jnp

from repro.core import queueing

Array = jax.Array
ArrayLike = Union[Array, float]

__all__ = [
    "CacheGeometry",
    "che_characteristic_time",
    "term_hit_probabilities",
    "query_full_hit_probability",
    "imbalance_probability",
    "service_params_from_cache_model",
    "service_time_cv",
]

_CHE_ITERS = 40


@dataclasses.dataclass(frozen=True)
class CacheGeometry:
    """Inputs to the disk-cache model.

    term_rates:  (T,) per-term request rate (queries/sec * terms-per-query
                 share), i.e. Zipf-shaped popularity.
    list_bytes:  (T,) full (unpartitioned) inverted-list size per term.
    cache_bytes: per-server memory available to the OS page cache.
    p:           number of index servers (document partitioning => each
                 server stores list_bytes / p per term).
    disk_bw:     sustained disk read bandwidth, bytes/sec.
    disk_seek:   per-query seek+rotation overhead, seconds.
    """

    term_rates: Array
    list_bytes: Array
    cache_bytes: ArrayLike
    p: ArrayLike
    disk_bw: float = 50e6
    disk_seek: float = 8e-3


def che_characteristic_time(geom: CacheGeometry) -> Array:
    """Solve Che's fixed point for T_c by bisection (monotone in T_c)."""
    z = geom.list_bytes / jnp.asarray(geom.p, jnp.float32)
    lam = geom.term_rates
    cap = jnp.asarray(geom.cache_bytes, jnp.float32)

    def filled(log_t):
        t = jnp.exp(log_t)
        return jnp.sum(z * (1.0 - jnp.exp(-lam * t)))

    # Bisection in log space: cache fill is monotone increasing in T_c.
    lo = jnp.asarray(-20.0, jnp.float32)
    hi = jnp.asarray(25.0, jnp.float32)

    def body(state, _):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        too_big = filled(mid) > cap
        return (jnp.where(too_big, lo, mid), jnp.where(too_big, mid, hi)), None

    (lo, hi), _ = jax.lax.scan(body, (lo, hi), None, length=_CHE_ITERS)
    t_c = jnp.exp(0.5 * (lo + hi))
    # If the whole (partitioned) working set fits in cache, T_c -> inf.
    total = jnp.sum(z)
    return jnp.where(total <= cap, jnp.inf, t_c)


def term_hit_probabilities(geom: CacheGeometry) -> Array:
    """h_i = 1 - exp(-lambda_i T_c) per term."""
    t_c = che_characteristic_time(geom)
    h = 1.0 - jnp.exp(-geom.term_rates * t_c)
    return jnp.where(jnp.isinf(t_c), jnp.ones_like(h), h)


def query_full_hit_probability(
    geom: CacheGeometry, query_terms: Array, lengths: Array
) -> Array:
    """P(all lists for the query are cached) per query (Eq 1's ``hit``).

    query_terms: (Q, Lmax) padded term ids; lengths: (Q,) #valid terms.
    Terms are independent under the IRM, so the full-hit probability is the
    product of per-term hit probabilities.
    """
    h = term_hit_probabilities(geom)
    ht = h[query_terms]  # (Q, Lmax)
    mask = jnp.arange(query_terms.shape[1])[None, :] < lengths[:, None]
    log_h = jnp.where(mask, jnp.log(jnp.maximum(ht, 1e-30)), 0.0)
    return jnp.exp(jnp.sum(log_h, axis=1))


def imbalance_probability(hit_q: Array, p: ArrayLike) -> Array:
    """P(servers split: some hit AND some miss) for one query.

    Under document partitioning each server's cache sees the same term
    stream with 1/p-size objects; treating per-server hits as independent
    Bernoulli(hit_q):  P_split = 1 - hit^p - (1-hit)^p.  This is the
    probability that the fork-join join actually pays the imbalance tax.
    """
    p = jnp.asarray(p, jnp.float32)
    return 1.0 - hit_q ** p - (1.0 - hit_q) ** p


def service_params_from_cache_model(
    geom: CacheGeometry,
    query_terms: Array,
    lengths: Array,
    *,
    cpu_per_entry: float = 20e-9,
    entry_bytes: float = 12.0,
    cpu_base: float = 2e-3,
) -> queueing.ServerParams:
    """Derive Eq 1 parameters (hit, S_hit, S_miss, S_disk) from the model.

    CPU time scales with the number of posting entries touched
    (intersection + ranking ~ linear pass over the shortest lists); disk
    time = seek + bytes_missed / disk_bw.  Constants are calibratable; the
    defaults land in the same regime as paper Table 5.
    """
    p = jnp.asarray(geom.p, jnp.float32)
    h_term = term_hit_probabilities(geom)
    hit_q = query_full_hit_probability(geom, query_terms, lengths)

    mask = (jnp.arange(query_terms.shape[1])[None, :] < lengths[:, None])
    q_bytes = jnp.where(mask, geom.list_bytes[query_terms] / p, 0.0)
    q_entries = q_bytes / entry_bytes

    # CPU time: linear in entries processed (both hit and miss paths).
    s_cpu_q = cpu_base + cpu_per_entry * jnp.sum(q_entries, axis=1)
    hit = jnp.mean(hit_q)
    w_hit = hit_q / jnp.maximum(jnp.sum(hit_q), 1e-9)
    w_miss = (1 - hit_q) / jnp.maximum(jnp.sum(1 - hit_q), 1e-9)
    s_hit = jnp.sum(w_hit * s_cpu_q)
    s_miss = jnp.sum(w_miss * s_cpu_q)

    # Disk bytes actually read: per term, missed with prob (1 - h_term).
    miss_bytes = jnp.where(mask, (1.0 - h_term[query_terms]) * q_bytes, 0.0)
    bytes_per_miss_query = jnp.sum(w_miss * jnp.sum(miss_bytes, axis=1))
    s_disk = geom.disk_seek + bytes_per_miss_query / geom.disk_bw

    return queueing.ServerParams(
        p=p, s_broker=jnp.asarray(0.0), s_hit=s_hit, s_miss=s_miss,
        s_disk=s_disk, hit=hit)


def service_time_cv(params: queueing.ServerParams) -> Array:
    """Coefficient of variation of the per-server service time under Eq 1.

    Mixture of Exp(s_hit) w.p. hit and Exp(s_miss)+Exp(s_disk) w.p. 1-hit.
    CV near 1 supports the paper's exponential service-time finding; the
    hit/miss split is what spreads *per-query* times across servers.
    """
    hit = jnp.asarray(params.hit)
    m_hit = jnp.asarray(params.s_hit)
    m_miss = jnp.asarray(params.s_miss) + jnp.asarray(params.s_disk)
    mean = hit * m_hit + (1 - hit) * m_miss
    # E[X^2]: exp => 2 mu^2; sum of two indep exps => 2(a^2+b^2)+2ab... use
    # Var(A+B)=a^2+b^2 with means a,b => E[(A+B)^2] = (a+b)^2 + a^2 + b^2.
    a = jnp.asarray(params.s_miss)
    b = jnp.asarray(params.s_disk)
    ex2 = hit * 2.0 * m_hit**2 + (1 - hit) * ((a + b) ** 2 + a**2 + b**2)
    var = ex2 - mean**2
    return jnp.sqrt(jnp.maximum(var, 0.0)) / mean
