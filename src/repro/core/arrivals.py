"""Arrival-process abstraction shared by workload generation and the DES.

The paper's Section 4.2 characterizes query traffic as Poisson *within a
stable window* whose rate follows diurnal/weekly structure across windows.
This module encodes exactly that: an :class:`ArrivalProcess` is a
piecewise-constant rate function (qps per time bin, tiling periodically)
plus, optionally, a replayed trace of concrete timestamps.

It is a registered pytree, so the streaming simulator
(`repro.core.simulator`) can close over it inside ``jax.lax.scan``: each
query chunk reads the rate at its start time and draws that chunk's
exponential gaps at that rate — the paper's "homogeneous within a window"
assumption made operational.  `repro.workloadgen.loadgen` builds the same
profiles for open-loop load generation, so the generator and the simulator
can never drift apart on what "the daily peak" means.

Four constructors cover the load regimes:

  * :meth:`ArrivalProcess.stationary` — constant-rate Poisson (one bin);
  * :meth:`ArrivalProcess.piecewise` — explicit rate-per-bin profiles
    (diurnal/weekly curves, folded traces, step loads);
  * :meth:`ArrivalProcess.flash_crowd` — baseline rate + burst windows
    (sudden-crowd stress loads, e.g. for calibration stability tests);
  * :meth:`ArrivalProcess.from_trace` — replay measured timestamps.

Leading dimensions of ``rates`` are scenario dimensions: a ``(S, B)``
rates array drives S independent scenarios through one shared profile
shape, which is how `repro.core.sweep` scales a normalized diurnal curve
by every grid point's mean arrival rate at once.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array
ArrayLike = Union[Array, Sequence[float], float]

__all__ = ["ArrivalProcess"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ArrivalProcess:
    """Piecewise-constant-rate Poisson arrivals, optionally trace-driven.

    rates: (..., n_bins) arrival rate (qps) per time bin; leading dims are
        scenario dims.  The profile tiles with period n_bins*bin_seconds.
    bin_seconds: scalar bin width in seconds.
    trace_gaps: optional (n,) interarrival gaps of a replayed trace.  When
        present the simulator consumes these instead of drawing gaps;
        ``rates`` then only provides the trace's mean rate (used e.g. to
        scale histogram bins).
    """

    rates: Array
    bin_seconds: Array
    trace_gaps: Optional[Array] = None

    # -- constructors ------------------------------------------------------

    @classmethod
    def stationary(cls, rate: ArrayLike) -> "ArrivalProcess":
        """Homogeneous Poisson at ``rate`` qps; any leading scenario shape."""
        r = jnp.asarray(rate)
        return cls(rates=r[..., None], bin_seconds=jnp.asarray(1.0))

    @classmethod
    def piecewise(cls, rates: ArrayLike, bin_seconds: ArrayLike
                  ) -> "ArrivalProcess":
        """Rate ``rates[..., i]`` on [i*bin, (i+1)*bin), tiling periodically."""
        return cls(rates=jnp.asarray(rates),
                   bin_seconds=jnp.asarray(bin_seconds))

    @classmethod
    def flash_crowd(
        cls,
        base_rate: ArrayLike,
        *,
        burst_starts: Union[Sequence[float], float],
        burst_seconds: float,
        burst_multiplier: float = 5.0,
        period_seconds: float = 3600.0,
        bin_seconds: float = 60.0,
    ) -> "ArrivalProcess":
        """Baseline load with flash-crowd burst windows (ROADMAP load shape).

        Rates are ``base_rate`` everywhere except on
        ``[start, start + burst_seconds)`` for each start in
        ``burst_starts`` (seconds into the period), where they are
        ``base_rate * burst_multiplier``.  The profile tiles with
        ``period_seconds``, so a single burst per period models a
        recurring spike and several starts model clustered crowds.
        ``base_rate`` may carry leading scenario dims; the burst windows
        are shared across scenarios (a sweep scales one crowd shape).
        """
        n_bins = max(1, int(round(period_seconds / bin_seconds)))
        edges = np.arange(n_bins) * float(bin_seconds)
        starts = np.atleast_1d(np.asarray(burst_starts, dtype=np.float64))
        in_burst = np.zeros(n_bins, dtype=bool)
        for s in starts % float(period_seconds):
            # a bin is burst-rated when the (period-wrapped, half-open)
            # burst window overlaps it AT ALL — either the bin's start
            # lies inside the window, or the burst starts mid-bin.  The
            # whole overlapped bin is elevated (conservative), so bursts
            # shorter than a bin are never silently dropped.
            rel = (edges - s) % float(period_seconds)
            in_burst |= (rel < float(burst_seconds)) | (
                rel > float(period_seconds) - float(bin_seconds))
        mult = jnp.where(jnp.asarray(in_burst), burst_multiplier, 1.0)
        rates = jnp.asarray(base_rate)[..., None] * mult
        return cls(rates=rates, bin_seconds=jnp.asarray(float(bin_seconds)))

    @classmethod
    def from_trace(cls, timestamps: ArrayLike) -> "ArrivalProcess":
        """Replay a measured (sorted, 1-D) arrival-timestamp trace.

        Gaps are differenced host-side in float64 BEFORE any float32
        conversion: near the end of a week-long window a float32
        timestamp only resolves 1/16 s, which would quantize sub-100 ms
        gaps to zero.  The gap values themselves are small and survive
        float32 fine.
        """
        t = np.asarray(timestamps, dtype=np.float64)
        gaps = jnp.asarray(np.diff(t, prepend=t[:1]))
        span = max(float(t[-1] - t[0]), 1e-9)
        mean_rate = (t.shape[0] - 1) / span
        return cls(rates=jnp.asarray(mean_rate)[None],
                   bin_seconds=jnp.asarray(1.0), trace_gaps=gaps)

    # -- derived quantities ------------------------------------------------

    @property
    def n_bins(self) -> int:
        return self.rates.shape[-1]

    @property
    def period_seconds(self) -> Array:
        return self.n_bins * self.bin_seconds

    @property
    def mean_rate(self) -> Array:
        """Per-scenario time-averaged rate, shape ``rates.shape[:-1]``."""
        return jnp.mean(self.rates, axis=-1)

    @property
    def peak_rate(self) -> Array:
        return jnp.max(self.rates, axis=-1)

    def rate_at(self, t: ArrayLike) -> Array:
        """Rate at absolute time ``t`` (scalar or per-scenario vector)."""
        t = jnp.asarray(t)
        idx = jnp.floor((t % self.period_seconds)
                        / self.bin_seconds).astype(jnp.int32)
        idx = jnp.clip(idx, 0, self.n_bins - 1)
        if self.rates.ndim == 1:
            return jnp.take(self.rates, idx)
        return jnp.take_along_axis(self.rates, idx[..., None], axis=-1)[..., 0]

    def scaled_by(self, scale: ArrayLike) -> "ArrivalProcess":
        """Scenario-scaled copy: rates ``scale[..., None] * rates``.

        Used by the sweep engine to drive every grid point's mean rate
        through one shared (typically mean-normalized) profile.
        """
        s = jnp.asarray(scale)
        return dataclasses.replace(self, rates=s[..., None] * self.rates)

    def normalized(self) -> "ArrivalProcess":
        """Copy with rates scaled to a time-averaged mean of 1 qps."""
        return dataclasses.replace(
            self, rates=self.rates / jnp.maximum(self.mean_rate[..., None],
                                                 1e-30))
