"""Capacity planning for model serving — the paper's methodology applied to
the assigned architectures.

The paper's pipeline is: measure a single server -> parameterize Eq 1 ->
predict cluster response time under Poisson load -> size replication
(Section 6).  Here the "single-server measurement" is the compiled dry-run:
`cost_analysis()` FLOPs/bytes and the HLO collective bytes give a roofline
service-time estimate per step, which becomes S_server in the same
fork-join queueing model:

  * a TP/EP-sharded model step is a fork-join across shards (the join is
    the output collective), so shard-time imbalance pays the H_p tax just
    like index servers with heterogeneous disk caches;
  * replicas of the serving cell take the role of cluster replicas.

This closes the loop between the dry-run roofline (repro.roofline) and the
paper's planner: one can ask "how many serving cells does qwen3-8b
decode_32k need for 500 req/s under a 100 ms SLO?" and get the Section-6
style answer.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import capacity, queueing, sweep

__all__ = ["HardwareSpec", "TPU_V5E", "RooflineTerms", "ServingModel",
           "serving_params", "plan_serving", "plan_over_grid"]


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware constants (defaults: TPU v5e, bf16)."""

    name: str
    peak_flops: float        # FLOP/s per chip
    hbm_bandwidth: float     # bytes/s per chip
    ici_bandwidth: float     # bytes/s per link
    vmem_bytes: float = 128 * 2**20
    hbm_bytes: float = 16 * 2**30


TPU_V5E = HardwareSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
)


@dataclasses.dataclass(frozen=True)
class RooflineTerms:
    """The three §Roofline terms, in seconds (already divided by chips)."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_lower_bound(self) -> float:
        """Perfect-overlap bound: all three engines run concurrently."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def step_time_serial_bound(self) -> float:
        """No-overlap (conservative, capacity-planning) bound."""
        return self.compute_s + self.memory_s + self.collective_s


def terms_from_analysis(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    collective_bytes: float,
    n_chips: int,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineTerms:
    """§Roofline: aggregate HLO counters -> per-(arch, mesh) terms."""
    return RooflineTerms(
        compute_s=hlo_flops / (n_chips * hw.peak_flops),
        memory_s=hlo_bytes / (n_chips * hw.hbm_bandwidth),
        collective_s=collective_bytes / (n_chips * hw.ici_bandwidth),
    )


@dataclasses.dataclass(frozen=True)
class ServingModel:
    """A serving cell: one model replica sharded over n_chips."""

    name: str
    terms: RooflineTerms
    n_chips: int
    batch_per_step: int      # requests retired per step
    dispatch_overhead_s: float = 50e-6   # broker analogue


def serving_params(model: ServingModel, *,
                   overlap_fraction: float = 0.0,
                   straggler_jitter: float = 0.0) -> queueing.ServerParams:
    """Map a serving cell onto Eq 1 parameters.

    The compiled step is a synchronous pipeline over n_chips — its chip-
    level fork-join is already serialized inside the step time, so the
    queueing-level server is the CELL (p=1).  Eq 1's decomposition maps
    onto overlap: the "hit" path is a perfectly overlapped step (all three
    engines concurrent), the "miss" path is the serial bound, with
    ``overlap_fraction`` playing the disk-cache hit ratio.  Stochastic
    per-chip jitter (the paper's imbalance) enters as an H_p-scaled
    inflation of the collective (join) term via ``straggler_jitter`` in
    [0, 1]: 0 = deterministic chips, 1 = fully exponential shard times.
    """
    t = model.terms
    jitter_tax = 1.0 + straggler_jitter * (
        float(queueing.harmonic_number(model.n_chips)) - 1.0)
    return queueing.ServerParams(
        p=1,
        s_broker=model.dispatch_overhead_s,
        s_hit=t.step_time_lower_bound,
        s_miss=t.compute_s + t.memory_s,
        s_disk=t.collective_s * jitter_tax,
        hit=overlap_fraction,
    )


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    model: str
    cells: int
    chips: int
    per_cell_rate: float
    response_upper_ms: float
    utilization: float
    bound: str


def plan_serving(
    model: ServingModel,
    target_rate_per_s: float,
    slo_seconds: float,
    *,
    result_cache: Optional[tuple[float, float]] = None,
) -> ServingPlan:
    """Section-6 case study for a model serving fleet.

    target_rate is in *requests*/s; a step retires batch_per_step requests,
    so the step arrival rate is rate / batch_per_step (continuous-batching
    approximation).
    """
    params = serving_params(model)
    step_rate_slo = capacity.max_rate_under_slo(
        params, slo_seconds, result_cache=result_cache)
    per_cell_req_rate = float(step_rate_slo) * model.batch_per_step
    if per_cell_req_rate <= 1e-6:
        # SLO below the single-step service time: no fleet size helps —
        # the latency floor is a property of the cell, not of replication
        # (the paper's baseline scenario: infeasible "even at very low
        # query arrival rates").
        return ServingPlan(
            model=model.name, cells=0, chips=0, per_cell_rate=0.0,
            response_upper_ms=float("inf"), utilization=0.0,
            bound=model.terms.bound)
    cells = max(1, math.ceil(target_rate_per_s / per_cell_req_rate))
    rate = target_rate_per_s / cells / model.batch_per_step
    if result_cache is None:
        _, hi = queueing.response_time_bounds(rate, params)
    else:
        hi = queueing.response_time_with_result_cache(
            rate, params, *result_cache)
    util = queueing.utilization(rate, queueing.service_time_server(params))
    return ServingPlan(
        model=model.name,
        cells=cells,
        chips=cells * model.n_chips,
        per_cell_rate=per_cell_req_rate,
        response_upper_ms=float(hi) * 1e3,
        utilization=float(util),
        bound=model.terms.bound,
    )


def plan_over_grid(
    grid: sweep.SweepGrid,
    slo_seconds: float,
    *,
    cost_fn: Optional[Callable] = None,
    simulate: bool = False,
    key=None,
    quantile: Optional[float] = None,
    n_queries: Optional[int] = None,
    profile=None,
    profile_bin_seconds: float = 3600.0,
    mesh=None,
    **sim_kwargs,
):
    """Section-6 what-if analysis over a whole configuration grid at once.

    Default: evaluates the analytical (Eq 7 upper bound) response surface
    for every (lambda, p, cpu, disk, hit) combination as one XLA program
    and extracts the constraint-satisfying frontier: per arrival rate, the
    cheapest configuration with R_upper <= SLO.  Returns the dense surface
    too so callers can plot Figs 9-12 style curves from the same
    evaluation.

    New knobs opened by the streaming simulation core:

      * ``simulate=True`` — replace the analytic surface with the
        streaming-simulated one (`sweep.sweep_simulated`); ``n_queries``
        and any extra ``sim_kwargs`` (mode, impl, chunk_size, hist_bins)
        pass through, and memory stays bounded by the chunk size no matter
        how long the simulated horizon is.
      * ``quantile=0.95`` — plan against tail latency instead of the
        mean/upper surface (works for both analytic and simulated paths).
      * ``profile=`` a relative-rate curve (e.g. ``loadgen.diurnal_rates``)
        with ``profile_bin_seconds`` — makes every simulated scenario's
        load time-varying, so "the cheapest config whose p95 survives the
        daily peak" is ``simulate=True, quantile=0.95, profile=...``.

    Replication rides the grid itself: build it with ``r=[1, 2, 4]``
    (and optionally ``result_cache=(hit_r, s_cache)``) and both paths
    price r dispatcher-routed replicas per cell — analytically at
    ``lam / r`` via Eq 7/8, simulated under a real routing policy
    (``cluster=ClusterSpec(routing="jsq")`` etc. passes through
    ``sim_kwargs``).  The frontier then answers "replicate, upgrade, or
    cache?" in one extraction.

    Elastic fleets ride the grid the same way: build it with
    ``autoscale=(AutoscalePolicy(...), ...)`` — the replica axis becomes
    a POLICY axis — and with ``simulate=True`` the frontier prices each
    policy by its observed replica-seconds, answering "which autoscaler
    config is cheapest under the p95 SLO over this load profile".
    Policy grids are simulation-only; the analytic path raises.

    ``mesh`` (a 1-D mesh from `repro.launch.mesh.make_sweep_mesh`) shards
    the scenario axis of either surface across devices — the
    million-scenario planning path of ``examples/global_sweep.py``.
    """
    if simulate:
        key = jax.random.PRNGKey(0) if key is None else key
        result = sweep.sweep_simulated(
            grid, key, n_queries=20_000 if n_queries is None else n_queries,
            profile=profile, profile_bin_seconds=profile_bin_seconds,
            mesh=mesh, **sim_kwargs)
    else:
        if (profile is not None or key is not None
                or n_queries is not None or sim_kwargs):
            raise ValueError(
                "profile/key/n_queries/simulation kwargs only take effect "
                "with simulate=True; the analytic path would silently "
                "ignore them")
        result = sweep.sweep_analytical(grid, mesh=mesh)
    frontier = sweep.extract_frontier(result, slo_seconds, cost_fn=cost_fn,
                                      quantile=quantile)
    return result, frontier
