"""The vertical search engine substrate (paper Sec 3): corpus, inverted
index, partitioning, scoring, broker, caches, and distributed execution."""
