"""Synthetic document corpus with the statistics of the TodoBR collection.

The real 10M-page TodoBR collection is proprietary (paper Sec 4.2), so the
engine is exercised on a synthetic corpus whose controllable knobs are the
properties the paper shows matter: Zipf term popularity in documents (which
shapes inverted-list sizes), document length distribution, and vocabulary
size.  Index *construction* is an offline batch job and runs host-side in
numpy; the query-time hot path (scoring) is JAX.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["CorpusConfig", "Corpus", "generate_corpus"]


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    n_docs: int = 100_000
    vocab_size: int = 50_000
    mean_doc_len: int = 150
    term_zipf_alpha: float = 1.0     # term frequency in documents
    seed: int = 0

    # bytes per posting entry: docid (8) + tf (4) — matches the paper's
    # "document identifier and within-document frequency" entry layout.
    entry_bytes: int = 12


@dataclasses.dataclass
class Corpus:
    config: CorpusConfig
    doc_terms: np.ndarray    # (n_postings,) term ids, grouped by doc
    doc_offsets: np.ndarray  # (n_docs + 1,) CSR offsets into doc_terms
    tf: np.ndarray           # (n_postings,) within-doc term frequency

    @property
    def n_docs(self) -> int:
        return self.config.n_docs

    @property
    def n_postings(self) -> int:
        return int(self.doc_terms.shape[0])


def generate_corpus(config: CorpusConfig) -> Corpus:
    """Sample documents as bags of Zipf-distributed terms.

    Each document draws L ~ Poisson(mean_doc_len) tokens from the Zipf term
    distribution; duplicate (doc, term) tokens collapse into tf counts —
    the same unique-terms-per-document structure an inverted file stores.
    """
    rng = np.random.default_rng(config.seed)
    n, v = config.n_docs, config.vocab_size

    lengths = np.maximum(rng.poisson(config.mean_doc_len, size=n), 1)
    total = int(lengths.sum())

    # Zipf term sampling via inverse CDF over ranked probabilities.
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = ranks ** (-config.term_zipf_alpha)
    probs /= probs.sum()
    cdf = np.cumsum(probs)
    tokens = np.searchsorted(cdf, rng.random(total)).astype(np.int64)
    tokens = np.minimum(tokens, v - 1)

    doc_ids = np.repeat(np.arange(n, dtype=np.int64), lengths)

    # Collapse duplicates: unique (doc, term) with counts.
    key = doc_ids * v + tokens
    uniq, counts = np.unique(key, return_counts=True)
    u_doc = (uniq // v).astype(np.int32)
    u_term = (uniq % v).astype(np.int32)

    order = np.argsort(u_doc, kind="stable")
    u_doc, u_term, counts = u_doc[order], u_term[order], counts[order]
    offsets = np.zeros(n + 1, dtype=np.int64)
    np.add.at(offsets, u_doc + 1, 1)
    offsets = np.cumsum(offsets)

    return Corpus(config=config, doc_terms=u_term,
                  doc_offsets=offsets, tf=counts.astype(np.int32))
