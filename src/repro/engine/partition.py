"""Index partitioning strategies (paper Sec 2.1 / 3.2).

Document partitioning (the paper's choice and the de-facto standard) plus
the term-partitioning baseline the related work compares against, so the
framework can reproduce the comparison conclusions.

Documents are assigned to servers randomly (uniform hashing), the policy
the paper cites as balancing storage well [5, 3].
"""

from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.engine.corpus import Corpus, CorpusConfig
from repro.engine.index import InvertedIndex, build_index

__all__ = ["partition_documents", "partition_terms", "Partitioned"]


@dataclasses.dataclass
class Partitioned:
    """A partitioned index: one InvertedIndex per server + routing info."""

    scheme: str                    # "document" | "term"
    shards: List[InvertedIndex]
    doc_base: np.ndarray           # (p,) global doc-id base per shard
    term_owner: np.ndarray | None  # (V,) owning server (term partitioning)

    @property
    def p(self) -> int:
        return len(self.shards)


def partition_documents(corpus: Corpus, p: int, *, seed: int = 0
                        ) -> Partitioned:
    """Random uniform assignment of documents to p servers.

    Each server builds a full local index over its subcollection of size
    b = n/p; global document frequencies are shared so local idf == global
    idf (Sec 3.3).
    """
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, p, size=corpus.n_docs)

    # Global doc freq over the whole collection for idf exchange.
    v = corpus.config.vocab_size
    gdf = np.zeros(v, dtype=np.int64)
    np.add.at(gdf, corpus.doc_terms, 1)

    lengths = np.diff(corpus.doc_offsets)
    shards, bases = [], []
    for s in range(p):
        docs = np.flatnonzero(assign == s)
        bases.append(docs)
        mask = np.isin(
            np.repeat(np.arange(corpus.n_docs), lengths), docs)
        sub_terms = corpus.doc_terms[mask]
        sub_tf = corpus.tf[mask]
        # renumber docs 0..b-1 inside the shard
        sub_lengths = lengths[docs]
        sub_offsets = np.concatenate([[0], np.cumsum(sub_lengths)])
        sub = Corpus(
            config=dataclasses.replace(corpus.config, n_docs=len(docs)),
            doc_terms=sub_terms, doc_offsets=sub_offsets, tf=sub_tf)
        shards.append(build_index(sub, global_doc_freq=gdf,
                                  total_docs=corpus.n_docs))
    # doc_base maps (shard, local_id) -> global id
    doc_base = np.zeros(p, dtype=np.int64)  # kept simple: store tables
    part = Partitioned(scheme="document", shards=shards,
                       doc_base=doc_base, term_owner=None)
    part.local_to_global = bases  # list of arrays
    return part


def partition_hybrid(corpus: Corpus, p: int, *, chunk_docs: int = 256,
                     seed: int = 0) -> Partitioned:
    """Hybrid partitioning (Sornil & Fox; Badue et al. [2], Sec 2.1):
    each inverted list is cut into equal-size chunks which are randomly
    distributed over the servers.

    Realized here by hashing (term, doc_block) pairs to servers: a term's
    postings land on many servers in contiguous chunks, balancing both
    storage AND per-query load (vs document partitioning's per-server
    full-query work or term partitioning's hot owners).
    """
    rng = np.random.default_rng(seed)
    v = corpus.config.vocab_size
    gdf = np.zeros(v, dtype=np.int64)
    np.add.at(gdf, corpus.doc_terms, 1)

    lengths = np.diff(corpus.doc_offsets)
    doc_of_posting = np.repeat(np.arange(corpus.n_docs), lengths)
    # chunk id = (term, doc // chunk_docs); server = hash(chunk) % p
    chunk_key = (corpus.doc_terms.astype(np.int64) * 1_000_003
                 + doc_of_posting // chunk_docs)
    owner = (chunk_key * 2654435761 % 2**32) % p

    shards = []
    for s in range(p):
        mask = owner == s
        sub_docs = doc_of_posting[mask]
        sub_terms = corpus.doc_terms[mask]
        sub_tf = corpus.tf[mask]
        order = np.argsort(sub_docs, kind="stable")
        sub_docs, sub_terms, sub_tf = (
            sub_docs[order], sub_terms[order], sub_tf[order])
        offsets = np.zeros(corpus.n_docs + 1, dtype=np.int64)
        np.add.at(offsets, sub_docs + 1, 1)
        offsets = np.cumsum(offsets)
        sub = Corpus(config=corpus.config, doc_terms=sub_terms,
                     doc_offsets=offsets, tf=sub_tf)
        shards.append(build_index(sub, global_doc_freq=gdf,
                                  total_docs=corpus.n_docs))
    return Partitioned(scheme="hybrid", shards=shards,
                       doc_base=np.zeros(p, dtype=np.int64),
                       term_owner=None)


def partition_terms(corpus: Corpus, p: int) -> Partitioned:
    """Term partitioning baseline: server s owns terms with hash(t) % p == s.

    Every server indexes the *whole* collection restricted to its terms, so
    a query only visits the owners of its terms (here, for the comparison
    benchmark, we still broadcast and let non-owners return empty).
    """
    v = corpus.config.vocab_size
    owner = (np.arange(v) * 2654435761 % 2**32) % p

    gdf = np.zeros(v, dtype=np.int64)
    np.add.at(gdf, corpus.doc_terms, 1)

    lengths = np.diff(corpus.doc_offsets)
    doc_of_posting = np.repeat(np.arange(corpus.n_docs), lengths)
    shards = []
    for s in range(p):
        mask = owner[corpus.doc_terms] == s
        sub_terms = corpus.doc_terms[mask]
        sub_tf = corpus.tf[mask]
        sub_docs = doc_of_posting[mask]
        # rebuild a CSR by doc for build_index
        order = np.argsort(sub_docs, kind="stable")
        sub_docs, sub_terms, sub_tf = (
            sub_docs[order], sub_terms[order], sub_tf[order])
        offsets = np.zeros(corpus.n_docs + 1, dtype=np.int64)
        np.add.at(offsets, sub_docs + 1, 1)
        offsets = np.cumsum(offsets)
        sub = Corpus(config=corpus.config, doc_terms=sub_terms,
                     doc_offsets=offsets, tf=sub_tf)
        shards.append(build_index(sub, global_doc_freq=gdf,
                                  total_docs=corpus.n_docs))
    return Partitioned(scheme="term", shards=shards,
                       doc_base=np.zeros(p, dtype=np.int64),
                       term_owner=owner)
