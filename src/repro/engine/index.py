"""Inverted index (paper Sec 3.2): vocabulary + CSR posting lists.

Each term's inverted list holds (doc_id, tf) entries.  Construction is an
offline numpy batch job; the resulting arrays are handed to JAX for the
query-time hot path.  Global idf factors are derived exactly as the paper
describes: document frequencies are exchanged after local index generation
(here: computed over the full collection, then broadcast).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.engine.corpus import Corpus

__all__ = ["InvertedIndex", "build_index"]


@dataclasses.dataclass
class InvertedIndex:
    """CSR inverted file over one (sub)collection."""

    n_docs: int
    vocab_size: int
    term_offsets: np.ndarray   # (V + 1,) int64 — CSR offsets per term
    doc_ids: np.ndarray        # (NNZ,) int32 — postings, doc-sorted per term
    tf: np.ndarray             # (NNZ,) float32 — within-doc frequency
    doc_norms: np.ndarray      # (D,) float32 — vector-model document norms
    idf: np.ndarray            # (V,) float32 — GLOBAL inverse doc frequency
    entry_bytes: int = 12

    @property
    def n_postings(self) -> int:
        return int(self.doc_ids.shape[0])

    def list_lengths(self) -> np.ndarray:
        return np.diff(self.term_offsets)

    def list_bytes(self) -> np.ndarray:
        """Per-term inverted-list size in bytes — drives the disk model."""
        return self.list_lengths() * self.entry_bytes

    def index_bytes(self) -> int:
        return self.n_postings * self.entry_bytes

    def as_device_arrays(self):
        """The query-time arrays, as jnp (offsets, doc_ids, weights, norms)."""
        w = self.tf * self.idf[np.repeat(
            np.arange(self.vocab_size), self.list_lengths())]
        return (jnp.asarray(self.term_offsets),
                jnp.asarray(self.doc_ids),
                jnp.asarray(w.astype(np.float32)),
                jnp.asarray(self.doc_norms))


def build_index(corpus: Corpus, *, global_doc_freq: np.ndarray = None,
                total_docs: int = None) -> InvertedIndex:
    """Invert a (sub)collection.

    global_doc_freq/total_docs inject collection-wide statistics so that a
    partition's local index still ranks with global idf (paper Sec 3.3:
    "each index server may then derive the global idf factor").
    """
    v = corpus.config.vocab_size
    terms = corpus.doc_terms
    tf = corpus.tf.astype(np.float32)

    # doc ids per posting from the CSR doc offsets
    lengths = np.diff(corpus.doc_offsets)
    doc_of_posting = np.repeat(
        np.arange(corpus.n_docs, dtype=np.int32), lengths)

    order = np.argsort(terms, kind="stable")  # stable keeps doc order
    t_sorted = terms[order]
    d_sorted = doc_of_posting[order]
    tf_sorted = tf[order]

    term_offsets = np.zeros(v + 1, dtype=np.int64)
    np.add.at(term_offsets, t_sorted + 1, 1)
    term_offsets = np.cumsum(term_offsets)

    if global_doc_freq is None:
        global_doc_freq = np.diff(term_offsets)
        total_docs = corpus.n_docs
    idf = np.log((total_docs + 1.0) / (global_doc_freq + 1.0)).astype(
        np.float32)

    # Vector-model document norms: ||d|| over tf*idf weights.
    w = tf_sorted * idf[t_sorted]
    norms_sq = np.zeros(corpus.n_docs, dtype=np.float64)
    np.add.at(norms_sq, d_sorted, (w ** 2).astype(np.float64))
    doc_norms = np.sqrt(np.maximum(norms_sq, 1e-12)).astype(np.float32)

    return InvertedIndex(
        n_docs=corpus.n_docs,
        vocab_size=v,
        term_offsets=term_offsets,
        doc_ids=d_sorted,
        tf=tf_sorted,
        doc_norms=doc_norms,
        idf=idf,
    )
