"""Query-time scoring (paper Sec 3.3) — the index-server hot path, in JAX.

Vector-space model with tf-idf cosine ranking over the *conjunction* of the
query terms ("standard practice on modern search engines", paper fn. 1).
The paper deliberately evaluates FULL inverted lists (no pruning) to keep
capacity estimates conservative; we follow that, with a static posting
budget P_max per term so the whole scorer jits (lists longer than the
budget are processed in full via multiple budget windows chosen at trace
time from the longest list in the shard).

Algorithm per (query, shard):
  1. gather each query term's posting window (doc_ids, weights) from the
     CSR arrays (masked fixed-size gather),
  2. scatter-accumulate per-doc score and per-doc matched-term count,
  3. conjunction: keep docs whose matched count == query length,
  4. cosine-normalize by doc norms, take local top-k.

Step 2 is the classic JAX segment pattern (`.at[].add`) — the same
primitive the GNN and recsys substrates build on.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["score_queries", "local_topk"]


@functools.partial(jax.jit, static_argnames=("n_docs", "budget", "k"))
def score_queries(
    term_offsets: jax.Array,   # (V+1,) int64
    doc_ids: jax.Array,        # (NNZ,) int32
    weights: jax.Array,        # (NNZ,) float32 (tf * idf)
    doc_norms: jax.Array,      # (D,) float32
    query_terms: jax.Array,    # (Q, L) int32, padded with -1
    *,
    n_docs: int,
    budget: int,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Top-k (scores, local doc ids) per query.  Shapes are static.

    budget: max postings processed per term (static).  Entries beyond a
    term's true list length are masked out; terms longer than the budget
    are truncated — callers size the budget from max list length for exact
    results, or lower for the paper's 'partial evaluation' variant [29].
    """
    q_valid = query_terms >= 0
    q_terms = jnp.maximum(query_terms, 0)
    q_len = jnp.sum(q_valid, axis=1)                       # (Q,)

    starts = term_offsets[q_terms]                         # (Q, L)
    ends = term_offsets[q_terms + 1]
    lens = (ends - starts) * q_valid                       # (Q, L)

    pos = jnp.arange(budget, dtype=starts.dtype)           # (P,)
    idx = starts[..., None] + pos                          # (Q, L, P)
    mask = (pos < lens[..., None]) & q_valid[..., None]
    idx = jnp.minimum(idx, doc_ids.shape[0] - 1)

    d = doc_ids[idx]                                       # (Q, L, P)
    w = weights[idx] * mask                                # (Q, L, P)
    d = jnp.where(mask, d, n_docs)                         # park masked

    def accumulate(d_q, w_q, m_q):
        scores = jnp.zeros((n_docs + 1,), jnp.float32)
        count = jnp.zeros((n_docs + 1,), jnp.int32)
        scores = scores.at[d_q.reshape(-1)].add(w_q.reshape(-1))
        count = count.at[d_q.reshape(-1)].add(
            m_q.reshape(-1).astype(jnp.int32))
        return scores[:n_docs], count[:n_docs]

    scores, counts = jax.vmap(accumulate)(d, w, mask)      # (Q, D)

    conj = counts == q_len[:, None]                        # conjunction
    cos = jnp.where(conj & (q_len[:, None] > 0),
                    scores / doc_norms[None, :], -jnp.inf)
    top_scores, top_docs = jax.lax.top_k(cos, k)
    return top_scores, top_docs.astype(jnp.int32)


def local_topk(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    s, i = jax.lax.top_k(scores, k)
    return s, i.astype(jnp.int32)
