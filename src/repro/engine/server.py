"""Index server: local query processing + the measurement harness.

`IndexServer` wraps one shard's device arrays with the jitted scorer and a
service-time instrumentation path that mirrors the paper's methodology
(Sec 4.3/5.3): CPU time is measured around the compiled scorer; disk time
comes from the LRU cache replay; the two compose into Eq 1 parameters.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.queueing import ServerParams
from repro.engine import cache as cache_lib
from repro.engine.index import InvertedIndex
from repro.engine.scoring import score_queries

__all__ = ["IndexServer", "measure_service_params", "measure_busy_trace"]


class IndexServer:
    def __init__(self, index: InvertedIndex, *, budget: int = None,
                 k_local: int = 10):
        self.index = index
        (self.term_offsets, self.doc_ids,
         self.weights, self.doc_norms) = index.as_device_arrays()
        max_list = int(index.list_lengths().max()) if index.n_postings else 1
        self.budget = int(budget or max_list)
        self.k_local = k_local

    def process(self, query_terms: jax.Array):
        """Local top-k for a batch of queries (the hot path)."""
        return score_queries(
            self.term_offsets, self.doc_ids, self.weights, self.doc_norms,
            query_terms, n_docs=self.index.n_docs, budget=self.budget,
            k=self.k_local)

    def timed_process(self, query_terms: jax.Array) -> float:
        """Wall-clock seconds for one batch (compiled, post-warmup)."""
        t0 = time.perf_counter()
        s, d = self.process(query_terms)
        jax.block_until_ready((s, d))
        return time.perf_counter() - t0


def measure_service_params(
    server: IndexServer,
    query_terms: np.ndarray,          # (Q, L) int, padded -1
    cache_bytes: int,
    *,
    p: int,
    s_broker: float,
    batch: int = 64,
    warmup_batches: int = 2,
    disk_bw: float = 50e6,
    disk_seek: float = 8e-3,
) -> ServerParams:
    """The paper's parameterization step, end to end.

    CPU time: measured around the compiled scorer per batch, divided by
    batch (hit and miss share the compute path; S_hit vs S_miss differ by
    the masked fraction of postings actually touched, which the replay
    splits).  Disk time and hit probability: LRU replay over this server's
    list sizes.  Returns Eq 1 parameters for the queueing model.
    """
    stats, hits, disk_time = cache_lib.measure_cache_behavior(
        query_terms, server.index.list_bytes(), cache_bytes,
        disk_bw=disk_bw, disk_seek=disk_seek,
        warmup=min(query_terms.shape[0] // 10, 2000))

    q = query_terms.shape[0]
    times = []
    qt = jnp.asarray(query_terms[: batch * (q // batch)].reshape(
        -1, batch, query_terms.shape[1]))
    for i in range(qt.shape[0]):
        dt = server.timed_process(qt[i])
        if i >= warmup_batches:
            times.append(dt / batch)
    s_cpu = float(np.mean(times)) if times else 1e-3

    miss = ~hits
    s_disk = float(disk_time[miss].mean()) if miss.any() else 0.0
    return ServerParams(
        p=p, s_broker=s_broker,
        s_hit=s_cpu, s_miss=s_cpu, s_disk=s_disk,
        hit=stats.hit)


def measure_busy_trace(
    server: IndexServer,
    query_terms: np.ndarray,          # (n, L) int, padded -1
    cache_bytes: int,
    *,
    batch: int = 64,
    warmup_batches: int = 2,
    disk_bw: float = 50e6,
    disk_seek: float = 8e-3,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-query instrumentation at ONE shard, for trace calibration.

    Where :func:`measure_service_params` reduces the run to Eq-1 scalars,
    this keeps the whole record: per-query busy time (timed compiled
    scorer, per batch, plus the cache replay's per-query disk time), the
    full-hit flag, the disk split, and the partial top-k results so the
    broker merge can be timed downstream.  ``n`` must be a multiple of
    ``batch``.  Returns (busy, hit, disk, scores, docs) with shapes
    ((n,), (n,), (n,), (n, k_local), (n, k_local)).
    """
    n = query_terms.shape[0]
    if n % batch:
        raise ValueError(f"n={n} must be a multiple of batch={batch}")
    _, hits, disk_time = cache_lib.measure_cache_behavior(
        query_terms, server.index.list_bytes(), cache_bytes,
        disk_bw=disk_bw, disk_seek=disk_seek, warmup=0)

    qt = jnp.asarray(query_terms.reshape(-1, batch, query_terms.shape[1]))
    for _ in range(max(warmup_batches, 1)):
        server.timed_process(qt[0])   # compile + warm before any timing
    cpu = np.zeros(n, dtype=np.float64)
    scores = np.zeros((n, server.k_local), dtype=np.float32)
    docs = np.zeros((n, server.k_local), dtype=np.int32)
    for i in range(qt.shape[0]):
        t0 = time.perf_counter()
        s, d = server.process(qt[i])
        jax.block_until_ready((s, d))
        cpu[i * batch:(i + 1) * batch] = (time.perf_counter() - t0) / batch
        scores[i * batch:(i + 1) * batch] = np.asarray(s)
        docs[i * batch:(i + 1) * batch] = np.asarray(d)

    disk = np.where(hits, 0.0, disk_time)
    return cpu + disk, hits.astype(np.float64), disk, scores, docs
