"""Distributed query processing: document partitioning on a JAX mesh.

The paper's cluster (Fig 1) maps onto the mesh as: one index server per
slice along the ``servers`` axis; the broker broadcast is the replication
of the query batch; the join is an all_gather of local top-k; the broker
merge is a final top_k.  Under `shard_map`, each shard runs exactly the
single-server hot path (`scoring.score_queries`) on its subcollection —
the code is literally the paper's architecture.

Index shards are stacked into leading-axis-p arrays (padded to the longest
shard) so one `NamedSharding` over the ``servers`` axis scatters them.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.engine.broker import merge_topk
from repro.engine.partition import Partitioned
from repro.engine.scoring import score_queries

__all__ = ["StackedShards", "stack_shards", "make_search_fn"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StackedShards:
    term_offsets: jax.Array   # (p, V+1)
    doc_ids: jax.Array        # (p, NNZ_max)
    weights: jax.Array        # (p, NNZ_max)
    doc_norms: jax.Array      # (p, B_max)
    local_to_global: jax.Array  # (p, B_max) int32
    meta: dict = dataclasses.field(
        metadata=dict(static=True), default_factory=dict)


def stack_shards(part: Partitioned) -> StackedShards:
    p = part.p
    nnz_max = max(s.n_postings for s in part.shards)
    b_max = max(s.n_docs for s in part.shards)
    v = part.shards[0].vocab_size

    offs = np.zeros((p, v + 1), np.int64)
    docs = np.zeros((p, nnz_max), np.int32)
    wts = np.zeros((p, nnz_max), np.float32)
    norms = np.ones((p, b_max), np.float32)
    l2g = np.zeros((p, b_max), np.int32)
    budget = 1
    for s, shard in enumerate(part.shards):
        offs[s] = shard.term_offsets
        docs[s, : shard.n_postings] = shard.doc_ids
        w = shard.tf * shard.idf[np.repeat(np.arange(v),
                                           shard.list_lengths())]
        wts[s, : shard.n_postings] = w
        norms[s, : shard.n_docs] = shard.doc_norms
        if hasattr(part, "local_to_global"):
            g = part.local_to_global[s]
            l2g[s, : len(g)] = g
        else:
            l2g[s, : shard.n_docs] = np.arange(shard.n_docs)
        budget = max(budget, int(shard.list_lengths().max()))
    return StackedShards(
        term_offsets=jnp.asarray(offs),
        doc_ids=jnp.asarray(docs),
        weights=jnp.asarray(wts),
        doc_norms=jnp.asarray(norms),
        local_to_global=jnp.asarray(l2g),
        meta=dict(p=p, b_max=b_max, budget=budget),
    )


def make_search_fn(mesh: Mesh, stacked: StackedShards, *, k: int = 10,
                   k_local: Optional[int] = None, axis: str = "servers"):
    """Build the jitted distributed search: queries (Q, L) -> top-k.

    Fork: queries replicated to every shard.  Local processing: the
    single-server scorer.  Join: all_gather of (scores, global ids).
    Merge: broker top-k.  One XLA program; the collectives ARE the
    broker/join of Fig 1.
    """
    k_local = k_local or k
    n_docs = stacked.meta["b_max"]
    budget = stacked.meta["budget"]

    def local(term_offsets, doc_ids, weights, doc_norms, l2g, queries):
        # shard_map gives (1, ...) slices along the servers axis
        s, d = score_queries(
            term_offsets[0], doc_ids[0], weights[0], doc_norms[0],
            queries, n_docs=n_docs, budget=budget, k=k_local)
        g = l2g[0][d]                                  # global doc ids
        s_all = jax.lax.all_gather(s, axis)            # (p, Q, k_local)
        g_all = jax.lax.all_gather(g, axis)
        return merge_topk(s_all, g_all, k=k)

    shard = compat.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis), P(axis), P(axis), P()),
        out_specs=(P(), P()),
        check_vma=False,
    )

    @jax.jit
    def search(queries: jax.Array):
        return shard(stacked.term_offsets, stacked.doc_ids, stacked.weights,
                     stacked.doc_norms, stacked.local_to_global, queries)

    return search
