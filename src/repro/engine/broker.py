"""Broker: broadcast, in-memory top-k merge, result cache (paper Sec 3.1).

The merge is the fork-join *join point*: partial ranked answers from all p
index servers are combined by a single top-k over the concatenated
candidates.  The broker "does not have to make ranking computations ...
other than comparing document ranks" (Sec 5.1) — the merge is exactly that
comparison, O(p*k log k) work, all in memory.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp

__all__ = ["merge_topk", "timed_merge_topk"]


@functools.partial(jax.jit, static_argnames=("k",))
def merge_topk(
    partial_scores: jax.Array,   # (p, Q, k_local)
    partial_docs: jax.Array,     # (p, Q, k_local) — GLOBAL doc ids
    *,
    k: int,
) -> tuple[jax.Array, jax.Array]:
    """Merge p partial ranked answers into the final top-k per query."""
    p, q, kl = partial_scores.shape
    flat_s = jnp.moveaxis(partial_scores, 0, 1).reshape(q, p * kl)
    flat_d = jnp.moveaxis(partial_docs, 0, 1).reshape(q, p * kl)
    top_s, idx = jax.lax.top_k(flat_s, k)
    top_d = jnp.take_along_axis(flat_d, idx, axis=1)
    return top_s, top_d


def timed_merge_topk(
    partial_scores: jax.Array,
    partial_docs: jax.Array,
    *,
    k: int,
) -> tuple[tuple[jax.Array, jax.Array], float]:
    """Instrumented merge: ((scores, docs), wall-clock seconds).

    The calibration harness's broker probe — the measured time is the
    paper's S_broker contribution for this batch (the broker "only
    compares document ranks"; the merge IS that comparison).  Callers
    should run one untimed batch first so compilation is excluded.
    """
    t0 = time.perf_counter()
    out = merge_topk(partial_scores, partial_docs, k=k)
    jax.block_until_ready(out)
    return out, time.perf_counter() - t0
