"""Disk-cache and result-cache simulation (paper Sec 3.4 / Scenario 6).

`LruByteCache` simulates the OS page cache over inverted-list bytes at one
index server: queries touch their terms' lists; a query is a *full hit*
when every list is resident (Eq 1's ``hit``).  This is the measurement
instrument that replaces the paper's /proc/diskstats readings and exposes
the mechanism behind service-time imbalance: p servers run the SAME query
stream over 1/p-size lists but their caches diverge only in degree — the
hit/miss split per query is what spreads service times.

`ResultCache` is the broker's application-level query-result cache
(Scenario 6, parameters from Baeza-Yates et al. [8]).

Both are host-side Python (they model OS/broker state machines, not device
compute); their *outputs* parameterize the JAX queueing model.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict

import numpy as np

__all__ = ["LruByteCache", "CacheStats", "ResultCache",
           "measure_cache_behavior"]


@dataclasses.dataclass
class CacheStats:
    queries: int = 0
    full_hits: int = 0
    bytes_from_disk: int = 0
    bytes_requested: int = 0

    @property
    def hit(self) -> float:
        return self.full_hits / max(self.queries, 1)

    @property
    def disk_fraction(self) -> float:
        return self.bytes_from_disk / max(self.bytes_requested, 1)


class LruByteCache:
    """Byte-capacity LRU over term ids (posting lists)."""

    def __init__(self, capacity_bytes: int):
        self.capacity = int(capacity_bytes)
        self._lru: OrderedDict[int, int] = OrderedDict()
        self._used = 0

    def access(self, term: int, size: int) -> bool:
        """Touch one list; returns True on hit.  Inserts on miss."""
        if term in self._lru:
            self._lru.move_to_end(term)
            return True
        size = min(size, self.capacity)
        while self._used + size > self.capacity and self._lru:
            _, evicted = self._lru.popitem(last=False)
            self._used -= evicted
        self._lru[term] = size
        self._used += size
        return False

    def query(self, terms, sizes) -> tuple[bool, int]:
        """Access all of a query's lists; (full_hit, bytes_from_disk)."""
        full_hit = True
        from_disk = 0
        for t, z in zip(terms, sizes):
            if not self.access(int(t), int(z)):
                full_hit = False
                from_disk += int(z)
        return full_hit, from_disk


class ResultCache:
    """LRU cache of final answers keyed by query id (Scenario 6)."""

    def __init__(self, capacity_entries: int):
        self.capacity = int(capacity_entries)
        self._lru: OrderedDict[int, bool] = OrderedDict()
        self.hits = 0
        self.lookups = 0

    def lookup(self, query_id: int) -> bool:
        self.lookups += 1
        if query_id in self._lru:
            self._lru.move_to_end(query_id)
            self.hits += 1
            return True
        if self.capacity > 0:
            if len(self._lru) >= self.capacity:
                self._lru.popitem(last=False)
            self._lru[query_id] = True
        return False

    @property
    def hit_ratio(self) -> float:
        return self.hits / max(self.lookups, 1)


def measure_cache_behavior(
    query_terms: np.ndarray,      # (Q, L) padded with -1
    list_bytes: np.ndarray,       # (V,) per-term list size at this server
    cache_bytes: int,
    *,
    disk_bw: float = 50e6,
    disk_seek: float = 8e-3,
    warmup: int = 0,
) -> tuple[CacheStats, np.ndarray, np.ndarray]:
    """Replay a query stream through the LRU; returns per-query outputs.

    Returns (stats, full_hit[Q] bool, disk_time[Q] seconds).  Mirrors the
    paper's methodology: warm the cache, then measure (``measured after
    warming up the index servers``, Sec 4.3).
    """
    cache = LruByteCache(cache_bytes)
    q = query_terms.shape[0]
    hits = np.zeros(q, dtype=bool)
    disk_time = np.zeros(q, dtype=np.float64)
    stats = CacheStats()
    for i in range(q):
        terms = query_terms[i]
        terms = terms[terms >= 0]
        sizes = list_bytes[terms]
        full_hit, from_disk = cache.query(terms, sizes)
        hits[i] = full_hit
        disk_time[i] = 0.0 if full_hit else disk_seek + from_disk / disk_bw
        if i >= warmup:
            stats.queries += 1
            stats.full_hits += int(full_hit)
            stats.bytes_from_disk += from_disk
            stats.bytes_requested += int(sizes.sum())
    return stats, hits, disk_time
