"""Shared AST machinery: modules, suppressions, and abstract domains.

Three layers, used by every rule module:

  * **Module** — a parsed source file with its import-alias table, so a
    rule can ask "does this call resolve to ``jax.random.exponential``?"
    without caring whether the file wrote ``jax.random.exponential``,
    ``jrandom.exponential`` or ``from jax import random``.
  * **Suppressions** — ``# staticcheck: disable=RPR0xx[,RPR0yy]`` on the
    flagged line.  Bare ``disable`` (no ID) and unknown IDs are themselves
    findings (RPR000) so suppressions cannot rot silently.
  * **Tracer abstraction** — a tiny abstract interpreter over function
    bodies with the three-value lattice STATIC < UNKNOWN < TRACED.  Jit
    entry points (``@jax.jit`` / ``functools.partial(jax.jit,
    static_argnames=...)``) mark their non-static parameters TRACED;
    functions handed to ``lax.scan``/``cond``/``while_loop``/``fori_loop``
    mark all parameters TRACED; values propagate through assignments,
    arithmetic, and jnp/lax calls.  Shape/dtype attribute reads and
    ``is (not) None`` tests are STATIC by construction (pytree structure
    and shapes are static under tracing) — that is what keeps the
    branch-on-tracer rule quiet on the streaming engine's legitimate
    ``if has_trace:`` / ``if r == 1:`` static branches while still
    catching a real ``if jnp.any(x > 0):`` inside a jitted function.
    TRACED only ever arises from values *derived from traced parameters*,
    so an UNKNOWN (e.g. any un-resolvable call result) never false-fires.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Iterator, Optional, Sequence, Union

__all__ = [
    "Finding",
    "Module",
    "iter_functions",
    "resolve_call",
    "TracerLattice",
    "FunctionContext",
    "jit_entry_info",
    "control_flow_bodies",
    "TracerInterp",
]

_SUPPRESS_RE = re.compile(
    r"#\s*staticcheck:\s*disable(?:=(?P<ids>[A-Za-z0-9_,\s]*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One diagnostic: rule ID + location + message."""

    rule_id: str
    path: str          # repo-relative posix path
    line: int
    col: int
    message: str
    suppressed: bool = False

    def render(self) -> str:
        tag = " (suppressed)" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule_id} {self.message}{tag}")


class Module:
    """A parsed source file + import aliases + suppression table."""

    def __init__(self, path: Union[str, pathlib.Path], rel_posix: str,
                 text: Optional[str] = None):
        self.path = pathlib.Path(path)
        self.rel = rel_posix
        self.text = self.path.read_text() if text is None else text
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=str(self.path))
        self.aliases = _import_aliases(self.tree)
        self.suppressions, self.bad_suppressions = _suppressions(self.text)

    def qualname(self, node: ast.AST) -> Optional[str]:
        """Resolve a Name/Attribute chain to a full dotted path, or None."""
        parts: list[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        root = self.aliases.get(node.id, node.id)
        parts.append(root)
        return ".".join(reversed(parts))

    def is_suppressed(self, rule_id: str, line: int) -> bool:
        return rule_id in self.suppressions.get(line, set())


def _import_aliases(tree: ast.Module) -> dict[str, str]:
    """Local name -> fully dotted module/symbol path."""
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                aliases[a.asname or a.name.split(".")[0]] = (
                    a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                aliases[a.asname or a.name] = f"{node.module}.{a.name}"
    return aliases


def _suppressions(text: str
                  ) -> tuple[dict[int, set[str]], list[tuple[int, str]]]:
    """Per-line suppressed rule IDs + malformed suppression comments.

    Only real COMMENT tokens count — docstrings that *mention* the
    suppression syntax (like this package's own docs) are not
    suppressions.
    """
    table: dict[int, set[str]] = {}
    bad: list[tuple[int, str]] = []
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(text).readline))
    except (tokenize.TokenError, IndentationError):
        return table, bad
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        m = _SUPPRESS_RE.search(tok.string)
        if not m:
            continue
        lineno = tok.start[0]
        ids = [s.strip() for s in (m.group("ids") or "").split(",")
               if s.strip()]
        if not ids:
            bad.append((lineno, "suppression without a rule ID"))
            continue
        table[lineno] = set(ids)
    return table, bad


def iter_functions(tree: ast.AST) -> Iterator[ast.FunctionDef]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def resolve_call(mod: Module, node: ast.Call) -> Optional[str]:
    """Fully qualified name of a call's callee, or None."""
    return mod.qualname(node.func)


# --------------------------------------------------------------------------
# Tracer abstraction
# --------------------------------------------------------------------------

class TracerLattice:
    STATIC = 0
    UNKNOWN = 1
    TRACED = 2

    @staticmethod
    def join(*vals: int) -> int:
        return max(vals) if vals else TracerLattice.STATIC


# attribute reads that are static regardless of the object's tracedness:
# shapes, ranks and dtypes are compile-time constants under jit
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "n_bins", "n_queries",
                 "p", "tap_size", "name"}

# callee roots whose results are traced when any argument is traced
_ARRAY_NAMESPACES = ("jax.numpy", "jnp", "jax.lax", "jax.random", "jax.nn",
                     "jax.scipy", "jax.tree_util", "jax")

_CONTROL_FLOW_FNS = {
    "jax.lax.scan": 0, "jax.lax.cond": (1, 2), "jax.lax.while_loop": (0, 1),
    "jax.lax.fori_loop": 2, "jax.lax.switch": None, "jax.lax.map": 0,
}


@dataclasses.dataclass
class FunctionContext:
    """Why a function's parameters are considered traced."""

    node: ast.FunctionDef
    kind: str                       # "jit" | "body"
    static_params: frozenset[str] = frozenset()


def jit_entry_info(mod: Module, fn: ast.FunctionDef
                   ) -> Optional[FunctionContext]:
    """FunctionContext if ``fn`` is jit-decorated (possibly via partial)."""
    for deco in fn.decorator_list:
        target = deco.func if isinstance(deco, ast.Call) else deco
        qn = mod.qualname(target)
        if qn in ("jax.jit", "jit"):
            static = _static_argnames(deco)
            return FunctionContext(fn, "jit", static)
        if qn in ("functools.partial", "partial") and isinstance(
                deco, ast.Call) and deco.args:
            inner = mod.qualname(deco.args[0])
            if inner in ("jax.jit", "jit"):
                static = _static_argnames(deco)
                return FunctionContext(fn, "jit", static)
    return None


def _static_argnames(deco: ast.AST) -> frozenset[str]:
    names: set[str] = set()
    if isinstance(deco, ast.Call):
        for kw in deco.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                for sub in ast.walk(kw.value):
                    if isinstance(sub, ast.Constant) and isinstance(
                            sub.value, str):
                        names.add(sub.value)
    return frozenset(names)


def control_flow_bodies(mod: Module, scope: ast.AST) -> set[str]:
    """Names of local functions passed to lax control-flow combinators.

    Their parameters (carry, per-step slices) are traced by construction.
    Lambdas are handled inline by the interpreter; this resolves the
    ``def body(...)`` / ``lax.scan(body, ...)`` idiom.
    """
    names: set[str] = set()
    for node in ast.walk(scope):
        if not isinstance(node, ast.Call):
            continue
        qn = resolve_call(mod, node)
        if qn is None:
            continue
        spec = _CONTROL_FLOW_FNS.get(qn)
        if spec is None and qn not in _CONTROL_FLOW_FNS:
            continue
        idxs: tuple[int, ...]
        if spec is None:
            idxs = tuple(range(len(node.args)))
        elif isinstance(spec, int):
            idxs = (spec,)
        else:
            idxs = tuple(spec)
        for i in idxs:
            if i < len(node.args) and isinstance(node.args[i], ast.Name):
                names.add(node.args[i].id)
    return names


class TracerInterp:
    """Forward abstract interpretation of one function body.

    Statement-ordered walk; ``If`` arms are interpreted in forked
    environments and joined.  The visitor calls ``on_test`` for every
    ``if``/``while`` test and ``on_call`` for every call site with the
    abstract values of the call's arguments — rules hook those.
    """

    def __init__(self, mod: Module, ctx: FunctionContext):
        self.mod = mod
        self.ctx = ctx
        self.env: dict[str, int] = {}
        fn = ctx.node
        args = list(fn.args.posonlyargs) + list(fn.args.args) + list(
            fn.args.kwonlyargs)
        for i, a in enumerate(args):
            if a.arg in ("self", "cls"):
                self.env[a.arg] = TracerLattice.STATIC
            elif a.arg in ctx.static_params or str(i) in ctx.static_params:
                self.env[a.arg] = TracerLattice.STATIC
            elif _annotated_static(a):
                self.env[a.arg] = TracerLattice.STATIC
            else:
                self.env[a.arg] = TracerLattice.TRACED

    # -- abstract evaluation ----------------------------------------------

    def value(self, node: Optional[ast.AST]) -> int:
        L = TracerLattice
        if node is None or isinstance(node, ast.Constant):
            return L.STATIC
        if isinstance(node, ast.Name):
            return self.env.get(node.id, L.STATIC)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return L.STATIC
            base = self.value(node.value)
            return base
        if isinstance(node, ast.Subscript):
            return L.join(self.value(node.value), self.value(node.slice))
        if isinstance(node, (ast.Tuple, ast.List)):
            return L.join(*[self.value(e) for e in node.elts])
        if isinstance(node, ast.BinOp):
            return L.join(self.value(node.left), self.value(node.right))
        if isinstance(node, ast.UnaryOp):
            return self.value(node.operand)
        if isinstance(node, ast.BoolOp):
            return L.join(*[self.value(v) for v in node.values])
        if isinstance(node, ast.Compare):
            # `x is None` / `x is not None` probes pytree STRUCTURE,
            # which is static under tracing even when x is traced
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return L.STATIC
            return L.join(self.value(node.left),
                          *[self.value(c) for c in node.comparators])
        if isinstance(node, ast.IfExp):
            return L.join(self.value(node.body), self.value(node.orelse))
        if isinstance(node, ast.Call):
            qn = resolve_call(self.mod, node)
            argv = [self.value(a) for a in node.args] + [
                self.value(kw.value) for kw in node.keywords]
            if qn is not None and qn.startswith(_ARRAY_NAMESPACES):
                return L.join(L.STATIC, *argv)
            return L.UNKNOWN
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp,
                             ast.DictComp)):
            return L.UNKNOWN
        if isinstance(node, ast.Starred):
            return self.value(node.value)
        if isinstance(node, ast.JoinedStr):
            return L.STATIC
        return L.UNKNOWN

    # -- statement walk ----------------------------------------------------

    def run(self, on_test, on_call) -> None:
        self._block(self.ctx.node.body, on_test, on_call)

    def _assign_target(self, target: ast.AST, val: int) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = val
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._assign_target(e, val)
        # attribute/subscript stores don't rebind names

    def _expr(self, node: ast.AST, on_call) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                argv = [self.value(a) for a in sub.args]
                kwv = {kw.arg: self.value(kw.value) for kw in sub.keywords}
                on_call(sub, argv, kwv)

    def _block(self, stmts, on_test, on_call) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                val_node = stmt.value
                if val_node is not None:
                    self._expr(val_node, on_call)
                val = self.value(val_node)
                if isinstance(stmt, ast.Assign):
                    for t in stmt.targets:
                        self._assign_target(t, val)
                else:
                    self._assign_target(stmt.target, val)
            elif isinstance(stmt, ast.If):
                self._expr(stmt.test, on_call)
                on_test(stmt, self.value(stmt.test))
                saved = dict(self.env)
                self._block(stmt.body, on_test, on_call)
                after_body = self.env
                self.env = dict(saved)
                self._block(stmt.orelse, on_test, on_call)
                for k in set(after_body) | set(self.env):
                    self.env[k] = TracerLattice.join(
                        after_body.get(k, TracerLattice.STATIC),
                        self.env.get(k, TracerLattice.STATIC))
            elif isinstance(stmt, ast.While):
                self._expr(stmt.test, on_call)
                on_test(stmt, self.value(stmt.test))
                self._block(stmt.body, on_test, on_call)
            elif isinstance(stmt, ast.For):
                self._expr(stmt.iter, on_call)
                self._assign_target(stmt.target, self.value(stmt.iter))
                self._block(stmt.body, on_test, on_call)
                self._block(stmt.orelse, on_test, on_call)
            elif isinstance(stmt, (ast.Return, ast.Expr, ast.Assert,
                                   ast.Raise)):
                for field in ast.iter_child_nodes(stmt):
                    self._expr(field, on_call)
            elif isinstance(stmt, (ast.With,)):
                for item in stmt.items:
                    self._expr(item.context_expr, on_call)
                self._block(stmt.body, on_test, on_call)
            elif isinstance(stmt, ast.Try):
                self._block(stmt.body, on_test, on_call)
                for h in stmt.handlers:
                    self._block(h.body, on_test, on_call)
                self._block(stmt.orelse, on_test, on_call)
                self._block(stmt.finalbody, on_test, on_call)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue  # nested defs are analyzed separately
            # pass/break/continue/import/global: nothing to do


def _annotated_static(arg: ast.arg) -> bool:
    """Parameters annotated as host types are static by declaration."""
    ann = arg.annotation
    if isinstance(ann, ast.Name):
        return ann.id in ("int", "str", "bool")
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        return ann.value in ("int", "str", "bool")
    return False
