"""Command-line driver: collect files, run rules, report, gate.

Usage (CI runs exactly this, see .github/workflows/ci.yml):

    python -m repro.staticcheck src tests          # rules + contract
    python -m repro.staticcheck --format json src  # machine-readable
    python -m repro.staticcheck --list-rules       # registry dump
    python -m repro.staticcheck --update-contract  # intentional API change

Exit codes: 0 = clean, 1 = unsuppressed findings, 2 = usage error.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Iterable, Optional, Sequence

from repro.staticcheck.analysis import Finding, Module
from repro.staticcheck.registry import RULES, rules_for_path

_SKIP_DIRS = {"__pycache__", ".git", ".venv", "build", "dist"}


def check_source(text: str, rel_posix: str,
                 path: Optional[pathlib.Path] = None) -> list[Finding]:
    """Run every applicable rule over one source string.

    ``rel_posix`` decides rule scoping (fixture tests pass synthetic
    paths like ``src/repro/core/x.py``).  Suppressed findings are kept
    (marked), so reporters can count them; RPR000 covers malformed
    suppressions.
    """
    try:
        mod = Module(path or pathlib.Path(rel_posix), rel_posix, text=text)
    except SyntaxError as e:
        return [Finding("RPR000", rel_posix, e.lineno or 1, 0,
                        f"could not parse: {e.msg}")]
    findings: list[Finding] = []
    for lineno, msg in mod.bad_suppressions:
        findings.append(Finding("RPR000", rel_posix, lineno, 0, msg))
    for lineno, ids in mod.suppressions.items():
        for rid in sorted(ids):
            if rid not in RULES:
                findings.append(Finding(
                    "RPR000", rel_posix, lineno, 0,
                    f"suppression references unknown rule ID {rid}"))
    for r in rules_for_path(rel_posix):
        for f in r.check(mod):
            if mod.is_suppressed(f.rule_id, f.line):
                f = Finding(f.rule_id, f.path, f.line, f.col, f.message,
                            suppressed=True)
            findings.append(f)
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule_id))
    return findings


def check_file(path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    try:
        rel = path.resolve().relative_to(root.resolve()).as_posix()
    except ValueError:
        rel = path.as_posix()
    return check_source(path.read_text(), rel, path=path)


def collect_files(targets: Sequence[str],
                  root: pathlib.Path) -> list[pathlib.Path]:
    files: list[pathlib.Path] = []
    for t in targets:
        p = (root / t) if not pathlib.Path(t).is_absolute() else \
            pathlib.Path(t)
        if p.is_file():
            files.append(p)
        elif p.is_dir():
            files.extend(
                f for f in sorted(p.rglob("*.py"))
                if not _SKIP_DIRS.intersection(f.parts))
        else:
            raise FileNotFoundError(t)
    return files


def run(targets: Sequence[str], root: pathlib.Path) -> list[Finding]:
    findings: list[Finding] = []
    for f in collect_files(targets, root):
        findings.extend(check_file(f, root))
    return findings


# --------------------------------------------------------------------------
# Reporters
# --------------------------------------------------------------------------

def report_text(findings: Iterable[Finding], out=sys.stdout) -> None:
    findings = list(findings)
    active = [f for f in findings if not f.suppressed]
    for f in active:
        print(f.render(), file=out)
    n_sup = sum(1 for f in findings if f.suppressed)
    print(f"staticcheck: {len(active)} finding(s), "
          f"{n_sup} suppressed, {len(RULES)} rule(s)", file=out)


def report_json(findings: Iterable[Finding], out=sys.stdout) -> None:
    findings = list(findings)
    payload = {
        "findings": [
            {"rule": f.rule_id, "path": f.path, "line": f.line,
             "col": f.col, "message": f.message,
             "suppressed": f.suppressed}
            for f in findings],
        "counts": {
            "active": sum(1 for f in findings if not f.suppressed),
            "suppressed": sum(1 for f in findings if f.suppressed),
        },
    }
    json.dump(payload, out, indent=2, sort_keys=True)
    print(file=out)


def list_rules(out=sys.stdout) -> None:
    for rid in sorted(RULES):
        r = RULES[rid]
        print(f"{rid}  [{r.family:<10}]  {r.name}", file=out)
        print(f"        {r.description}", file=out)


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro-staticcheck",
        description="AST + abstract-interpretation checks for the repro "
                    "codebase (conventions, tracer safety, Pallas "
                    "structure, eval_shape contract)")
    ap.add_argument("targets", nargs="*", default=["src", "tests"],
                    help="files/directories to check (default: src tests)")
    ap.add_argument("--root", default=".",
                    help="repo root that scoping globs are relative to")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    ap.add_argument("--no-contract", action="store_true",
                    help="skip the eval_shape contract check (pure AST)")
    ap.add_argument("--update-contract", action="store_true",
                    help="re-snapshot shape_contract.json and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        list_rules()
        return 0

    # imported lazily: pulls in jax (the AST rules do not need it)
    if args.update_contract:
        from repro.staticcheck import contract
        contract.save()
        print(f"wrote {contract.CONTRACT_PATH}")
        return 0

    root = pathlib.Path(args.root)
    targets = args.targets or ["src", "tests"]
    try:
        findings = run(targets, root)
    except FileNotFoundError as e:
        print(f"staticcheck: no such target: {e}", file=sys.stderr)
        return 2

    if not args.no_contract:
        from repro.staticcheck import contract
        findings.extend(contract.check())

    reporter = report_json if args.format == "json" else report_text
    reporter(findings)
    return 1 if any(not f.suppressed for f in findings) else 0


if __name__ == "__main__":
    sys.exit(main())
