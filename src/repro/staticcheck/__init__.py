"""repro.staticcheck: rule-based static analysis for this repo.

Four rule families over one registry (`repro.staticcheck.registry`):

  * convention rules (RPR001-099) — the ROADMAP "Standing conventions";
  * tracer-safety rules (RPR101-199) — JAX footguns that never throw;
  * Pallas rules (RPR201-299) — kernel grid/BlockSpec structure;
  * the eval_shape contract (RPR301) — entry-point shape/dtype pinning.

Run ``python -m repro.staticcheck src tests`` (or the
``repro-staticcheck`` console script).  Suppress a single line with
``# staticcheck: disable=RPR0xx`` — bare ``disable`` is itself a finding.
"""

from repro.staticcheck import contract as _contract  # registers RPR301
from repro.staticcheck import rules_conventions as _rc  # noqa: F401
from repro.staticcheck import rules_pallas as _rp  # noqa: F401
from repro.staticcheck import rules_tracer as _rt  # noqa: F401
from repro.staticcheck.analysis import Finding, Module
from repro.staticcheck.cli import check_source, main, run
from repro.staticcheck.registry import RULES, Rule, rules_for_path

__all__ = [
    "Finding",
    "Module",
    "RULES",
    "Rule",
    "check_source",
    "main",
    "run",
    "rules_for_path",
]
