"""``python -m repro.staticcheck`` entry point."""

import sys

import repro.staticcheck  # noqa: F401  (registers every rule)
from repro.staticcheck.cli import main

if __name__ == "__main__":
    sys.exit(main())
