"""JAX tracer-safety rules.

These are the bug classes that never throw — they silently bake one
scenario into a jitted sweep (RPR101/RPR105), correlate arrival streams
(RPR102), fall back to host numpy mid-trace (RPR103), or promote the f32
streaming carry to f64 (RPR104).

Tracer rules only analyze functions that are *demonstrably* jit-reachable:
``@jax.jit`` / ``functools.partial(jax.jit, ...)`` entry points and
function bodies handed to ``lax.scan``/``cond``/``while_loop``/
``fori_loop``.  Host-side helpers (e.g. ``ArrivalProcess.from_trace``'s
deliberate float64 accumulation) are out of scope by construction.
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.staticcheck.analysis import (
    Finding,
    FunctionContext,
    Module,
    TracerInterp,
    TracerLattice,
    control_flow_bodies,
    iter_functions,
    jit_entry_info,
    resolve_call,
)
from repro.staticcheck.registry import rule

_HOT_SCOPE = ["src/repro/core/*.py", "src/repro/calibrate/*.py"]


def _jit_reachable(mod: Module) -> Iterator[tuple[ast.FunctionDef,
                                                  FunctionContext]]:
    """(fn, context) for jit entry points and lax control-flow bodies."""
    body_names = control_flow_bodies(mod, mod.tree)
    for fn in iter_functions(mod.tree):
        ctx = jit_entry_info(mod, fn)
        if ctx is not None:
            yield fn, ctx
        elif fn.name in body_names:
            yield fn, FunctionContext(fn, "body")


# --------------------------------------------------------------------------
# RPR101 / RPR105: Python control flow & host conversions on tracers
# --------------------------------------------------------------------------

@rule("RPR101", "no-python-branch-on-tracer", "tracer",
      "Python if/while on a traced value inside a jit-reachable function "
      "bakes one branch into the compiled sweep; use jnp.where / lax.cond",
      scope=_HOT_SCOPE)
def check_branch_on_tracer(mod: Module) -> Iterator[Finding]:
    findings: list[Finding] = []
    for fn, ctx in _jit_reachable(mod):
        interp = TracerInterp(mod, ctx)

        def on_test(stmt: ast.stmt, val: int) -> None:
            if val == TracerLattice.TRACED:
                kw = "while" if isinstance(stmt, ast.While) else "if"
                findings.append(Finding(
                    "RPR101", mod.rel, stmt.lineno, stmt.col_offset,
                    f"Python `{kw}` on a traced value in jit-reachable "
                    f"`{fn.name}`; use jnp.where or lax.cond"))

        interp.run(on_test, lambda *_: None)
    yield from findings


_HOST_CASTS = {"float", "int", "bool"}


@rule("RPR105", "no-host-cast-on-tracer", "tracer",
      "float()/int()/bool() on a traced value forces a concretization "
      "error (or silent host sync) inside jit; keep it as an array",
      scope=_HOT_SCOPE)
def check_host_cast_on_tracer(mod: Module) -> Iterator[Finding]:
    findings: list[Finding] = []
    for fn, ctx in _jit_reachable(mod):
        interp = TracerInterp(mod, ctx)

        def on_call(node: ast.Call, argv: list[int], kwv: dict) -> None:
            qn = resolve_call(mod, node)
            if (qn in _HOST_CASTS and argv
                    and argv[0] == TracerLattice.TRACED):
                findings.append(Finding(
                    "RPR105", mod.rel, node.lineno, node.col_offset,
                    f"`{qn}()` applied to a traced value in "
                    f"jit-reachable `{fn.name}`"))

        interp.run(lambda *_: None, on_call)
    yield from findings


# --------------------------------------------------------------------------
# RPR102: PRNG key reuse
# --------------------------------------------------------------------------

# calls that CONSUME their key argument: sampling the same key twice (or
# splitting it twice) yields identical/correlated streams.  fold_in is a
# pure derivation (the simulator deliberately salts one key many times)
# and is NOT consumption.
_KEY_CONSUMERS = {
    "exponential", "normal", "uniform", "gamma", "beta", "bernoulli",
    "randint", "choice", "permutation", "categorical", "truncated_normal",
    "laplace", "poisson", "binomial", "bits", "gumbel", "split",
}
_KEY_MAKERS = {"PRNGKey", "key", "split", "fold_in", "wrap_key_data",
               "clone"}


def _random_leaf(mod: Module, node: ast.Call) -> Optional[str]:
    qn = resolve_call(mod, node)
    if qn is None:
        return None
    head, _, leaf = qn.rpartition(".")
    if head in ("jax.random", "random", "jrandom", "jr"):
        return leaf
    if qn.startswith("jax.random."):
        return qn.split(".", 2)[-1]
    return None


class _KeyWalker:
    """Path-sensitive key-consumption counter for one function body.

    ``keys`` maps a variable name to (consumed_count, loop_depth_at_def).
    ``If`` arms run in forked states; an arm that returns/raises does not
    contribute to the joined state (that is what keeps the per-mode
    ``return jax.random.exponential(key, ...)`` dispatch in
    ``sample_service_times_batch`` clean).  A consumption at a loop depth
    greater than the key's definition depth is an immediate finding —
    every iteration would re-consume the same key.
    """

    def __init__(self, mod: Module, fn: ast.FunctionDef):
        self.mod = mod
        self.fn = fn
        self.keys: dict[str, tuple[int, int]] = {}
        self.depth = 0
        self.findings: list[Finding] = []
        for a in (list(fn.args.posonlyargs) + list(fn.args.args)
                  + list(fn.args.kwonlyargs)):
            if "key" in a.arg.lower() or a.arg in ("rng", "prng"):
                self.keys[a.arg] = (0, 0)

    # -- events ------------------------------------------------------------

    def _consume(self, name: str, node: ast.AST, leaf: str) -> None:
        if name not in self.keys:
            return
        count, def_depth = self.keys[name]
        if self.depth > def_depth:
            self.findings.append(Finding(
                "RPR102", self.mod.rel, node.lineno, node.col_offset,
                f"PRNG key `{name}` consumed by `{leaf}` inside a loop "
                f"but derived outside it (in `{self.fn.name}`); every "
                "iteration reuses the same randomness — fold_in the "
                "loop index or split per iteration"))
            return
        if count >= 1:
            self.findings.append(Finding(
                "RPR102", self.mod.rel, node.lineno, node.col_offset,
                f"PRNG key `{name}` consumed more than once (again by "
                f"`{leaf}` in `{self.fn.name}`); split or fold_in "
                "before each use"))
        self.keys[name] = (count + 1, def_depth)

    def _visit_call(self, node: ast.Call) -> None:
        leaf = _random_leaf(self.mod, node)
        if leaf is None:
            return
        if leaf in _KEY_CONSUMERS:
            arg = node.args[0] if node.args else None
            for kw in node.keywords:
                if kw.arg == "key":
                    arg = kw.value
            if isinstance(arg, ast.Name):
                self._consume(arg.id, node, leaf)

    def _maybe_bind(self, stmt: ast.Assign | ast.AnnAssign) -> None:
        value = stmt.value
        if not isinstance(value, ast.Call):
            return
        leaf = _random_leaf(self.mod, value)
        if leaf not in _KEY_MAKERS:
            return
        targets = (stmt.targets if isinstance(stmt, ast.Assign)
                   else [stmt.target])
        for t in targets:
            elts = t.elts if isinstance(t, (ast.Tuple, ast.List)) else [t]
            for e in elts:
                if isinstance(e, ast.Name):
                    self.keys[e.id] = (0, self.depth)

    # -- walk --------------------------------------------------------------

    def run(self) -> list[Finding]:
        self._block(self.fn.body)
        return self.findings

    def _exprs(self, node: ast.AST) -> None:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                self._visit_call(sub)

    def _block(self, stmts: list[ast.stmt]) -> bool:
        """Walk statements; True if the block definitely terminates."""
        for stmt in stmts:
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                if stmt.value is not None:
                    self._exprs(stmt.value)
                self._maybe_bind(stmt)
            elif isinstance(stmt, ast.AugAssign):
                self._exprs(stmt.value)
            elif isinstance(stmt, ast.If):
                self._exprs(stmt.test)
                saved = dict(self.keys)
                body_done = self._block(stmt.body)
                after_body = self.keys
                self.keys = dict(saved)
                else_done = self._block(stmt.orelse)
                if body_done and not else_done:
                    pass                       # keep the else state
                elif else_done and not body_done:
                    self.keys = after_body
                else:
                    merged = {}
                    for k in set(after_body) | set(self.keys):
                        c1, d1 = after_body.get(k, (0, self.depth))
                        c2, d2 = self.keys.get(k, (0, self.depth))
                        merged[k] = (max(c1, c2), min(d1, d2))
                    self.keys = merged
                if body_done and else_done:
                    return True
            elif isinstance(stmt, (ast.For, ast.While)):
                if isinstance(stmt, ast.For):
                    self._exprs(stmt.iter)
                else:
                    self._exprs(stmt.test)
                self.depth += 1
                self._block(stmt.body)
                self.depth -= 1
                self._block(stmt.orelse)
            elif isinstance(stmt, (ast.Return, ast.Raise)):
                for child in ast.iter_child_nodes(stmt):
                    self._exprs(child)
                return True
            elif isinstance(stmt, (ast.Expr, ast.Assert)):
                self._exprs(stmt)
            elif isinstance(stmt, ast.With):
                for item in stmt.items:
                    self._exprs(item.context_expr)
                if self._block(stmt.body):
                    return True
            elif isinstance(stmt, ast.Try):
                self._block(stmt.body)
                for h in stmt.handlers:
                    self._block(h.body)
                self._block(stmt.orelse)
                self._block(stmt.finalbody)
            elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                   ast.ClassDef)):
                continue                       # analyzed separately
        return False


@rule("RPR102", "no-prng-key-reuse", "tracer",
      "a jax.random key sampled (or split) twice without re-derivation "
      "yields identical streams and silently correlates scenarios",
      scope=["src/**/*.py"])
def check_key_reuse(mod: Module) -> Iterator[Finding]:
    for fn in iter_functions(mod.tree):
        yield from _KeyWalker(mod, fn).run()


# --------------------------------------------------------------------------
# RPR103: host numpy on traced values in hot modules
# --------------------------------------------------------------------------

@rule("RPR103", "no-numpy-on-tracers", "tracer",
      "numpy ops applied to traced arguments in a hot module force a "
      "trace-time concretization; use jax.numpy",
      scope=["src/repro/core/*.py", "src/repro/kernels/**/*.py"])
def check_numpy_on_tracers(mod: Module) -> Iterator[Finding]:
    findings: list[Finding] = []
    for fn, ctx in _jit_reachable(mod):
        interp = TracerInterp(mod, ctx)

        def on_call(node: ast.Call, argv: list[int], kwv: dict) -> None:
            qn = resolve_call(mod, node)
            if (qn is not None and qn.startswith("numpy.")
                    and TracerLattice.TRACED in argv):
                findings.append(Finding(
                    "RPR103", mod.rel, node.lineno, node.col_offset,
                    f"host numpy call `{qn}` on a traced value in "
                    f"jit-reachable `{fn.name}`; use jax.numpy"))

        interp.run(lambda *_: None, on_call)
    yield from findings


# --------------------------------------------------------------------------
# RPR104: f64 leaks into the f32 streaming scan
# --------------------------------------------------------------------------

_F64_NAMES = {"jax.numpy.float64", "numpy.float64", "jnp.float64"}


@rule("RPR104", "no-f64-in-streaming-scan", "tracer",
      "float64 literal/dtype inside a jit-reachable function promotes "
      "the f32 max-plus carry and drifts the tail estimates",
      scope=_HOT_SCOPE)
def check_f64_promotion(mod: Module) -> Iterator[Finding]:
    for fn, _ctx in _jit_reachable(mod):
        for node in ast.walk(fn):
            if isinstance(node, (ast.Attribute, ast.Name)):
                qn = mod.qualname(node)
                if qn in _F64_NAMES:
                    yield Finding(
                        "RPR104", mod.rel, node.lineno, node.col_offset,
                        f"float64 dtype `{qn}` inside jit-reachable "
                        f"`{fn.name}`; the streaming scan is f32 by "
                        "contract")
            elif (isinstance(node, ast.Constant)
                  and node.value in ("float64", "f64")):
                yield Finding(
                    "RPR104", mod.rel, node.lineno, node.col_offset,
                    "string dtype 'float64' inside jit-reachable "
                    f"`{fn.name}`; the streaming scan is f32 by contract")
