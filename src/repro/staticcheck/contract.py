"""Abstract-run harness: eval_shape the public API against a contract.

``jax.eval_shape`` traces every registered entry point with *abstract*
inputs — no kernel executes, no RNG draws, yet the full pytree of output
shapes, dtypes and weak-type flags comes out.  Comparing that against the
committed ``shape_contract.json`` turns silent shape/dtype regressions
(an accidental f64 promotion in the scan carry, a dropped scenario axis,
a field that became weakly typed) into a red CI job with a one-line diff.

The contract is intentionally *data*, not code: when an API change is
deliberate, regenerate the file with

    python -m repro.staticcheck --update-contract

and review the JSON diff in the PR like any other artifact.

Probe design notes:

  * PRNG keys (and per-probe array inputs: the batch rate vector, the
    sweep's lam axis, TraceRecord leaves) are passed as *abstract*
    ``ShapeDtypeStruct`` arguments, so the streaming ``lax.scan`` binds
    abstractly instead of running 60k queries.
  * Host-side scalars and static configuration (ServerParams, grid
    axes other than lam, ``n_queries``) stay concrete — the entry points
    legitimately call ``int()``/``float()`` on them before tracing.
  * ``plan_capacity`` is host-side by design (it returns Python
    scalars), so its probe runs the analytic path concretely and the
    contract pins the *Python types* of the plan's fields.
"""

from __future__ import annotations

import json
import pathlib
from typing import Callable

from repro.staticcheck.analysis import Finding
from repro.staticcheck.registry import register_datarule

CONTRACT_PATH = pathlib.Path(__file__).with_name("shape_contract.json")
CONTRACT_REL = "src/repro/staticcheck/shape_contract.json"

register_datarule(
    "RPR301", "eval-shape-contract", "contract",
    "entry-point output shapes/dtypes/weak-types must match the "
    "committed shape_contract.json (regenerate with --update-contract "
    "when the change is intentional)")


def _spec(leaf) -> str:
    """'float32[3,256]' (+ '~' when weakly typed), or 'py:int'."""
    dtype = getattr(leaf, "dtype", None)
    if dtype is None:
        return f"py:{type(leaf).__name__}"
    shape = ",".join(str(d) for d in getattr(leaf, "shape", ()))
    weak = "~" if getattr(leaf, "weak_type", False) else ""
    return f"{dtype}[{shape}]{weak}"


def _tree_specs(out) -> dict[str, str]:
    from jax.tree_util import keystr, tree_flatten_with_path
    leaves, _ = tree_flatten_with_path(out)
    return {keystr(path): _spec(leaf) for path, leaf in leaves}


# --------------------------------------------------------------------------
# Probes
# --------------------------------------------------------------------------

def _probes() -> dict[str, Callable[[], dict[str, str]]]:
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.calibrate import fit, measure
    from repro.core import capacity, simulator, sweep
    from repro.core.cluster import ClusterSpec
    from repro.core.queueing import ServerParams
    from repro.launch.elastic import AutoscalePolicy

    params = ServerParams(p=4, s_broker=0.004, s_hit=0.0125, s_miss=0.05,
                          s_disk=0.04, hit=0.5)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)

    def p_sim():
        return _tree_specs(jax.eval_shape(
            lambda k: simulator.simulate_fork_join(
                k, 50.0, 256, params, chunk_size=128, tap_size=8),
            key))

    def p_sim_replicated():
        return _tree_specs(jax.eval_shape(
            lambda k: simulator.simulate_fork_join(
                k, 120.0, 256, params, chunk_size=128,
                cluster=ClusterSpec(r=3, routing="jsq",
                                    result_cache=(0.3, 0.001))),
            key))

    def p_sim_telemetry():
        from repro.obs.timeline import TelemetrySpec
        return _tree_specs(jax.eval_shape(
            lambda k: simulator.simulate_fork_join(
                k, 120.0, 256, params, chunk_size=128,
                cluster=ClusterSpec(r=2),
                telemetry=TelemetrySpec(n_bins=8, slo_seconds=0.7)),
            key))

    def p_sim_autoscale():
        from repro.obs.timeline import TelemetrySpec
        pol = AutoscalePolicy(min_r=1, max_r=3,
                              decision_interval_seconds=0.25)
        return _tree_specs(jax.eval_shape(
            lambda k: simulator.simulate_fork_join(
                k, 120.0, 256, params, chunk_size=128,
                cluster=ClusterSpec(routing="jsq", autoscale=pol),
                telemetry=TelemetrySpec(n_bins=8)),
            key))

    def p_sim_fault():
        from repro.core.faults import FaultSpec
        from repro.obs.timeline import TelemetrySpec
        ft = FaultSpec(outages=((0, 1.0, 3.0),), mtbf_seconds=30.0,
                       degraded=((1, 2.0),), broker_timeout_seconds=0.4,
                       quorum_k=3, hedge_after_seconds=0.3)
        return _tree_specs(jax.eval_shape(
            lambda k: simulator.simulate_fork_join(
                k, 120.0, 256, params, chunk_size=128,
                cluster=ClusterSpec(r=3, fault=ft),
                telemetry=TelemetrySpec(n_bins=8)),
            key))

    def p_sim_batch():
        lam = jax.ShapeDtypeStruct((3,), jnp.float32)
        batch_params = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(jnp.asarray(x, jnp.float32), (3,)),
            params)
        return _tree_specs(jax.eval_shape(
            lambda k, l: simulator.simulate_fork_join_batch(
                k, l, batch_params, 256, p=4, chunk_size=128),
            key, lam))

    grid = sweep.SweepGrid.build(
        lam=[40.0, 60.0], p=[4.0], cpu=[1.0, 2.0], disk=[1.0],
        r=[1.0, 2.0], base=params)

    # SweepResult/SimSweepResult are deliberately NOT pytrees (they carry
    # the grid); the probes return their array fields as a dict, which
    # also pins the field names themselves.
    def p_sweep_analytical():
        lam = jax.ShapeDtypeStruct((2,), jnp.float32)

        def go(l):
            res = sweep.sweep_analytical(dataclasses.replace(grid, lam=l))
            return {"response_lower": res.response_lower,
                    "response_upper": res.response_upper,
                    "utilization": res.utilization}

        return _tree_specs(jax.eval_shape(go, lam))

    def p_sweep_simulated():
        lam = jax.ShapeDtypeStruct((2,), jnp.float32)

        def go(k, l):
            res = sweep.sweep_simulated(
                dataclasses.replace(grid, lam=l), k, n_queries=256,
                chunk_size=128, tap_size=4)
            return {"stats": res.stats}

        return _tree_specs(jax.eval_shape(go, key, lam))

    def p_calibrate():
        n, p = 128, 4
        tr = measure.TraceRecord(
            arrival=jax.ShapeDtypeStruct((n,), jnp.float32),
            response=jax.ShapeDtypeStruct((n,), jnp.float32),
            broker_busy=jax.ShapeDtypeStruct((n,), jnp.float32),
            server_busy=jax.ShapeDtypeStruct((n, p), jnp.float32),
            server_hit=jax.ShapeDtypeStruct((n, p), jnp.float32),
            server_disk=jax.ShapeDtypeStruct((n, p), jnp.float32),
        )
        return _tree_specs(jax.eval_shape(
            lambda t: fit.calibrate(t, n_windows=4, n_iters=2), tr))

    def p_plan_capacity():
        plan = capacity.plan_capacity(params, 200.0, 0.5, simulate=False)
        return {f".{f}": _spec(getattr(plan, f))
                for f in sorted(vars(plan))}

    return {
        "simulate_fork_join": p_sim,
        "simulate_fork_join[r=3,cache]": p_sim_replicated,
        "simulate_fork_join[telemetry]": p_sim_telemetry,
        "simulate_fork_join[autoscale]": p_sim_autoscale,
        "simulate_fork_join[fault]": p_sim_fault,
        "simulate_fork_join_batch": p_sim_batch,
        "sweep_analytical": p_sweep_analytical,
        "sweep_simulated": p_sweep_simulated,
        "fit.calibrate": p_calibrate,
        "plan_capacity": p_plan_capacity,
    }


# --------------------------------------------------------------------------
# Snapshot / check / update
# --------------------------------------------------------------------------

def snapshot() -> dict[str, dict[str, str]]:
    """Run every probe; {probe name: {leaf path: spec}}."""
    return {name: probe() for name, probe in sorted(_probes().items())}


def load(path: pathlib.Path = CONTRACT_PATH) -> dict:
    return json.loads(path.read_text())


def save(path: pathlib.Path = CONTRACT_PATH) -> None:
    path.write_text(json.dumps({"probes": snapshot()}, indent=2,
                               sort_keys=True) + "\n")


def check(path: pathlib.Path = CONTRACT_PATH,
          live: dict | None = None) -> list[Finding]:
    """Diff the live snapshot against the committed contract.

    ``live`` lets callers reuse one snapshot across several comparisons
    (the probes re-trace every entry point, which costs seconds).
    """
    if not path.exists():
        return [Finding("RPR301", CONTRACT_REL, 1, 0,
                        "shape contract file missing; run "
                        "`python -m repro.staticcheck --update-contract`")]
    committed = load(path).get("probes", {})
    live = snapshot() if live is None else live
    findings: list[Finding] = []

    def diff(probe: str, want: dict, got: dict) -> None:
        for leaf in sorted(set(want) | set(got)):
            w, g = want.get(leaf), got.get(leaf)
            if w == g:
                continue
            if w is None:
                msg = f"new output leaf `{probe}{leaf}` = {g}"
            elif g is None:
                msg = f"output leaf `{probe}{leaf}` ({w}) disappeared"
            else:
                msg = (f"`{probe}{leaf}` changed: contract says {w}, "
                       f"eval_shape says {g}")
            findings.append(Finding(
                "RPR301", CONTRACT_REL, 1, 0,
                msg + " — if intentional, regenerate with "
                "--update-contract"))

    for probe in sorted(set(committed) | set(live)):
        if probe not in live:
            findings.append(Finding(
                "RPR301", CONTRACT_REL, 1, 0,
                f"probe `{probe}` is in the contract but no longer "
                "registered"))
        elif probe not in committed:
            findings.append(Finding(
                "RPR301", CONTRACT_REL, 1, 0,
                f"probe `{probe}` has no committed contract entry"))
        else:
            diff(probe, committed[probe], live[probe])
    return findings
