"""Convention rules: the ROADMAP "Standing conventions", as AST checks.

These subsume (and extend) the old 34-line grep guard that used to live in
``tests/test_conventions.py``:

  * RPR001 — version-gated JAX symbols only in ``repro/compat.py``;
  * RPR002 — no bespoke arrival-gap synthesis outside the sanctioned
    arrival modules (everything else goes through ``ArrivalProcess``);
  * RPR003 — no raw arrays fed to the calibration fitters (trace
    ingestion goes through ``TraceRecord``);
  * RPR004 — no hand-wired multi-``simulate_fork_join`` replica modeling
    (replication goes through the dispatcher layer's ``r=``);
  * RPR005 — measurement taps go through the observability layer
    (``telemetry=`` takes a ``TelemetrySpec``; ``Timeline`` objects are
    engine output, never hand-built);
  * RPR006 — topology goes through ``cluster=ClusterSpec(...)``: the
    loose ``r=``/``routing=``/``result_cache=``/``replica_impl=``
    keywords on engine entry points are deprecated shims.
"""

from __future__ import annotations

import ast
from typing import Iterator

from repro.staticcheck.analysis import Finding, Module, resolve_call
from repro.staticcheck.registry import rule

# --------------------------------------------------------------------------
# RPR001: compat-shim convention (PR 1)
# --------------------------------------------------------------------------

# fully qualified names that compat.py wraps; referencing them anywhere
# else makes the next JAX upgrade a multi-file hunt
_SHIMMED_QUALNAMES = {
    "jax.sharding.AxisType",
    "jax.shard_map",
    "jax.experimental.shard_map.shard_map",
}
# gated *attribute* names: flagged wherever they hang off any base —
# pltpu.TPUCompilerParams, tpu.TPUCompilerParams, x.CompilerParams ...
_SHIMMED_ATTRS = {"TPUCompilerParams", "CompilerParams"}


@rule("RPR001", "compat-shim-only-in-compat", "convention",
      "version-gated JAX symbols (TPUCompilerParams/CompilerParams, "
      "jax.sharding.AxisType, jax.shard_map) must go through "
      "repro/compat.py shims",
      scope=["src/**/*.py"], exclude=["src/repro/compat.py"])
def check_compat_shims(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Attribute) and node.attr in _SHIMMED_ATTRS:
            yield Finding(
                "RPR001", mod.rel, node.lineno, node.col_offset,
                f"direct use of gated Pallas symbol `.{node.attr}`; call "
                "repro.compat.tpu_compiler_params() instead")
            continue
        if isinstance(node, (ast.Attribute, ast.Name)):
            qn = mod.qualname(node)
            if qn in _SHIMMED_QUALNAMES:
                yield Finding(
                    "RPR001", mod.rel, node.lineno, node.col_offset,
                    f"direct use of version-gated `{qn}`; use the "
                    "repro.compat shim instead")
        elif isinstance(node, ast.ImportFrom):
            if node.module and node.module.startswith(
                    "jax.experimental.shard_map"):
                yield Finding(
                    "RPR001", mod.rel, node.lineno, node.col_offset,
                    "import of jax.experimental.shard_map; use "
                    "repro.compat.shard_map instead")


# --------------------------------------------------------------------------
# RPR002: ArrivalProcess convention (PR 2)
# --------------------------------------------------------------------------

# modules allowed to synthesize arrival gaps directly: the abstraction
# itself, the paper's Sec-4.2 workload statistics, the load generator and
# the calibration trace sampler
_ARRIVAL_SANCTIONED = (
    "src/repro/core/arrivals.py",
    "src/repro/core/workload.py",
    "src/repro/workloadgen/loadgen.py",
    "src/repro/calibrate/measure.py",
)


@rule("RPR002", "arrivals-via-arrival-process", "convention",
      "bespoke arrival-gap synthesis (cumsum over exponential draws) "
      "outside the sanctioned arrival modules; express load shapes as "
      "ArrivalProcess constructors",
      scope=["src/**/*.py"], exclude=list(_ARRIVAL_SANCTIONED))
def check_bespoke_arrivals(mod: Module) -> Iterator[Finding]:
    for fn in [n for n in ast.walk(mod.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Module))]:
        tainted: set[str] = set()

        def _has_exp_draw(node: ast.AST) -> bool:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    qn = resolve_call(mod, sub)
                    if qn in ("jax.random.exponential",
                              "numpy.random.exponential"):
                        return True
                if isinstance(sub, ast.Name) and sub.id in tainted:
                    return True
            return False

        body = fn.body if not isinstance(fn, ast.Module) else []
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, ast.Assign) and _has_exp_draw(sub.value):
                    for t in sub.targets:
                        for n in ast.walk(t):
                            if isinstance(n, ast.Name):
                                tainted.add(n.id)
                if isinstance(sub, ast.Call):
                    qn = resolve_call(mod, sub)
                    if qn in ("jax.numpy.cumsum", "numpy.cumsum",
                              "jnp.cumsum") and sub.args and _has_exp_draw(
                                  sub.args[0]):
                        yield Finding(
                            "RPR002", mod.rel, sub.lineno, sub.col_offset,
                            "bespoke arrival synthesis (cumsum of "
                            "exponential gaps); construct a "
                            "repro.core.arrivals.ArrivalProcess instead")


# --------------------------------------------------------------------------
# RPR003: TraceRecord convention (PR 3)
# --------------------------------------------------------------------------

_FITTER_NAMES = {
    "fit_moments", "calibrate", "refine", "window_stats", "window_plan",
    "calibrate_and_validate", "validate",
}
_FITTER_MODULES = ("repro.calibrate", "fit.", "measure.", "validate.")
_RAW_ARRAY_FACTORIES = {
    "jax.numpy.asarray", "jax.numpy.array", "jax.numpy.stack",
    "jax.numpy.concatenate", "numpy.asarray", "numpy.array", "numpy.stack",
    "numpy.concatenate",
}


def _is_fitter_call(mod: Module, node: ast.Call) -> bool:
    qn = resolve_call(mod, node)
    if qn is None:
        return False
    leaf = qn.rsplit(".", 1)[-1]
    if leaf not in _FITTER_NAMES:
        return False
    # only calls that resolve INTO the calibrate package (imported from
    # it, or attribute access on one of its modules)
    return ("calibrate" in qn or qn.startswith(_FITTER_MODULES)
            or qn == leaf and leaf in mod.aliases)


def _is_raw_array(mod: Module, node: ast.AST) -> bool:
    if isinstance(node, ast.Call):
        return resolve_call(mod, node) in _RAW_ARRAY_FACTORIES
    if isinstance(node, (ast.List, ast.Tuple)):
        return any(_is_raw_array(mod, e) for e in node.elts) or all(
            isinstance(e, ast.Constant) for e in node.elts) and bool(
                node.elts)
    return False


@rule("RPR003", "traces-are-trace-records", "convention",
      "raw arrays passed to calibration fitters; construct a "
      "repro.calibrate.measure.TraceRecord (or a list of them) instead",
      scope=["src/**/*.py", "tests/**/*.py", "examples/**/*.py"])
def check_raw_trace_arrays(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_fitter_call(mod, node)):
            continue
        first = node.args[0] if node.args else None
        for kw in node.keywords:
            if kw.arg in ("traces", "trace"):
                first = kw.value
        if first is not None and _is_raw_array(mod, first):
            yield Finding(
                "RPR003", mod.rel, node.lineno, node.col_offset,
                "raw array fed to a calibration fitter; trace ingestion "
                "goes through TraceRecord (ROADMAP calibration "
                "convention)")


# --------------------------------------------------------------------------
# RPR004: replica-topology convention (PR 4)
# --------------------------------------------------------------------------

_SIM_ENTRY_LEAVES = {"simulate_fork_join", "simulate_fork_join_batch"}
_REPLICA_NAMES = {"r", "replicas", "n_replicas", "n_rep", "num_replicas"}


@rule("RPR004", "replicas-via-dispatcher", "convention",
      "hand-wired replica modeling around simulate_fork_join; use the "
      "engine's dispatcher layer (cluster=ClusterSpec(r=..., "
      "routing=...)) instead",
      scope=["src/**/*.py"])
def check_handwired_replicas(mod: Module) -> Iterator[Finding]:
    loops = [n for n in ast.walk(mod.tree)
             if isinstance(n, (ast.For, ast.While))]

    def _enclosing_loop(call: ast.Call) -> bool:
        return any(any(sub is call for sub in ast.walk(lp)) for lp in loops)

    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = resolve_call(mod, node)
        if qn is None or qn.rsplit(".", 1)[-1] not in _SIM_ENTRY_LEAVES:
            continue
        kwargs = {kw.arg for kw in node.keywords}
        has_topology = bool({"r", "cluster"} & kwargs)
        # (a) a per-replica loop that never tells the engine about r
        if not has_topology and _enclosing_loop(node):
            yield Finding(
                "RPR004", mod.rel, node.lineno, node.col_offset,
                "simulate_fork_join called in a loop without a replica "
                "topology; modeling replicas by repeated simulator calls "
                "assumes perfect splitting — pass "
                "cluster=ClusterSpec(r=..., routing=...) instead")
            continue
        # (b) lam divided by a replica count by hand (perfect-split
        # assumption smuggled into the arrival rate)
        for arg in list(node.args[:2]) + [
                kw.value for kw in node.keywords if kw.arg == "lam"]:
            if (isinstance(arg, ast.BinOp) and isinstance(arg.op, ast.Div)
                    and isinstance(arg.right, ast.Name)
                    and arg.right.id in _REPLICA_NAMES
                    and not has_topology):
                yield Finding(
                    "RPR004", mod.rel, node.lineno, node.col_offset,
                    f"arrival rate divided by `{arg.right.id}` by hand; "
                    "pass the TOTAL rate with cluster=ClusterSpec(r=...) "
                    "so routing imbalance is modeled (ROADMAP "
                    "replica-topology convention)")


# --------------------------------------------------------------------------
# RPR005: telemetry-tap convention (PR 8)
# --------------------------------------------------------------------------

_TELEMETRY_ENTRY_LEAVES = {"simulate_fork_join", "simulate_fork_join_batch",
                           "sweep_simulated"}


@rule("RPR005", "telemetry-via-spec", "convention",
      "measurement taps go through the observability layer: telemetry= "
      "takes a repro.obs.TelemetrySpec (or None), and Timeline objects "
      "are engine output, never hand-built",
      scope=["src/**/*.py", "examples/**/*.py"],
      exclude=["src/repro/obs/*.py", "src/repro/core/simulator.py"])
def check_telemetry_spec(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = resolve_call(mod, node)
        leaf = qn.rsplit(".", 1)[-1] if qn else None
        if leaf == "Timeline":
            yield Finding(
                "RPR005", mod.rel, node.lineno, node.col_offset,
                "Timeline constructed by hand; timelines are engine "
                "output — pass telemetry=TelemetrySpec(...) to the "
                "simulator, or use timeline_from_trace for measured "
                "traces")
            continue
        if leaf not in _TELEMETRY_ENTRY_LEAVES:
            continue
        for kw in node.keywords:
            if kw.arg != "telemetry" or kw.value is None:
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and v.value is None:
                continue
            if isinstance(v, (ast.Constant, ast.Tuple, ast.List,
                              ast.Dict)):
                yield Finding(
                    "RPR005", mod.rel, node.lineno, node.col_offset,
                    "raw literal passed as telemetry=; construct a "
                    "repro.obs.TelemetrySpec (bin count, horizon and "
                    "SLO live in ONE validated place)")


# --------------------------------------------------------------------------
# RPR006: ClusterSpec convention (PR 9)
# --------------------------------------------------------------------------

# entry point leaf -> the loose keywords its resolve_cluster shim accepts
_CLUSTER_DEPRECATED = {
    "simulate_fork_join": {"r", "routing", "result_cache", "replica_impl"},
    "simulate_fork_join_batch": {"r", "routing", "result_cache",
                                 "replica_impl"},
    "sweep_simulated": {"routing", "replica_impl"},
    "plan_capacity": {"routing", "result_cache"},
    "validate": {"replicas", "routing", "result_cache"},
}


@rule("RPR006", "topology-via-cluster-spec", "convention",
      "deprecated loose topology keywords (r=/routing=/result_cache=/"
      "replica_impl=/replicas=) on engine entry points; consolidate "
      "them onto cluster=ClusterSpec(...)",
      # fnmatch `*` crosses `/`, so one `*.py` per root covers nesting
      # (a `tests/**/*.py` scope would skip files directly under tests/)
      scope=["src/*.py", "tests/*.py", "examples/*.py",
             "benchmarks/*.py"],
      exclude=["src/repro/core/cluster.py"])
def check_cluster_spec(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = resolve_call(mod, node)
        leaf = qn.rsplit(".", 1)[-1] if qn else None
        deprecated = _CLUSTER_DEPRECATED.get(leaf)
        if not deprecated:
            continue
        bad = sorted(deprecated & {kw.arg for kw in node.keywords})
        if bad:
            yield Finding(
                "RPR006", mod.rel, node.lineno, node.col_offset,
                f"deprecated loose keyword(s) {', '.join(bad)} on "
                f"{leaf}(); move them onto cluster=ClusterSpec(...) "
                "(ROADMAP ClusterSpec convention)")


# --------------------------------------------------------------------------
# RPR007: FaultSpec convention (PR 10)
# --------------------------------------------------------------------------

# the fault recurrence primitives only the engine may drive directly;
# everyone else describes faults declaratively on the ClusterSpec
_FAULT_PRIMITIVES = {"fault_scan", "fault_init"}


@rule("RPR007", "faults-via-fault-spec", "convention",
      "fault injection goes through cluster=ClusterSpec(fault=FaultSpec("
      "...)): raw literals on fault= and hand-threaded fault_scan/"
      "fault_init outage-mask recurrences bypass the validated spec",
      scope=["src/*.py", "tests/*.py", "examples/*.py",
             "benchmarks/*.py"],
      exclude=["src/repro/core/faults.py",
               "src/repro/core/simulator.py",
               "tests/test_faults.py"])
def check_fault_spec(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        qn = resolve_call(mod, node)
        leaf = qn.rsplit(".", 1)[-1] if qn else None
        if leaf in _FAULT_PRIMITIVES:
            yield Finding(
                "RPR007", mod.rel, node.lineno, node.col_offset,
                f"direct {leaf}() call hand-threads the outage-mask "
                "recurrence; describe the faults as ClusterSpec(fault="
                "FaultSpec(...)) and let the engine drive it")
            continue
        if leaf != "ClusterSpec":
            continue
        for kw in node.keywords:
            if kw.arg != "fault":
                continue
            v = kw.value
            literal = isinstance(v, (ast.Dict, ast.List, ast.Tuple,
                                     ast.Set))
            literal = literal or (isinstance(v, ast.Constant)
                                  and v.value is not None)
            if literal:
                yield Finding(
                    "RPR007", mod.rel, node.lineno, node.col_offset,
                    "raw literal on ClusterSpec(fault=...); build a "
                    "FaultSpec(...) so outage windows and quorum knobs "
                    "are validated in one place")
