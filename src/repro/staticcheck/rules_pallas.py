"""Pallas kernel structure rules.

The five ``kernels/*/kernel.py`` files share one shape: compute grid from
shapes with ``//``, build BlockSpecs with index-map lambdas, and hand
everything to ``pl.pallas_call``.  Three things go wrong in practice and
none of them throw where the mistake is:

  * a grid dimension silently truncates when the shape is not a block
    multiple (RPR203);
  * an index-map lambda with the wrong arity fails deep inside Pallas
    with an error that does not mention the BlockSpec (RPR202) — note the
    arity is ``len(grid) + num_scalar_prefetch`` under
    ``PrefetchScalarGridSpec``, and bound constants like
    ``lambda h, i, j, n_rep=n_rep: ...`` do not count;
  * compiler params constructed from ``pltpu`` directly break on the next
    JAX rename (RPR201 — the structured version of the old grep guard);
  * a kernel without ``interpret=`` plumbing cannot be validated on CPU
    (RPR204).
"""

from __future__ import annotations

import ast
from typing import Iterator, Optional

from repro.staticcheck.analysis import Finding, Module, iter_functions, \
    resolve_call
from repro.staticcheck.registry import rule

_KERNEL_SCOPE = ["src/repro/kernels/*/kernel.py",
                 "src/repro/kernels/**/kernel.py"]


def _is_pallas_call(mod: Module, node: ast.Call) -> bool:
    qn = resolve_call(mod, node)
    return qn is not None and qn.rsplit(".", 1)[-1] == "pallas_call"


def _kw(node: ast.Call, name: str) -> Optional[ast.expr]:
    for kw in node.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _resolve_tuple(fn: ast.FunctionDef, node: Optional[ast.expr]
                   ) -> Optional[ast.Tuple]:
    """Follow one level of `name = (…)` assignment to a tuple literal."""
    if isinstance(node, ast.Tuple):
        return node
    if isinstance(node, ast.Name):
        for stmt in ast.walk(fn):
            if (isinstance(stmt, ast.Assign)
                    and isinstance(stmt.value, ast.Tuple)
                    and any(isinstance(t, ast.Name) and t.id == node.id
                            for t in stmt.targets)):
                return stmt.value
    return None


def _grid_info(mod: Module, fn: ast.FunctionDef, call: ast.Call
               ) -> tuple[Optional[ast.Tuple], int]:
    """(grid tuple literal, num_scalar_prefetch) for one pallas_call."""
    grid = _kw(call, "grid")
    prefetch = 0
    spec = _kw(call, "grid_spec")
    if grid is None and isinstance(spec, ast.Call):
        grid = _kw(spec, "grid")
        n = _kw(spec, "num_scalar_prefetch")
        if isinstance(n, ast.Constant) and isinstance(n.value, int):
            prefetch = n.value
    return _resolve_tuple(fn, grid), prefetch


def _index_map_lambdas(mod: Module, scope: ast.AST
                       ) -> Iterator[ast.Lambda]:
    """Index-map lambdas of every BlockSpec under ``scope``."""
    for sub in ast.walk(scope):
        if not isinstance(sub, ast.Call):
            continue
        qn = resolve_call(mod, sub)
        if qn is None or qn.rsplit(".", 1)[-1] != "BlockSpec":
            continue
        lam = _kw(sub, "index_map")
        if lam is None and len(sub.args) >= 2:
            lam = sub.args[1]
        if isinstance(lam, ast.Lambda):
            yield lam


@rule("RPR201", "compiler-params-via-compat", "pallas",
      "pallas_call compiler_params must come from "
      "repro.compat.tpu_compiler_params(), not a direct pltpu "
      "constructor (the constructor name is version-gated)",
      scope=_KERNEL_SCOPE)
def check_compiler_params_source(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(mod, node)):
            continue
        cp = _kw(node, "compiler_params")
        if cp is None:
            continue
        if isinstance(cp, ast.Call):
            qn = resolve_call(mod, cp) or ""
            if qn.rsplit(".", 1)[-1] == "tpu_compiler_params":
                continue
        yield Finding(
            "RPR201", mod.rel, cp.lineno, cp.col_offset,
            "compiler_params not built by "
            "repro.compat.tpu_compiler_params(); direct construction "
            "breaks on the next JAX rename")


@rule("RPR202", "index-map-arity", "pallas",
      "BlockSpec index-map arity must equal len(grid) + "
      "num_scalar_prefetch (bound defaults excluded)",
      scope=_KERNEL_SCOPE)
def check_index_map_arity(mod: Module) -> Iterator[Finding]:
    for fn in iter_functions(mod.tree):
        calls = [n for n in ast.walk(fn)
                 if isinstance(n, ast.Call) and _is_pallas_call(mod, n)]
        for node in calls:
            grid, prefetch = _grid_info(mod, fn, node)
            if grid is None:
                continue            # arity not statically determinable
            want = len(grid.elts) + prefetch
            # a lone pallas_call owns every BlockSpec in the function,
            # including `spec = pl.BlockSpec(...)` bound to a name first
            scope = fn if len(calls) == 1 else node
            for lam in _index_map_lambdas(mod, scope):
                n_args = (len(lam.args.posonlyargs) + len(lam.args.args)
                          - len(lam.args.defaults))
                if n_args != want:
                    yield Finding(
                        "RPR202", mod.rel, lam.lineno, lam.col_offset,
                        f"index-map lambda takes {n_args} grid args but "
                        f"grid has {len(grid.elts)} dims + {prefetch} "
                        "scalar-prefetch refs")


@rule("RPR203", "grid-divisibility-guard", "pallas",
      "a grid dimension computed with // silently truncates the last "
      "partial block; assert divisibility (or use pl.cdiv with masking)",
      scope=_KERNEL_SCOPE)
def check_grid_divisibility(mod: Module) -> Iterator[Finding]:
    for fn in iter_functions(mod.tree):
        has_guard = any(
            isinstance(stmt, ast.Assert)
            and any(isinstance(sub, ast.BinOp)
                    and isinstance(sub.op, ast.Mod)
                    for sub in ast.walk(stmt.test))
            for stmt in ast.walk(fn) if isinstance(stmt, ast.Assert))
        uses_cdiv = any(
            isinstance(sub, ast.Call)
            and (resolve_call(mod, sub) or "").rsplit(".", 1)[-1] == "cdiv"
            for sub in ast.walk(fn))
        if has_guard or uses_cdiv:
            continue
        for node in ast.walk(fn):
            if not (isinstance(node, ast.Call)
                    and _is_pallas_call(mod, node)):
                continue
            grid, _ = _grid_info(mod, fn, node)
            if grid is None:
                continue
            for elt in grid.elts:
                for sub in ast.walk(elt):
                    if isinstance(sub, ast.BinOp) and isinstance(
                            sub.op, ast.FloorDiv):
                        yield Finding(
                            "RPR203", mod.rel, sub.lineno, sub.col_offset,
                            "grid dim uses // with no divisibility "
                            "assert (and no pl.cdiv) in "
                            f"`{fn.name}`; a partial block would be "
                            "silently dropped")


@rule("RPR204", "interpret-plumbing", "pallas",
      "pallas_call without interpret= plumbing cannot run the CPU "
      "validation path (ROADMAP: TPU target, interpret-mode CI)",
      scope=_KERNEL_SCOPE)
def check_interpret_plumbing(mod: Module) -> Iterator[Finding]:
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and _is_pallas_call(mod, node)):
            continue
        if _kw(node, "interpret") is None:
            yield Finding(
                "RPR204", mod.rel, node.lineno, node.col_offset,
                "pallas_call without interpret=; thread an interpret "
                "flag through so CPU CI can validate the kernel")
