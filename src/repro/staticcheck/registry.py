"""The one rule registry: stable IDs, metadata, scoping.

Every staticcheck rule registers here with a stable ``RPR####`` ID.  The
CLI (`repro.staticcheck.cli`), the convention tests
(`tests/test_conventions.py`, `tests/test_staticcheck.py`), the CI job and
the README rule table all read THIS table — rule IDs exist in exactly one
place, so adding a rule is one ``@rule(...)`` decorator and suppressions
(``# staticcheck: disable=RPR0xx``) can never reference a phantom ID.

ID bands (families):

  * ``RPR000``           framework (suppression hygiene)
  * ``RPR001``-``RPR099`` repo conventions (ROADMAP "Standing conventions")
  * ``RPR101``-``RPR199`` JAX tracer safety
  * ``RPR201``-``RPR299`` Pallas kernel structure
  * ``RPR301``-``RPR399`` abstract-run (eval_shape) contract
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Callable, Iterable, Iterator, Optional, Sequence

__all__ = ["Rule", "RULES", "rule", "rules_for_path", "FAMILIES"]

FAMILIES = ("framework", "convention", "tracer", "pallas", "contract")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One registered rule.

    ``scope`` is a sequence of fnmatch glob patterns over the repo-relative
    posix path (e.g. ``src/repro/core/*.py``); a file is checked by the
    rule iff it matches at least one include pattern and no pattern in
    ``exclude``.  ``check`` takes a `repro.staticcheck.analysis.Module`
    and yields `Finding`s; contract rules have ``check=None`` (they run in
    the eval_shape harness, not per-file).
    """

    id: str
    name: str
    family: str
    description: str
    scope: tuple[str, ...]
    exclude: tuple[str, ...] = ()
    check: Optional[Callable[..., Iterator]] = None

    def applies_to(self, rel_posix: str) -> bool:
        if not any(fnmatch.fnmatch(rel_posix, pat) for pat in self.scope):
            return False
        return not any(fnmatch.fnmatch(rel_posix, pat)
                       for pat in self.exclude)


RULES: dict[str, Rule] = {}


def rule(id: str, name: str, family: str, description: str,
         scope: Sequence[str], exclude: Sequence[str] = ()):
    """Register a checker function under a stable rule ID."""
    if family not in FAMILIES:
        raise ValueError(f"unknown rule family {family!r}")
    if id in RULES:
        raise ValueError(f"duplicate rule id {id}")

    def deco(fn):
        RULES[id] = Rule(id=id, name=name, family=family,
                         description=description, scope=tuple(scope),
                         exclude=tuple(exclude), check=fn)
        return fn

    return deco


def register_datarule(id: str, name: str, family: str, description: str,
                      scope: Sequence[str] = ()) -> Rule:
    """Register a rule that has no per-file checker (e.g. the contract)."""
    if id in RULES:
        raise ValueError(f"duplicate rule id {id}")
    r = Rule(id=id, name=name, family=family, description=description,
             scope=tuple(scope), check=None)
    RULES[id] = r
    return r


def rules_for_path(rel_posix: str) -> list[Rule]:
    return [r for r in RULES.values()
            if r.check is not None and r.applies_to(rel_posix)]


# the framework's own rule: emitted by the driver (repro.staticcheck.cli)
# for suppression comments with no rule ID, unknown rule IDs, and
# unparseable files
register_datarule(
    "RPR000", "suppression-hygiene", "framework",
    "suppressions must name a registered rule ID; files must parse")
