"""Bench-regression gate: diff fresh BENCH_*.json records against the
committed baselines.

Usage:
    BENCH_OUTPUT_DIR=/tmp/bench BENCH_QUICK=1 \
        python -m benchmarks.run --only streaming,calibrate,replicated
    python benchmarks/check_regression.py \
        --baseline . --fresh /tmp/bench [--max-throughput-drop 0.30]

Policy (the CI contract):
  * throughput metrics may not drop more than ``--max-throughput-drop``
    (default 30%, absorbing runner-to-runner noise);
  * the analytic peak-memory proxies (``peak_mem_streaming_bytes`` —
    S x r x p x chunk floats) may not grow AT ALL: they are
    deterministic functions of the engine's carried state, so any growth
    is a real structural regression;
  * measured compiled footprints (``peak_mem_measured_bytes``) get a 10%
    allowance for XLA-version layout noise;
  * ``telemetry_overhead_frac`` is gated by an ABSOLUTE ceiling (kind
    "ceiling": fresh value <= allowance, no baseline comparison) — the
    default-bins telemetry slowdown must stay under 10% regardless of
    what a previous runner measured;
  * every fresh record must carry the ``profile`` block (compile_s,
    flops, bytes_accessed, peak_bytes) that `benchmarks._util
    .profile_block` embeds — a bench silently dropping its profiling
    hook is a regression of the observability contract itself.

Exits 1 on any violation; always prints the comparison table.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

# metric name -> (kind, allowance); kind "higher" = bigger is better,
# "ceiling" = fresh value must stay under the ABSOLUTE allowance
GATES = {
    "queries_per_s": ("higher", None),
    "queries_per_s_jsq": ("higher", None),
    "queries_fitted_per_s": ("higher", None),
    "scenarios_per_s": ("higher", None),
    "peak_mem_streaming_bytes": ("exact-max", 0.0),
    "peak_mem_measured_bytes": ("max", 0.10),
    "telemetry_overhead_frac": ("ceiling", 0.10),
}

BASELINE_FILES = ("BENCH_streaming.json", "BENCH_calibrate.json",
                  "BENCH_replicated.json", "BENCH_sharded.json",
                  "BENCH_obs.json", "BENCH_faults.json")

# keys every record's profile block must carry (see _util.profile_block)
_PROFILE_KEYS = ("compile_s", "flops", "bytes_accessed", "peak_bytes")


def compare(baseline: dict, fresh: dict, name: str,
            max_drop: float) -> list[str]:
    failures = []
    for metric, (kind, allowance) in GATES.items():
        if metric not in baseline or metric not in fresh:
            continue
        old, new = float(baseline[metric]), float(fresh[metric])
        if kind == "higher":
            rel = (new - old) / old if old else 0.0
            verdict = rel >= -max_drop
            note = f"{rel:+.1%} (floor {-max_drop:.0%})"
        elif kind == "ceiling":
            verdict = new <= (allowance or 0.0)
            note = f"absolute ceiling {allowance or 0.0:.0%}"
        else:
            allowed = old * (1.0 + (allowance or 0.0))
            verdict = new <= allowed
            note = f"{new - old:+,.0f} B (ceiling +{allowance or 0.0:.0%})"
        status = "ok " if verdict else "FAIL"
        print(f"  {status} {name}:{metric:28s} {old:>16,.1f} -> "
              f"{new:>16,.1f}  {note}")
        if not verdict:
            failures.append(f"{name}:{metric}")
    return failures


def check_profile(fresh: dict, name: str) -> list[str]:
    """Require the uniform profile block on every fresh record."""
    prof = fresh.get("profile")
    missing = ([k for k in _PROFILE_KEYS if k not in prof]
               if isinstance(prof, dict) else list(_PROFILE_KEYS))
    if missing:
        print(f"  FAIL {name}:profile block missing keys {missing}")
        return [f"{name}:profile"]
    print(f"  ok   {name}:profile{'':23s} compile "
          f"{prof['compile_s']:.2f}s, {prof['flops'] / 1e6:,.1f} Mflops, "
          f"peak {prof['peak_bytes'] / 2**20:,.1f} MiB")
    return []


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=".",
                    help="dir with committed BENCH_*.json baselines")
    ap.add_argument("--fresh", required=True,
                    help="dir with freshly measured BENCH_*.json")
    ap.add_argument("--max-throughput-drop", type=float, default=0.30)
    args = ap.parse_args()

    base_dir = pathlib.Path(args.baseline)
    fresh_dir = pathlib.Path(args.fresh)
    failures: list[str] = []
    seen = 0
    for fname in BASELINE_FILES:
        b, f = base_dir / fname, fresh_dir / fname
        if not b.exists():
            print(f"  -- {fname}: no committed baseline yet, skipping")
            continue
        if not f.exists():
            print(f"  FAIL {fname}: baseline exists but the bench "
                  "produced no fresh record")
            failures.append(f"{fname}:missing")
            continue
        seen += 1
        short = fname.removeprefix("BENCH_").removesuffix(".json")
        fresh_rec = json.loads(f.read_text())
        failures += compare(json.loads(b.read_text()), fresh_rec,
                            short, args.max_throughput_drop)
        failures += check_profile(fresh_rec, short)
    if seen == 0:
        print("no benchmark records compared — refusing to pass vacuously")
        sys.exit(1)
    if failures:
        print(f"\nREGRESSION: {', '.join(failures)}")
        sys.exit(1)
    print(f"\nall gates green across {seen} benchmark record(s)")


if __name__ == "__main__":
    main()
