"""Calibration throughput benchmark, persisted to BENCH_calibrate.json.

Tracks the fitting pipeline's cost on a realistic multi-rate trace set:
moment matching alone (the closed-form pass every batch pays) and the
full calibrate() pipeline (moments + window stats + the candidate-grid
seeded Gauss-Newton refinement).  The headline figure is trace queries
fitted per second — calibration must stay cheap enough to re-run on
every measurement window in production.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax

from benchmarks import _util


def bench_calibrate(rows):
    from repro.calibrate import calibrate, fit_moments, simulate_trace
    from repro.core import capacity

    true = dataclasses.replace(capacity.TABLE5_PARAMS, p=4)
    rates = [10.0, 22.0, 14.0, 18.0]
    # no BENCH_QUICK scaling here: fitting cost is dominated by the
    # per-window/Gauss-Newton fixed work, so a shorter trace would
    # *deflate* queries_fitted_per_s and trip the CI regression gate
    # against full-size committed baselines.  The full bench is seconds.
    traces = [simulate_trace(jax.random.PRNGKey(i), lam, 25_000, true)
              for i, lam in enumerate(rates)]
    n_total = sum(tr.n_queries for tr in traces)

    fit_moments(traces)                       # compile/warm
    t0 = time.perf_counter()
    moments = fit_moments(traces)
    jax.block_until_ready(moments.s_disk)
    dt_moments = time.perf_counter() - t0

    cal = calibrate(traces, n_windows=16)     # compile/warm
    t0 = time.perf_counter()
    cal = calibrate(traces, n_windows=16)
    jax.block_until_ready(cal.alpha)
    dt_full = time.perf_counter() - t0

    profile = _util.profile_block(
        jax.jit(lambda trs: calibrate(trs, n_windows=16)), traces,
        name=f"calibrate[{len(traces)}x{traces[0].n_queries}]", n_runs=1)

    record = {
        "bench": "calibrate",
        "n_traces": len(traces),
        "n_queries_total": n_total,
        "p": int(true.p),
        "moment_fit_seconds": dt_moments,
        "full_calibrate_seconds": dt_full,
        "queries_fitted_per_s": n_total / dt_full,
        "traces_per_s": len(traces) / dt_full,
        "alpha": float(cal.alpha),
        "s_disk_rel_err": abs(float(cal.params.s_disk)
                              - float(true.s_disk)) / float(true.s_disk),
        "profile": profile,
    }
    out = _util.bench_output_path("BENCH_calibrate.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("calibrate_fit", dt_full * 1e6,
                 f"{n_total} trace queries fitted in {dt_full * 1e3:.0f}ms"
                 f" ({n_total / dt_full / 1e6:.2f}M queries/s; moments "
                 f"alone {dt_moments * 1e3:.0f}ms); -> {out}"))
