"""Scenario-sharded sweep benchmark: the million-scenario planning path.

Measures `sweep_analytical`/`sweep_simulated` with a 1-D ("scenario",)
mesh from `repro.launch.mesh.make_sweep_mesh` over 8 XLA devices:

* analytical — a 1,000,000-scenario (L,P,C,D,H,R) grid evaluated as one
  shard_map program (the SNIPPETS.md 38M-qps global planning exercise
  needs surfaces of this size);
* simulated — a replicated fused-engine grid streamed with each device
  owning a scenario shard.

The device count must be fixed BEFORE jax initializes, so the harness
entry (`bench_sharded_sweep`) re-runs this module as a CHILD process
with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and reads
the record it writes.  On a single-core CI host the 8 virtual devices
timeshare one core — the numbers pin the *sharded program's* throughput
trajectory (vs its own committed baseline on the same runner class),
they do not claim an 8x speedup.  Results go to ``BENCH_sharded.json``
for the bench-regression gate (``queries_per_s`` and ``scenarios_per_s``
are both gated "higher").
"""

from __future__ import annotations

import json
import os
import statistics
import subprocess
import sys
import time

from benchmarks import _util

_DEVICES = 8
_TIMING_PASSES = 3


def bench_sharded_sweep(rows):
    out = _util.bench_output_path("BENCH_sharded.json")
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                        f"{_DEVICES}").strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(root, "src"), root,
                    env.get("PYTHONPATH", "")) if p)
    r = subprocess.run([sys.executable, "-m", "benchmarks.sharded_bench"],
                       env=env, capture_output=True, text=True,
                       timeout=1800)
    if r.returncode != 0:
        raise RuntimeError(
            f"sharded bench child failed\nSTDOUT:\n{r.stdout}\n"
            f"STDERR:\n{r.stderr}")
    record = json.loads(out.read_text())
    rows.append((
        "sharded_sweep", record["wall_seconds"] * 1e6,
        f"{record['n_scenarios_analytical']} analytic scenarios on "
        f"{_DEVICES} devices, {record['scenarios_per_s'] / 1e6:.2f}M "
        f"scen/s; simulated {record['n_scenarios_simulated']} scen x "
        f"{record['n_queries']} q sharded: "
        f"{record['queries_per_s'] / 1e6:.2f}M queries/s; -> {out}"))


def _main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.core import capacity, sweep
    from repro.launch.mesh import make_sweep_mesh

    assert len(jax.devices()) == _DEVICES, jax.devices()
    mesh = make_sweep_mesh()

    # --- analytical: 100 x 4 x 5 x 5 x 20 x 5 = 1,000,000 scenarios ----
    big = sweep.SweepGrid.build(
        lam=jnp.linspace(10.0, 120.0, 100),
        p=jnp.asarray([50.0, 100.0, 200.0, 400.0]),
        cpu=jnp.linspace(1.0, 3.0, 5),
        disk=jnp.linspace(1.0, 3.0, 5),
        hit=jnp.linspace(0.05, 0.95, 20),
        r=jnp.asarray([1.0, 2.0, 4.0, 8.0, 16.0]),
        base=capacity.TABLE5_PARAMS,
        result_cache=(0.2, 2e-3),
    )
    n_ana = big.n_scenarios

    def run_ana():
        res = sweep.sweep_analytical(big, mesh=mesh)
        jax.block_until_ready(res.response_upper)
        return res

    run_ana()                                   # compile + warm
    times = []
    for _ in range(_TIMING_PASSES):
        t0 = time.perf_counter()
        run_ana()
        times.append(time.perf_counter() - t0)
    dt_ana = statistics.median(times)

    # --- simulated: 32-scenario replicated slab, sharded 4 per device --
    sim_grid = sweep.SweepGrid.build(
        lam=jnp.linspace(30.0, 90.0, 16),
        p=jnp.asarray([8.0]),
        hit=jnp.asarray([0.17, 0.5]),
        r=jnp.asarray([2.0]),
        base=capacity.TABLE5_PARAMS,
        broker_from_p=False,
        result_cache=(0.2, 2e-3),
    )
    n_sim = sim_grid.n_scenarios
    # quick stays large: the sharded path pays ~5s of per-call trace/
    # dispatch overhead, and a small horizon would sink queries_per_s
    # far below the full-size baseline the regression gate compares to
    n_q = _util.scale_queries(200_000, 150_000)

    def run_sim():
        res = sweep.sweep_simulated(
            sim_grid, jax.random.PRNGKey(0), n_queries=n_q,
            chunk_size=4096, mesh=mesh)
        jax.block_until_ready(res.mean)
        return res

    run_sim()                                   # compile + warm
    times = []
    for _ in range(_TIMING_PASSES):
        t0 = time.perf_counter()
        run_sim()
        times.append(time.perf_counter() - t0)
    dt_sim = statistics.median(times)

    # SweepResult carries the grid (not a pytree); profile the surfaces
    def _surfaces():
        res = sweep.sweep_analytical(big, mesh=mesh)
        return {"response_lower": res.response_lower,
                "response_upper": res.response_upper,
                "utilization": res.utilization}

    profile = _util.profile_block(
        jax.jit(_surfaces),
        name=f"sharded_analytical[{n_ana}x{_DEVICES}dev]", n_runs=0)

    record = {
        "bench": "sharded_sweep",
        "n_devices": _DEVICES,
        "n_scenarios_analytical": n_ana,
        "wall_seconds_analytical": dt_ana,
        "scenarios_per_s": n_ana / dt_ana,
        "n_scenarios_simulated": n_sim,
        "n_queries": n_q,
        "chunk_size": 4096,
        "r": 2,
        "routing": "round_robin",
        "wall_seconds": dt_sim,
        "queries_per_s": n_sim * n_q / dt_sim,
        "profile": profile,
    }
    out = _util.bench_output_path("BENCH_sharded.json")
    out.write_text(json.dumps(record, indent=2) + "\n")


if __name__ == "__main__":
    _main()
