"""Kernel micro-benchmarks (interpret-mode correctness + XLA-path timing).

On CPU the Pallas kernels run interpreted (correctness only, not speed),
so per-kernel rows time the pure-jnp reference at kernel-realistic shapes
and report the kernel's VMEM working set vs the ref's HBM intermediate —
the structural quantity the TPU kernel optimizes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _time(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def bench_maxplus_scan(rows):
    from repro.kernels.maxplus_scan import ops, ref
    shape = (64, 65_536)
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    a = jnp.cumsum(jax.random.exponential(k1, shape), -1)
    b = jax.random.exponential(k2, shape)
    us_ref = _time(lambda: ref.maxplus_scan_ref(a + b, b))
    rows.append(("kernel_maxplus_ref_xla", us_ref,
                 f"shape={shape} (kernel: interpret-validated; "
                 f"VMEM tile 8x512)"))


def bench_flash_attention(rows):
    from repro.kernels.flash_attention import ref
    b, s, h, kv, d = 1, 2048, 8, 2, 128
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b * h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b * kv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b * kv, s, d), jnp.float32)
    us = _time(lambda: ref.flash_attention_ref(q, k, v, n_rep=h // kv))
    hbm_scores = b * h * s * s * 4 / 2**20
    vmem = (128 * d + 2 * 256 * d + 128 * d) * 4 / 2**10
    rows.append(("kernel_flash_ref_xla", us,
                 f"ref materializes {hbm_scores:.0f}MiB scores; kernel "
                 f"tiles {vmem:.0f}KiB VMEM"))


def bench_decode_attention(rows):
    from repro.kernels.decode_attention import ref
    b, s, kv, g, d = 8, 32_768, 8, 4, 128
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (b * kv, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b * kv, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b * kv, s, d), jnp.float32)
    us = _time(lambda: ref.decode_attention_ref(q, k, v,
                                                jnp.asarray(s - 1)))
    bytes_kv = 2 * b * kv * s * d * 4 / 2**30
    rows.append(("kernel_decode_ref_xla", us,
                 f"streams {bytes_kv:.2f}GiB KV once (roofline-optimal "
                 f"schedule fused in kernel)"))


def bench_embedding_bag(rows):
    from repro.kernels.embedding_bag import ref
    r, d, bf, m = 1_000_000, 64, 8192, 4
    table = jax.random.normal(jax.random.PRNGKey(3), (r, d), jnp.float32)
    rng = np.random.default_rng(0)
    ids = jnp.asarray(rng.integers(0, r, (bf, m)).astype(np.int32))
    counts = jnp.asarray(rng.integers(1, m + 1, bf).astype(np.int32))
    us = _time(lambda: ref.embedding_bag_ref(table, ids, counts))
    rows.append(("kernel_embedding_bag_ref_xla", us,
                 f"{bf}x{m} bags over {r} rows; kernel gathers rows by "
                 f"scalar-prefetch DMA"))


def bench_cin_fuse(rows):
    from repro.kernels.cin_fuse import ref
    b, hk, m, d, o = 4096, 200, 39, 10, 200
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    xk = jax.random.normal(ks[0], (b, hk, d), jnp.float32)
    x0 = jax.random.normal(ks[1], (b, m, d), jnp.float32)
    w = jax.random.normal(ks[2], (hk * m, o), jnp.float32) * 0.1
    us = _time(lambda: ref.cin_layer_ref(xk, x0, w), n=1)
    inter = b * hk * m * d * 4 / 2**30
    rows.append(("kernel_cin_ref_xla", us,
                 f"ref materializes {inter:.1f}GiB outer product; "
                 f"kernel keeps it in VMEM"))


def bench_simulator_scale(rows):
    """DES throughput: queries x servers per second of wall time."""
    import dataclasses
    from repro.core import capacity, simulator
    pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=256)
    t0 = time.perf_counter()
    res = simulator.simulate_fork_join(
        jax.random.PRNGKey(5), 20.0, 50_000, pr, mode="exponential")
    jax.block_until_ready(res.mean_response)
    dt = time.perf_counter() - t0
    rows.append(("simulator_256x50k", dt * 1e6,
                 f"{256 * 50_000 / dt / 1e6:.1f}M server-events/s"))
