"""Benchmark harness: one function per paper table/figure + kernel/DES
micro-benches.  Prints ``name,us_per_call,derived`` CSV.

Usage: PYTHONPATH=src python -m benchmarks.run [--only SUBSTR[,SUBSTR...]]

``--only`` takes a comma-separated list of substrings; a benchmark runs
if ANY of them occurs in its function name (so CI's regression job can
ask for ``--only streaming,calibrate,replicated`` in one pass).
Environment knobs for CI live in `benchmarks._util`: ``BENCH_QUICK=1``
shrinks horizons, ``BENCH_OUTPUT_DIR`` redirects the BENCH_*.json
records.
"""

from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (calibrate_bench, faults_bench, kernels_bench,
                        obs_bench, paper_tables, partitioning_bench,
                        replicated_bench, sharded_bench, streaming_bench,
                        sweep_bench)

BENCHES = [
    paper_tables.bench_table2_query_lengths,
    paper_tables.bench_fig2_zipf_popularity,
    paper_tables.bench_table3_folding,
    paper_tables.bench_fig6_interarrival_fits,
    paper_tables.bench_fig7_service_time_fits,
    paper_tables.bench_fig9_server_residence,
    paper_tables.bench_fig10_response_vs_lambda,
    paper_tables.bench_fig11_response_vs_p,
    paper_tables.bench_fig12_scenarios,
    paper_tables.bench_fig13_upgrade_grids,
    paper_tables.bench_fig14_result_cache,
    paper_tables.bench_table5_measurement,
    kernels_bench.bench_maxplus_scan,
    kernels_bench.bench_flash_attention,
    kernels_bench.bench_decode_attention,
    kernels_bench.bench_embedding_bag,
    kernels_bench.bench_cin_fuse,
    kernels_bench.bench_simulator_scale,
    sweep_bench.bench_sweep_grid,
    sweep_bench.bench_sweep_simulated,
    streaming_bench.bench_streaming_sweep,
    replicated_bench.bench_replicated_sweep,
    faults_bench.bench_faults,
    sharded_bench.bench_sharded_sweep,
    calibrate_bench.bench_calibrate,
    obs_bench.bench_obs_telemetry,
    partitioning_bench.bench_partitioning,
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated name substrings to run")
    args = ap.parse_args()
    wanted = ([s.strip() for s in args.only.split(",") if s.strip()]
              if args.only else None)

    rows = []
    failures = 0
    for bench in BENCHES:
        if wanted and not any(w in bench.__name__ for w in wanted):
            continue
        try:
            bench(rows)
        except Exception:  # noqa: BLE001 — keep the harness running
            failures += 1
            print(f"# BENCH FAILED: {bench.__name__}", file=sys.stderr)
            traceback.print_exc()

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
