"""Telemetry-overhead benchmark, persisted to BENCH_obs.json.

Guards the two promises the observability layer makes:

* ``telemetry=None`` costs NOTHING — the static branch compiles to the
  pre-telemetry program.  The bench asserts the plain run's base stats
  are *bitwise identical* with and without the telemetry code in the
  tree, and gates the plain ``queries_per_s`` "higher" like every other
  throughput metric;
* default-bins telemetry (``TelemetrySpec()``, 64 bins) stays cheap —
  ``telemetry_overhead_frac`` (relative slowdown of the telemetry run
  over the plain run; interleaved passes, min of each, so scheduler
  jitter cannot masquerade as overhead) is gated by an ABSOLUTE ceiling
  in `benchmarks.check_regression` (<10%, the ISSUE's acceptance bar).

The record also embeds kernel ProfileRecords (`profile_kernels`) so the
roofline report can consume a committed baseline without recompiling.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import _util

_TIMING_PASSES = 5


def bench_obs_telemetry(rows):
    from repro.core import capacity, simulator
    from repro.core.queueing import ServerParams
    from repro.obs import profile as obs_profile
    from repro.obs.timeline import DEFAULT_TIMELINE_BINS, TelemetrySpec

    n_scen, p, chunk = 3, 8, 4096
    n_q = _util.scale_queries(400_000, 100_000)
    lam = jnp.asarray([10.0, 18.0, 25.0])
    vec = ServerParams(**{
        f.name: jnp.asarray(
            [getattr(capacity.TABLE5_PARAMS, f.name)] * n_scen,
            jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    spec = TelemetrySpec()                    # default bins

    def run(telemetry):
        res = simulator.simulate_fork_join_batch(
            jax.random.PRNGKey(0), lam, vec, n_q, p=p,
            chunk_size=chunk, telemetry=telemetry)
        jax.block_until_ready(res.sum_response)
        return res

    def once(telemetry):
        t0 = time.perf_counter()
        run(telemetry)
        return time.perf_counter() - t0

    res_plain = run(None)                     # compile + warm both
    res_tel = run(spec)
    t_plain, t_tel = [], []
    for _ in range(_TIMING_PASSES):           # interleaved: drift hits
        t_plain.append(once(None))            # both programs equally
        t_tel.append(once(spec))
    dt_plain, dt_tel = min(t_plain), min(t_tel)

    # the zero-cost contract: telemetry=None and telemetry=spec draw the
    # same RNG stream, so the base stats must agree BITWISE
    for field in ("count", "sum_response", "sumsq_response"):
        a = jnp.asarray(getattr(res_plain, field))
        b = jnp.asarray(getattr(res_tel, field))
        assert bool(jnp.all(a == b)), (
            f"telemetry changed base stat {field!r}: {a} != {b}")
    total = float(jnp.sum(res_tel.timeline.count))
    assert total == float(n_scen * n_q), (
        f"timeline lost queries: {total} != {n_scen * n_q}")

    overhead = max(0.0, dt_tel / dt_plain - 1.0)
    profile = _util.profile_block(
        jax.jit(lambda key: simulator.simulate_fork_join_batch(
            key, lam, vec, n_q, p=p, chunk_size=chunk, telemetry=spec)),
        jax.random.PRNGKey(0),
        name=f"obs_telemetry[{n_scen}x{n_q},{spec.n_bins}bins]", n_runs=0)

    record = {
        "bench": "obs_telemetry",
        "n_scenarios": n_scen,
        "p": p,
        "n_queries": n_q,
        "chunk_size": chunk,
        "n_bins": spec.n_bins,
        "default_bins": DEFAULT_TIMELINE_BINS,
        "wall_seconds": dt_plain,
        "wall_seconds_telemetry": dt_tel,
        "queries_per_s": n_scen * n_q / dt_plain,
        "queries_per_s_telemetry": n_scen * n_q / dt_tel,
        "telemetry_overhead_frac": overhead,
        "profile": profile,
        "kernel_profiles": [r.to_json()
                            for r in obs_profile.profile_kernels(n_runs=1)],
    }
    out = _util.bench_output_path("BENCH_obs.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("obs_telemetry", dt_tel * 1e6,
                 f"{n_scen} scen x {n_q} queries; plain "
                 f"{n_scen * n_q / dt_plain / 1e6:.2f}M q/s, "
                 f"{spec.n_bins}-bin telemetry "
                 f"{n_scen * n_q / dt_tel / 1e6:.2f}M q/s "
                 f"(+{overhead:.1%} overhead); base stats bitwise "
                 f"identical; -> {out}"))
