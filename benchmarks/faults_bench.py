"""Fault-injection benchmark: degraded-path throughput + memory law.

Registers the perf trajectory of the streaming engine with a live
`FaultSpec` (outage windows + stochastic MTBF/MTTR + partial-quorum
merge + hedged retries — every fault channel at once) and ASSERTS the
acceptance criteria the fault layer must never regress:

* ``ClusterSpec(fault=None)`` stays BIT-IDENTICAL to an all-up spec
  (no outages, slowdown factors of 1, never-firing timeout and hedge)
  in every shared statistic — the fault machinery may cost nothing
  when nothing can fail;
* the fused engine's r-free peak-memory law survives fault injection:
  the outage mask and quorum join add O(S x r) carry slots and
  S x p x chunk temporaries, so measured compiled temp memory per
  extra replica stays under the same small buffer allowance as the
  fault-free engine;
* measured temp memory is INDEPENDENT of n_queries (the faulted
  engine is still streaming).

All are checked against XLA's own ``memory_analysis()`` of the lowered
streaming program.  Timing is a median of 3 passes.  The headline
``queries_per_s`` measures the ALL-CHANNELS faulted run (outage +
MTBF + quorum + hedge on round_robin); ``fault_overhead_frac`` records
its slowdown against the fault-free twin.  Results go to
``BENCH_faults.json`` for CI's bench-regression diff.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import _util

_F32 = 4
# same allowance as BENCH_replicated: the fault path may keep a few
# S x p x chunk temporaries (quorum sort, hedge draws) but must not
# re-introduce an r-scaled full re-scan
_MAX_BUFFERS_PER_R = 10.0
_TIMING_PASSES = 3

# every statistic the fault-free and all-up programs must share bitwise
_SHARED_FIELDS = ("count", "sum_response", "sumsq_response", "sum_broker",
                  "sum_cluster", "sum_server", "hist")


def _compiled_temp_bytes(lam, params, n_queries, p, r, chunk, fault=None):
    from repro.core import simulator
    proc = simulator._as_batch_process(lam)
    compiled = simulator._simulate_stream.lower(
        jax.random.PRNGKey(0), proc, params, jnp.asarray(0.0),
        jnp.asarray(0.0), n_queries=n_queries, p=p, mode="exponential",
        impl="xla", chunk=chunk, warmup_fraction=0.1, hist_bins=256,
        tap_size=0, r=r, routing="round_robin",
        has_cache=False, replica_impl="fused",
        fault=fault).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def bench_faults(rows):
    from repro.core import capacity, simulator
    from repro.core.cluster import ClusterSpec
    from repro.core.faults import FaultSpec
    from repro.core.queueing import ServerParams

    n_scen, p, r, chunk = 3, 8, 4, 4096
    n_q = _util.scale_queries(200_000, 50_000)
    lam = jnp.asarray([30.0, 60.0, 90.0])
    vec = ServerParams(**{
        f.name: jnp.asarray(
            [getattr(capacity.TABLE5_PARAMS, f.name)] * n_scen,
            jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    key = jax.random.PRNGKey(0)

    # every fault channel live at once: one replica down for a stretch,
    # a background MTBF/MTTR churn, a slow disk on server 2, k-of-p
    # quorum under a broker deadline, and one hedged retry
    horizon = n_q / float(lam[0])
    full_fault = FaultSpec(
        outages=((0, 0.2 * horizon, 0.5 * horizon),),
        mtbf_seconds=0.3 * horizon, mttr_seconds=0.03 * horizon,
        degraded=((2, 1.5),),
        broker_timeout_seconds=0.25, quorum_k=p - 1,
        hedge_after_seconds=0.4)
    # the all-up twin: nothing can ever fire, numerics must not move
    all_up = FaultSpec(degraded=((0, 1.0),),
                       broker_timeout_seconds=1e9, quorum_k=1,
                       hedge_after_seconds=1e9)

    def run(fault, n=n_q):
        res = simulator.simulate_fork_join_batch(
            key, lam, vec, n, p=p, impl="xla", chunk_size=chunk,
            cluster=ClusterSpec(r=r, routing="round_robin", fault=fault))
        jax.block_until_ready(res.sum_response)
        return res

    def timed(fault):
        res = run(fault)                       # compile + warm
        times = []
        for _ in range(_TIMING_PASSES):
            t0 = time.perf_counter()
            run(fault)
            times.append(time.perf_counter() - t0)
        return statistics.median(times), res

    # --- acceptance: fault=None bit-identical to the all-up spec -------
    probe_q = 20_000
    res_none = run(None, probe_q)
    res_all_up = run(all_up, probe_q)
    for name in _SHARED_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(res_none, name)),
            np.asarray(getattr(res_all_up, name)),
            err_msg=f"all-up FaultSpec perturbed {name}: the fault "
                    "machinery is no longer free when nothing can fail")

    dt_free, _ = timed(None)
    dt, res = timed(full_fault)

    profile = _util.profile_block(
        jax.jit(lambda k: simulator.simulate_fork_join_batch(
            k, lam, vec, n_q, p=p, impl="xla", chunk_size=chunk,
            cluster=ClusterSpec(r=r, routing="round_robin",
                                fault=full_fault))),
        jax.random.PRNGKey(0),
        name=f"faulted_stream[{n_scen}x{r}x{n_q}]", n_runs=0)

    # --- the r-free memory law must survive fault injection ------------
    probe_mem_q = 50_000
    temp_r1 = _compiled_temp_bytes(lam, vec, probe_mem_q, p, 1, chunk,
                                   fault=full_fault)
    temp_r4 = _compiled_temp_bytes(lam, vec, probe_mem_q, p, r, chunk,
                                   fault=full_fault)
    temp_r4_long = _compiled_temp_bytes(lam, vec, 4 * probe_mem_q, p, r,
                                        chunk, fault=full_fault)
    temp_r4_free = _compiled_temp_bytes(lam, vec, probe_mem_q, p, r, chunk)

    unit = n_scen * p * chunk * _F32
    slope_per_r = (temp_r4 - temp_r1) / (r - 1)
    assert slope_per_r <= _MAX_BUFFERS_PER_R * unit, (
        f"faulted peak temp grows {slope_per_r / unit:.1f} S*p*chunk "
        f"buffers per replica — above {_MAX_BUFFERS_PER_R}; fault "
        "injection broke the fused r-free streaming law")
    assert abs(temp_r4_long - temp_r4) <= 0.02 * temp_r4, (
        f"faulted peak temp moved with n_queries ({temp_r4} -> "
        f"{temp_r4_long}); the faulted engine is no longer streaming")

    queries_per_s = n_scen * n_q / dt
    record = {
        "bench": "faults",
        "n_scenarios": n_scen,
        "p": p,
        "r": r,
        "n_queries": n_q,
        "chunk_size": chunk,
        "routing": "round_robin",
        "fault": repr(full_fault),
        "wall_seconds": dt,
        "wall_seconds_fault_free": dt_free,
        "queries_per_s": queries_per_s,
        "queries_per_s_fault_free": n_scen * n_q / dt_free,
        "fault_overhead_frac": dt / dt_free - 1.0,
        "availability": float(jnp.mean(res.availability)),
        "spill_fraction": float(jnp.mean(res.spill_fraction)),
        "degraded_fraction": float(jnp.mean(res.degraded_fraction)),
        "peak_mem_measured_bytes": temp_r4,
        "peak_mem_measured_r1_bytes": temp_r1,
        "peak_mem_fault_free_bytes": temp_r4_free,
        "peak_mem_slope_buffers_per_r": slope_per_r / unit,
        "profile": profile,
    }
    out = _util.bench_output_path("BENCH_faults.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("faults", dt * 1e6,
                 f"{n_scen} scen x {r} replicas x {n_q} queries, every "
                 f"fault channel live; {queries_per_s / 1e6:.2f}M "
                 f"queries/s ({(dt / dt_free - 1.0) * 100:+.0f}% vs "
                 f"fault-free), availability "
                 f"{float(jnp.mean(res.availability)) * 100:.1f}%, "
                 f"spill {float(jnp.mean(res.spill_fraction)) * 100:.1f}%, "
                 f"degraded "
                 f"{float(jnp.mean(res.degraded_fraction)) * 100:.1f}%; "
                 f"peak temp {temp_r4 / 2**20:.1f} MiB "
                 f"({slope_per_r / unit:.1f} SxPxChunk buffers/replica, "
                 f"n-invariant; all-up spec bit-identical); -> {out}"))
