"""What-if sweep throughput: batched grid vs per-scenario Python loop.

The tentpole claim: a >=10,000-scenario what-if grid evaluates as ONE
jitted XLA call, >=50x faster than looping scenarios through the same
(compiled) scalar evaluation in Python — the dispatch overhead alone
dominates the loop.  Rows report scenarios/sec for both paths plus the
batched Lindley-recursion simulator's sample-path throughput.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, n=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def _grid():
    from repro.core import sweep
    # 10 x 5 x 5 x 5 x 8 = 10,000 scenarios
    return sweep.SweepGrid.build(
        lam=jnp.linspace(1.0, 50.0, 10),
        p=jnp.linspace(20.0, 200.0, 5),
        cpu=jnp.linspace(1.0, 4.0, 5),
        disk=jnp.linspace(1.0, 4.0, 5),
        hit=jnp.linspace(0.02, 0.30, 8),
    )


def bench_sweep_grid(rows):
    from repro.core import queueing, sweep
    from repro.core.queueing import ServerParams

    grid = _grid()
    n = grid.n_scenarios

    def batched(g):
        return sweep.sweep_analytical(g).response_upper

    t_batch = _time(batched, grid)

    # Per-scenario baseline: the identical computation, compiled once,
    # dispatched from a Python loop one scenario at a time.
    @jax.jit
    def scalar_eval(lam, params):
        _, hi = queueing.response_time_bounds(lam, params)
        return hi

    import dataclasses
    lam_full, params_full = grid.broadcast_full()
    lam_full = lam_full.reshape(-1)
    fields = {f.name: getattr(params_full, f.name).reshape(-1)
              for f in dataclasses.fields(ServerParams)}

    def loop():
        out = []
        for i in range(n):
            out.append(scalar_eval(
                lam_full[i],
                ServerParams(**{k: v[i] for k, v in fields.items()})))
        return jnp.stack(out)

    # sanity: both paths agree before we time them
    import numpy as np
    np.testing.assert_allclose(np.asarray(batched(grid)).reshape(-1),
                               np.asarray(loop()), rtol=1e-4)

    t_loop = _time(loop, n=1)
    speedup = t_loop / t_batch
    rows.append(("sweep_grid_batched", t_batch * 1e6,
                 f"{n} scenarios in one jitted call; "
                 f"{n / t_batch / 1e6:.2f}M scen/s"))
    rows.append(("sweep_grid_python_loop", t_loop * 1e6,
                 f"{n / t_loop:.0f} scen/s; batched is {speedup:.0f}x "
                 f"faster (floor: 50x)"))
    assert speedup >= 50.0, f"batched sweep only {speedup:.1f}x faster"


def bench_sweep_simulated(rows):
    from repro.core import capacity, sweep

    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 20.0, 25.0]),
        p=jnp.asarray([8.0]),
        cpu=jnp.asarray([1.0, 2.0]),
        disk=jnp.asarray([1.0, 2.0]),
        base=capacity.TABLE5_PARAMS,
        hit=jnp.asarray([0.17]),
        broker_from_p=False,
    )
    n_q = 20_000
    t = _time(lambda: sweep.sweep_simulated(
        grid, jax.random.PRNGKey(0), n_queries=n_q).mean, n=1)
    paths = grid.n_scenarios * (8 + 1)
    rows.append(("sweep_simulated_12x8", t * 1e6,
                 f"{paths} sample paths x {n_q} queries streamed; "
                 f"{paths * n_q / t / 1e6:.1f}M events/s"))
