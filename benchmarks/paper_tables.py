"""Benchmarks reproducing every paper table/figure (deliverable d).

Each function prints ``name,us_per_call,derived`` CSV rows: us_per_call
times the underlying JAX computation; ``derived`` carries the
reproduction's headline number next to the paper's published value.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import capacity, queueing, simulator, workload
from repro.workloadgen import loadgen, querygen


def _time(fn, *args, n=3):
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6, out


def bench_table2_query_lengths(rows):
    """Table 2: query length distribution {1: .32, 2: .41, >=3: .27}."""
    uni = querygen.build_universe(querygen.TODOBR)
    _, terms = querygen.sample_query_stream(uni, 50_000)
    lens = (terms >= 0).sum(1)
    p1, p2 = float((lens == 1).mean()), float((lens == 2).mean())
    rows.append(("table2_len1", 0.0, f"p={p1:.3f} paper=0.32"))
    rows.append(("table2_len2", 0.0, f"p={p2:.3f} paper=0.41"))
    rows.append(("table2_median", 0.0,
                 f"median={int(np.median(lens))} paper=2"))


def bench_fig2_zipf_popularity(rows):
    """Fig 2: recover Zipf alphas (0.82 query / 0.98 term for TodoBR)."""
    for name, alpha in [("query", 0.82), ("term", 0.98)]:
        def draw():
            ids = workload.sample_zipf(jax.random.PRNGKey(0), 20_000,
                                       alpha, (300_000,))
            freqs = workload.rank_frequencies(ids, 20_000)
            return workload.fit_zipf_alpha(freqs)
        us, est = _time(draw)
        rows.append((f"fig2_zipf_{name}", us,
                     f"alpha={float(est):.3f} paper={alpha}"))


def bench_table3_folding(rows):
    """Table 3: folding boosts TodoBR Monday 0.69 -> 23.58 qps (~34x)."""
    t = loadgen.diurnal_arrivals(0.69, days=243, seed=0)
    folded, boost = loadgen.fold(t)
    rate = len(folded) / loadgen.WEEK_SECONDS
    rows.append(("table3_folding", 0.0,
                 f"boost={boost:.0f}x rate={rate:.1f}qps paper~34x/20.9qps"))


def bench_fig6_interarrival_fits(rows):
    """Fig 6: Exponential fits interarrivals; Lognormal/Pareto fail."""
    gaps = jax.random.exponential(jax.random.PRNGKey(1), (85_604,)) / 23.8
    us, (_, stats) = _time(lambda g: workload.best_fit(g, "ks"), gaps, n=1)
    rows.append(("fig6_ks_exponential", us,
                 f"D_exp={float(stats['exponential']):.4f} "
                 f"D_logn={float(stats['lognormal']):.4f} "
                 f"D_pareto={float(stats['pareto']):.4f}"))


def bench_fig7_service_time_fits(rows):
    """Fig 7: per-server service times ~ Exponential (mixture workload)."""
    key = jax.random.PRNGKey(2)
    params = simulator._vec_params(capacity.TABLE5_PARAMS)
    svc = simulator.sample_service_times_batch(key, 1, 85_604, 1, params,
                                               "cache")[0, 0]
    winner, stats = workload.best_fit(svc, "ks")
    rows.append(("fig7_service_fit", 0.0,
                 f"winner={winner} D_exp={float(stats['exponential']):.4f}"
                 f" D_pareto={float(stats['pareto']):.4f}"))


def bench_fig9_server_residence(rows):
    """Fig 9: R_server model vs simulated measurement across lambda."""
    pr = capacity.TABLE5_PARAMS
    for lam in (10.0, 20.0, 28.0):
        us, res = _time(
            lambda l: simulator.simulate_fork_join(
                jax.random.PRNGKey(3), l, 120_000, pr,
                mode="exponential"), lam, n=1)
        sim = float(res.mean_server_residence)
        model = float(queueing.fork_join_lower_bound(lam, pr))
        err = abs(sim - model) / sim * 100
        rows.append((f"fig9_lam{int(lam)}", us,
                     f"sim={sim:.3f}s model={model:.3f}s err={err:.0f}% "
                     f"paper<=23%"))


def bench_fig10_response_vs_lambda(rows):
    """Fig 10: system response within Eq 7 bounds, near upper at load."""
    pr = capacity.TABLE5_PARAMS
    for lam in (10.0, 20.0, 28.0):
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(4), lam, 120_000, pr, mode="exponential")
        lo, hi = queueing.response_time_bounds(lam, pr)
        m = float(res.mean_response)
        rows.append((f"fig10_lam{int(lam)}", 0.0,
                     f"sim={m:.3f} in [{float(lo):.3f},{float(hi):.3f}] "
                     f"gap_to_upper={100 * (float(hi) - m) / float(hi):.0f}%"
                     f" paper~20%@28qps"))


def bench_fig11_response_vs_p(rows):
    """Fig 11: response grows ~H_p with cluster size at fixed lambda."""
    for p in (2, 4, 8):
        pr = dataclasses.replace(capacity.TABLE5_PARAMS, p=p,
                                 s_broker=capacity.TABLE5_SBROKER[p])
        res = simulator.simulate_fork_join(
            jax.random.PRNGKey(5), 28.0, 120_000, pr, mode="exponential")
        lo, hi = queueing.response_time_bounds(28.0, pr)
        paper_hi = {2: 0.61, 4: 0.84, 8: 1.10}[p]
        rows.append((f"fig11_p{p}", 0.0,
                     f"sim={float(res.mean_response):.3f} "
                     f"upper={float(hi):.3f} paper_upper={paper_hi} "
                     f"(H_p ratios match; see EXPERIMENTS §Fig11)"))


def bench_fig12_scenarios(rows):
    """Fig 12 + Scenarios 1-4: upper bound curves and the 286 ms point."""
    for name in ("baseline", "memory+disks", "memory+cpus", "cpus+disks",
                 "memory+cpus+disks"):
        params = capacity.scenario(name)
        lam_max = float(capacity.max_rate_under_slo(params, 0.300))
        rows.append((f"fig12_{name.replace('+', '_')}", 0.0,
                     f"max_qps@300ms={lam_max:.1f}"))
    p4 = capacity.scenario("memory+cpus+disks")
    _, hi = queueing.response_time_bounds(56.0, p4)
    rows.append(("fig12_scenario4_point", 0.0,
                 f"R(56qps)={float(hi) * 1e3:.0f}ms paper=286ms"))
    plan = capacity.plan_capacity(p4, 200.0, 0.300)
    rows.append(("fig12_replication", 0.0,
                 f"replicas={plan.n_replicas}x{plan.servers_per_replica} "
                 f"paper=4x100"))


def bench_fig13_upgrade_grids(rows):
    """Fig 13: response surface over (cpu, disk) speed per memory size."""
    us, _ = _time(lambda: capacity.upgrade_grid(4.0, memory=1), n=2)
    for mem in (1, 4):
        g = np.asarray(capacity.upgrade_grid(4.0, memory=mem))
        disk_gain = float(g[0, 0] - g[0, -1])
        cpu_gain = float(g[0, 0] - g[-1, 0])
        dom = "disk" if disk_gain > cpu_gain else "cpu"
        rows.append((f"fig13_mem{mem}x", us,
                     f"dominant={dom} paper={'disk' if mem == 1 else 'cpu'}"))


def bench_fig14_result_cache(rows):
    """Fig 14 + Scenario 6: result caching at the broker."""
    p4 = capacity.scenario("memory+cpus+disks")
    r65 = queueing.response_time_with_result_cache(65.0, p4, 0.5, 0.069e-3)
    rows.append(("fig14_scenario6", 0.0,
                 f"R(65qps)={float(r65) * 1e3:.0f}ms paper=282ms"))
    from repro.core.cluster import ClusterSpec
    plan = capacity.plan_capacity(
        p4, 195.0, 0.300, cluster=ClusterSpec(result_cache=(0.5, 0.069e-3)))
    rows.append(("fig14_replication", 0.0,
                 f"replicas={plan.n_replicas}x100 paper=3x100 (@195qps)"))


def bench_table5_measurement(rows):
    """Table 5 analogue: measure a small live engine, report Eq 1 params."""
    from repro.engine import corpus as C, index as I, server as S
    ccfg = C.CorpusConfig(n_docs=3000, vocab_size=2000, mean_doc_len=40)
    idx = I.build_index(C.generate_corpus(ccfg))
    uni = querygen.build_universe(querygen.WorkloadConfig(
        "t", n_unique_queries=500, vocab_size=2000))
    _, qterms = querygen.sample_query_stream(uni, 512)
    srv = S.IndexServer(idx, k_local=10)
    t0 = time.perf_counter()
    params = S.measure_service_params(
        srv, np.tile(qterms, (2, 1)), cache_bytes=idx.index_bytes() // 5,
        p=8, s_broker=0.2e-3, batch=64)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("table5_measured", us,
                 f"hit={float(params.hit):.2f} "
                 f"S_cpu={float(params.s_hit) * 1e3:.2f}ms "
                 f"S_disk={float(params.s_disk) * 1e3:.2f}ms"))
