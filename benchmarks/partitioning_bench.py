"""Index-partitioning comparison (paper Sec 2.1's long-running debate):
document vs term vs hybrid partitioning on the same corpus + workload.

Metrics per scheme: storage imbalance (max/mean postings per server) and
per-query work imbalance (max/mean postings *touched* per server over a
Zipf query stream) — the quantity that becomes service-time imbalance and
thus the H_p tax (Sec 3.4).
"""

from __future__ import annotations

import numpy as np

from repro.engine import corpus as corpus_lib
from repro.engine import partition
from repro.workloadgen import querygen


def _work_imbalance(part, qterms: np.ndarray) -> float:
    """max/mean per-server postings touched over the stream."""
    p = part.p
    work = np.zeros(p)
    for s, shard in enumerate(part.shards):
        lens = shard.list_lengths()
        for row in qterms:
            terms = row[row >= 0]
            work[s] += lens[terms].sum()
    return float(work.max() / max(work.mean(), 1.0))


def bench_partitioning(rows):
    cfg = corpus_lib.CorpusConfig(n_docs=3000, vocab_size=1500,
                                  mean_doc_len=40, seed=0)
    corp = corpus_lib.generate_corpus(cfg)
    uni = querygen.build_universe(querygen.WorkloadConfig(
        "t", n_unique_queries=400, vocab_size=1500, seed=0))
    _, qterms = querygen.sample_query_stream(uni, 200)
    p = 8

    schemes = {
        "document": partition.partition_documents(corp, p),
        "term": partition.partition_terms(corp, p),
        "hybrid": partition.partition_hybrid(corp, p),
    }
    for name, part in schemes.items():
        sizes = np.array([s.n_postings for s in part.shards], float)
        storage = sizes.max() / max(sizes.mean(), 1.0)
        work = _work_imbalance(part, qterms)
        rows.append((f"partition_{name}", 0.0,
                     f"storage_imb={storage:.3f} work_imb={work:.3f} "
                     f"(paper Sec 2.1: doc partitioning is the standard; "
                     f"hybrid balances best)"))
