"""Shared benchmark plumbing: output routing and the CI quick mode.

Two environment knobs keep one benchmark codebase serving both roles:

* ``BENCH_OUTPUT_DIR`` — where ``BENCH_*.json`` records land (default:
  the working directory).  CI's bench-regression job points this at a
  scratch dir so the freshly measured records can be diffed against the
  *committed* baselines without overwriting them.
* ``BENCH_QUICK=1`` — shrink the simulated horizons (n_queries only;
  scenario counts, server counts and chunk sizes stay fixed so
  throughput and the peak-memory proxies remain comparable to the
  committed full-size baselines — streaming throughput is per-chunk
  work, amortized well before the quick horizon).
"""

from __future__ import annotations

import os
import pathlib


def bench_output_path(filename: str) -> pathlib.Path:
    out_dir = pathlib.Path(os.environ.get("BENCH_OUTPUT_DIR", "."))
    out_dir.mkdir(parents=True, exist_ok=True)
    return out_dir / filename


def quick() -> bool:
    return os.environ.get("BENCH_QUICK", "") not in ("", "0")


def scale_queries(full: int, quick_value: int) -> int:
    """Pick the simulated horizon for the current mode."""
    return quick_value if quick() else full


def profile_block(fn, *args, name: str, n_runs: int = 1, **kwargs) -> dict:
    """Uniform ``record["profile"]`` block for every BENCH_*.json.

    Thin shim over `repro.obs.profile.profile_jit` (lazy import so the
    harness can enumerate benches without jax): compile time, XLA
    cost-analysis flops/bytes and memory-analysis peak of the bench's
    own entry point.  ``n_runs=0`` skips timed executions — the heavy
    simulation benches already report wall_seconds from their own
    medians, so the profile block only adds the compile/cost/memory
    facts there.
    """
    from repro.obs.profile import profile_jit

    return profile_jit(fn, *args, name=name, n_runs=n_runs,
                       **kwargs).to_json()
