"""Streaming-sweep benchmark: throughput + peak-memory proxy, persisted.

Registers the perf trajectory of the streaming chunked engine: simulated
queries/second on a sweep-shaped batch, and the peak-memory proxy of the
carried state (S x p x chunk floats) against what the old materializing
path would have allocated (~6 arrays of S x p x n_queries floats inside
one XLA program).  Results go to ``BENCH_streaming.json`` in the working
directory so successive PRs can diff them.

The headline run streams n_queries an order of magnitude past the old
engine's comfortable ceiling — the ISSUE's acceptance scenario.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp

from benchmarks import _util

# ~6 materialized S x p x n arrays (gaps/arrivals, broker, services,
# fork times, completions, response) in the old monolithic engine
_OLD_PATH_ARRAYS = 6
_F32 = 4


def bench_streaming_sweep(rows):
    from repro.core import capacity, sweep

    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([10.0, 18.0, 25.0]),
        p=jnp.asarray([8.0]),
        cpu=jnp.asarray([1.0, 2.0]),
        disk=jnp.asarray([1.0, 2.0]),
        base=capacity.TABLE5_PARAMS,
        hit=jnp.asarray([0.17]),
        broker_from_p=False,
    )
    n_scen, p, chunk = grid.n_scenarios, 8, 4096
    # ~10x past the old path's comfortable grid ceiling (CI quick mode
    # shortens the horizon only; per-chunk throughput stays comparable)
    n_q = _util.scale_queries(600_000, 150_000)

    def run():
        res = sweep.sweep_simulated(grid, jax.random.PRNGKey(0),
                                    n_queries=n_q, chunk_size=chunk)
        jax.block_until_ready(res.mean)
        return res

    res = run()                       # compile + run
    t0 = time.perf_counter()
    res = run()
    dt = time.perf_counter() - t0

    # SimSweepResult carries the grid (not a pytree); profile the stats
    profile = _util.profile_block(
        jax.jit(lambda key: sweep.sweep_simulated(
            grid, key, n_queries=n_q, chunk_size=chunk).stats),
        jax.random.PRNGKey(0),
        name=f"streaming_sweep[{n_scen}x{n_q}]", n_runs=0)

    queries_per_s = n_scen * n_q / dt
    events_per_s = n_scen * (p + 1) * n_q / dt
    peak_stream = n_scen * p * chunk * _F32
    peak_materialized = _OLD_PATH_ARRAYS * n_scen * p * n_q * _F32

    record = {
        "bench": "streaming_sweep",
        "n_scenarios": n_scen,
        "p": p,
        "n_queries": n_q,
        "chunk_size": chunk,
        "wall_seconds": dt,
        "queries_per_s": queries_per_s,
        "events_per_s": events_per_s,
        "peak_mem_streaming_bytes": peak_stream,
        "peak_mem_materializing_bytes": peak_materialized,
        "memory_reduction_x": peak_materialized / peak_stream,
        "mean_response_check": [float(x) for x in
                                jnp.ravel(res.mean)[:3]],
        "profile": profile,
    }
    out = _util.bench_output_path("BENCH_streaming.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("streaming_sweep", dt * 1e6,
                 f"{n_scen} scen x {n_q} queries streamed; "
                 f"{queries_per_s / 1e6:.2f}M queries/s; peak state "
                 f"{peak_stream / 2**20:.1f} MiB vs "
                 f"{peak_materialized / 2**30:.1f} GiB materialized "
                 f"({peak_materialized / peak_stream:.0f}x); "
                 f"-> {out}"))
