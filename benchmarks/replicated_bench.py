"""Replicated-cluster sweep benchmark: throughput + peak-memory law.

Registers the perf trajectory of the two-level (dispatcher -> r replicas
of broker + p servers) streaming engine and ASSERTS the post-fusion
memory acceptance criterion.  The fused engine routes, compacts and
segment-scans each chunk once, so its peak temp state is S x p x chunk
floats — INDEPENDENT of r (only the S x r x p carries grow with r):

* measured compiled temp memory per extra replica stays under a small
  constant number of S x p x chunk f32 buffers (no lower bound any more
  — the whole point of fusion is that the slope collapses);
* the fused program's footprint is strictly below the masked oracle's
  (which keeps the old S x r x p x chunk law);
* measured temp memory is INDEPENDENT of n_queries (streaming: a 4x
  longer horizon must not grow the program's footprint);
* the elastic autoscaling scenario (``ClusterSpec(autoscale=...)``)
  obeys the SAME slope and n-invariance laws — the controller carry is
  O(S) scalars, so autoscale= must not re-introduce an r-scaled buffer.

All are checked against XLA's own ``memory_analysis()`` of the lowered
streaming program, not a hand-waved proxy.  Timing is a median of 3
passes (single-pass wall noise on shared runners is ~15%).  The headline
``queries_per_s`` measures round_robin on ``impl="pallas"`` (the fused
kernel path); ``queries_per_s_xla`` records the associative-scan
fallback and ``queries_per_s_jsq`` the load-aware policy (JSQ keeps its
carried-work inner scan, so it rides impl="xla").  Results go to
``BENCH_replicated.json`` (see `benchmarks._util.bench_output_path`) so
CI's bench-regression job can diff successive PRs.
"""

from __future__ import annotations

import dataclasses
import json
import statistics
import time

import jax
import jax.numpy as jnp

from benchmarks import _util

_F32 = 4
# slope allowance: the fused scan keeps a handful of S x p x chunk
# buffers live in TOTAL (routing, compaction, segmented scan internals);
# the per-replica increment is only carry-sized, but XLA layout noise
# can attribute a buffer or two to the r axis — assert < 10 so a
# re-masking regression (r full re-scans) cannot hide
_MAX_BUFFERS_PER_R = 10.0
_TIMING_PASSES = 3


def _compiled_temp_bytes(lam, params, n_queries, p, r, chunk,
                         replica_impl="fused", autoscale=None):
    from repro.core import simulator
    proc = simulator._as_batch_process(lam)
    compiled = simulator._simulate_stream.lower(
        jax.random.PRNGKey(0), proc, params, jnp.asarray(0.0),
        jnp.asarray(0.0), n_queries=n_queries, p=p, mode="exponential",
        impl="xla", chunk=chunk, warmup_fraction=0.1, hist_bins=256,
        tap_size=0, r=r, routing="round_robin",
        has_cache=False, replica_impl=replica_impl,
        autoscale=autoscale).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def bench_replicated_sweep(rows):
    from repro.core import capacity, sweep
    from repro.core.cluster import ClusterSpec
    from repro.core.queueing import ServerParams
    from repro.launch.elastic import AutoscalePolicy

    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([30.0, 60.0, 90.0]),
        p=jnp.asarray([8.0]),
        base=capacity.TABLE5_PARAMS,
        hit=jnp.asarray([0.17]),
        broker_from_p=False,
        r=jnp.asarray([4.0]),
        result_cache=(0.2, 2e-3),
    )
    n_scen, p, r, chunk = 3, 8, 4, 4096
    n_q = _util.scale_queries(400_000, 100_000)

    def run(bench_grid, spec, impl):
        res = sweep.sweep_simulated(bench_grid, jax.random.PRNGKey(0),
                                    n_queries=n_q, chunk_size=chunk,
                                    cluster=spec, impl=impl)
        jax.block_until_ready(res.mean)
        return res

    def timed(bench_grid, spec, impl):
        res = run(bench_grid, spec, impl)      # compile + warm
        times = []
        for _ in range(_TIMING_PASSES):
            t0 = time.perf_counter()
            run(bench_grid, spec, impl)
            times.append(time.perf_counter() - t0)
        return statistics.median(times), res

    rr = ClusterSpec(routing="round_robin")
    dt, res = timed(grid, rr, "pallas")        # the fused kernel path
    dt_xla, _ = timed(grid, rr, "xla")
    dt_jsq, _ = timed(grid, ClusterSpec(routing="jsq"), "xla")

    # SimSweepResult carries the grid (not a pytree); profile the stats
    profile = _util.profile_block(
        jax.jit(lambda key: sweep.sweep_simulated(
            grid, key, n_queries=n_q, chunk_size=chunk,
            cluster=rr, impl="pallas").stats),
        jax.random.PRNGKey(0),
        name=f"replicated_sweep[{n_scen}x{r}x{n_q}]", n_runs=0)

    queries_per_s = n_scen * n_q / dt
    events_per_s = n_scen * r * (p + 1) * n_q / dt
    # fused law: ONE S x p x chunk pass regardless of r, + S x r x p carries
    peak_state = n_scen * p * chunk * _F32 + n_scen * r * p * _F32

    # --- the post-fusion r-free memory law, measured off the compiled
    # streaming program itself -------------------------------------------
    vec = ServerParams(**{
        f.name: jnp.asarray(
            [getattr(capacity.TABLE5_PARAMS, f.name)] * n_scen,
            jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    lam = jnp.asarray([30.0, 60.0, 90.0])
    probe_q = 50_000
    temp_r1 = _compiled_temp_bytes(lam, vec, probe_q, p, 1, chunk)
    temp_r4 = _compiled_temp_bytes(lam, vec, probe_q, p, r, chunk)
    temp_r4_long = _compiled_temp_bytes(lam, vec, 4 * probe_q, p, r, chunk)
    temp_r4_masked = _compiled_temp_bytes(lam, vec, probe_q, p, r, chunk,
                                          replica_impl="masked")

    unit = n_scen * p * chunk * _F32          # one S x p x chunk buffer
    slope_per_r = (temp_r4 - temp_r1) / (r - 1)
    assert slope_per_r <= _MAX_BUFFERS_PER_R * unit, (
        f"peak temp grows {slope_per_r / unit:.1f} S*p*chunk buffers per "
        f"replica — above {_MAX_BUFFERS_PER_R}; the fused r-free "
        "streaming law is broken")
    assert temp_r4 < temp_r4_masked, (
        f"fused footprint {temp_r4} >= masked oracle {temp_r4_masked}; "
        "fusion stopped paying for itself")
    assert abs(temp_r4_long - temp_r4) <= 0.02 * temp_r4, (
        f"peak temp moved with n_queries ({temp_r4} -> {temp_r4_long}); "
        "the engine is no longer streaming")

    # --- elastic autoscaling scenario: the controller carry is O(S)
    # scalars, so the fused r-free law must survive autoscale= — the
    # same slope/streaming assertions, lowered with a live policy -------
    pol = AutoscalePolicy(min_r=1, max_r=r, decision_interval_seconds=0.5)
    as_grid = dataclasses.replace(
        grid, r=jnp.ones((1,), jnp.float32), autoscale=(pol,))
    dt_as, res_as = timed(as_grid, ClusterSpec(routing="jsq"), "xla")
    mean_active = float(jnp.mean(
        res_as.stats.replica_seconds
        / jnp.maximum(res_as.stats.elapsed_seconds, 1e-30)))

    pol_r1 = AutoscalePolicy(min_r=1, max_r=1,
                             decision_interval_seconds=0.5)
    temp_as_r1 = _compiled_temp_bytes(lam, vec, probe_q, p, 1, chunk,
                                      autoscale=pol_r1)
    temp_as = _compiled_temp_bytes(lam, vec, probe_q, p, r, chunk,
                                   autoscale=pol)
    temp_as_long = _compiled_temp_bytes(lam, vec, 4 * probe_q, p, r,
                                        chunk, autoscale=pol)
    slope_as_per_r = (temp_as - temp_as_r1) / (r - 1)
    assert slope_as_per_r <= _MAX_BUFFERS_PER_R * unit, (
        f"autoscaled peak temp grows {slope_as_per_r / unit:.1f} "
        f"S*p*chunk buffers per replica — above {_MAX_BUFFERS_PER_R}; "
        "the elastic controller broke the fused r-free streaming law")
    assert abs(temp_as_long - temp_as) <= 0.02 * temp_as, (
        f"autoscaled peak temp moved with n_queries ({temp_as} -> "
        f"{temp_as_long}); the elastic engine is no longer streaming")

    record = {
        "bench": "replicated_sweep",
        "n_scenarios": n_scen,
        "p": p,
        "r": r,
        "n_queries": n_q,
        "chunk_size": chunk,
        "routing": "round_robin",
        "replica_impl": "fused",
        "impl": "pallas",
        "wall_seconds": dt,
        "wall_seconds_xla": dt_xla,
        "wall_seconds_jsq": dt_jsq,
        "wall_seconds_autoscale": dt_as,
        "queries_per_s": queries_per_s,
        "queries_per_s_xla": n_scen * n_q / dt_xla,
        "queries_per_s_jsq": n_scen * n_q / dt_jsq,
        "queries_per_s_autoscale": n_scen * n_q / dt_as,
        "events_per_s": events_per_s,
        "peak_mem_streaming_bytes": peak_state,
        "peak_mem_measured_bytes": temp_r4,
        "peak_mem_measured_r1_bytes": temp_r1,
        "peak_mem_measured_masked_bytes": temp_r4_masked,
        "peak_mem_slope_buffers_per_r": slope_per_r / unit,
        "peak_mem_autoscale_bytes": temp_as,
        "peak_mem_autoscale_slope_buffers_per_r": slope_as_per_r / unit,
        "autoscale_policy": f"{pol.min_r}..{pol.max_r}"
                            f"@{pol.target_utilization:g}",
        "mean_active_replicas": mean_active,
        "mean_response_check": [float(x) for x in
                                jnp.ravel(res.mean)[:3]],
        "profile": profile,
    }
    out = _util.bench_output_path("BENCH_replicated.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("replicated_sweep", dt * 1e6,
                 f"{n_scen} scen x {r} replicas x {n_q} queries; "
                 f"{queries_per_s / 1e6:.2f}M queries/s fused-pallas "
                 f"(xla {n_scen * n_q / dt_xla / 1e6:.2f}M, jsq "
                 f"{n_scen * n_q / dt_jsq / 1e6:.2f}M, autoscale "
                 f"{n_scen * n_q / dt_as / 1e6:.2f}M @ mean "
                 f"{mean_active:.2f} active); peak temp "
                 f"{temp_r4 / 2**20:.1f} MiB vs masked "
                 f"{temp_r4_masked / 2**20:.1f} MiB, "
                 f"{slope_per_r / unit:.1f} SxPxChunk buffers/replica "
                 f"(autoscaled {slope_as_per_r / unit:.1f}), "
                 f"n-invariant; -> {out}"))
