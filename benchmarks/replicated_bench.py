"""Replicated-cluster sweep benchmark: throughput + peak-memory law.

Registers the perf trajectory of the two-level (dispatcher -> r replicas
of broker + p servers) streaming engine and ASSERTS the ISSUE's memory
acceptance criterion: peak state is S x r x p x chunk floats —

* measured compiled temp memory grows (sub)linearly in r, with a per-r
  slope of a small constant number of S x p x chunk f32 buffers;
* measured temp memory is INDEPENDENT of n_queries (streaming: a 4x
  longer horizon must not grow the program's footprint).

Both are checked against XLA's own ``memory_analysis()`` of the lowered
streaming program, not a hand-waved proxy.  Results go to
``BENCH_replicated.json`` (see `benchmarks._util.bench_output_path`) so
CI's bench-regression job can diff successive PRs.
"""

from __future__ import annotations

import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks import _util

_F32 = 4
# slope allowance: the scan keeps a handful of S x p x chunk buffers
# live per replica (fork broadcast, services, completions, scan
# internals) — measured ~5.5 on jax 0.8 CPU; assert < 10 so a
# re-materializing regression (O(n_queries) growth) cannot hide
_MAX_BUFFERS_PER_R = 10.0


def _compiled_temp_bytes(lam, params, n_queries, p, r, chunk):
    from repro.core import simulator
    proc = simulator._as_batch_process(lam)
    compiled = simulator._simulate_stream.lower(
        jax.random.PRNGKey(0), proc, params, jnp.asarray(0.0),
        jnp.asarray(0.0), n_queries=n_queries, p=p, mode="exponential",
        impl="xla", chunk=chunk, warmup_fraction=0.1, hist_bins=256,
        tap_size=0, r=r, routing="round_robin",
        has_cache=False).compile()
    return int(compiled.memory_analysis().temp_size_in_bytes)


def bench_replicated_sweep(rows):
    from repro.core import capacity, sweep
    from repro.core.queueing import ServerParams

    grid = sweep.SweepGrid.build(
        lam=jnp.asarray([30.0, 60.0, 90.0]),
        p=jnp.asarray([8.0]),
        base=capacity.TABLE5_PARAMS,
        hit=jnp.asarray([0.17]),
        broker_from_p=False,
        r=jnp.asarray([4.0]),
        result_cache=(0.2, 2e-3),
    )
    n_scen, p, r, chunk = 3, 8, 4, 4096
    n_q = _util.scale_queries(400_000, 100_000)

    def run(routing):
        res = sweep.sweep_simulated(grid, jax.random.PRNGKey(0),
                                    n_queries=n_q, chunk_size=chunk,
                                    routing=routing)
        jax.block_until_ready(res.mean)
        return res

    run("round_robin")                    # compile + warm
    t0 = time.perf_counter()
    res = run("round_robin")
    dt = time.perf_counter() - t0
    run("jsq")
    t0 = time.perf_counter()
    run("jsq")
    dt_jsq = time.perf_counter() - t0

    queries_per_s = n_scen * n_q / dt
    events_per_s = n_scen * r * (p + 1) * n_q / dt
    peak_state = n_scen * r * p * chunk * _F32

    # --- the S x r x p x chunk memory law, measured off the compiled
    # streaming program itself -------------------------------------------
    vec = ServerParams(**{
        f.name: jnp.asarray(
            [getattr(capacity.TABLE5_PARAMS, f.name)] * n_scen,
            jnp.float32)
        for f in dataclasses.fields(ServerParams)})
    lam = jnp.asarray([30.0, 60.0, 90.0])
    probe_q = 50_000
    temp_r1 = _compiled_temp_bytes(lam, vec, probe_q, p, 1, chunk)
    temp_r4 = _compiled_temp_bytes(lam, vec, probe_q, p, r, chunk)
    temp_r4_long = _compiled_temp_bytes(lam, vec, 4 * probe_q, p, r, chunk)

    unit = n_scen * p * chunk * _F32          # one S x p x chunk buffer
    slope_per_r = (temp_r4 - temp_r1) / (r - 1)
    assert unit <= slope_per_r <= _MAX_BUFFERS_PER_R * unit, (
        f"peak temp grows {slope_per_r / unit:.1f} S*p*chunk buffers per "
        f"replica — outside [1, {_MAX_BUFFERS_PER_R}]; the S x r x p x "
        "chunk streaming law is broken")
    assert abs(temp_r4_long - temp_r4) <= 0.02 * temp_r4, (
        f"peak temp moved with n_queries ({temp_r4} -> {temp_r4_long}); "
        "the engine is no longer streaming")

    record = {
        "bench": "replicated_sweep",
        "n_scenarios": n_scen,
        "p": p,
        "r": r,
        "n_queries": n_q,
        "chunk_size": chunk,
        "routing": "round_robin",
        "wall_seconds": dt,
        "wall_seconds_jsq": dt_jsq,
        "queries_per_s": queries_per_s,
        "events_per_s": events_per_s,
        "peak_mem_streaming_bytes": peak_state,
        "peak_mem_measured_bytes": temp_r4,
        "peak_mem_measured_r1_bytes": temp_r1,
        "peak_mem_slope_buffers_per_r": slope_per_r / unit,
        "mean_response_check": [float(x) for x in
                                jnp.ravel(res.mean)[:3]],
    }
    out = _util.bench_output_path("BENCH_replicated.json")
    out.write_text(json.dumps(record, indent=2) + "\n")

    rows.append(("replicated_sweep", dt * 1e6,
                 f"{n_scen} scen x {r} replicas x {n_q} queries; "
                 f"{queries_per_s / 1e6:.2f}M queries/s (jsq "
                 f"{n_scen * n_q / dt_jsq / 1e6:.2f}M); peak temp "
                 f"{temp_r4 / 2**20:.1f} MiB, "
                 f"{slope_per_r / unit:.1f} SxPxChunk buffers/replica, "
                 f"n-invariant; -> {out}"))
